"""Roofline extraction + analytic perf model validation.

Key documented fact: XLA cost_analysis counts while-loop bodies ONCE
(test_cost_analysis_counts_while_once proves it).  The §Roofline terms are
therefore derived from core/perfmodel.py closed forms, validated here against
cost_analysis on a fully-unrolled reduced config.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core import rooflines
from repro.core.perfmodel import MeshInfo, train_step_terms, decode_step_terms
from repro.configs import get_config


def _cost_props(compiled):
    """compiled.cost_analysis() returns a dict in jax>=0.4.27 but a
    one-element list of dicts on older jaxlibs — normalise to the dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca


# ---------------------------------------------------------------------------
# collective-bytes HLO parser
# ---------------------------------------------------------------------------

SAMPLE_HLO = """
  %ag = f32[256,4096]{1,0} all-gather(f32[16,4096]{1,0} %x), dimensions={0}
  %ar = bf16[1024]{0} all-reduce(bf16[1024]{0} %y), to_apply=%add
  %rs = f32[16,128]{1,0} reduce-scatter(f32[256,128]{1,0} %z), dimensions={0}
  %cp = f32[8,8]{1,0} collective-permute(f32[8,8]{1,0} %w)
  %noise = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
"""


def test_collective_bytes_parser():
    out = rooflines.collective_bytes(SAMPLE_HLO)
    assert out["all-gather"] == 256 * 4096 * 4
    assert out["all-reduce"] == 2 * 1024 * 2          # AR counted 2x (RS+AG)
    assert out["reduce-scatter"] == 16 * 128 * 4
    assert out["collective-permute"] == 8 * 8 * 4
    assert out["count"] == 4
    assert out["total"] == sum(out[k] for k in (
        "all-gather", "all-reduce", "reduce-scatter", "collective-permute"))


def test_collective_bytes_real_hlo():
    """Parse a real compiled psum HLO."""
    mesh = jax.make_mesh((1,), ("d",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    f = shard_map(lambda x: lax.psum(x, "d"), mesh=mesh,
                  in_specs=P(), out_specs=P(), check_rep=False)
    hlo = jax.jit(f).lower(jnp.ones((64, 64))).compile().as_text()
    out = rooflines.collective_bytes(hlo)
    assert out["count"] >= 1
    assert out["total"] >= 64 * 64 * 4


# ---------------------------------------------------------------------------
# the while-loop undercount fact
# ---------------------------------------------------------------------------

def test_cost_analysis_counts_while_once():
    def f_scan(w, x):
        y, _ = lax.scan(lambda c, wi: (jnp.tanh(c @ wi), None), x, w)
        return y

    def f_unroll(w, x):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return x

    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 128), jnp.float32)
    fs = _cost_props(jax.jit(f_scan).lower(w, x).compile())["flops"]
    fu = _cost_props(jax.jit(f_unroll).lower(w, x).compile())["flops"]
    assert fu == pytest.approx(8 * fs, rel=0.01)


# ---------------------------------------------------------------------------
# perfmodel vs cost_analysis on an unrolled reduced config
# ---------------------------------------------------------------------------

def test_perfmodel_matmul_flops_match_hlo():
    """Dense matmul flops of a reduced qwen3 forward match XLA's count when
    the program is fully unrolled (period scan replaced by python loop)."""
    from repro.models import model as M
    from repro.models.config import ATTN_GLOBAL

    cfg = get_config("qwen3-0.6b").reduced(n_layers=2, vocab=256)
    params = M.lm_init(jax.random.PRNGKey(0), cfg)

    # unrolled forward: python loop over layers (no scan anywhere except
    # attention chunking, disabled by tiny seq < chunk)
    from repro.models import blocks as B
    import repro.models.layers as L

    def fwd(params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None],
                               tokens.shape)
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["blocks"][0])
            x, _ = B.attn_block(p, x, cfg, kind=ATTN_GLOBAL, pos=pos)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        head = params["embed"].T
        return (x @ head.astype(x.dtype)).astype(jnp.float32)

    b, s = 2, 64
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    p_abs = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                         params)
    ca = _cost_props(jax.jit(fwd).lower(p_abs, tok).compile())
    hlo_flops = ca["flops"]

    # analytic forward matmul+attention flops (train terms / bwd_mult, tp=1)
    t = train_step_terms(cfg, seq=s, batch=b, mesh=MeshInfo(dp=1, tp=1),
                         remat="none", n_micro=1)
    fwd_flops = t.flops / 3.0            # remat none -> bwd_mult 3, fwd = 1/3
    # HLO includes softmax/norms we don't count: demand agreement within 30%
    assert hlo_flops == pytest.approx(fwd_flops, rel=0.3), \
        (hlo_flops, fwd_flops)


# ---------------------------------------------------------------------------
# perfmodel sanity across archs/cells
# ---------------------------------------------------------------------------

MESH = MeshInfo(dp=16, tp=16)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "yi-34b", "olmoe-1b-7b",
                                  "mamba2-370m", "recurrentgemma-2b"])
def test_terms_positive_and_scale(arch):
    cfg = get_config(arch)
    t = train_step_terms(cfg, seq=4096, batch=256, mesh=MESH)
    assert t.flops > 0 and t.hbm_bytes > 0 and t.coll_bytes > 0
    t2 = train_step_terms(cfg, seq=4096, batch=512, mesh=MESH)
    assert t2.flops == pytest.approx(2 * t.flops, rel=0.01)


def test_decode_terms_kv_dominated():
    cfg = get_config("yi-34b")
    t = decode_step_terms(cfg, seq=32768, batch=128, mesh=MESH)
    # decode at 32k must be memory-dominated: bytes/819GBs >> flops/197T
    assert t.hbm_bytes / 819e9 > t.flops / 197e12


def test_moe_flops_use_active_params():
    moe = get_config("olmoe-1b-7b")
    t = train_step_terms(moe, seq=4096, batch=256, mesh=MESH)
    # full-expert compute would be ~8x the top-8 active compute
    dense_equiv = train_step_terms(
        moe, seq=4096, batch=256, mesh=MESH, moe_capacity_factor=1.0)
    assert t.flops < 1.5 * dense_equiv.flops


def test_multipod_adds_pod_collectives():
    cfg = get_config("qwen3-0.6b")
    t1 = train_step_terms(cfg, seq=4096, batch=256, mesh=MeshInfo(16, 16))
    t2 = train_step_terms(cfg, seq=4096, batch=256,
                          mesh=MeshInfo(32, 16, pods=2))
    assert "pod_allreduce" in t2.notes and "pod_allreduce" not in t1.notes
