"""Split-KV decode-attention kernel: parity vs the dense oracle across the
coarsening matrix x (ragged pos, GQA, sliding window), the new repro.tune
family (candidate legality, cost direction, cache round-trip), and the
cfg="auto" dispatch through kernels.ops."""
import importlib
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoarseningConfig, KIND_GAPPED
from repro.core.analysis import decode_attention_cost
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.tune import KernelSpec, TuningCache, autotune, \
    enumerate_candidates, model_cost, search

tune_cache = importlib.import_module("repro.tune.cache")
tune_search = importlib.import_module("repro.tune.search")

KEY = jax.random.PRNGKey(7)
B, HKV, G, S, D = 2, 2, 2, 256, 32
H = HKV * G
BKV = 64

SPECS = ("none", "con2", "con4", "gap2", "gap4")


def _qkv(dtype=jnp.float32):
    q = (jax.random.normal(KEY, (B, 1, H, D)) * 0.5).astype(dtype)
    kc = (jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, HKV, D))
          * 0.5).astype(dtype)
    vc = jax.random.normal(jax.random.fold_in(KEY, 2),
                           (B, S, HKV, D)).astype(dtype)
    return q, kc, vc


@pytest.mark.parametrize("spec", SPECS)
@pytest.mark.parametrize("pos", [(0, 0), (17, 200), (S - 1, S - 1), (5, 163)],
                         ids=["zero", "ragged", "full", "ragged2"])
@pytest.mark.parametrize("window", [None, 32], ids=["global", "window"])
def test_matches_dense_oracle(spec, pos, window):
    """Every legal (kind, degree) merely redistributes kv blocks — output
    must equal the dense layers.decode_attention path, per slot, at ragged
    per-slot positions."""
    q, kc, vc = _qkv()
    pos = jnp.asarray(pos, jnp.int32)
    want = L.decode_attention(q, kc, vc, pos, window=window)
    got = ops.decode_attention(q, kc, vc, pos, CoarseningConfig.parse(spec),
                               bkv=BKV, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_bf16_cache_parity():
    q, kc, vc = _qkv(jnp.bfloat16)
    pos = jnp.asarray([100, 3], jnp.int32)
    want = L.decode_attention(q, kc, vc, pos)
    got = ops.decode_attention(q, kc, vc, pos, "con4", bkv=BKV)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_layers_dispatch_falls_back_on_bad_geometry():
    """backend='pallas' with a cache length the kv block can't tile must
    fall back to the dense path, not raise."""
    q = jax.random.normal(KEY, (B, 1, H, D))
    kc = jax.random.normal(jax.random.fold_in(KEY, 1), (B, 48, HKV, D))
    vc = jax.random.normal(jax.random.fold_in(KEY, 2), (B, 48, HKV, D))
    pos = jnp.asarray([5, 40], jnp.int32)
    want = L.decode_attention(q, kc, vc, pos)
    got = L.decode_attention(q, kc, vc, pos, backend="pallas", bkv=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_ref_oracle_matches_layers():
    q, kc, vc = _qkv()
    pos = jnp.asarray([31, 250], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(ref.decode_attention(q, kc, vc, pos, window=16)),
        np.asarray(L.decode_attention(q, kc, vc, pos, window=16)),
        rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# tuner family
# ---------------------------------------------------------------------------

DEC_SPEC = KernelSpec.make("decode_attention", (8, 32, 8, 4096, 128),
                           dtype="bfloat16", bkv=128, window=0)


def test_candidates_respect_kv_split_divisibility():
    cands = enumerate_candidates(DEC_SPEC)
    assert cands
    for c in cands:
        assert 4096 % (128 * c.degree) == 0
        # kernel implements neither replication nor SIMD
        assert c.replication == 1 and c.vector_width == 1
    small = KernelSpec.make("decode_attention", (2, 4, 2, 256, 32),
                            dtype="float32", bkv=128, window=0)
    assert all(c.degree <= 2 for c in enumerate_candidates(small))


def test_coarsening_beats_dense_baseline_from_512():
    """The acceptance direction the decode benchmark table asserts: every
    coarsened degree beats the dense full-length einsum at S >= 512, and
    deeper coarsening is monotone at paper scale."""
    for s in (512, 1024, 2048, 4096):
        dense = decode_attention_cost(8, 32, 8, s, 128, CoarseningConfig(),
                                      bkv=128, dense=True).modeled_s
        prev = dense
        for deg in (2, 4):
            c = decode_attention_cost(8, 32, 8, s, 128,
                                      CoarseningConfig.parse(f"con{deg}"),
                                      bkv=128, kv_len=s).modeled_s
            assert c < dense, (s, deg, c, dense)
            assert c < prev, (s, deg)
            prev = c


def test_length_aware_grid_tracks_live_prefix():
    """Cost must track kv_len (the live prefix), not the allocated length."""
    cfg = CoarseningConfig.parse("con4")
    full = decode_attention_cost(8, 32, 8, 4096, 128, cfg, bkv=128,
                                 kv_len=4096).modeled_s
    short = decode_attention_cost(8, 32, 8, 4096, 128, cfg, bkv=128,
                                  kv_len=512).modeled_s
    assert short < full / 4


def test_auto_matches_or_beats_fixed_degrees():
    res = search(DEC_SPEC)
    best = model_cost(DEC_SPEC, res.best)
    for deg in (1, 2, 4, 8):
        cfg = CoarseningConfig.parse(f"con{deg}" if deg > 1 else "none")
        assert best <= model_cost(DEC_SPEC, cfg) * (1 + 1e-9)


def test_tuner_cache_roundtrip(tmp_path):
    cache = TuningCache(str(tmp_path / "tune.json"))
    cfg = autotune(DEC_SPEC, cache=cache)
    fresh = TuningCache(str(tmp_path / "tune.json"))
    assert fresh.get(DEC_SPEC) == cfg
    blob = json.load(open(str(tmp_path / "tune.json")))
    [entry] = blob["entries"].values()
    assert entry["cfg"] == cfg.label


def test_ops_auto_dispatch(scratch_default_cache):
    """cfg='auto' resolves through the tuner, persists the winner under the
    decode_attention family key, and matches the explicitly-tuned result."""
    q, kc, vc = _qkv()
    pos = jnp.asarray([40, 130], jnp.int32)
    before = tune_search.SEARCH_COUNT
    got = ops.decode_attention(q, kc, vc, pos, "auto", bkv=BKV)
    assert tune_search.SEARCH_COUNT == before + 1
    spec = KernelSpec.make("decode_attention", (B, H, HKV, S, D),
                           dtype="float32", bkv=BKV, window=0)
    best = search(spec).best
    want = ops.decode_attention(q, kc, vc, pos, best, bkv=BKV)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    blob = json.load(open(scratch_default_cache))
    assert blob["entries"][spec.key]["cfg"] == best.label
    # second call: served from the persisted cache, no re-search
    ops._auto_cfg.cache_clear()
    tune_cache._DEFAULT.clear()
    mid = tune_search.SEARCH_COUNT
    ops.decode_attention(q, kc, vc, pos, "auto", bkv=BKV)
    assert tune_search.SEARCH_COUNT == mid
