"""Golden parity: every kernels/ops.py op matches its kernels/ref.py oracle
across the coarsening matrix {none, con2, con4, gap2, gap4} x {plain,
+pipe2, +simd2}.

This is the paper's system invariant stated once for the WHOLE op surface:
any legal (kind, degree, replication, vector_width) merely redistributes
work.  Combos a kernel family cannot instantiate (gapped on a sequential
carry, SIMD where the block geometry won't divide) are excluded by the
legality table rather than skipped at runtime, so a silently-broken combo
cannot hide as a skip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoarseningConfig
from repro.kernels import ops, ref
from repro.kernels import gather_stream as gs
from repro.kernels.embed_gather import ref_embed_gather

KEY = jax.random.PRNGKey(42)

KINDS = ("none", "con2", "con4", "gap2", "gap4")
MECHS = ("", "+pipe2", "+simd2")

# family -> mechanisms it can legally combine with the kind matrix
# (dp_scan additionally excludes gapped kinds below)
LEGAL_MECHS = {
    "ew_stream": MECHS,
    "gather_stream": MECHS,
    "matmul": MECHS,
    "stencil5": MECHS,
    "dp_scan": MECHS,
    "flash_attention": ("",),        # row-block kernel: kinds only
    "embed_gather": ("", "+simd2"),
    "ssd": ("",),
    "rglru": ("",),
}


def _k(i):
    return jax.random.fold_in(KEY, i)


def _cases():
    for fam, mechs in LEGAL_MECHS.items():
        for kind in KINDS:
            if fam == "dp_scan" and kind.startswith("gap"):
                continue
            for mech in mechs:
                spec = (kind + mech).lstrip("+") or "none"
                yield pytest.param(fam, spec, id=f"{fam}-{spec}")


@pytest.mark.parametrize("fam,spec", list(_cases()))
def test_op_matches_oracle(fam, spec):
    cfg = CoarseningConfig.parse(spec)
    rtol = atol = 1e-5

    if fam == "ew_stream":
        n = 4096
        xs = tuple(jax.random.normal(_k(i), (n,)) for i in range(4))
        want = ref.ew_stream(xs, ai=6)
        got = ops.ew_stream(xs, cfg, ai=6, block=256)
    elif fam == "gather_stream":
        n, table = 2048, 1024
        idx = jnp.asarray(gs.make_indices(n, table, 256, seed=5))
        tabs = tuple(jax.random.normal(_k(10 + i), (table,))
                     for i in range(3))
        want = ref.gather_stream(tabs, idx, ai=6)
        got = ops.gather_stream(idx, tabs, cfg, ai=6, block=128)
    elif fam == "matmul":
        a = jax.random.normal(_k(20), (256, 128))
        b = jax.random.normal(_k(21), (128, 256))
        want = ref.matmul(a, b)
        got = ops.matmul(a, b, cfg, bm=32, bn=64, bk=64)
        rtol = atol = 2e-4
    elif fam == "stencil5":
        x = jax.random.normal(_k(30), (128, 256))
        want = ref.stencil5(x)
        got = ops.stencil5(x, cfg, block_rows=8)
    elif fam == "dp_scan":
        c = jax.random.uniform(_k(40), (64, 256))
        want = ref.dp_scan(c)
        got = ops.dp_scan(c, cfg)
    elif fam == "flash_attention":
        b, h, hkv, s, d = 1, 2, 1, 256, 32
        q = jax.random.normal(_k(50), (b, h, s, d)) * 0.5
        kk = jax.random.normal(_k(51), (b, hkv, s, d)) * 0.5
        v = jax.random.normal(_k(52), (b, hkv, s, d))
        want = ref.attention(q, kk, v, causal=True)
        got = ops.flash_attention(q, kk, v, cfg, bq=64, bkv=64, causal=True)
        rtol = atol = 2e-4
    elif fam == "embed_gather":
        n, vocab, d = 1024, 256, 32
        ids = jax.random.randint(_k(60), (n,), 0, vocab)
        table = jax.random.normal(_k(61), (vocab, d))
        want = ref_embed_gather(ids, table)
        got = ops.embed_gather(ids, table, cfg, block=64)
        rtol = atol = 1e-6
    elif fam == "ssd":
        b, h, g, s, p, n = 1, 4, 1, 128, 16, 8
        x = jax.random.normal(_k(70), (b, h, s, p)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(_k(71), (b, h, s))) * 0.1
        a = -jnp.exp(jax.random.normal(_k(72), (h,)) * 0.3)
        bm = jax.random.normal(_k(73), (b, g, s, n)) * 0.3
        cm = jax.random.normal(_k(74), (b, g, s, n)) * 0.3
        want = ops.ssd(x, dt, a, bm, cm, backend="ref")
        got = ops.ssd(x, dt, a, bm, cm, cfg, chunk=64)
        rtol = atol = 2e-3
    elif fam == "rglru":
        b, s, d = 1, 64, 256
        x = jax.random.normal(_k(80), (b, s, d))
        r = jax.random.normal(_k(81), (b, s, d))
        i = jax.random.normal(_k(82), (b, s, d))
        ap = jax.random.normal(_k(83), (d,))
        want = ref.rglru(x, r, i, ap)
        got = ops.rglru(x, r, i, ap, cfg, block_d=32, block_t=32)
        rtol = atol = 1e-4
    else:
        raise AssertionError(fam)

    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=atol)
