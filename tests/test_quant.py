"""repro.quant: pack/unpack round-trip properties (hypothesis), dequant-
fused kernel parity vs the dense-dequant oracle across coarsening
kinds/degrees (matmul, moe_ffn, int8-KV decode attention), the model-level
dispatch with dense fallback, quant-aware tuner keys with DISTINCT winning
degrees vs dense specs, and the end-to-end quantized serve path."""
import dataclasses
import importlib
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import CoarseningConfig
from repro.core.analysis import moe_ffn_cost
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.models import model as M
from repro.quant import (QTensor, dequantize, dequantize_kv, pack_int4,
                         quantize, quantize_int4, quantize_int8, quantize_kv,
                         quantize_params, unpack_int4)
from repro.tune import KernelSpec, TuningCache, autotune, search

tune_search = importlib.import_module("repro.tune.search")

KEY = jax.random.PRNGKey(7)
SPECS = ("none", "con2", "con4", "gap2", "gap4")

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                      # container without dev extras
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# format round-trips
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _weights = st.integers(0, 2**31 - 1).map(
        lambda s: np.asarray(
            np.random.default_rng(s).standard_normal((64, 16))
            * np.exp(np.random.default_rng(s + 1).uniform(-3, 3)),
            np.float32))

    @settings(max_examples=25, deadline=None)
    @given(w=_weights)
    def test_int8_roundtrip_error_bounded(w):
        """|w - dequant(quant(w))| <= scale/2 elementwise, exact shapes."""
        qt = quantize_int8(jnp.asarray(w))
        assert qt.q.shape == w.shape and qt.q.dtype == jnp.int8
        assert qt.scale.shape == (1, w.shape[1])
        err = np.abs(np.asarray(dequantize(qt)) - w)
        bound = np.broadcast_to(np.asarray(qt.scale) / 2, w.shape)
        assert (err <= bound + 1e-7).all()

    @settings(max_examples=25, deadline=None)
    @given(w=_weights, group=st.sampled_from([8, 16, 32]))
    def test_int4_roundtrip_error_bounded(w, group):
        qt = quantize_int4(jnp.asarray(w), group=group)
        k, n = w.shape
        assert qt.q.shape == (k // 2, n) and qt.q.dtype == jnp.uint8
        assert qt.scale.shape == (k // group, n)
        err = np.abs(np.asarray(dequantize(qt)) - w)
        bound = np.repeat(np.asarray(qt.scale), group, axis=0) / 2
        assert (err <= bound + 1e-7).all()

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_int4_pack_unpack_exact(seed):
        q = np.random.default_rng(seed).integers(-7, 8, size=(32, 8))
        out = np.asarray(unpack_int4(pack_int4(jnp.asarray(q))))
        np.testing.assert_array_equal(out, q.astype(np.float32))


@pytest.mark.parametrize("mode,group", [("int8", 0), ("int4", 16),
                                        ("int4", 32)])
def test_roundtrip_deterministic(mode, group):
    """Always-on (no-hypothesis) version of the round-trip bound."""
    w = jax.random.normal(KEY, (64, 32)) * 3.0
    qt = quantize(w, mode, group=group or 32)
    assert qt.shape == w.shape
    err = np.abs(np.asarray(dequantize(qt)) - np.asarray(w))
    if mode == "int8":
        bound = np.broadcast_to(np.asarray(qt.scale) / 2, w.shape)
    else:
        bound = np.repeat(np.asarray(qt.scale), qt.group, axis=0) / 2
    assert (err <= bound + 1e-7).all()


def test_kv_roundtrip_and_shapes():
    x = jax.random.normal(KEY, (2, 9, 3, 16)) * 5.0
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 9, 3)
    err = np.abs(np.asarray(dequantize_kv(q, s)) - np.asarray(x))
    assert (err <= np.asarray(s)[..., None] / 2 + 1e-7).all()


def test_int4_rejects_untileable_group():
    with pytest.raises(ValueError):
        quantize_int4(jax.random.normal(KEY, (48, 8)), group=32)
    with pytest.raises(ValueError):
        quantize_int4(jax.random.normal(KEY, (32, 8)), group=5)


def test_qtensor_is_pytree():
    qt = quantize_int8(jax.random.normal(KEY, (16, 8)))
    mapped = jax.tree.map(lambda a: a, qt)
    assert isinstance(mapped, QTensor) and mapped.bits == 8
    leaves = jax.tree.leaves(qt)
    assert {l.dtype for l in leaves} == {jnp.dtype(jnp.int8),
                                        jnp.dtype(jnp.float32)}


def test_quantize_params_walks_only_eligible_leaves():
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(KEY, cfg)
    qp, rep = quantize_params(params, "int8")
    assert rep["quantized"] > 0 and rep["bytes_after"] < rep["bytes_before"]
    # embeddings / head / norms stay dense
    assert not isinstance(qp["embed"], QTensor)
    if "lm_head" in qp:
        assert not isinstance(qp["lm_head"], QTensor)
    blk = qp["blocks"][0]
    assert isinstance(blk["attn"]["wq"], QTensor)
    assert isinstance(blk["ffn"]["w1"], QTensor)
    assert not isinstance(blk["ln1"]["scale"], QTensor)


# ---------------------------------------------------------------------------
# dequant-fused kernel parity vs the dense-dequant oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", SPECS)
@pytest.mark.parametrize("mode", ["int8", "int4"])
def test_quant_matmul_matches_dequant_oracle(mode, spec):
    m, n, k = 256, 256, 256
    a = jax.random.normal(KEY, (m, k)) * 0.3
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n)) * 0.3
    qt = quantize(b, mode)
    want = ref.matmul(a, dequantize(qt))
    got = ops.quant_matmul(a, qt, CoarseningConfig.parse(spec),
                           bm=64, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("spec", SPECS + ("con8", "gap8"))
@pytest.mark.parametrize("mode", ["int8", "int4"])
def test_quant_moe_ffn_matches_dequant_oracle(mode, spec):
    e, cap, d, f = 8, 4, 64, 64
    xe = jax.random.normal(KEY, (e, cap, d)) * 0.5
    w1 = jax.random.normal(jax.random.fold_in(KEY, 1), (e, d, f)) / 8
    w3 = jax.random.normal(jax.random.fold_in(KEY, 2), (e, d, f)) / 8
    w2 = jax.random.normal(jax.random.fold_in(KEY, 3), (e, f, d)) / 8
    wts = jax.random.uniform(jax.random.fold_in(KEY, 4), (e, cap))
    q1, q3, q2 = (quantize(w, mode) for w in (w1, w3, w2))
    want = ref.moe_ffn(xe, dequantize(q1), dequantize(q3), dequantize(q2),
                       wts)
    got = ops.quant_moe_ffn(xe, q1, q3, q2, wts,
                            CoarseningConfig.parse(spec))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("spec", SPECS)
def test_decode_int8_kv_matches_dequant_oracle(spec):
    b, h, hkv, s, d = 2, 4, 2, 256, 32
    q = jax.random.normal(KEY, (b, 1, h, d))
    kc = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, hkv, d))
    vc = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, d))
    pos = jnp.asarray([100, 255], jnp.int32)
    kq, ks = quantize_kv(kc)
    vq, vs = quantize_kv(vc)
    want = ref.decode_attention(q, dequantize_kv(kq, ks),
                                dequantize_kv(vq, vs), pos)
    got = ops.decode_attention(q, kq, vq, pos, CoarseningConfig.parse(spec),
                               bkv=64, k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # and the quantized path is CLOSE to full-precision attention
    full = ref.decode_attention(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=0.1, atol=0.05)


def test_quant_matmul_int4_rejects_bad_group_tiling():
    from repro.kernels import matmul as KM
    with pytest.raises(ValueError):
        KM.make_qkernel(128, 128, 256, CoarseningConfig(), bits=4,
                        group=48, bk=128)


# ---------------------------------------------------------------------------
# model-level dispatch: quantized ffn/moe with kernel + dense fallback
# ---------------------------------------------------------------------------

def test_ffn_quantized_kernel_and_fallback(scratch_default_cache):
    pf = L.ffn_init(KEY, 256, 512)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (128, 256)) * 0.1
    qf, _ = quantize_params({"w1": pf["w1"], "w3": pf["w3"],
                             "w2": pf["w2"]}, "int8")
    dense = L.ffn({k: dequantize(v) for k, v in qf.items()}, x)
    # pallas: tileable geometry -> the dequant-fused kernel
    got_k = L.ffn(qf, x, backend="pallas")
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)
    # ref backend -> dense-dequant fallback, numerically the oracle
    got_f = L.ffn(qf, x)
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)
    # untileable geometry under pallas -> fallback, not an error
    pf2 = L.ffn_init(jax.random.fold_in(KEY, 9), 96, 80)
    qf2, _ = quantize_params(pf2, "int8")
    x2 = jax.random.normal(jax.random.fold_in(KEY, 10), (5, 96))
    got2 = L.ffn(qf2, x2, backend="pallas")
    want2 = L.ffn({k: dequantize(v) for k, v in qf2.items()}, x2)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want2),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", ["int8", "int4"])
def test_moe_quantized_backend_close_to_dense(mode, scratch_default_cache):
    """moe() with QTensor expert weights: the pallas fused-dequant path and
    the einsum fallback must agree with each other exactly, and stay close
    to the unquantized layer."""
    cfg = get_config("olmoe-1b-7b").reduced()
    p = L.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (2, 16, cfg.d_model))
    want, _ = L.moe(p, x, cfg, capacity=32)
    qp, rep = quantize_params(p, mode)
    assert isinstance(qp["w1"], QTensor)
    got_ref, _ = L.moe(qp, x, cfg, capacity=32)
    got_pal, _ = L.moe(qp, x, dataclasses.replace(cfg, moe_backend="pallas"),
                       capacity=32)
    np.testing.assert_allclose(np.asarray(got_pal), np.asarray(got_ref),
                               rtol=1e-4, atol=1e-4)
    tol = 0.05 if mode == "int8" else 0.3
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=tol, atol=tol)


def test_decode_attention_layer_quant_fallback_matches_kernel(
        scratch_default_cache):
    b, h, hkv, s, d = 2, 4, 2, 128, 32
    q = jax.random.normal(KEY, (b, 1, h, d))
    kc = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, hkv, d))
    vc = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, d))
    pos = jnp.asarray([50, 127], jnp.int32)
    kq, ks = quantize_kv(kc)
    vq, vs = quantize_kv(vc)
    ref_o = L.decode_attention(q, kq, vq, pos, k_scale=ks, v_scale=vs)
    pal_o = L.decode_attention(q, kq, vq, pos, k_scale=ks, v_scale=vs,
                               backend="pallas", bkv=64)
    np.testing.assert_allclose(np.asarray(pal_o), np.asarray(ref_o),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# tuner: quant-aware keys and DISTINCT winners
# ---------------------------------------------------------------------------

def test_resolve_cfg_keys_on_real_dtype(scratch_default_cache):
    """The dtype-audit satellite: every op now hands resolve_cfg the REAL
    array dtype, so bf16 and f32 instances of one geometry occupy different
    cache keys (and quantized ones a third) instead of colliding on the old
    'float32' default."""
    n = 1 << 14
    for dt in ("float32", "bfloat16"):
        ops.resolve_cfg("auto", "ew_stream", (n,), dtype=dt, n_loads=2,
                        ai=4, variant="base", block=512)
    ops.resolve_cfg("auto", "matmul", (512, 256, 512), dtype="bfloat16",
                    bm=128, bn=128, bk=256, wbits=8, group=0)
    blob = json.load(open(scratch_default_cache))
    assert len(blob["entries"]) == 3
    dts = {k.split("|")[2] for k in blob["entries"]}
    assert {"float32", "bfloat16"} <= dts
    assert any("wbits=8" in k for k in blob["entries"])
    # and the op-level call sites really pass the array dtype through
    x = jax.random.normal(KEY, (1 << 14,))
    ops.ew_stream((x, x), "auto", ai=4, block=512)
    spec = KernelSpec.make("ew_stream", (1 << 14,), dtype="float32",
                           n_loads=2, ai=4, variant="base", block=512)
    blob = json.load(open(scratch_default_cache))
    assert spec.key in blob["entries"]


def test_quant_spec_distinct_cache_key_and_winner(tmp_path):
    """The acceptance bar: at the same geometry the tuner picks DIFFERENT
    winning degrees for the quantized spec than for the dense one, because
    packed panes + dequant move the memory/compute crossover."""
    cache = TuningCache(str(tmp_path / "tune.json"))
    shape = (64, 128, 2048, 1024)
    dense = KernelSpec.make("moe_ffn", shape, dtype="bfloat16")
    q8 = KernelSpec.make("moe_ffn", shape, dtype="bfloat16", wbits=8,
                         group=0)
    q4 = KernelSpec.make("moe_ffn", shape, dtype="bfloat16", wbits=4,
                         group=32)
    assert len({dense.key, q8.key, q4.key}) == 3
    wins = {s.key: autotune(s, cache=cache) for s in (dense, q8, q4)}
    assert len(cache.entries) == 3
    assert wins[q8.key] != wins[dense.key] or wins[q4.key] != wins[dense.key]
    # the modeled quantized time beats dense at its own winner
    q8c = moe_ffn_cost(*shape, wins[q8.key], wbits=8)
    dc = moe_ffn_cost(*shape, wins[dense.key])
    assert q8c.modeled_s < dc.modeled_s


def test_ops_quant_auto_dispatch(scratch_default_cache):
    """cfg='auto' on quant_moe_ffn persists under the wbits-tagged key and
    the second call never re-searches."""
    e, cap, d, f = 8, 4, 64, 64
    xe = jax.random.normal(KEY, (e, cap, d)) * 0.5
    ws = [jax.random.normal(jax.random.fold_in(KEY, i), shp) / 8
          for i, shp in enumerate([(e, d, f), (e, d, f), (e, f, d)])]
    wts = jax.random.uniform(jax.random.fold_in(KEY, 4), (e, cap))
    q1, q3, q2 = (quantize(w, "int8") for w in ws)
    before = tune_search.SEARCH_COUNT
    ops.quant_moe_ffn(xe, q1, q3, q2, wts, "auto")
    assert tune_search.SEARCH_COUNT == before + 1
    spec = KernelSpec.make("moe_ffn", (e, cap, d, f), dtype="float32",
                           wbits=8, group=0)
    blob = json.load(open(scratch_default_cache))
    assert spec.key in blob["entries"]
    ops.quant_moe_ffn(xe, q1, q3, q2, wts, "auto")
    assert tune_search.SEARCH_COUNT == before + 1


def test_warm_covers_quant_families(tmp_path):
    from repro.tune import warm_for_model
    cfg = dataclasses.replace(get_config("olmoe-1b-7b"), quant="int8",
                              kv_quant="int8")
    cache = TuningCache(str(tmp_path / "warm.json"))
    out = warm_for_model(cfg, seq=128, batch=8, cache=cache, verbose=False)
    assert {"matmul_q", "moe_ffn_q", "decode_attention_q"} <= set(out)


# ---------------------------------------------------------------------------
# end-to-end: quantized prefill + decode vs the f32 path
# ---------------------------------------------------------------------------

def _decode_logits(cfg, params, toks, n_steps=3, s_max=64):
    logits, cache = M.lm_prefill(params, {"tokens": toks}, cfg, s_max=s_max)
    b = toks.shape[0]
    pos = jnp.full((b,), toks.shape[1], jnp.int32)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [logits]
    for _ in range(n_steps):
        lg, cache = M.lm_decode_step(params, cache, tok, pos, cfg)
        out.append(lg)
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        pos = pos + 1
    return out


def test_quantized_decode_logits_close_to_f32(scratch_default_cache):
    """The acceptance bar: --quant int8 --kv-quant int8 end-to-end decode
    logits stay within the documented tolerance of the f32 path (README
    Quantization: ~0.05 max logit delta at reduced scale)."""
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(KEY, cfg)
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 12), 1,
                              cfg.vocab)
    base = _decode_logits(cfg, params, toks)
    qcfg = dataclasses.replace(cfg, quant="int8", kv_quant="int8",
                               decode_backend="pallas", decode_bkv=16)
    qparams, rep = quantize_params(params, "int8")
    assert rep["quantized"] > 0
    qlog = _decode_logits(qcfg, qparams, toks)
    for a, b in zip(base, qlog):
        d = float(np.abs(np.asarray(a) - np.asarray(b)).max())
        assert d < 0.05, d
        # greedy decode must agree at this scale
        np.testing.assert_array_equal(np.asarray(jnp.argmax(a, -1)),
                                      np.asarray(jnp.argmax(b, -1)))


def test_prefill_decode_compose_with_int8_kv(scratch_default_cache):
    """Chunked prefill then decode on a quantized cache must equal one-shot
    prefill: quantize-on-append is position-wise, so chunking can't change
    the stored payloads."""
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              kv_quant="int8")
    params = M.lm_init(KEY, cfg)
    toks = jax.random.randint(jax.random.fold_in(KEY, 2), (2, 16), 1,
                              cfg.vocab)
    one, cache_one = M.lm_prefill(params, {"tokens": toks}, cfg, s_max=64)
    cache = M.lm_init_cache(cfg, 2, 64)
    assert cache["blocks"][0]["k"].dtype == jnp.int8
    for i in range(0, 16, 8):
        pos0 = jnp.full((2,), i, jnp.int32)
        chunked, cache = M.lm_prefill(params, {"tokens": toks[:, i:i + 8]},
                                      cfg, cache=cache, pos0=pos0)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(one),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_array_equal(
        np.asarray(cache["blocks"][0]["k"]),
        np.asarray(cache_one["blocks"][0]["k"]))


def test_encdec_quantized_prefill_close_to_f32(scratch_default_cache):
    """Enc-dec models serve quantized too: the stacked xattn wk/wv leaves
    become QTensors and the cross-K/V precompute paths must dequantize them
    (regression: they used raw .astype and crashed)."""
    cfg = get_config("seamless-m4t-large-v2").reduced()
    assert cfg.is_encdec
    params = M.lm_init(KEY, cfg)
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (2, 8), 1,
                              cfg.vocab)
    frames = jax.random.normal(jax.random.fold_in(KEY, 4),
                               (2, 16, cfg.d_model)) * 0.1
    batch = {"tokens": toks, "src_frames": frames}
    want, _ = M.lm_prefill(params, batch, cfg, s_max=32)
    qparams, rep = quantize_params(params, "int8")
    assert rep["quantized"] > 0
    got, _ = M.lm_prefill(qparams, batch, cfg, s_max=32)
    assert float(np.abs(np.asarray(got) - np.asarray(want)).max()) < 0.25
    # the xkv_precompute training-path branch dequantizes too
    h_want, _ = M.lm_apply(params, batch, cfg, xkv_precompute=True)
    h_got, _ = M.lm_apply(qparams, batch, cfg, xkv_precompute=True)
    assert float(np.abs(np.asarray(h_got, np.float32)
                        - np.asarray(h_want, np.float32)).max()) < 0.25


def test_encdec_int8_cross_cache_parity(scratch_default_cache):
    """kv_quant="int8" on an enc-dec model quantizes the CROSS cache too:
    int8 payloads + per-(token, kv-head) f32 scales, written once at encoder
    prefill (_prefill_enc_cache) and dequantized on every cross-attention
    read.  Prefill + decode logits must track the dense-cache path within
    int8 round-trip error, and the scale leaves must survive the decode
    cache carry."""
    base = get_config("seamless-m4t-large-v2").reduced()
    qcfg = dataclasses.replace(base, kv_quant="int8")
    params = M.lm_init(KEY, base)
    toks = jax.random.randint(jax.random.fold_in(KEY, 5), (2, 8), 1,
                              base.vocab)
    frames = jax.random.normal(jax.random.fold_in(KEY, 6),
                               (2, 16, base.d_model)) * 0.1
    batch = {"tokens": toks, "src_frames": frames}

    c = M.lm_init_cache(qcfg, 2, 32)
    assert c["blocks"][0]["enc_k"].dtype == jnp.int8
    assert c["blocks"][0]["enc_k_scale"].dtype == jnp.float32
    assert (c["blocks"][0]["enc_k_scale"].shape
            == c["blocks"][0]["enc_k"].shape[:-1])

    outs = {}
    for name, cfg in (("dense", base), ("int8", qcfg)):
        logits, cache = M.lm_prefill(params, batch, cfg, s_max=32)
        if name == "int8":
            blk = cache["blocks"][0]
            assert blk["enc_k"].dtype == jnp.int8
            # the encoder K/V really was quantized (non-trivial scales)
            assert float(jnp.abs(blk["enc_k_scale"]).max()) > 0
        pos = jnp.full((2,), toks.shape[1], jnp.int32)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        seq = [logits]
        for _ in range(3):
            lg, cache = M.lm_decode_step(params, cache, tok, pos, cfg)
            seq.append(lg)
            tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
            pos = pos + 1
        outs[name] = seq
    for a, b in zip(outs["dense"], outs["int8"]):
        d = float(np.abs(np.asarray(a) - np.asarray(b)).max())
        assert d < 0.05, d


def test_batched_server_quant_smoke(scratch_default_cache):
    """BatchedServer end-to-end with --quant int8 --kv-quant int8: runs to
    completion and reports the memory saving."""
    from repro.launch.serve import BatchedServer
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(KEY, cfg)
    srv = BatchedServer(cfg, params, slots=2, max_len=32, chunk=8,
                        decode_block=4, quant="int8", kv_quant="int8")
    assert srv.try_admit(list(range(1, 9)), 4)
    while srv.any_active:
        srv.step()
    assert len(srv.completed) == 1 and len(srv.completed[0]) >= 4
    assert srv.weight_mib < srv.weight_mib_dense
    assert srv.cache_mib < srv.cache_mib_dense
    assert srv.quant_report["quantized"] > 0
