"""Shared fixtures for the tuner-coupled test modules."""
import importlib

import pytest

tune_cache = importlib.import_module("repro.tune.cache")


@pytest.fixture
def scratch_default_cache(tmp_path, monkeypatch):
    """Point the process-wide default tuning cache at a scratch file and
    wipe every in-process memo that could answer for it, so cfg="auto"
    dispatch tests are isolated and repeatable."""
    from repro.kernels import ops
    monkeypatch.setenv(tune_cache.ENV_VAR, str(tmp_path / "auto.json"))

    def wipe():
        tune_cache._DEFAULT.clear()
        ops._auto_cfg.cache_clear()
        ops._flash_vjp_fn.cache_clear()
        ops._flash_sparse_fn.cache_clear()

    wipe()
    yield str(tmp_path / "auto.json")
    wipe()
