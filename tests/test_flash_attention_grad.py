"""Differentiable coarsened flash attention: jax.grad through the custom-VJP
kernel vs jax.grad(mea_attention)/ref.attention across causal/window/GQA/
degree sweeps (both coarsening axes), the scale satellite, the
flash_attention_bwd tuner family, the models/layers dispatch wrapper with
its fallback rules, and a train-step smoke at attn_backend="pallas"."""
import dataclasses
import importlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import CoarseningConfig
from repro.core.analysis import (flash_attention_cost,
                                 flash_attention_bwd_cost)
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.models import model as M
from repro.tune import KernelSpec, enumerate_candidates, model_cost, search

tune_cache = importlib.import_module("repro.tune.cache")

KEY = jax.random.PRNGKey(7)
B, H, HKV, S, D = 1, 4, 2, 128, 16
BQ = BKV = 32


def _operands(hkv=HKV, s=S, sk=None, dtype=jnp.float32):
    sk = sk or s
    q = (jax.random.normal(KEY, (B, H, s, D)) * 0.5).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(KEY, 1),
                           (B, hkv, sk, D)) * 0.5).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2),
                          (B, hkv, sk, D)).astype(dtype)
    return q, k, v


def _grads(fn, *args):
    return jax.grad(lambda *a: jnp.sum(jnp.sin(fn(*a))),
                    argnums=tuple(range(len(args))))(*args)


def _assert_grad_parity(cfg, bwd_cfg, *, causal=True, window=None, hkv=HKV,
                        sk=None, scale=None, atol=1e-4):
    q, k, v = _operands(hkv=hkv, sk=sk)
    want = _grads(lambda a, b, c: ref.attention(
        a, b, c, causal=causal, window=window, scale=scale), q, k, v)
    got = _grads(lambda a, b, c: ops.flash_attention(
        a, b, c, CoarseningConfig.parse(cfg),
        bwd_cfg=CoarseningConfig.parse(bwd_cfg), bq=BQ, bkv=BKV,
        causal=causal, window=window, scale=scale), q, k, v)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-3, atol=atol)


# ---------------------------------------------------------------------------
# grad parity: coarsening on either axis merely redistributes work
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", ["none", "con2", "con4", "gap2", "gap4"])
def test_grad_parity_fwd_degrees(cfg):
    """Sweep the FORWARD (q-row axis) degree at a base backward."""
    _assert_grad_parity(cfg, "none")


@pytest.mark.parametrize("bwd", ["con2", "con4", "gap2", "gap4"])
def test_grad_parity_bwd_degrees(bwd):
    """Sweep the BACKWARD dK/dV (kv-block axis) degree — consecutive = one
    wide recompute tile per program, gapped = strided."""
    _assert_grad_parity("none", bwd)


@pytest.mark.parametrize("cfg,bwd", [("con2", "gap2"), ("gap2", "con4"),
                                     ("con4", "con2")])
def test_grad_parity_mixed_axes(cfg, bwd):
    """Forward and backward coarsen independently (different axes)."""
    _assert_grad_parity(cfg, bwd)


@pytest.mark.parametrize("window", [32, 64])
@pytest.mark.parametrize("cfg,bwd", [("con2", "con2"), ("gap2", "gap2")])
def test_grad_parity_windowed(cfg, bwd, window):
    _assert_grad_parity(cfg, bwd, window=window)


@pytest.mark.parametrize("hkv", [1, 2, 4])
def test_grad_parity_gqa(hkv):
    """GQA: dK/dV partials reduce over the query-head group."""
    _assert_grad_parity("con2", "con2", hkv=hkv)


def test_grad_parity_noncausal_cross():
    """Non-causal Sq != Sk (the cross-attention geometry)."""
    _assert_grad_parity("con2", "gap2", causal=False, sk=64)


def test_scale_threads_through_fwd_and_bwd():
    """Satellite bugfix: ops.flash_attention takes `scale` and threads it
    through the kernel — value AND gradient must honor it."""
    q, k, v = _operands()
    want = ref.attention(q, k, v, scale=0.5)
    got = ops.flash_attention(q, k, v, "con2", bwd_cfg="con2",
                              bq=BQ, bkv=BKV, scale=0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    _assert_grad_parity("con2", "con2", scale=0.5)
    # and a non-default scale really changes the result
    base = ops.flash_attention(q, k, v, "con2", bwd_cfg="con2",
                               bq=BQ, bkv=BKV)
    assert not np.allclose(np.asarray(got), np.asarray(base))


def test_mea_grad_is_the_oracle():
    """The acceptance-bar statement: custom-VJP grads match
    jax.grad(mea_attention) within 1e-4 (f32)."""
    q, k, v = _operands()
    qm, km, vm = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    want = _grads(lambda a, b, c: L.mea_attention(a, b, c, causal=True),
                  qm, km, vm)
    got = _grads(lambda a, b, c: ops.flash_attention(
        a, b, c, CoarseningConfig.parse("con2"),
        bwd_cfg=CoarseningConfig.parse("con2"), bq=BQ, bkv=BKV), q, k, v)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g),
                                   np.asarray(w.transpose(0, 2, 1, 3)),
                                   rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention_bwd tuner family
# ---------------------------------------------------------------------------

BWD_SPEC = KernelSpec.make("flash_attention_bwd", (8, 16, 4, 2048, 2048, 128),
                           dtype="bfloat16", bq=128, bkv=128, causal=True)
FWD_SPEC = KernelSpec.make("flash_attention", (8, 16, 4, 2048, 2048, 128),
                           dtype="bfloat16", bq=128, bkv=128, causal=True)


def test_bwd_candidates_respect_kv_divisibility():
    """Legality: the dK/dV degree tiles the KV axis (bkv*deg | sk), not the
    q axis — the two families enumerate different spaces."""
    cands = enumerate_candidates(BWD_SPEC)
    assert cands
    for c in cands:
        assert 2048 % (128 * c.degree) == 0
        assert c.replication == 1 and c.vector_width == 1
    # sk=512 tiles degrees {1,2,4} on the kv axis even though sq=256 only
    # tiles {1,2} on the q axis — the bwd family keys off sk
    small = KernelSpec.make("flash_attention_bwd", (1, 4, 2, 256, 512, 64),
                            bq=128, bkv=128)
    assert {c.degree for c in enumerate_candidates(small)} == {1, 2, 4}


def test_fwd_and_bwd_tune_independently(scratch_default_cache):
    """The same geometry resolves through TWO cache keys; each family's
    winner is its own modeled argmin."""
    for spec in (FWD_SPEC, BWD_SPEC):
        res = search(spec)
        costs = {c.label: model_cost(spec, c)
                 for c in enumerate_candidates(spec)}
        assert res.best.label == min(costs, key=costs.get)
    assert FWD_SPEC.key != BWD_SPEC.key


def test_coarsened_bwd_beats_dense_baseline():
    """The attention-benchmark acceptance direction: at every paper-scale
    length, some coarsened degree beats the mea baseline on fwd+bwd, and
    the modeled argmin (what AUTO dispatches) matches or beats every fixed
    degree."""
    for s in (512, 1024, 2048, 4096):
        dense = (flash_attention_cost(8, 16, 4, s, s, 128,
                                      CoarseningConfig(), dense=True).modeled_s
                 + flash_attention_bwd_cost(8, 16, 4, s, s, 128,
                                            CoarseningConfig(),
                                            dense=True).modeled_s)
        fixed = {}
        for deg in (1, 2, 4, 8):
            if s % (128 * deg):
                continue
            cfg = CoarseningConfig.parse(f"con{deg}" if deg > 1 else "none")
            fixed[deg] = (flash_attention_cost(8, 16, 4, s, s, 128,
                                               cfg).modeled_s
                          + flash_attention_bwd_cost(8, 16, 4, s, s, 128,
                                                     cfg, q_cfg=cfg).modeled_s)
        assert min(fixed.values()) < dense, (s, fixed, dense)
        spec_f = KernelSpec.make("flash_attention", (8, 16, 4, s, s, 128),
                                 dtype="bfloat16", bq=128, bkv=128,
                                 causal=True)
        spec_b = KernelSpec.make("flash_attention_bwd",
                                 (8, 16, 4, s, s, 128), dtype="bfloat16",
                                 bq=128, bkv=128, causal=True)
        bf, bb = search(spec_f).best, search(spec_b).best
        auto = (flash_attention_cost(8, 16, 4, s, s, 128, bf).modeled_s
                + flash_attention_bwd_cost(8, 16, 4, s, s, 128, bb,
                                           q_cfg=bf).modeled_s)
        assert auto <= min(fixed.values()) * (1 + 1e-9), (s, auto, fixed)


def test_gapped_bwd_pays_divergence_penalty():
    """Causal dK/dV: gapped fuses segment-0 kv rows into every program so
    the causal sweep degenerates to the worst row — consecutive must model
    cheaper at every degree (the decode kernel's divergence framing)."""
    for deg in (2, 4, 8):
        con = flash_attention_bwd_cost(
            8, 16, 4, 2048, 2048, 128,
            CoarseningConfig.parse(f"con{deg}")).modeled_s
        gap = flash_attention_bwd_cost(
            8, 16, 4, 2048, 2048, 128,
            CoarseningConfig.parse(f"gap{deg}")).modeled_s
        assert con < gap, (deg, con, gap)


def test_warm_covers_flash_families(tmp_path):
    from repro.tune import TuningCache, warm_for_model
    cfg = get_config("qwen3-0.6b")
    cache = TuningCache(str(tmp_path / "warm.json"))
    out = warm_for_model(cfg, seq=256, batch=4, cache=cache, verbose=False)
    assert "flash_attention" in out and "flash_attention_bwd" in out


# ---------------------------------------------------------------------------
# models/layers dispatch wrapper + fallback rules
# ---------------------------------------------------------------------------

def _model_operands(s=64, sk=None, hkv=2):
    sk = sk or s
    q = jax.random.normal(KEY, (2, s, 4, 32)) * 0.5
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (2, sk, hkv, 32)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (2, sk, hkv, 32))
    return q, k, v


def test_layer_dispatch_matches_mea(scratch_default_cache):
    q, k, v = _model_operands()
    want = L.mea_attention(q, k, v, causal=True)
    got = L.flash_attention(q, k, v, causal=True, pos_trivial=True,
                            backend="pallas", bq=32, bkv=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # it really went through the kernel — but a FORWARD-ONLY dispatch must
    # resolve (and persist) only the forward family: the backward search
    # is deferred to the first backward trace
    keys = list(json.load(open(scratch_default_cache))["entries"])
    assert any(k_.startswith("flash_attention|") for k_ in keys)
    assert not any(k_.startswith("flash_attention_bwd|") for k_ in keys)
    jax.grad(lambda a: jnp.sum(L.flash_attention(
        a, k, v, causal=True, pos_trivial=True, backend="pallas",
        bq=32, bkv=32)))(q)
    keys = list(json.load(open(scratch_default_cache))["entries"])
    assert any(k_.startswith("flash_attention_bwd|") for k_ in keys)


def test_layer_dispatch_fallbacks(scratch_default_cache):
    """Ragged q_pos, k_len, untileable shapes, and untileable explicit
    degrees all fall back to mea_attention (bit-exact, no error)."""
    q, k, v = _model_operands()
    want = L.mea_attention(q, k, v, causal=True)
    # causal without the trivial-positions proof -> mea
    got = L.flash_attention(q, k, v, causal=True, pos_trivial=False,
                            backend="pallas", bq=32, bkv=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0,
                               atol=0)
    # k_len masking -> mea
    kl = jnp.full((2,), 48, jnp.int32)
    got = L.flash_attention(q, k, v, causal=True, pos_trivial=True,
                            k_len=kl, backend="pallas", bq=32, bkv=32)
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(L.mea_attention(q, k, v, causal=True, k_len=kl)),
        rtol=0, atol=0)
    # untileable sequence -> mea
    q2, k2, v2 = _model_operands(s=48)
    got = L.flash_attention(q2, k2, v2, causal=True, pos_trivial=True,
                            backend="pallas", bq=32, bkv=32)
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(L.mea_attention(q2, k2, v2, causal=True)), rtol=0, atol=0)
    # explicit degree the geometry can't tile -> mea
    got = L.flash_attention(q, k, v, causal=True, pos_trivial=True,
                            backend="pallas", cfg="con4", bwd_cfg="none",
                            bq=32, bkv=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0,
                               atol=0)


def test_layer_dispatch_cross_attention(scratch_default_cache):
    """Non-causal Sq != Sk dispatches the kernel without a positions
    proof (mask-free)."""
    q, k, v = _model_operands(s=64, sk=96)
    want = L.mea_attention(q, k, v, causal=False)
    got = L.flash_attention(q, k, v, causal=False, backend="pallas",
                            bq=32, bkv=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# train-step smoke: attn_backend="pallas" matches the ref backend
# ---------------------------------------------------------------------------

def test_train_step_loss_and_grad_parity(scratch_default_cache):
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              compute_dtype="float32")
    cfg_k = dataclasses.replace(cfg, attn_backend="pallas",
                                attn_bq=32, attn_bkv=32)
    key = jax.random.PRNGKey(0)
    params = M.lm_init(key, cfg)
    b, s = 2, 64
    batch = {"tokens": jax.random.randint(jax.random.fold_in(key, 1),
                                          (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(jax.random.fold_in(key, 2),
                                          (b, s), 0, cfg.vocab)}

    def loss(p, c, remat="none"):
        return M.lm_loss(p, batch, c, remat=remat)[0]

    l_ref, l_pal = loss(params, cfg), loss(params, cfg_k)
    np.testing.assert_allclose(float(l_pal), float(l_ref), rtol=1e-5)
    g_ref = jax.grad(loss)(params, cfg)
    g_pal = jax.grad(loss)(params, cfg_k)
    for w, g in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pal)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-3, atol=1e-4)
    # remat="dots" saves the checkpoint-named kernel output; grads unchanged
    g_dots = jax.grad(lambda p: loss(p, cfg_k, remat="dots"))(params)
    for w, g in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_dots)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-3, atol=1e-4)


def test_explicit_positions_keep_mea_path(scratch_default_cache):
    """A batch carrying explicit positions (packing) must produce identical
    losses under both backends BECAUSE the pallas config falls back."""
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              compute_dtype="float32")
    cfg_k = dataclasses.replace(cfg, attn_backend="pallas",
                                attn_bq=32, attn_bkv=32)
    key = jax.random.PRNGKey(3)
    params = M.lm_init(key, cfg)
    b, s = 2, 64
    pos = jnp.broadcast_to(jnp.arange(7, 7 + s, dtype=jnp.int32)[None],
                           (b, s))
    batch = {"tokens": jax.random.randint(jax.random.fold_in(key, 1),
                                          (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(jax.random.fold_in(key, 2),
                                          (b, s), 0, cfg.vocab),
             "positions": pos}
    l_ref = M.lm_loss(params, batch, cfg)[0]
    l_pal = M.lm_loss(params, batch, cfg_k)[0]
    np.testing.assert_allclose(float(l_pal), float(l_ref), rtol=0, atol=0)
