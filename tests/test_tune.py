"""Autotuner subsystem tests: search ranking, cache persistence, and the
cfg="auto" dispatch path through kernels.ops."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoarseningConfig, KIND_CONSECUTIVE, KIND_GAPPED
from repro.kernels import ops
from repro.tune import (KernelSpec, TuningCache, autotune,
                        enumerate_candidates, model_cost, search)
import importlib

# the package re-exports the search() function under the submodule's name,
# so fetch the modules themselves via importlib
tune_cache = importlib.import_module("repro.tune.cache")
tune_search = importlib.import_module("repro.tune.search")

STREAM_SPEC = KernelSpec.make("ew_stream", (1 << 20,), n_loads=8, ai=6,
                              variant="base", block=1024)


# ---------------------------------------------------------------------------
# search = exhaustive modeled argmin
# ---------------------------------------------------------------------------

def test_search_returns_modeled_argmin():
    res = search(STREAM_SPEC)
    all_costs = {c.label: model_cost(STREAM_SPEC, c)
                 for c in enumerate_candidates(STREAM_SPEC)}
    assert res.best.label == min(all_costs, key=all_costs.get)
    # ranking is sorted by modeled cost
    modeled = [c.modeled_s for c in res.candidates]
    assert modeled == sorted(modeled)


def test_streaming_prefers_consecutive_over_gapped():
    """Paper F1: burst-coalesced consecutive beats gapped on regular
    streams, at every degree."""
    for d in (2, 4, 8):
        con = model_cost(STREAM_SPEC, CoarseningConfig(KIND_CONSECUTIVE, d))
        gap = model_cost(STREAM_SPEC, CoarseningConfig(KIND_GAPPED, d))
        assert con < gap, (d, con, gap)
    res = search(STREAM_SPEC, replications=(1,), vector_widths=(1,))
    assert res.best.kind == KIND_CONSECUTIVE


def test_gather_keeps_gapped_edge():
    """Paper F2 analog: on the irregular kernel the gapped variant keeps a
    small miss-concurrency edge, so the tuner prefers it."""
    spec = KernelSpec.make("gather_stream", (1 << 20, 1 << 14), n_loads=8,
                           ai=6, block=1024, hit_rate=0.854,
                           window_elems=8192)
    res = search(spec, vector_widths=(1,))
    assert res.best.kind == KIND_GAPPED


def test_scan_never_picks_gapped():
    spec = KernelSpec.make("dp_scan", (1 << 16, 1024))
    assert all(c.kind != KIND_GAPPED for c in enumerate_candidates(spec))
    assert search(spec).best.kind != KIND_GAPPED


def test_candidates_respect_divisibility():
    # 3 * 2**10 elements: degree 8 would need n % (1024*8) == 0 -> invalid
    spec = KernelSpec.make("ew_stream", (3 * (1 << 10),), n_loads=2, ai=6,
                           variant="base", block=1024)
    cands = enumerate_candidates(spec)
    assert cands and all(c.degree <= 3 for c in cands)
    assert all((3 * (1 << 10)) % (1024 * c.vector_width * c.degree) == 0
               for c in cands)


def test_simd_refused_for_data_dependent_variants():
    spec = KernelSpec.make("ew_stream", (1 << 16,), n_loads=4, ai=6,
                           variant="if_in", block=1024)
    assert all(c.vector_width == 1 for c in enumerate_candidates(spec))
    uni = KernelSpec.make("ew_stream", (1 << 16,), n_loads=4, ai=6,
                          variant="if_id", block=1024)
    assert any(c.vector_width > 1 for c in enumerate_candidates(uni))


# ---------------------------------------------------------------------------
# measured strategies
# ---------------------------------------------------------------------------

def _fake_measure(winner_label, calls):
    def measure(spec, cfg):
        calls.append(cfg.label)
        return 1e-6 if cfg.label == winner_label else 1e-3
    return measure


def test_exhaustive_ranks_by_measurement():
    calls = []
    # make a config the model ranks LAST the measured winner
    res = search(STREAM_SPEC, measure=_fake_measure("base", calls),
                 strategy="exhaustive")
    assert res.best.label == "base"
    assert res.source == "measured"
    assert len(calls) == len(enumerate_candidates(STREAM_SPEC))


def test_greedy_measures_only_top_k():
    calls = []
    res = search(STREAM_SPEC, measure=_fake_measure("base", calls),
                 strategy="greedy", top_k=3)
    assert len(calls) == 3
    # 'base' is not in the model's top-3, so greedy can't find it — it picks
    # the best measured among the shortlist
    assert res.best.label in calls


def test_measured_strategy_requires_measure():
    with pytest.raises(ValueError):
        search(STREAM_SPEC, strategy="exhaustive")


# ---------------------------------------------------------------------------
# cache persistence
# ---------------------------------------------------------------------------

def test_cache_roundtrip(tmp_path):
    path = str(tmp_path / "tune.json")
    c1 = TuningCache(path)
    cfg = autotune(STREAM_SPEC, cache=c1)
    assert os.path.exists(path)
    c2 = TuningCache(path)                      # fresh load from disk
    assert c2.get(STREAM_SPEC) == cfg
    blob = json.load(open(path))
    assert blob["version"] == tune_cache.CACHE_VERSION
    [entry] = blob["entries"].values()
    assert entry["cfg"] == cfg.label and entry["source"] == "model"


def test_cache_version_mismatch_invalidates(tmp_path):
    path = str(tmp_path / "tune.json")
    c1 = TuningCache(path)
    autotune(STREAM_SPEC, cache=c1)
    blob = json.load(open(path))
    blob["version"] = -1
    json.dump(blob, open(path, "w"))
    c2 = TuningCache(path)
    assert len(c2) == 0 and c2.get(STREAM_SPEC) is None


def test_autotune_second_call_hits_cache(tmp_path):
    cache = TuningCache(str(tmp_path / "tune.json"))
    before = tune_search.SEARCH_COUNT
    a = autotune(STREAM_SPEC, cache=cache)
    assert tune_search.SEARCH_COUNT == before + 1
    b = autotune(STREAM_SPEC, cache=cache)
    assert tune_search.SEARCH_COUNT == before + 1       # no re-search
    assert a == b
    assert cache.stats["hits"] == 1 and cache.stats["misses"] == 1


# ---------------------------------------------------------------------------
# cfg="auto" through ops
# ---------------------------------------------------------------------------

def test_ops_auto_matches_explicitly_tuned(scratch_default_cache):
    n, block = 1 << 14, 512
    xs = tuple(jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(0), i),
                                 (n,)) for i in range(4))
    got = ops.ew_stream(xs, "auto", ai=6, block=block)

    spec = KernelSpec.make("ew_stream", (n,), n_loads=4, ai=6,
                           variant="base", block=block)
    best = search(spec).best
    want = ops.ew_stream(xs, best, ai=6, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # and the winner was persisted under the spec key
    blob = json.load(open(scratch_default_cache))
    assert blob["entries"][spec.key]["cfg"] == best.label


def test_ops_auto_resolves_from_persisted_cache(scratch_default_cache):
    n = 1 << 14
    xs = tuple(jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(1), i),
                                 (n,)) for i in range(2))
    before = tune_search.SEARCH_COUNT
    ops.ew_stream(xs, "auto", ai=4, block=512)
    assert tune_search.SEARCH_COUNT == before + 1
    # wipe every in-process memo: only the JSON file can answer now
    tune_cache._DEFAULT.clear()
    ops._auto_cfg.cache_clear()
    ops.ew_stream(xs, "auto", ai=4, block=512)
    assert tune_search.SEARCH_COUNT == before + 1       # served from disk


def test_verify_family_picks_its_own_degree():
    """The short-q verify family is its own tuning problem: at one serving
    geometry (small-batch GQA, 2k paged cache, 512-token prompts) the three
    attention families split three ways.  Verify amortises the per-split
    q-pane and combine traffic over T rows, so it coarsens harder than
    single-row decode; the causal prefill tile at bq=256 keeps more work per
    pane and stops earlier.  Geometry shared with benchmarks/specdecode.py."""
    b, h, hkv, d = 2, 32, 4, 128
    s, ps = 2048, 128
    npp = s // ps
    dec = search(KernelSpec.make("decode_attention_paged", (b, h, hkv, npp, d),
                                 dtype="bfloat16", page_size=ps, window=0))
    ver = search(KernelSpec.make("flash_attention_verify",
                                 (b, h, hkv, 5, npp, d),
                                 dtype="bfloat16", page_size=ps, window=0))
    pre = search(KernelSpec.make("flash_attention", (b, h, hkv, 512, 512, d),
                                 dtype="bfloat16", causal=True, window=0,
                                 bq=256, bkv=128))
    assert dec.best.label == "con4"
    assert ver.best.label == "con8"
    assert pre.best.label == "con2"
    # the criterion proper: verify's winning degree differs from both
    assert ver.best.degree not in (dec.best.degree, pre.best.degree)


def test_sparse_family_picks_its_own_degree():
    """The block-sparse family coarsens the LIVE-SLOT axis, so its degree
    legality (max_live % deg == 0) is independent of sequence length —
    unlike the dense family, whose q-row coarsening needs sq % (bq*deg)
    == 0.  At a 33280-token window=512 prefill (260 q-blocks, not
    divisible by 8) dense con8 is illegal, so the two families MUST split:
    sparse rides the padded 8-slot live list at con8 while dense stops at
    con4.  Geometry shared with benchmarks/sparse_attention.py."""
    from repro.kernels.sparse_attention import build_block_index
    b, h, hkv, d = 1, 4, 1, 256
    s, bq, bkv, w = 33280, 128, 128, 512
    idx = build_block_index(s, s, bq, bkv, causal=True, window=w)
    ml, nl = int(idx.shape[1]), int((idx >= 0).sum())
    sp = search(KernelSpec.make("flash_attention_sparse",
                                (b, h, hkv, s, s, d), dtype="bfloat16",
                                bq=bq, bkv=bkv, causal=True, window=w,
                                gstride=0, max_live=ml, n_live=nl))
    dn = search(KernelSpec.make("flash_attention", (b, h, hkv, s, s, d),
                                dtype="bfloat16", causal=True, window=0,
                                bq=bq, bkv=bkv))
    assert sp.best.label == "con8"
    assert dn.best.label == "con4"
    # the criterion proper: the sparse family's winner differs from dense
    assert sp.best.degree != dn.best.degree
    # and no dense candidate at degree 8 was even legal at this sq
    assert all(c.cfg.degree != 8 for c in dn.candidates)


def test_ops_auto_ref_backend_skips_tuning():
    a = jax.random.normal(jax.random.PRNGKey(2), (64, 64))
    b = jax.random.normal(jax.random.PRNGKey(3), (64, 64))
    before = tune_search.SEARCH_COUNT
    out = ops.matmul(a, b, "auto", backend="ref")
    assert tune_search.SEARCH_COUNT == before
    np.testing.assert_allclose(out, a @ b, rtol=1e-5, atol=1e-5)
