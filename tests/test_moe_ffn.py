"""Grouped-expert fused MoE FFN kernel: parity vs the einsum oracle across
the expert-coarsening matrix x (top_k, capacity, E_pad padding, dtype), the
new repro.tune family (candidate legality, cost direction, cache
round-trip), the cfg="auto" dispatch through kernels.ops, the
moe_backend="pallas" model dispatch with einsum fallback, and shardmap-path
parity on a 2-device mesh."""
import dataclasses
import importlib
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import CoarseningConfig
from repro.core.analysis import moe_ffn_cost
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.tune import KernelSpec, TuningCache, autotune, \
    enumerate_candidates, model_cost, search

tune_cache = importlib.import_module("repro.tune.cache")
tune_search = importlib.import_module("repro.tune.search")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.PRNGKey(11)
E, CAP, D, F = 16, 8, 32, 64

SPECS = ("none", "con2", "con4", "con8", "gap2", "gap4", "gap8")


def _operands(e=E, cap=CAP, d=D, f=F, dtype=jnp.float32):
    xe = (jax.random.normal(KEY, (e, cap, d)) * 0.5).astype(dtype)
    w1 = (jax.random.normal(jax.random.fold_in(KEY, 1), (e, d, f))
          / np.sqrt(d)).astype(dtype)
    w3 = (jax.random.normal(jax.random.fold_in(KEY, 2), (e, d, f))
          / np.sqrt(d)).astype(dtype)
    w2 = (jax.random.normal(jax.random.fold_in(KEY, 3), (e, f, d))
          / np.sqrt(f)).astype(dtype)
    wts = jax.random.uniform(jax.random.fold_in(KEY, 4), (e, cap))
    return xe, w1, w3, w2, wts


@pytest.mark.parametrize("spec", SPECS)
def test_matches_einsum_oracle(spec):
    """Every legal (kind, degree) merely redistributes experts — output must
    equal the untiled einsum oracle within f32 tolerance."""
    xe, w1, w3, w2, wts = _operands()
    want = ref.moe_ffn(xe, w1, w3, w2, wts)
    got = ops.moe_ffn(xe, w1, w3, w2, wts, CoarseningConfig.parse(spec))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_bf16_parity():
    xe, w1, w3, w2, wts = _operands(dtype=jnp.bfloat16)
    want = ref.moe_ffn(xe, w1, w3, w2, wts)
    got = ops.moe_ffn(xe, w1, w3, w2, wts, "con4")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_untileable_degree_raises():
    from repro.kernels import moe_ffn as K
    with pytest.raises(ValueError):
        K.make_kernel(E, CAP, D, F, CoarseningConfig.parse("con3"))


# ---------------------------------------------------------------------------
# model dispatch (moe_backend knob, fallback, combine dtype)
# ---------------------------------------------------------------------------

def _moe_cfg(**over):
    cfg = get_config("olmoe-1b-7b").reduced()
    return dataclasses.replace(cfg, **over) if over else cfg


@pytest.mark.parametrize("top_k", [1, 2])
@pytest.mark.parametrize("capacity", [4, 32], ids=["drop", "nodrop"])
def test_moe_backend_pallas_matches_ref(top_k, capacity,
                                        scratch_default_cache):
    """moe_backend='pallas' must equal the einsum path per (top_k, capacity)
    — including E_pad padding (8 experts padded to 16) and dropped
    overflow tokens."""
    cfg = _moe_cfg(top_k=top_k)
    assert cfg.n_experts_padded != cfg.n_experts   # the padding case
    p = L.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (2, 16, cfg.d_model))
    want, aux_ref = L.moe(p, x, cfg, capacity=capacity)
    got, aux_k = L.moe(p, x, dataclasses.replace(cfg, moe_backend="pallas"),
                       capacity=capacity)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(aux_k), float(aux_ref), rtol=1e-6)


def test_moe_backend_falls_back_on_bad_degree():
    """An explicit degree the padded expert count can't tile must fall back
    to the einsum path, not raise."""
    cfg = _moe_cfg()
    p = L.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (2, 16, cfg.d_model))
    want, _ = L.moe(p, x, cfg, capacity=32)
    got, _ = L.moe(p, x, dataclasses.replace(
        cfg, moe_backend="pallas", moe_ffn_cfg="con3"), capacity=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_combine_dtype_honored_in_scatter():
    """cfg.moe_combine_dtype='bfloat16' must change the combine-scatter
    accumulator on the NON-shardmap path (and stay close to f32)."""
    cfg = _moe_cfg()
    p = L.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (2, 16, cfg.d_model))
    want, _ = L.moe(p, x, cfg, capacity=32)
    got16, _ = L.moe(p, x, dataclasses.replace(
        cfg, moe_combine_dtype="bfloat16"), capacity=32)
    np.testing.assert_allclose(np.asarray(got16, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)
    # and it really ran in bf16: exact f32 equality must NOT hold
    assert not np.allclose(np.asarray(got16, np.float32),
                           np.asarray(want, np.float32), rtol=0, atol=0)


def test_ffn_routes_through_ops_matmul():
    """The dense ffn() matmuls route through ops.matmul: ref passthrough is
    numerically exact; the pallas backend matches at a tileable geometry."""
    pf = L.ffn_init(KEY, 128, 256)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (4, 8, 128))
    want = (jax.nn.silu(x @ pf["w1"]) * (x @ pf["w3"])) @ pf["w2"]
    np.testing.assert_allclose(np.asarray(L.ffn(pf, x)), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    pf2 = L.ffn_init(jax.random.fold_in(KEY, 7), 256, 512)
    x2 = jax.random.normal(jax.random.fold_in(KEY, 8), (128, 256)) * 0.1
    np.testing.assert_allclose(
        np.asarray(L.ffn(pf2, x2, backend="pallas")),
        np.asarray(L.ffn(pf2, x2)), rtol=1e-4, atol=1e-4)
    # untileable geometry falls back to the passthrough, not an error
    pf3 = L.ffn_init(jax.random.fold_in(KEY, 9), 96, 80)
    x3 = jax.random.normal(jax.random.fold_in(KEY, 10), (5, 96))
    np.testing.assert_allclose(
        np.asarray(L.ffn(pf3, x3, backend="pallas")),
        np.asarray(L.ffn(pf3, x3)), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# tuner family
# ---------------------------------------------------------------------------

MOE_SPEC = KernelSpec.make("moe_ffn", (64, 128, 2048, 1024),
                           dtype="bfloat16")


def test_candidates_respect_expert_divisibility():
    cands = enumerate_candidates(MOE_SPEC)
    assert cands
    for c in cands:
        assert 64 % c.degree == 0
        # kernel implements neither replication nor SIMD
        assert c.replication == 1 and c.vector_width == 1
    small = KernelSpec.make("moe_ffn", (4, 8, 64, 128), dtype="float32")
    assert {c.degree for c in enumerate_candidates(small)} == {1, 2, 4}


def test_fused_beats_dense_baseline_from_16_experts():
    """The acceptance direction the moe benchmark table asserts: at every
    point with E >= 16, at least one coarsened degree beats the unfused
    einsum baseline in modeled cost."""
    for t, e, k in ((256, 16, 2), (1024, 64, 8), (1024, 64, 4),
                    (4096, 128, 8)):
        cap = L.moe_default_capacity(t, e, k)
        dense = moe_ffn_cost(e, cap, 2048, 1024, CoarseningConfig(),
                             dense=True).modeled_s
        best = min(moe_ffn_cost(e, cap, 2048, 1024,
                                CoarseningConfig.parse(f"con{d}")).modeled_s
                   for d in (2, 4, 8) if e % d == 0)
        assert best < dense, (t, e, k, best, dense)


def test_auto_matches_or_beats_fixed_degrees():
    res = search(MOE_SPEC)
    best = model_cost(MOE_SPEC, res.best)
    for deg in (1, 2, 4, 8):
        cfg = CoarseningConfig.parse(f"con{deg}" if deg > 1 else "none")
        assert best <= model_cost(MOE_SPEC, cfg) * (1 + 1e-9)


def test_tuner_cache_roundtrip(tmp_path):
    cache = TuningCache(str(tmp_path / "tune.json"))
    cfg = autotune(MOE_SPEC, cache=cache)
    fresh = TuningCache(str(tmp_path / "tune.json"))
    assert fresh.get(MOE_SPEC) == cfg
    blob = json.load(open(str(tmp_path / "tune.json")))
    [entry] = blob["entries"].values()
    assert entry["cfg"] == cfg.label


def test_ops_auto_dispatch(scratch_default_cache):
    """cfg='auto' resolves through the tuner, persists the winner under the
    moe_ffn family key, and the second call never re-searches."""
    xe, w1, w3, w2, wts = _operands()
    before = tune_search.SEARCH_COUNT
    got = ops.moe_ffn(xe, w1, w3, w2, wts, "auto")
    assert tune_search.SEARCH_COUNT == before + 1
    spec = KernelSpec.make("moe_ffn", (E, CAP, D, F), dtype="float32")
    best = search(spec).best
    want = ops.moe_ffn(xe, w1, w3, w2, wts, best)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    blob = json.load(open(scratch_default_cache))
    assert blob["entries"][spec.key]["cfg"] == best.label
    ops._auto_cfg.cache_clear()
    tune_cache._DEFAULT.clear()
    mid = tune_search.SEARCH_COUNT
    ops.moe_ffn(xe, w1, w3, w2, wts, "auto")
    assert tune_search.SEARCH_COUNT == mid


def test_warm_covers_moe_family(tmp_path):
    from repro.tune import warm_for_model
    cfg = get_config("olmoe-1b-7b")
    cache = TuningCache(str(tmp_path / "warm.json"))
    out = warm_for_model(cfg, seq=128, batch=8, cache=cache, verbose=False)
    assert "moe_ffn" in out


# ---------------------------------------------------------------------------
# shardmap-path parity (2-device mesh, subprocess)
# ---------------------------------------------------------------------------

def test_moe_shardmap_pallas_matches_ref(tmp_path):
    """The EP shard_map dispatch with moe_backend='pallas' must equal the
    single-device einsum path on a 2-device mesh."""
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import layers as L
        from repro.models.layers import NOSHARD
        from repro.distributed.sharding import make_shard_ctx

        cfg = get_config("olmoe-1b-7b").reduced()
        key = jax.random.PRNGKey(0)
        p = L.moe_init(key, cfg)
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (2, 16, cfg.d_model))
        y_ref, aux_ref = L.moe(p, x, cfg, capacity=32, shard=NOSHARD)

        cfg_k = dataclasses.replace(cfg, moe_backend="pallas")
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        shard = make_shard_ctx(mesh)
        with mesh:
            y_sm, aux_sm = jax.jit(
                lambda p, x: L.moe(p, x, cfg_k, capacity=32, shard=shard)
            )(p, x)
        np.testing.assert_allclose(np.asarray(y_sm, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(float(aux_sm), float(aux_ref), rtol=0.3)
        print("moe shardmap pallas OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env[tune_cache.ENV_VAR] = str(tmp_path / "shardmap_tune.json")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
