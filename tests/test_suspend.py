"""Resumable preemption on the real PagedEngine.

The contract under test: ``suspend(slot)`` swaps a running slot's live
pages + non-paged state to host and frees its device pages; ``resume``
restores into freshly allocated pages (any free slot) and generation
continues BITWISE where it stopped — zero prefill steps re-run.  The
cache-row invariant that makes this sound (rows >= written are always
rewritten before any read) is the same one the decode re-run rescue and
the spec rollback lean on.

Oracle: the same trace on the same engine class with no suspension.
"""
import jax
import numpy as np
import pytest

from repro.serve import PagedEngine, Request, Scheduler, State


@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, **over):
    kw = dict(slots=2, num_pages=20, page_size=8, max_len=48, chunk=8,
              decode_block=4)
    kw.update(over)
    return PagedEngine(cfg, params, **kw)


def _drive(eng, slot, req, out):
    while len(out) < req.gen:
        out.extend(eng.decode([slot])[slot])
    eng.finish(slot)
    return out[: req.gen]


def test_suspend_resume_is_bitwise_with_zero_reprefill(tiny_model):
    cfg, params = tiny_model
    rng = np.random.default_rng(0)
    prompt = list(map(int, rng.integers(1, cfg.vocab, 11)))
    gen = 14

    ref_eng = _engine(cfg, params)
    req = Request(rid=0, prompt=prompt, gen=gen)
    ref = _drive(ref_eng, 0, req, [ref_eng.admit(0, req)])

    eng = _engine(cfg, params)
    req = Request(rid=0, prompt=prompt, gen=gen)
    out = [eng.admit(0, req)]
    prefills = eng.prefill_steps
    out.extend(eng.decode([0])[0])          # partial progress
    live_before = eng.pool.num_live
    susp = eng.suspend(0)
    # suspension freed every page the slot held
    assert eng.pool.num_live == 0 and not eng.active[0]
    assert live_before > 0 and susp.n_pages > 0 and susp.nbytes > 0
    # written rows: the prompt + each decoded token fed back in; the newest
    # sampled token rides in susp.last, not in the cache yet
    assert susp.n_tokens == len(prompt) + len(out) - 1

    eng.resume(1, susp)                      # a DIFFERENT slot
    assert eng.pool.num_live == susp.n_pages
    out = _drive(eng, 1, req, out)
    assert out == ref, "suspend/resume changed the greedy stream"
    assert eng.prefill_steps == prefills == ref_eng.prefill_steps, \
        "resume re-ran prefill"
    assert eng.pool.num_live == 0
    eng.pool.check()


def test_scheduler_swap_path_on_real_engine(tiny_model):
    """Pool pressure with swapping on: every request finishes with the
    greedy stream of an unpressured run, total prefill steps equal the
    unpressured run's (each prompt prefilled exactly once — evictions went
    through suspend, not recompute)."""
    cfg, params = tiny_model
    rng = np.random.default_rng(1)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, 6)))
               for _ in range(3)]
    gen = 18

    ref_eng = _engine(cfg, params, slots=3, num_pages=32, max_len=32)
    ref_sched = Scheduler(ref_eng)
    for p in prompts:
        ref_sched.submit(p, gen)
    ref = {r.rid: r.output for r in ref_sched.run_until_done()}
    assert ref_eng.suspends == 0, "reference run must be unpressured"

    eng = _engine(cfg, params, slots=3, num_pages=8, max_len=32)
    sched = Scheduler(eng)                  # unbounded host budget: swap
    for p in prompts:
        sched.submit(p, gen)
    done = sched.run_until_done()
    assert eng.suspends > 0 and eng.suspends == eng.resumes, \
        "pool failed to force a swap eviction — weaken num_pages"
    for req in done:
        assert req.state is State.FINISHED
        assert req.output == ref[req.rid], req.rid
    assert eng.prefill_steps == ref_eng.prefill_steps, \
        "a swap eviction re-ran prefill"
    assert sum(r.swaps for r in done) == eng.suspends
    assert eng.pool.num_live == 0 and len(sched.swap) == 0
    sched.swap.check()
    eng.pool.check()
