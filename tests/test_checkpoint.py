"""Checkpoint manager: roundtrip, atomicity, retention, integrity, resume."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_checkpoint, load_checkpoint


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"params": {"w": jax.random.normal(k, (16, 8)),
                       "layers": [jnp.ones((4,)), jnp.zeros((2, 2))]},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 10, t, extra={"note": "hi"})
    restored, manifest = load_checkpoint(str(tmp_path), t)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, restored)
    assert manifest["extra"]["note"] == "hi"
    assert manifest["step"] == 10


def test_latest_points_to_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.latest_step() == 3
    restored, _ = mgr.restore(_tree())
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 _tree(3), restored)


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(s, _tree(s), blocking=True)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_integrity_detects_corruption(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    npz = os.path.join(tmp_path, "step_00000001", "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(100)
        f.write(b"\x00" * 32)
    with pytest.raises(IOError):
        load_checkpoint(str(tmp_path), _tree())


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(42, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 42


def test_should_save_interval(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_interval_steps=10)
    assert not mgr.should_save(0)
    assert mgr.should_save(10)
    assert not mgr.should_save(11)


def test_tmp_dirs_never_latest(tmp_path):
    """Partial saves (crash mid-write) must not be visible as LATEST."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    # simulate a crashed partial save
    os.makedirs(os.path.join(tmp_path, "step_00000002.tmp"))
    assert mgr.latest_step() == 1


def test_elastic_reshard_restore(tmp_path):
    """Restore with explicit shardings (elastic re-mesh path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = load_checkpoint(str(tmp_path), t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
    assert restored["w"].sharding == sh["w"]
