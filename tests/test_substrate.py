"""Optimizer / data / compression substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data import DataConfig, TokenPipeline
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         accumulate_grads, clip_by_global_norm,
                         int8_compress_grads, plan_buckets, bucket_coarsen)
from repro.optim.compression import bucket_restore, int8_decompress
from repro.optim.schedule import wsd_schedule


def _toy_params(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"w": jax.random.normal(jax.random.fold_in(k, 1), (16, 4)),
                  "bias": jnp.zeros((4,))}}


def _toy_loss(p, batch):
    x, y = batch
    h = jnp.tanh(x @ p["a"])
    out = h @ p["b"]["w"] + p["b"]["bias"]
    return jnp.mean((out - y) ** 2), {"dummy": jnp.sum(out)}


# ---------------------------------------------------------------------------
# adamw
# ---------------------------------------------------------------------------

def test_adamw_reduces_loss():
    params = _toy_params()
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=3e-2, weight_decay=0.0)
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (32, 8))
    y = jax.random.normal(jax.random.fold_in(key, 1), (32, 4))
    l0 = float(_toy_loss(params, (x, y))[0])
    for _ in range(50):
        g = jax.grad(lambda p: _toy_loss(p, (x, y))[0])(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(_toy_loss(params, (x, y))[0]) < 0.5 * l0


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(gn), np.sqrt(1000.0), rtol=1e-5)
    norm_after = float(jnp.linalg.norm(clipped["a"]))
    assert np.isclose(norm_after, 1.0, rtol=1e-4)


def test_wsd_schedule_shape():
    assert float(wsd_schedule(jnp.asarray(0), warmup=10)) < 0.2
    assert np.isclose(float(wsd_schedule(jnp.asarray(50), warmup=10)), 1.0)
    late = float(wsd_schedule(jnp.asarray(10 + 10000 + 2000),
                              warmup=10, stable=10000, decay=1000))
    assert np.isclose(late, 0.1, atol=1e-5)


# ---------------------------------------------------------------------------
# gradient accumulation == full batch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_micro", [1, 2, 4])
def test_accumulate_matches_full_batch(n_micro):
    params = _toy_params()
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (8, 8))
    y = jax.random.normal(jax.random.fold_in(key, 1), (8, 4))
    loss_full, g_full = jax.value_and_grad(
        lambda p: _toy_loss(p, (x, y))[0])(params)
    loss_acc, g_acc, _ = accumulate_grads(_toy_loss, params, (x, y), n_micro)
    np.testing.assert_allclose(float(loss_acc), float(loss_full), rtol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-4,
                                                         atol=1e-5),
                 g_acc, g_full)


# ---------------------------------------------------------------------------
# compression: bucket coarsening + int8 error feedback
# ---------------------------------------------------------------------------

def test_bucket_roundtrip():
    params = _toy_params()
    plan = plan_buckets(params, bucket_bytes=256)      # force several buckets
    buckets = bucket_coarsen(params, plan)
    assert len(buckets) == len(plan.sizes) and len(buckets) > 1
    restored = bucket_restore(buckets, plan)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b),
                 params, restored)


def test_bucket_coarsening_reduces_transactions():
    """The paper's LSU insight on collectives: fewer, wider buckets."""
    params = {f"p{i}": jnp.zeros((64,)) for i in range(32)}
    plan = plan_buckets(params, bucket_bytes=64 * 64 * 4)
    assert len(plan.sizes) < 32 / 4          # >= 4x fewer transactions


def test_int8_error_feedback_converges():
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal(1000, dtype=np.float32))}
    resid = None
    acc_true = np.zeros(1000, np.float32)
    acc_q = np.zeros(1000, np.float32)
    for step in range(50):
        q, scales, resid = int8_compress_grads(g, resid)
        deq = int8_decompress(q, scales)
        acc_true += np.asarray(g["w"])
        acc_q += np.asarray(deq["w"])
    # error feedback keeps the accumulated estimate unbiased
    rel = np.abs(acc_q - acc_true).max() / np.abs(acc_true).max()
    assert rel < 0.01


@given(seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_int8_single_step_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal(256, dtype=np.float32))}
    q, scales, resid = int8_compress_grads(g, None)
    deq = int8_decompress(q, scales)
    scale = float(scales["w"])
    assert float(jnp.max(jnp.abs(deq["w"] + resid["w"] - g["w"]))) < 1e-5
    assert float(jnp.max(jnp.abs(resid["w"]))) <= scale * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_determinism_and_state():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=4)
    p1 = TokenPipeline(cfg)
    batches = [p1.next_batch() for _ in range(3)]
    # restore from state after 1 batch reproduces batches 2,3
    p2 = TokenPipeline(cfg)
    p2.next_batch()
    st = p2.state_dict()
    p3 = TokenPipeline(cfg)
    p3.load_state_dict(st)
    for want in batches[1:]:
        got = p3.next_batch()
        np.testing.assert_array_equal(got["tokens"], want["tokens"])


def test_pipeline_labels_shifted():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=2)
    b = TokenPipeline(cfg).next_batch()
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_pipeline_learnable_structure():
    """The copy motif means label[t] is predictable from token[t-half]."""
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=8)
    b = TokenPipeline(cfg).next_batch()
    toks = np.asarray(b["tokens"])
    view = toks[:, : (64 // 16) * 16].reshape(8, -1, 16)
    pred = (view[:, :, :8] + 1) % (cfg.vocab - 2) + 1
    assert (view[:, :, 8:] == pred).mean() > 0.95
