"""Chunked-prefill parity: lm_prefill must fill the decode caches so that
chunked-prefill-then-decode reproduces token-by-token forced decode, and the
chunked serve path must produce identical greedy outputs at a fraction of
the model steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import BatchedServer
from repro.models import model as M

KEY = jax.random.PRNGKey(0)

# one arch per cache family: GQA attention, sliding window, SSD state,
# RG-LRU hybrid, MoE routing
ARCHS = ("qwen3-0.6b", "gemma3-1b", "mamba2-370m", "recurrentgemma-2b",
         "olmoe-1b-7b")


def _forced_decode(params, cfg, tok, gen, s_max):
    """Token-by-token forced ingestion + greedy decode; returns per-step
    logits (the ground truth lm_prefill must reproduce)."""
    b, plen = tok.shape
    step = jax.jit(lambda p, c, t, po: M.lm_decode_step(p, c, t, po, cfg))
    cache = M.lm_init_cache(cfg, b, s_max)
    logits_seq = []
    cur = tok[:, :1]
    for t in range(plen + gen - 1):
        logits, cache = step(params, cache, cur, jnp.full((b,), t, jnp.int32))
        logits_seq.append(np.asarray(logits))
        if t + 1 < plen:
            cur = tok[:, t + 1:t + 2]
        else:
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return logits_seq


@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_prefill_matches_forced_decode(arch):
    cfg = get_config(arch).reduced()
    params = M.lm_init(KEY, cfg)
    b, plen, gen, s_max, chunk = 1, 7, 4, 64, 4
    tok = jax.random.randint(jax.random.PRNGKey(9), (b, plen), 0, cfg.vocab)
    want = _forced_decode(params, cfg, tok, gen, s_max)

    # ingest in chunks of 4 (the second one partial) then greedy-decode
    cache = M.lm_init_cache(cfg, b, s_max)
    for i in range(0, plen, chunk):
        logits, cache = M.lm_prefill(
            params, {"tokens": tok[:, i:i + chunk]}, cfg, cache=cache,
            pos0=jnp.full((b,), i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits), want[min(i + chunk, plen) - 1],
            rtol=3e-2, atol=3e-2)
    step = jax.jit(lambda p, c, t, po: M.lm_decode_step(p, c, t, po, cfg))
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(plen, plen + gen - 1):
        logits, cache = step(params, cache, cur, jnp.full((b,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits), want[t],
                                   rtol=3e-2, atol=3e-2)
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def test_prefill_greedy_tokens_identical_to_forced_decode():
    """The serving contract: not just close logits — the sampled (greedy)
    token stream must be identical."""
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(KEY, cfg)
    b, plen, gen, s_max = 1, 10, 8, 64
    tok = jax.random.randint(jax.random.PRNGKey(3), (b, plen), 0, cfg.vocab)
    want_logits = _forced_decode(params, cfg, tok, gen, s_max)
    want = [int(np.argmax(l[0])) for l in want_logits[plen - 1:]]

    cache = M.lm_init_cache(cfg, b, s_max)
    logits, cache = M.lm_prefill(params, {"tokens": tok}, cfg, cache=cache)
    got = [int(jnp.argmax(logits[0]))]
    step = jax.jit(lambda p, c, t, po: M.lm_decode_step(p, c, t, po, cfg))
    for t in range(plen, plen + gen - 1):
        logits, cache = step(params, cache,
                             jnp.asarray([[got[-1]]], jnp.int32),
                             jnp.full((b,), t, jnp.int32))
        got.append(int(jnp.argmax(logits[0])))
    assert got == want


def test_prefill_mask_protects_other_slots():
    """Continuous-batching admit: prefilling slot 1 must leave slot 0's
    cache bit-identical (mid-generation state is sacred)."""
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(KEY, cfg)
    b, s_max = 2, 32
    tok = jax.random.randint(jax.random.PRNGKey(5), (b, 6), 0, cfg.vocab)
    _, cache = M.lm_prefill(params, {"tokens": tok}, cfg, s_max=s_max)

    newtok = jax.random.randint(jax.random.PRNGKey(6), (b, 6), 0, cfg.vocab)
    mask = jnp.asarray([False, True])
    _, cache2 = M.lm_prefill(params, {"tokens": newtok}, cfg, cache=cache,
                             pos0=jnp.zeros((b,), jnp.int32), mask=mask)

    def slot(c, tree, idx, stacked):
        return jax.tree.map(
            lambda a: a[:, idx] if stacked else a[idx], tree)

    for old, new in zip(cache["blocks"], cache2["blocks"]):
        jax.tree.map(lambda a, b_: np.testing.assert_array_equal(
            np.asarray(a[:, 0], np.float32), np.asarray(b_[:, 0], np.float32)),
            old, new)
        # and slot 1 actually changed
        changed = jax.tree.leaves(jax.tree.map(
            lambda a, b_: float(jnp.max(jnp.abs(
                a[:, 1].astype(jnp.float32) - b_[:, 1].astype(jnp.float32)))),
            old, new))
        assert max(changed) > 0
    for old, new in zip(cache["tail"], cache2["tail"]):
        jax.tree.map(lambda a, b_: np.testing.assert_array_equal(
            np.asarray(a[0], np.float32), np.asarray(b_[0], np.float32)),
            old, new)


def test_prefill_fills_encdec_cross_cache():
    """Enc-dec prefill must populate the per-layer cross K/V from
    src_frames (they start zero) and the self-attn rows for the chunk."""
    cfg = get_config("seamless-m4t-large-v2").reduced()
    params = M.lm_init(KEY, cfg)
    b, plen, s_max = 1, 6, 32
    tok = jax.random.randint(jax.random.PRNGKey(4), (b, plen), 0, cfg.vocab)
    frames = jax.random.normal(jax.random.PRNGKey(5),
                               (b, 8, cfg.d_model)) * 0.1
    logits, cache = M.lm_prefill(
        params, {"tokens": tok, "src_frames": frames}, cfg, s_max=s_max)
    assert np.isfinite(np.asarray(logits)).all()
    blk = cache["blocks"][0]
    assert float(jnp.max(jnp.abs(blk["enc_k"][:, :, :8].astype(jnp.float32)))) > 0
    assert float(jnp.max(jnp.abs(blk["k"].astype(jnp.float32)))) > 0
    # decode continues from the filled caches
    step = jax.jit(lambda p, c, t, po: M.lm_decode_step(p, c, t, po, cfg))
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, _ = step(params, cache, cur, jnp.full((b,), plen, jnp.int32))
    assert np.isfinite(np.asarray(logits2)).all()


def test_serve_chunked_prefill_step_count_and_outputs():
    """End-to-end: chunked serving must cut model steps per request from
    prompt_len + gen to ceil(prompt_len/chunk) + gen while emitting the same
    greedy tokens as chunk=1 (token-by-token-equivalent) serving."""
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(KEY, cfg)
    rng = np.random.default_rng(2)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, 9)))
               for _ in range(3)]
    gen = 5

    def run(chunk, decode_block):
        server = BatchedServer(cfg, params, slots=2, max_len=64,
                               chunk=chunk, decode_block=decode_block)
        pending = list(prompts)
        while pending or server.any_active:
            while pending and server.try_admit(pending[0], gen):
                pending.pop(0)
            if not server.any_active:
                break
            server.step()
        return server

    fine = run(1, 1)
    coarse = run(4, 4)
    assert sorted(map(tuple, fine.completed)) \
        == sorted(map(tuple, coarse.completed))
    # ceil(9/4)=3 prefill steps per request vs 9
    assert coarse.prefill_steps == 3 * len(prompts)
    assert fine.prefill_steps == 9 * len(prompts)
    assert all(len(o) == gen for o in coarse.completed)
