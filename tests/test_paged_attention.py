"""Paged (block-table) decode attention: parity vs the contiguous oracle
through randomly permuted, fragmented block tables — across the coarsening
matrix, GQA, sliding window, int8-KV pools, and pages whose tail rows lie
past ``pos`` (must be masked, not read) — plus the decode_attention_paged
tuner family (candidate legality, page_size/kv_bits in the spec key, paged
cost direction, cfg='auto' dispatch)."""
import importlib
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoarseningConfig
from repro.core.analysis import decode_attention_cost
from repro.kernels import ops
from repro.models import layers as L
from repro.quant import quantize_kv
from repro.tune import KernelSpec, enumerate_candidates, model_cost, search

tune_cache = importlib.import_module("repro.tune.cache")
tune_search = importlib.import_module("repro.tune.search")

KEY = jax.random.PRNGKey(3)
B, HKV, G, D = 2, 2, 2, 16
H = HKV * G
PS, NPP = 8, 8                      # page size, per-slot table entries
S = PS * NPP
N_PAGES = B * NPP + 3               # a few never-referenced pages
SPECS = ("none", "con2", "con4", "gap2", "gap4")


def _fragmented():
    """Pools + a randomly permuted block table; every row past each slot's
    ``pos`` (page tails AND whole never-referenced pages) is poisoned with
    huge values so any unmasked read shows up as a parity failure."""
    rng = np.random.default_rng(11)
    kp = rng.normal(size=(N_PAGES, PS, HKV, D)).astype(np.float32)
    vp = rng.normal(size=(N_PAGES, PS, HKV, D)).astype(np.float32)
    perm = rng.permutation(np.arange(1, N_PAGES))[: B * NPP].reshape(B, NPP)
    pos = np.asarray([PS * 3 + 2, S - 1], np.int32)   # mid-page + full
    for bb in range(B):
        for lp in range(NPP):
            row0 = lp * PS
            dead = max(0, min(PS, pos[bb] + 1 - row0))
            kp[perm[bb, lp], dead:] = 1e4
            vp[perm[bb, lp], dead:] = 1e4
    unref = sorted(set(range(1, N_PAGES)) - set(perm.ravel()))
    kp[unref] = 1e4
    vp[unref] = 1e4
    q = rng.normal(size=(B, 1, H, D)).astype(np.float32)
    return (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(perm, jnp.int32), jnp.asarray(pos))


@pytest.fixture(scope="module")
def data():
    return _fragmented()


@pytest.mark.parametrize("spec", SPECS)
@pytest.mark.parametrize("window", [None, 11], ids=["causal", "window"])
def test_paged_matches_contiguous_oracle(data, spec, window):
    """Both coarsening kinds, GQA heads, fragmented table, poisoned tails:
    the paged kernel must equal the gather-to-contiguous dense oracle."""
    q, kp, vp, bt, pos = data
    cfg = CoarseningConfig.parse(spec) if spec != "none" \
        else CoarseningConfig()
    want = ops.paged_decode_attention(q, kp, vp, bt, pos, backend="ref",
                                      window=window)
    got = ops.paged_decode_attention(q, kp, vp, bt, pos, cfg,
                                     backend="pallas", window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    assert np.abs(np.asarray(got)).max() < 100, "poisoned tail row leaked in"


def test_paged_oracle_equals_contiguous_reference(data):
    """The gather oracle itself must agree with the plain contiguous path
    when the table is the identity layout."""
    q, kp, vp, bt, pos = data
    k = kp[bt].reshape(B, S, HKV, D)
    v = vp[bt].reshape(B, S, HKV, D)
    want = L.decode_attention(q, k, v, pos)
    got = ops.paged_decode_attention(q, kp, vp, bt, pos, backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("spec", ("con2", "gap2"))
def test_int8_kv_pool_parity(data, spec):
    q, kp, vp, bt, pos = data
    kq, ks = quantize_kv(kp)
    vq, vs = quantize_kv(vp)
    want = ops.paged_decode_attention(q, kq, vq, bt, pos, backend="ref",
                                      k_scale=ks, v_scale=vs)
    got = ops.paged_decode_attention(q, kq, vq, bt, pos,
                                     CoarseningConfig.parse(spec),
                                     backend="pallas", k_scale=ks,
                                     v_scale=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_layers_paged_dispatch_and_fallback(data):
    """models.layers.paged_decode_attention: the pallas path matches the
    gather fallback, and a degree that can't tile npp falls back silently."""
    q, kp, vp, bt, pos = data
    want = L.paged_decode_attention(q, kp, vp, bt, pos, backend="ref")
    got = L.paged_decode_attention(q, kp, vp, bt, pos, backend="pallas",
                                   cfg="con2")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # npp=8 is not divisible by degree 16 -> dense fallback, not an error
    got = L.paged_decode_attention(q, kp, vp, bt, pos, backend="pallas",
                                   cfg="con16")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# tuner family
# ---------------------------------------------------------------------------

PAGED_SPEC = KernelSpec.make("decode_attention_paged", (8, 32, 8, 32, 128),
                             dtype="bfloat16", page_size=128, window=0)


def test_candidates_divide_the_page_table():
    cands = enumerate_candidates(PAGED_SPEC)
    assert cands
    for c in cands:
        assert 32 % c.degree == 0
        assert c.replication == 1 and c.vector_width == 1
    small = KernelSpec.make("decode_attention_paged", (2, 4, 2, 4, 32),
                            dtype="float32", page_size=64, window=0)
    assert all(c.degree <= 4 for c in enumerate_candidates(small))


def test_page_size_and_kv_bits_join_the_spec_key():
    a = KernelSpec.make("decode_attention_paged", (8, 32, 8, 32, 128),
                        dtype="bfloat16", page_size=128, window=0)
    b = KernelSpec.make("decode_attention_paged", (8, 32, 8, 32, 128),
                        dtype="bfloat16", page_size=64, window=0)
    c = KernelSpec.make("decode_attention_paged", (8, 32, 8, 32, 128),
                        dtype="int8", page_size=128, window=0, kv_bits=8)
    assert len({a.key, b.key, c.key}) == 3


def test_paged_cost_pays_the_table_lookup():
    """Paging turns every kv pane into a table-indexed fetch: the modeled
    cost must exceed the same geometry's contiguous cost (extra descriptors
    + per-page lookup latency), for both kinds."""
    b, h, hkv, d = 8, 32, 8, 128
    ps, npp = 128, 32
    for spec in ("none", "con4", "gap4"):
        cfg = CoarseningConfig.parse(spec) if spec != "none" \
            else CoarseningConfig()
        contig = decode_attention_cost(b, h, hkv, npp * ps, d, cfg,
                                       bkv=ps).modeled_s
        paged = decode_attention_cost(b, h, hkv, npp * ps, d, cfg, bkv=ps,
                                      page_size=ps).modeled_s
        assert paged > contig, spec


def test_paged_auto_dispatch(scratch_default_cache, data):
    """cfg='auto' searches the decode_attention_paged family once, persists
    the winner, and matches the explicitly-tuned kernel."""
    q, kp, vp, bt, pos = data
    before = tune_search.SEARCH_COUNT
    got = ops.paged_decode_attention(q, kp, vp, bt, pos, "auto")
    assert tune_search.SEARCH_COUNT == before + 1
    spec = KernelSpec.make("decode_attention_paged", (B, H, HKV, NPP, D),
                           dtype="float32", page_size=PS, window=0)
    best = search(spec).best
    want = ops.paged_decode_attention(q, kp, vp, bt, pos, best)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    blob = json.load(open(scratch_default_cache))
    assert blob["entries"][spec.key]["cfg"] == best.label
    assert model_cost(spec, best) <= min(
        model_cost(spec, c) for c in enumerate_candidates(spec)) * (1 + 1e-9)
