"""Fault-tolerance runtime: watchdog, preemption, retry, elastic plan."""
import signal
import time

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime import (StepWatchdog, PreemptionHandler, retry_step,
                           SimulatedFailure, elastic_restore_plan)


def test_watchdog_flags_straggler():
    flagged = []
    wd = StepWatchdog(threshold=3.0,
                      on_straggler=lambda s, dt, ema: flagged.append(s))
    for i in range(10):
        wd.observe(i, 0.1)
    assert not flagged
    wd.observe(10, 1.0)                  # 10x the EMA
    assert flagged == [10]
    # straggler sample must not poison the EMA
    assert wd.ema < 0.2


def test_watchdog_context_manager():
    wd = StepWatchdog(threshold=100.0, hang_timeout=60.0)
    with wd.step(0):
        time.sleep(0.01)
    assert wd.ema is not None and wd.ema >= 0.01


def test_watchdog_hang_timer_fires():
    hung = []
    wd = StepWatchdog(hang_timeout=0.05, on_hang=lambda s: hung.append(s))
    wd._arm(3)
    time.sleep(0.15)
    assert hung == [3]


def test_preemption_checkpoint_then_exit():
    pre = PreemptionHandler().install()
    ran, exited = [], []
    try:
        def body(step):
            ran.append(step)
            if step == 4:
                pre.trigger()            # simulated SIGTERM mid-run

        last = pre.run_until_preempted(body, on_exit=lambda s: exited.append(s),
                                       max_steps=100)
    finally:
        pre.uninstall()
    assert ran == [0, 1, 2, 3, 4]
    assert exited == [5] and last == 5


def test_preemption_real_signal():
    pre = PreemptionHandler(signals=(signal.SIGUSR1,)).install()
    try:
        signal.raise_signal(signal.SIGUSR1)
        assert pre.preempted
    finally:
        pre.uninstall()


def test_retry_recovers_then_raises():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise SimulatedFailure("boom")
        return "ok"

    assert retry_step(flaky, retries=3, backoff_s=0.001) == "ok"
    assert len(calls) == 3

    with pytest.raises(SimulatedFailure):
        retry_step(lambda: (_ for _ in ()).throw(SimulatedFailure("x")),
                   retries=1, backoff_s=0.001)


def test_elastic_plan_single_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = elastic_restore_plan(mesh, global_batch=8,
                                param_specs={"w": P("data", "model")})
    assert plan.dp_degree == 1 and plan.tp_degree == 1
    assert plan.batch_per_replica == 8
    assert not plan.notes


def test_elastic_plan_flags_indivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = elastic_restore_plan(mesh, global_batch=7, param_specs={})
    assert plan.batch_per_replica == 7   # 7 // 1
    mesh2 = jax.make_mesh((1,), ("data",))
    plan2 = elastic_restore_plan(mesh2, global_batch=8, param_specs={})
    assert plan2.dp_degree == 1
