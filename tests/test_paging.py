"""Executable spec of the page pool + block tables (repro.serve.paging).

Two layers:

* deterministic tests (always run): unit edges + a seeded np.random
  admit/grow/finish/preempt random walk asserting the pool invariants at
  every step — these keep tier-1 coverage even where hypothesis isn't
  installed;
* hypothesis property tests (skipped without the package): the same
  invariants driven by minimized counterexample search over arbitrary op
  sequences.

Invariants under test (module docstring of paging.py):
  * a writable page (refcount == 1) appears in at most one block table
  * free + live == num_pages - 1 (the null page is neither)
  * a refcount-shared page is freed exactly when the last holder releases
  * any admit/decode/finish/preempt sequence conserves pages (no leaks)
"""
import numpy as np
import pytest

from repro.serve import (NULL_PAGE, BlockTables, PagePool, PoolExhausted,
                         SwapStore, pages_needed)


# ---------------------------------------------------------------------------
# unit edges
# ---------------------------------------------------------------------------

def test_pages_needed():
    assert pages_needed(0, 8) == 0
    assert pages_needed(1, 8) == 1
    assert pages_needed(8, 8) == 1
    assert pages_needed(9, 8) == 2
    assert pages_needed(64, 16) == 4


def test_alloc_never_hands_out_null_page():
    pool = PagePool(5, 8)
    pages = pool.alloc(4)
    assert NULL_PAGE not in pages
    assert sorted(pages) == [1, 2, 3, 4]
    with pytest.raises(PoolExhausted):
        pool.alloc(1)
    pool.release(pages)
    assert pool.num_free == 4


def test_alloc_failure_has_no_side_effects():
    pool = PagePool(5, 8)
    pool.alloc(2)
    free_before = list(pool._free)
    rc_before = pool.refcount.copy()
    with pytest.raises(PoolExhausted):
        pool.alloc(3)
    assert pool._free == free_before
    np.testing.assert_array_equal(pool.refcount, rc_before)
    pool.check()


def test_double_free_raises():
    pool = PagePool(4, 8)
    (p,) = pool.alloc(1)
    pool.release([p])
    with pytest.raises(ValueError, match="double free"):
        pool.release([p])


def test_incref_dead_page_raises():
    pool = PagePool(4, 8)
    with pytest.raises(ValueError):
        pool.incref([1])
    with pytest.raises(ValueError):
        pool.incref([NULL_PAGE])


def test_refcounted_shared_page_freed_only_at_zero():
    pool = PagePool(6, 8)
    shared = pool.alloc(2)          # registry holds refcount 1
    pool.incref(shared)             # slot A admits
    pool.incref(shared)             # slot B admits
    pool.release(shared)            # A finishes
    assert pool.num_free == 3
    assert all(pool.refcount[p] == 2 for p in shared)
    pool.release(shared)            # B finishes
    assert pool.num_free == 3       # registry still pins them
    pool.release(shared)            # registry drops the prefix
    assert pool.num_free == 5
    pool.check()


def test_block_table_overflow_raises_and_leaves_table_intact():
    bt = BlockTables(2, 3)
    bt.append(0, [5, 6])
    with pytest.raises(PoolExhausted):
        bt.append(0, [7, 8])
    assert bt[0] == [5, 6]


def test_truncate_returns_tail_keeps_prefix():
    """The speculative-decode rollback primitive: pages leave the table
    back-to-front, so a shared prefix at the front is never touched."""
    bt = BlockTables(2, 6)
    bt.append(0, [7, 3, 9, 5])
    assert bt.truncate(0, 2) == [9, 5]
    assert bt[0] == [7, 3]
    assert bt.truncate(0, 2) == []          # idempotent at the boundary
    assert bt.truncate(0, 0) == [7, 3]
    assert bt[0] == []
    with pytest.raises(ValueError):
        bt.truncate(0, -1)


def test_device_image_null_padding_and_active_nulling():
    bt = BlockTables(3, 4)
    bt.append(0, [3, 1])
    bt.append(2, [2])
    img = bt.device()
    assert img.dtype == np.int32
    np.testing.assert_array_equal(img[0], [3, 1, NULL_PAGE, NULL_PAGE])
    np.testing.assert_array_equal(img[1], NULL_PAGE)
    np.testing.assert_array_equal(
        bt.device(active=[False, False, True])[0], NULL_PAGE)
    np.testing.assert_array_equal(
        bt.device(active=[False, False, True])[2], [2, 0, 0, 0])


# ---------------------------------------------------------------------------
# SwapStore: the host budget behind swap-vs-recompute
# ---------------------------------------------------------------------------

def test_swap_store_accounting_lifecycle():
    sw = SwapStore(budget_bytes=100)
    assert sw.fits(60)
    sw.put(1, "suspA", 60)
    assert 1 in sw and len(sw) == 1 and sw.used_bytes == 60
    assert not sw.fits(50)              # over budget -> recompute
    assert sw.refused == 1
    assert sw.fits(40)
    sw.put(2, "suspB", 40)
    # peek does NOT remove: resume may fail and retry later
    assert sw.peek(1) == "suspA" and sw.peek(1) == "suspA"
    assert sw.pop(1) == "suspA"
    assert sw.used_bytes == 40 and 1 not in sw
    sw.drop(2)                          # request cancelled while suspended
    assert sw.used_bytes == 0 and len(sw) == 0
    assert (sw.swapped_out, sw.swapped_in, sw.dropped) == (2, 1, 1)
    sw.check()


def test_swap_store_edges():
    sw = SwapStore()                    # unbounded: always fits
    assert sw.fits(10**12) and sw.refused == 0
    sw.put(7, object(), 5)
    with pytest.raises(ValueError, match="already swapped"):
        sw.put(7, object(), 5)
    with pytest.raises(KeyError):
        sw.pop(8)
    with pytest.raises(ValueError):
        SwapStore(budget_bytes=-1)


# ---------------------------------------------------------------------------
# the serving random walk (deterministic; mirrors the scheduler's use)
# ---------------------------------------------------------------------------

def _assert_invariants(pool: PagePool, bt: BlockTables, shared: set):
    pool.check()
    assert pool.num_free + pool.num_live == pool.capacity
    owners = bt.owners()
    assert NULL_PAGE not in owners, "null page inside a live block table"
    for page, slots in owners.items():
        assert pool.refcount[page] >= 1
        if pool.refcount[page] == 1:
            assert len(slots) == 1, \
                f"writable page {page} owned by slots {slots}"
        else:
            assert page in shared or len(slots) <= pool.refcount[page]


def _random_walk(seed: int, steps: int = 300):
    rng = np.random.default_rng(seed)
    slots, npp, ps = 4, 8, 8
    pool = PagePool(int(rng.integers(6, 20)), ps)
    bt = BlockTables(slots, npp)
    written = [0] * slots
    active = [False] * slots
    # one registered prefix, pinned by the registry for the whole walk
    try:
        prefix_pages = pool.alloc(min(2, pool.capacity))
    except PoolExhausted:
        prefix_pages = []
    shared = set(prefix_pages)
    holds_prefix = [False] * slots

    for _ in range(steps):
        op = rng.choice(["admit", "grow", "finish", "preempt"])
        s = int(rng.integers(slots))
        if op == "admit" and not active[s]:
            n_tok = int(rng.integers(1, npp * ps))
            use_prefix = bool(prefix_pages) and bool(rng.integers(2)) \
                and n_tok > len(prefix_pages) * ps
            base = prefix_pages if use_prefix else []
            try:
                fresh = pool.alloc(pages_needed(n_tok, ps) - len(base))
            except PoolExhausted:
                continue
            pool.incref(base)
            bt.append(s, list(base) + fresh)
            active[s], written[s] = True, n_tok
            holds_prefix[s] = use_prefix
        elif op == "grow" and active[s]:
            n = int(rng.integers(1, 2 * ps))
            need = pages_needed(written[s] + n, ps) - bt.num_pages(s)
            if need > 0:
                if bt.num_pages(s) + need > npp:
                    continue
                try:
                    bt.append(s, pool.alloc(need))
                except PoolExhausted:
                    continue
            written[s] += n
        elif op in ("finish", "preempt") and active[s]:
            pool.release(bt.drop(s))
            active[s], written[s] = False, 0
            holds_prefix[s] = False
        _assert_invariants(pool, bt, shared)

    for s in range(slots):
        if active[s]:
            pool.release(bt.drop(s))
    pool.release(prefix_pages)
    assert pool.num_live == 0
    assert pool.num_free == pool.capacity, "random walk leaked pages"
    pool.check()


@pytest.mark.parametrize("seed", range(8))
def test_random_admit_decode_finish_preempt_never_leaks(seed):
    _random_walk(seed)


# ---------------------------------------------------------------------------
# hypothesis property layer (skipped cleanly where hypothesis is missing;
# CI installs it via requirements-dev.txt)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    # deadline=None (shared CI machines make per-example timing flaky),
    # bounded max_examples so tier-1 stays fast
    FAST = settings(max_examples=40, deadline=None)

    @given(st.integers(1, 64), st.integers(0, 2048))
    @FAST
    def test_prop_pages_needed_covers(ps, n_tok):
        n = pages_needed(n_tok, ps)
        assert n * ps >= n_tok
        assert (n - 1) * ps < n_tok or n == 0

    @given(st.integers(3, 24), st.lists(st.integers(0, 6), max_size=24))
    @FAST
    def test_prop_alloc_release_conserves(num_pages, sizes):
        pool = PagePool(num_pages, 8)
        held = []
        for n in sizes:
            try:
                held.append(pool.alloc(n))
            except PoolExhausted:
                assert n > pool.num_free
            assert pool.num_free + pool.num_live == pool.capacity
            pool.check()
        for pages in held:
            pool.release(pages)
        assert pool.num_free == pool.capacity

    @given(st.data())
    @FAST
    def test_prop_serving_walk_invariants(data):
        """Arbitrary admit/grow/finish interleavings: no aliasing of
        writable pages, exact conservation, no leaks at the end."""
        slots, npp, ps = 3, 6, 4
        pool = PagePool(data.draw(st.integers(4, 16)), ps)
        bt = BlockTables(slots, npp)
        active = [False] * slots
        written = [0] * slots
        ops = data.draw(st.lists(
            st.tuples(st.sampled_from(["admit", "grow", "stop"]),
                      st.integers(0, slots - 1), st.integers(1, npp * ps)),
            max_size=40))
        for op, s, n_tok in ops:
            if op == "admit" and not active[s]:
                try:
                    bt.append(s, pool.alloc(pages_needed(n_tok, ps)))
                except PoolExhausted:
                    continue
                active[s], written[s] = True, n_tok
            elif op == "grow" and active[s]:
                need = pages_needed(written[s] + n_tok, ps) - bt.num_pages(s)
                if need > 0:
                    if bt.num_pages(s) + need > npp:
                        continue
                    try:
                        bt.append(s, pool.alloc(need))
                    except PoolExhausted:
                        continue
                written[s] += n_tok
            elif op == "stop" and active[s]:
                pool.release(bt.drop(s))
                active[s] = False
            _assert_invariants(pool, bt, set())
        for s in range(slots):
            if active[s]:
                pool.release(bt.drop(s))
        assert pool.num_free == pool.capacity
        pool.check()

    @given(st.integers(3, 12), st.lists(st.integers(1, 3), max_size=3),
           st.integers(1, 200), st.booleans())
    @FAST
    def test_prop_pool_exhausted_has_no_partial_effects(
            num_pages, pre, n_tok, grow):
        """The contract every eviction/retry path leans on: when an admit
        or growth allocation raises PoolExhausted — from the pool (too few
        free pages) or from the table (per-slot overflow, pages released
        by the caller as the engine does) — the free list, refcounts, and
        EVERY block table are exactly as before the attempt."""
        ps, cap_tab = 4, 4
        pool = PagePool(num_pages, ps)
        bt = BlockTables(2, cap_tab)
        for n in pre:                    # occupy slot 0 with fitting allocs
            if n <= pool.num_free and bt.num_pages(0) + n <= cap_tab:
                bt.append(0, pool.alloc(n))
        free0, rc0 = list(pool._free), pool.refcount.copy()
        tables0 = [list(t) for t in bt.tables]
        slot = 0 if grow else 1          # growth extends 0, admit fills 1
        try:
            pages = pool.alloc(pages_needed(n_tok, ps))
            try:
                bt.append(slot, pages)
            except PoolExhausted:
                pool.release(pages)      # the engine's cleanup on overflow
                raise
        except PoolExhausted:
            assert pool._free == free0
            np.testing.assert_array_equal(pool.refcount, rc0)
            assert [list(t) for t in bt.tables] == tables0
        pool.check()

    @given(st.integers(2, 5), st.integers(1, 4), st.integers(1, 4))
    @FAST
    def test_prop_shared_prefix_freed_at_refcount_zero(num_shared, a, b):
        pool = PagePool(num_shared + 4, 8)
        shared = pool.alloc(num_shared)
        for _ in range(a + b):
            pool.incref(shared)
        for i in range(a + b):
            pool.release(shared)
            assert all(pool.refcount[p] == a + b - i for p in shared)
        assert pool.num_free == pool.capacity - len(shared)
        pool.release(shared)          # the registry's own refcount
        assert pool.num_free == pool.capacity
        pool.check()
else:
    @pytest.mark.skip(reason="hypothesis not installed in this environment")
    def test_prop_hypothesis_layer():
        pass
