"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True).

The invariant under test is the paper's: coarsening (any kind x degree),
replication and vectorization redistribute work but never change results.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoarseningConfig
from repro.kernels import ops, ref
from repro.kernels import ew_stream as ew
from repro.kernels import gather_stream as gs

KEY = jax.random.PRNGKey(0)
CFGS = ["none", "con2", "con4", "con8", "gap2", "gap4", "gap8", "con2+simd2"]


def k(i):
    return jax.random.fold_in(KEY, i)


# ---------------------------------------------------------------------------
# ew_stream: variants x coarsening configs x shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ew.VARIANTS)
@pytest.mark.parametrize("spec", ["none", "con4", "gap4", "con8", "gap2+simd2"])
def test_ew_stream_variants(variant, spec):
    n, n_loads = 8192, 8
    inputs = [jax.random.normal(k(i), (n,), jnp.float32)
              for i in range(n_loads)]
    expected = ref.ew_stream(inputs, ai=6, variant=variant)
    got = ops.ew_stream(tuple(inputs), CoarseningConfig.parse(spec),
                        ai=6, variant=variant, block=512)
    np.testing.assert_allclose(got, expected, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("spec", ["pipe2", "pipe4", "con4+pipe2",
                                  "gap2+pipe4"])
def test_ew_stream_pipeline_replication(spec):
    """Replication (num_compute_units analog) must not change results, even
    combined with coarsening; gids must be replication-aware."""
    n = 8192
    inputs = [jax.random.normal(k(i + 900), (n,)) for i in range(4)]
    for variant in ("base", "if_id"):
        expected = ref.ew_stream(inputs, ai=6, variant=variant)
        got = ops.ew_stream(tuple(inputs), CoarseningConfig.parse(spec),
                            ai=6, variant=variant, block=512)
        np.testing.assert_allclose(got, expected, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,block", [(2048, 128), (16384, 1024)])
@pytest.mark.parametrize("ai", [1, 6, 10])
def test_ew_stream_shapes_ai(n, block, ai):
    inputs = [jax.random.normal(k(i + 50), (n,)) for i in range(4)]
    expected = ref.ew_stream(inputs, ai=ai)
    for spec in ["con4", "gap4"]:
        got = ops.ew_stream(tuple(inputs), CoarseningConfig.parse(spec),
                            ai=ai, block=block)
        np.testing.assert_allclose(got, expected, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# gather_stream (irregular)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", CFGS)
@pytest.mark.parametrize("window", [64, 2048])
def test_gather_stream(spec, window):
    n, table = 4096, 2048
    idx = jnp.asarray(gs.make_indices(n, table, window, seed=3))
    tables = tuple(jax.random.normal(k(i + 100), (table,)) for i in range(4))
    expected = ref.gather_stream(tables, idx, ai=6)
    got = ops.gather_stream(idx, tables, CoarseningConfig.parse(spec),
                            ai=6, block=256)
    np.testing.assert_allclose(got, expected, rtol=1e-5, atol=1e-5)


def test_make_indices_locality():
    idx = gs.make_indices(4096, 4096, 64, seed=0)
    # every 64-run stays within a 64-wide window
    for blk in range(0, 4096, 64):
        run = idx[blk:blk + 64]
        assert run.max() - run.min() < 64 or (run.max() - run.min()) > 4000


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["none", "con2", "con4", "gap2", "gap4",
                                  "con2+simd2"])
@pytest.mark.parametrize("mnk", [(256, 256, 256), (512, 384, 256)])
def test_matmul(spec, mnk):
    m, n, kk = mnk
    a = jax.random.normal(k(200), (m, kk))
    b = jax.random.normal(k(201), (kk, n))
    got = ops.matmul(a, b, CoarseningConfig.parse(spec), bm=32, bn=64, bk=128)
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    a = jax.random.normal(k(210), (256, 256), dtype)
    b = jax.random.normal(k(211), (256, 256), dtype)
    got = ops.matmul(a, b, CoarseningConfig.parse("con2"), bm=64, bn=128, bk=128)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        got, ref.matmul(a, b), rtol=tol, atol=tol * 8)


# ---------------------------------------------------------------------------
# stencil / scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["none", "con2", "con4", "gap2", "gap4"])
def test_stencil(spec):
    x = jax.random.normal(k(300), (128, 256))
    got = ops.stencil5(x, CoarseningConfig.parse(spec), block_rows=8)
    np.testing.assert_allclose(got, ref.stencil5(x), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("spec", ["none", "con2", "con4"])
def test_dp_scan(spec):
    cost = jax.random.uniform(k(400), (64, 256))
    got = ops.dp_scan(cost, CoarseningConfig.parse(spec))
    np.testing.assert_allclose(got, ref.dp_scan(cost), rtol=1e-5, atol=1e-5)


def test_dp_scan_rejects_gapped():
    with pytest.raises(ValueError):
        ops.dp_scan(jnp.ones((8, 256)), CoarseningConfig.parse("gap2"))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["none", "con2", "con4", "gap2", "gap4"])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 128)])
def test_flash_attention(spec, causal, window):
    b, h, hkv, s, d = 2, 4, 2, 512, 64
    q = jax.random.normal(k(500), (b, h, s, d)) * 0.5
    kk = jax.random.normal(k(501), (b, hkv, s, d)) * 0.5
    v = jax.random.normal(k(502), (b, hkv, s, d))
    expected = ref.attention(q, kk, v, causal=causal, window=window)
    got = ops.flash_attention(q, kk, v, CoarseningConfig.parse(spec),
                              bq=64, bkv=64, causal=causal, window=window)
    np.testing.assert_allclose(got, expected, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("hkv", [1, 4])
def test_flash_attention_gqa(hkv):
    b, h, s, d = 1, 4, 256, 32
    q = jax.random.normal(k(510), (b, h, s, d)) * 0.5
    kk = jax.random.normal(k(511), (b, hkv, s, d)) * 0.5
    v = jax.random.normal(k(512), (b, hkv, s, d))
    got = ops.flash_attention(q, kk, v, CoarseningConfig.parse("con2"),
                              bq=64, bkv=64)
    np.testing.assert_allclose(got, ref.attention(q, kk, v),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssd / rglru
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["none", "con2", "con4"])
def test_ssd_consecutive(spec):
    b, h, g, s, p, n = 2, 8, 2, 256, 32, 16
    x = jax.random.normal(k(600), (b, h, s, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(k(601), (b, h, s))) * 0.1
    a = -jnp.exp(jax.random.normal(k(602), (h,)) * 0.3)
    bm = jax.random.normal(k(603), (b, g, s, n)) * 0.3
    cm = jax.random.normal(k(604), (b, g, s, n)) * 0.3
    expected = ops.ssd(x, dt, a, bm, cm, backend="ref")
    got = ops.ssd(x, dt, a, bm, cm, CoarseningConfig.parse(spec), chunk=64)
    np.testing.assert_allclose(got, expected, rtol=2e-3, atol=2e-3)


def test_ssd_gapped_groups1():
    b, h, s, p, n = 2, 8, 128, 32, 16
    x = jax.random.normal(k(610), (b, h, s, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(k(611), (b, h, s))) * 0.1
    a = -jnp.exp(jax.random.normal(k(612), (h,)) * 0.3)
    bm = jax.random.normal(k(613), (b, 1, s, n)) * 0.3
    cm = jax.random.normal(k(614), (b, 1, s, n)) * 0.3
    expected = ops.ssd(x, dt, a, bm, cm, backend="ref")
    got = ops.ssd(x, dt, a, bm, cm, CoarseningConfig.parse("gap4"), chunk=64)
    np.testing.assert_allclose(got, expected, rtol=2e-3, atol=2e-3)


def test_ssd_gapped_rejects_multigroup():
    with pytest.raises(ValueError):
        ops.ssd(jnp.ones((1, 8, 128, 16)), jnp.ones((1, 8, 128)),
                -jnp.ones((8,)), jnp.ones((1, 2, 128, 8)),
                jnp.ones((1, 2, 128, 8)), CoarseningConfig.parse("gap2"))


def test_ssd_chunked_matches_naive():
    b, s, h, p, g, n = 2, 128, 4, 16, 1, 8
    x = jax.random.normal(k(620), (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(k(621), (b, s, h))) * 0.1
    a = -jnp.exp(jax.random.normal(k(622), (h,)) * 0.3)
    bm = jax.random.normal(k(623), (b, s, g, n)) * 0.3
    cm = jax.random.normal(k(624), (b, s, g, n)) * 0.3
    np.testing.assert_allclose(ref.ssd_chunked(x, dt, a, bm, cm, chunk=32),
                               ref.ssd(x, dt, a, bm, cm),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("spec", ["none", "con2", "con4", "gap2", "gap4"])
@pytest.mark.parametrize("window,block", [(1024, 256), (512, 512)])
def test_windowed_gather(spec, window, block):
    """Scalar-prefetch windowed gather (the true LSU-cache implementation:
    data-dependent 2L-wide window DMA per slice) matches the oracle."""
    from repro.kernels import windowed_gather as wg
    n, table = 1 << 13, 1 << 13
    idx = jnp.asarray(gs.make_indices(n, table, window, seed=7))
    tbl = jax.random.normal(k(850), (table,))
    fn = wg.make_kernel(n, table, CoarseningConfig.parse(spec),
                        window=window, block=block)
    np.testing.assert_allclose(fn(idx, tbl), wg.ref(idx, tbl),
                               rtol=1e-5, atol=1e-5)


def test_windowed_gather_rejects_bad_geometry():
    from repro.kernels import windowed_gather as wg
    with pytest.raises(ValueError):
        wg.make_kernel(1 << 12, 1 << 12, CoarseningConfig(), window=100,
                       block=256)


@pytest.mark.parametrize("spec", ["none", "con2", "con4", "con8",
                                  "gap2", "gap4", "gap8"])
def test_embed_gather(spec):
    from repro.kernels.embed_gather import ref_embed_gather
    n, vocab, d = 2048, 512, 64
    ids = jax.random.randint(k(800), (n,), 0, vocab)
    table = jax.random.normal(k(801), (vocab, d))
    got = ops.embed_gather(ids, table, CoarseningConfig.parse(spec),
                           block=128)
    np.testing.assert_allclose(got, ref_embed_gather(ids, table),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("spec", ["none", "con2", "con4", "gap2", "gap4"])
def test_rglru(spec):
    b, s, d = 2, 128, 512
    x = jax.random.normal(k(700), (b, s, d))
    r = jax.random.normal(k(701), (b, s, d))
    i = jax.random.normal(k(702), (b, s, d))
    ap = jax.random.normal(k(703), (d,))
    got = ops.rglru(x, r, i, ap, CoarseningConfig.parse(spec),
                    block_d=64, block_t=32)
    np.testing.assert_allclose(got, ref.rglru(x, r, i, ap),
                               rtol=1e-4, atol=1e-4)
