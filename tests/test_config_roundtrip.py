"""CoarseningConfig.parse round-trips and plan_stream/plan_rows invariants
(pure unit tests — no hypothesis dependency, unlike the property suite)."""
import pytest

from repro.core import (CoarseningConfig, plan_stream, KIND_NONE,
                        KIND_CONSECUTIVE, KIND_GAPPED)
from repro.core.coarsening import plan_rows, row_starts


# ---------------------------------------------------------------------------
# parse <-> label round-trip
# ---------------------------------------------------------------------------

ALL_CFGS = [
    CoarseningConfig(kind, degree, repl, vw)
    for kind in (KIND_NONE, KIND_CONSECUTIVE, KIND_GAPPED)
    for degree in ((1,) if kind == KIND_NONE else (2, 4, 8))
    for repl in (1, 2, 4)
    for vw in (1, 2)
]


@pytest.mark.parametrize("cfg", ALL_CFGS, ids=lambda c: c.label)
def test_parse_label_roundtrip(cfg):
    assert CoarseningConfig.parse(cfg.label) == cfg


@pytest.mark.parametrize("spec,want", [
    ("none", CoarseningConfig()),
    ("base", CoarseningConfig()),
    ("con4", CoarseningConfig(KIND_CONSECUTIVE, 4)),
    ("gap8", CoarseningConfig(KIND_GAPPED, 8)),
    ("consecutive:4", CoarseningConfig(KIND_CONSECUTIVE, 4)),
    ("gapped:2", CoarseningConfig(KIND_GAPPED, 2)),
    ("con4+pipe2", CoarseningConfig(KIND_CONSECUTIVE, 4, 2, 1)),
    ("con4+pipe2+simd2", CoarseningConfig(KIND_CONSECUTIVE, 4, 2, 2)),
    ("gap2,pipe4", CoarseningConfig(KIND_GAPPED, 2, 4, 1)),
    ("pipe2+simd4", CoarseningConfig(KIND_NONE, 1, 2, 4)),
])
def test_parse_spellings(spec, want):
    assert CoarseningConfig.parse(spec) == want


def test_parse_rejects_garbage():
    with pytest.raises(ValueError):
        CoarseningConfig.parse("warp4")


def test_degree1_normalises_to_none():
    assert CoarseningConfig(KIND_CONSECUTIVE, 1).kind == KIND_NONE
    assert CoarseningConfig(KIND_NONE, 7).degree == 1


# ---------------------------------------------------------------------------
# plan_stream invariants
# ---------------------------------------------------------------------------

PLAN_CASES = [
    (1 << 16, "none", 1024), (1 << 16, "con4", 1024), (1 << 16, "gap4", 1024),
    (1 << 16, "con8", 512), (1 << 16, "gap8", 512),
    (1 << 16, "con2+simd2", 1024), (1 << 16, "gap2+simd2", 1024),
    (1 << 14, "con4+pipe2", 256), (3 << 12, "con2", 512),
]


@pytest.mark.parametrize("n,spec,block", PLAN_CASES,
                         ids=[f"{s}-b{b}" for _, s, b in PLAN_CASES])
def test_plan_stream_invariants(n, spec, block):
    cfg = CoarseningConfig.parse(spec)
    plan = plan_stream(n, cfg, block=block)
    # every element is covered exactly once
    assert plan.grid * cfg.degree * plan.block == n
    # the DMA descriptors per operand cover exactly one program's tile
    assert plan.dmas_per_operand * plan.dma_elems == cfg.degree * plan.block
    # SIMD widens the effective block
    assert plan.block == block * cfg.vector_width
    # view/block shapes agree with the kind's distribution
    assert plan.view_shape[plan.block_shape.index(1)] == plan.grid
    assert plan.contiguous == (cfg.kind != KIND_GAPPED)
    assert plan.dmas_per_operand == (1 if plan.contiguous else cfg.degree)


def test_plan_stream_rejects_indivisible():
    with pytest.raises(ValueError):
        plan_stream(1000, CoarseningConfig.parse("con4"), block=1024)
    with pytest.raises(ValueError):
        plan_stream(1 << 12, CoarseningConfig.parse("simd2"), block=1 << 12)


# ---------------------------------------------------------------------------
# plan_rows invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["none", "con2", "con4", "gap2", "gap4"])
def test_plan_rows_partitions_rows(spec):
    rows, block_rows = 256, 8
    cfg = CoarseningConfig.parse(spec)
    plan = plan_rows(rows, cfg, block_rows)
    assert plan.grid * plan.fused_rows == rows
    assert plan.dmas_per_operand == (1 if plan.contiguous else cfg.degree)
    # the per-program start blocks tile [0, rows/block_rows) exactly once
    seen = sorted(s for i in range(plan.grid)
                  for s in row_starts(plan, i))
    assert seen == list(range(rows // block_rows))
