"""Distribution tests.  Multi-device cases run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test session
keeps seeing exactly 1 device (per the assignment)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import (param_specs, param_shardings,
                                        batch_specs, cache_specs)
from repro.launch.steps import StepConfig, build_train_step, abstract_params

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# sharding rules (single device; pure spec logic)
# ---------------------------------------------------------------------------

def test_param_specs_cover_all_archs():
    from jax.sharding import PartitionSpec as P
    for arch in ("qwen3-0.6b", "olmoe-1b-7b", "mamba2-370m",
                 "recurrentgemma-2b", "seamless-m4t-large-v2"):
        cfg = get_config(arch)
        abstract = abstract_params(cfg)
        specs = param_specs(abstract)
        leaves_a = jax.tree.leaves(abstract)
        leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_a) == len(leaves_s)
        for a, s in zip(leaves_a, leaves_s):
            assert len(s) <= a.ndim


def test_param_specs_drop_indivisible_dims():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import _leaf_rule

    class Key:
        def __init__(self, k):
            self.key = k

    class Leaf:
        ndim = 2
        shape = (60, 1024)                      # 60 % 16 != 0

    rule = _leaf_rule((Key("embed"),), Leaf, {"data": 16, "model": 16})
    assert rule[0] is None                      # indivisible dim dropped
    assert rule[1] == "data"                    # divisible dim kept

    # padded vocab shards cleanly for every arch (vocab_padded % 256 == 0)
    cfg = get_config("seamless-m4t-large-v2")   # raw vocab 256206 % 16 != 0
    assert cfg.vocab_padded % 16 == 0
    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)
    specs = param_specs(abstract_params(cfg), FakeMesh)
    assert specs["embed"] == P("model", "data")  # shardable after padding


def test_2d_fsdp_tp_rules():
    from jax.sharding import PartitionSpec as P
    cfg = get_config("qwen3-0.6b")
    specs = param_specs(abstract_params(cfg))
    blk = specs["blocks"][0]
    assert blk["attn"]["wq"] == P(None, "data", "model")   # stacked + 2D
    assert blk["attn"]["wo"] == P(None, "model", "data")
    assert blk["ffn"]["w2"] == P(None, "model", "data")
    assert specs["final_norm"]["scale"] == P(None)


def test_moe_expert_parallel_rules():
    from jax.sharding import PartitionSpec as P
    cfg = get_config("olmoe-1b-7b")
    specs = param_specs(abstract_params(cfg))
    blk = specs["blocks"][0]
    assert blk["moe"]["w1"] == P(None, "model", "data", None)  # EP on model


# ---------------------------------------------------------------------------
# multi-device correctness (subprocess, 8 fake devices)
# ---------------------------------------------------------------------------

def test_sharded_train_step_matches_single_device():
    """Loss + grads identical (up to fp tolerance) on mesh (4,2) vs (1,1)."""
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.steps import StepConfig, build_train_step
        from repro.launch.mesh import make_production_mesh
        from repro.models import model as M
        from repro.optim import adamw_init

        cfg = get_config("qwen3-0.6b").reduced()
        losses = {}
        for shape in [(1, 1), (4, 2)]:
            mesh = jax.make_mesh(shape, ("data", "model"))
            sc = StepConfig(seq=32, batch=8, kind="train", n_micro=2,
                            remat="full")
            fn, _, in_sh, out_sh = build_train_step(cfg, mesh, sc)
            with mesh:
                params = jax.jit(lambda k: M.lm_init(k, cfg),
                                 out_shardings=in_sh[0])(jax.random.PRNGKey(0))
                opt = jax.jit(adamw_init, out_shardings=in_sh[1])(params)
                tok = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                         cfg.vocab)
                batch = {"tokens": tok, "labels": tok}
                batch = jax.tree.map(jax.device_put, batch, in_sh[2])
                p2, o2, loss, gn = jax.jit(
                    fn, in_shardings=in_sh, out_shardings=out_sh)(
                    params, opt, batch)
                losses[shape] = (float(loss), float(gn))
        a, b = losses[(1, 1)], losses[(4, 2)]
        assert abs(a[0] - b[0]) < 2e-2, (a, b)
        assert abs(a[1] - b[1]) / max(a[1], 1e-6) < 5e-2, (a, b)
        print("OK", losses)
    """)


def test_pipeline_parallel_matches_sequential():
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply

        S = 4
        mesh = jax.make_mesh((S,), ("stage",))
        d = 16
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (S, d, d)) * 0.3

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        x = jax.random.normal(jax.random.fold_in(key, 1), (8, d))
        want = x
        for i in range(S):
            want = stage_fn(ws[i], want)
        got = pipeline_apply(stage_fn, ws, x, mesh=mesh, n_micro=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        print("pipeline OK")
    """, devices=4)


def test_pipeline_parallel_gradients():
    """Gradients must flow through the ppermute pipeline (training-capable
    PP, not just inference)."""
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply

        S, d = 4, 8
        mesh = jax.make_mesh((S,), ("stage",))
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (S, d, d)) * 0.3
        x = jax.random.normal(jax.random.fold_in(key, 1), (8, d))

        def stage_fn(w, xx):
            return jnp.tanh(xx @ w)

        def loss_pipe(ws):
            y = pipeline_apply(stage_fn, ws, x, mesh=mesh, n_micro=4)
            return jnp.sum(y ** 2)

        def loss_seq(ws):
            y = x
            for i in range(S):
                y = stage_fn(ws[i], y)
            return jnp.sum(y ** 2)

        g1 = jax.grad(loss_pipe)(ws)
        g2 = jax.grad(loss_seq)(ws)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-5)
        print("pipeline grad OK")
    """, devices=4)


def test_bucketed_psum_matches_pertensor():
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.collectives import bucketed_psum, pertensor_psum

        mesh = jax.make_mesh((8,), ("data",))
        key = jax.random.PRNGKey(0)
        grads = {f"p{i}": jax.random.normal(jax.random.fold_in(key, i),
                                            (33, 7)) for i in range(11)}
        a = bucketed_psum(grads, mesh=mesh, bucket_bytes=4096)
        b = pertensor_psum(grads, mesh=mesh)
        jax.tree.map(lambda x, y: np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=1e-6), a, b)
        print("bucketed == pertensor OK")
    """)


def test_moe_shardmap_matches_reference():
    """The shard_map EP dispatch must equal the single-device dispatch when
    capacity admits every token (no drops)."""
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import layers as L
        from repro.models.layers import NOSHARD
        from repro.distributed.sharding import make_shard_ctx

        cfg = get_config("olmoe-1b-7b").reduced()
        key = jax.random.PRNGKey(0)
        p = L.moe_init(key, cfg)
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))

        y_ref, aux_ref = L.moe(p, x, cfg, capacity=32, shard=NOSHARD)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shard = make_shard_ctx(mesh)
        with mesh:
            y_sm, aux_sm = jax.jit(
                lambda p, x: L.moe(p, x, cfg, capacity=32, shard=shard)
            )(p, x)
        np.testing.assert_allclose(np.asarray(y_sm, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=2e-2, atol=2e-2)
        # aux is estimated per dp shard then averaged (standard at scale):
        # close to, not identical to, the global statistic
        np.testing.assert_allclose(float(aux_sm), float(aux_ref), rtol=0.3)

        # bf16 EP combine (§Perf C8) stays close to the f32 combine
        import dataclasses
        cfg16 = dataclasses.replace(cfg, moe_combine_dtype="bfloat16")
        with mesh:
            y16, _ = jax.jit(
                lambda p, x: L.moe(p, x, cfg16, capacity=32, shard=shard)
            )(p, x)
        np.testing.assert_allclose(np.asarray(y16, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=5e-2, atol=5e-2)
        # gradients flow through the shard_map dispatch
        g = jax.jit(jax.grad(lambda p, x: L.moe(p, x, cfg, capacity=32,
                                                shard=shard)[0].sum()))(p, x)
        gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0
        print("moe shard_map OK", float(aux_sm))
    """)


def test_int8_ef_psum_close_to_exact():
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.collectives import int8_ef_psum, pertensor_psum

        mesh = jax.make_mesh((8,), ("data",))
        key = jax.random.PRNGKey(0)
        grads = {"w": jax.random.normal(key, (64, 32)),
                 "b": jax.random.normal(jax.random.fold_in(key, 1), (17,))}
        exact = pertensor_psum(grads, mesh=mesh)
        approx, resid = int8_ef_psum(grads, None, mesh=mesh)
        for k in grads:
            a, e = np.asarray(approx[k]), np.asarray(exact[k])
            rel = np.abs(a - e).max() / (np.abs(e).max() + 1e-9)
            assert rel < 0.05, (k, rel)          # int8 quantization error
        # residual carries the error (EF): |resid| <= scale/2
        print("int8 psum OK")
    """)


def test_elastic_restart_across_meshes():
    """Train 2 steps on mesh (2,2), checkpoint, resume on (8,1): loss
    continues from the same state (elastic re-mesh)."""
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from repro.configs import get_config
        from repro.launch.train import train

        cfg = get_config("qwen3-0.6b").reduced()
        d = tempfile.mkdtemp()
        m1 = jax.make_mesh((2, 2), ("data", "model"))
        l1, _ = train(cfg, steps=3, batch=8, seq=32, ckpt_dir=d,
                      save_every=100, mesh=m1, log_every=100)
        m2 = jax.make_mesh((8, 1), ("data", "model"))
        l2, _ = train(cfg, steps=5, batch=8, seq=32, ckpt_dir=d,
                      save_every=100, mesh=m2, log_every=100)
        assert len(l2) == 2, (len(l1), len(l2))   # resumed at step 3
        assert l2[0] < l1[0] + 0.5                # continued, not restarted
        print("elastic OK", l1, l2)
    """)
