"""Speculative decoding suite (repro.serve.spec).

The acceptance gate: greedy spec-decode outputs are BITWISE identical to
non-spec greedy decode on the same prompts — under forced rejection (a
fresh random draft proposes garbage, every step takes the correction
path), under a cooperative self-draft (the acceptance upper bound), and
under pool pressure that forces preemption mid-request.  The tie guard +
decode-graph rescue (module docstring of spec.py) is what makes this hold
on XLA CPU, where the T-row verify graph and the 1-row decode graph lower
with different reduction orders.

Plus the paged-rollback bookkeeping: worst-case K+1 page growth at
admission (`step_growth_bound`), truncate-based rollback conserving pages,
and a hypothesis walk over accept/reject counts pinning the pool and
block-table invariants the engine's decode step relies on.
"""
import jax
import numpy as np
import pytest

from repro.serve import (BlockTables, PagePool, Request, Scheduler,
                         SpecPagedEngine, draft_of, pages_needed)


@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _trace(cfg):
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, int(n))))
               for n in (9, 17, 5, 24, 12)]
    gens = [12, 6, 1, 16, 9]
    return prompts, gens


def _run(make, prompts, gens):
    eng = make()
    sched = Scheduler(eng)
    for p, g in zip(prompts, gens):
        sched.submit(p, g)
    done = sched.run_until_done()
    assert eng.pool.num_live == 0 and not eng.active.any(), "leaked pages"
    eng.pool.check()
    return eng, [r.output for r in done], done


KW = dict(slots=3, num_pages=40, page_size=8, max_len=64, chunk=8)


def _base_outputs(cfg, params, prompts, gens, **kw):
    from repro.serve import PagedEngine
    kw = {**KW, **kw}
    _, out, _ = _run(lambda: PagedEngine(cfg, params, decode_block=4, **kw),
                     prompts, gens)
    return out


# ---------------------------------------------------------------------------
# bitwise parity with non-spec decode
# ---------------------------------------------------------------------------

def test_parity_under_forced_rejection(tiny_model):
    """A fresh random draft agrees with the target only by chance, so ~every
    step rejects at row 0 and emits the target's own correction — the
    worst case for the rollback path and the rescue pass."""
    cfg, params = tiny_model
    prompts, gens = _trace(cfg)
    base = _base_outputs(cfg, params, prompts, gens)
    eng, out, _ = _run(
        lambda: SpecPagedEngine(cfg, params, spec_k=4,
                                rng=jax.random.PRNGKey(7), **KW),
        prompts, gens)
    assert out == base
    assert eng.acceptance_rate < 0.3          # the draft really is garbage
    assert eng.spec_steps > 0


def test_parity_and_multi_token_steps_with_self_draft(tiny_model):
    """Target as its own draft: every proposal the tie guard clears is
    accepted, so steps emit >1 token on average — and outputs still match
    the base engine bitwise."""
    cfg, params = tiny_model
    prompts, gens = _trace(cfg)
    base = _base_outputs(cfg, params, prompts, gens)
    eng, out, _ = _run(
        lambda: SpecPagedEngine(cfg, params, spec_k=4, draft_cfg=cfg,
                                draft_params=params, **KW),
        prompts, gens)
    assert out == base
    assert eng.acceptance_rate > 0.2
    assert eng.decoded_tokens / eng.spec_steps > 1.2


def test_parity_under_preemption(tiny_model):
    """A pool small enough to force preemption: rollback, requeue, and
    re-prefill (target AND draft caches) still land on the base outputs."""
    cfg, params = tiny_model
    rng = np.random.default_rng(5)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, 6)))
               for _ in range(3)]
    gens = [18] * 3
    kw = dict(slots=3, num_pages=8, page_size=8, max_len=32, chunk=8)
    base = _base_outputs(cfg, params, prompts, gens, **kw)
    eng, out, done = _run(
        lambda: SpecPagedEngine(cfg, params, spec_k=4,
                                rng=jax.random.PRNGKey(7), **kw),
        prompts, gens)
    assert sum(r.preemptions for r in done) > 0, \
        "pool failed to force preemption — weaken num_pages"
    assert out == base


# ---------------------------------------------------------------------------
# robustness: suspend/resume + fault injection on the spec engine
# ---------------------------------------------------------------------------

def test_spec_suspend_resume_bitwise_releases_target_and_draft(tiny_model):
    """Host-swap of a speculating slot: suspend must free EVERY page the
    slot held — target and draft caches share the one block table, so the
    pool draining to zero proves both — and resume (into a different slot)
    must continue the accepted stream bitwise with no re-prefill of
    either model."""
    cfg, params = tiny_model
    rng = np.random.default_rng(11)
    prompt = list(map(int, rng.integers(1, cfg.vocab, 9)))
    gen = 14
    mk = lambda: SpecPagedEngine(cfg, params, spec_k=4,
                                 rng=jax.random.PRNGKey(7), **KW)

    ref_eng = mk()
    req = Request(rid=0, prompt=prompt, gen=gen)
    ref = [ref_eng.admit(0, req)]
    while len(ref) < gen:
        ref.extend(ref_eng.decode([0])[0])
    ref = ref[:gen]

    eng = mk()
    req = Request(rid=0, prompt=prompt, gen=gen)
    out = [eng.admit(0, req)]
    prefills = eng.prefill_steps
    out.extend(eng.decode([0])[0])
    susp = eng.suspend(0)
    assert eng.pool.num_live == 0, "suspend leaked target or draft pages"
    eng.pool.check()
    eng.resume(1, susp)
    while len(out) < gen:
        out.extend(eng.decode([1])[1])
    assert out[:gen] == ref, "suspend/resume changed the spec stream"
    assert eng.prefill_steps == prefills == ref_eng.prefill_steps
    eng.finish(1)
    assert eng.pool.num_live == 0
    eng.pool.check()


def test_spec_nan_poisoned_verify_rows_fall_back_bitwise(tiny_model):
    """NaN rows injected into the host-side verify logits must fail the
    clear-guard (finite check) and take the same decode-graph rescue as a
    tie — outputs stay bitwise equal to the clean spec run."""
    from repro.serve import FaultPlan, FaultyEngine
    cfg, params = tiny_model
    prompts, gens = _trace(cfg)
    mk = lambda: SpecPagedEngine(cfg, params, spec_k=4,
                                 rng=jax.random.PRNGKey(7), **KW)
    _, ref, _ = _run(mk, prompts, gens)

    plan = FaultPlan(5, p_nan=0.05)
    eng = mk()
    sched = Scheduler(FaultyEngine(eng, plan))
    for p, g in zip(prompts, gens):
        sched.submit(p, g)
    done = sched.run_until_done()
    assert plan.nan_rows > 0 and eng.nan_rows > 0, \
        "trace failed to poison a verify row"
    assert [r.output for r in done] == ref
    assert eng.pool.num_live == 0
    eng.pool.check()


# ---------------------------------------------------------------------------
# construction + accounting
# ---------------------------------------------------------------------------

def test_draft_of_shrinks_but_shares_vocab(tiny_model):
    cfg, _ = tiny_model
    d = draft_of(cfg)
    assert d.vocab == cfg.vocab
    assert d.n_layers <= cfg.n_layers and d.d_model <= cfg.d_model


def test_vocab_mismatch_rejected(tiny_model):
    import dataclasses
    cfg, params = tiny_model
    bad = dataclasses.replace(draft_of(cfg), vocab=cfg.vocab // 2)
    with pytest.raises(ValueError, match="vocab"):
        SpecPagedEngine(cfg, params, spec_k=2, draft_cfg=bad, **KW)


def test_spec_k_validated(tiny_model):
    cfg, params = tiny_model
    with pytest.raises(ValueError, match="spec_k"):
        SpecPagedEngine(cfg, params, spec_k=0, **KW)


def test_step_growth_bound_accounts_k_plus_1_rows(tiny_model):
    """The scheduler's admission headroom hook: a verify step may append
    K+1 rows per running slot, and an incoming request additionally needs
    its prompt pages plus its own first step's growth."""
    cfg, params = tiny_model
    eng = SpecPagedEngine(cfg, params, spec_k=4,
                          rng=jax.random.PRNGKey(7), **KW)
    ps = eng.page_size
    req = Request(rid=0, prompt=[1] * 9, gen=12)
    eng.admit(0, req)
    written = int(eng.written[0])
    want = max(0, pages_needed(written + 5, ps) - eng.bt.num_pages(0))
    assert eng.step_growth_bound() == want
    incoming = Request(rid=1, prompt=[1] * 11, gen=8)
    assert eng.step_growth_bound(incoming) == \
        want + pages_needed(11 + 5, ps)
    eng.pool.release(eng.bt.drop(0))
    eng.pool.check()


# ---------------------------------------------------------------------------
# hypothesis: accept/reject walks conserve pages exactly
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    FAST = settings(max_examples=60, deadline=None)

    @given(st.integers(1, 17), st.integers(1, 8),
           st.lists(st.integers(0, 9), min_size=1, max_size=40))
    @FAST
    def test_prop_spec_walk_conserves_pages(prompt_len, spec_k, accepts):
        """The decode step's exact page dance, abstracted from the model:
        grow to the worst case (written + K + 1 rows), emit 1..K+1 tokens,
        truncate back to the accepted rows.  After every step the pool
        conserves (free + live == capacity) and the block table holds
        EXACTLY pages_needed(written) pages — the invariant the verify
        kernel's pos-masking relies on."""
        ps = 4
        pool = PagePool(64, ps)
        bt = BlockTables(1, 64)
        written = prompt_len
        bt.append(0, pool.alloc(pages_needed(written, ps)))
        for acc in accepts:
            need = pages_needed(written + spec_k + 1, ps) - bt.num_pages(0)
            if need > 0:
                bt.append(0, pool.alloc(need))
            emitted = min(acc, spec_k) + 1          # correction or bonus
            written += emitted
            pool.release(bt.truncate(0, pages_needed(written, ps)))
            assert pool.num_free + pool.num_live == pool.capacity
            assert bt.num_pages(0) == pages_needed(written, ps)
            pool.check()
        pool.release(bt.drop(0))
        assert pool.num_free == pool.capacity, "spec walk leaked pages"
        pool.check()

    @given(st.integers(0, 5), st.integers(2, 6))
    @FAST
    def test_prop_truncate_keeps_prefix_returns_tail(n_keep, n_total):
        bt = BlockTables(1, 8)
        pages = list(range(3, 3 + n_total))
        bt.append(0, pages)
        tail = bt.truncate(0, n_keep)
        assert bt[0] == pages[:min(n_keep, n_total)]
        assert tail == pages[min(n_keep, n_total):]
else:
    @pytest.mark.skip(reason="hypothesis not installed in this environment")
    def test_prop_hypothesis_layer():
        pass
