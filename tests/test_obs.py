"""Observability suite: the repro.obs registry + trace recorder, and their
wiring through the serving stack.

Layer 1 — the instruments alone: counter/gauge/histogram semantics, the
Prometheus text exposition, the JSON snapshot, the disabled recorder's
zero-allocation fast path, span nesting per track, ring-buffer drops, and
the Chrome trace-event schema (``validate_chrome`` accepts what
``to_chrome`` emits and rejects malformed blobs).

Layer 2 — a FakeEngine (with suspend/resume so the swap path traces) under
forced preemption and a seeded FaultPlan: every submitted request's trace
track starts at QUEUED and ends at exactly ONE terminal state, and the
trace ``signature()`` (the wall-clock-free projection) replays bit-equal
for the same seeds.

Layer 3 — thin-view parity: the legacy counter attributes on SwapStore,
FaultPlan and TuningCache are views over registry counters and can never
drift from them; plus one real-PagedEngine acceptance run (reduced qwen3,
undersized pool, fault injection, trace enabled) pinning the --trace-out
contract: complete lifecycles, a schema-valid Perfetto-loadable export,
and registry values bitwise equal to the engine's legacy attributes."""
import json
import tracemalloc

import numpy as np
import pytest

from repro.obs import (ENGINE_TRACK, QUANTA_BUCKETS, REQ_TRACK_BASE,
                       SCHED_TRACK, TERMINAL_STATES, Counter, Gauge,
                       Histogram, NULL_TRACER, Registry, TraceRecorder,
                       validate_chrome)
from repro.serve import (BlockTables, FaultPlan, FaultyEngine, PagePool,
                         PoolExhausted, Request, Scheduler, State,
                         SwapStore, pages_needed)


# ---------------------------------------------------------------------------
# layer 1: instruments
# ---------------------------------------------------------------------------

def test_counter_semantics():
    reg = Registry()
    c = reg.counter("reqs_total")
    c.inc()
    c.inc(41)
    assert c.value == 42 and isinstance(c.value, int)
    with pytest.raises(ValueError):
        c.inc(-1)
    # get-or-create: same (name, labels) is the same instrument
    assert reg.counter("reqs_total") is c
    assert reg.counter("reqs_total", state="ok") is not c
    assert reg.value("reqs_total") == 42


def test_gauge_watermarks():
    g = Registry().gauge("free_pages")
    for v in (7, 2, 9, 4):
        g.set(v)
    assert g.value == 4
    assert g.lo == 2 and g.hi == 9      # lifetime water marks survive sets
    g.inc(3)
    g.dec(1)
    assert g.value == 6


def test_histogram_buckets_and_quantile():
    h = Histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.6, 3.0, 100.0):
        h.observe(v)
    assert h.counts == [1, 2, 1, 1]      # last = +inf overflow
    assert h.count == 5 and h.sum == pytest.approx(106.6)
    assert h.quantile(0.5) == 2.0
    assert h.quantile(1.0) == 4.0        # +inf clamps to last finite bound
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(2.0, 1.0))


def test_registry_type_mismatch_and_value_default():
    reg = Registry()
    reg.counter("x_total")
    with pytest.raises(TypeError):
        reg.gauge("x_total")
    with pytest.raises(KeyError):
        reg.value("nope")
    assert reg.value("nope", default=0) == 0
    assert "x_total" in reg and len(reg) == 1


def test_prometheus_exposition():
    reg = Registry()
    reg.counter("req_total", "served requests", state="ok").inc(3)
    reg.gauge("pool_free").set(5)
    reg.gauge("pool_free").set(2)
    h = reg.histogram("wait_q", QUANTA_BUCKETS)
    h.observe(0)
    h.observe(3)
    text = reg.to_prometheus()
    assert "# TYPE req_total counter" in text
    assert "# HELP req_total served requests" in text
    assert 'req_total{state="ok"} 3' in text
    assert "pool_free 2" in text
    assert "pool_free_lo 2" in text and "pool_free_hi 5" in text
    # cumulative buckets + the implicit +Inf
    assert 'wait_q_bucket{le="0.0"} 1' in text
    assert 'wait_q_bucket{le="4.0"} 2' in text
    assert 'wait_q_bucket{le="+Inf"} 2' in text
    assert "wait_q_count 2" in text


def test_snapshot_is_jsonable():
    reg = Registry()
    reg.counter("c_total").inc()
    reg.gauge("g").set(1.5)
    reg.histogram("h", (1.0, 2.0)).observe(0.5)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["counters"]["c_total"] == 1
    assert snap["gauges"]["g"]["value"] == 1.5
    assert snap["histograms"]["h"]["count"] == 1
    assert "p50" in snap["histograms"]["h"]
    # untouched gauge watermarks serialize as null, not Infinity
    reg2 = Registry()
    reg2.gauge("never_set")
    assert json.loads(json.dumps(reg2.snapshot()))["gauges"][
        "never_set"]["lo"] is None


# ---------------------------------------------------------------------------
# layer 1: trace recorder
# ---------------------------------------------------------------------------

def test_disabled_recorder_allocates_nothing():
    """20k disabled calls must allocate no per-call memory: the traced
    peak stays under a small constant (interpreter/pytest-internal noise —
    method caches, GC bookkeeping — lands in the ~1 KiB range regardless
    of call count; one tuple-per-call would be >1 MiB here) and nothing is
    retained in the buffer."""
    rec = TraceRecorder(capacity=8, enabled=False)
    assert not rec and not NULL_TRACER
    # warm up attribute/bytecode caches before measuring
    rec.event("w")
    rec.begin("w")
    rec.end()
    with rec.span("w"):
        pass
    rec.lifecycle(0, "QUEUED")
    tracemalloc.start()
    i = 0
    while i < 20000:    # small ints are interned: the loop itself is free
        rec.event("e")
        rec.begin("b")
        rec.end()
        with rec.span("s"):
            pass
        rec.lifecycle(1, "FINISHED")
        i += 1
    current, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak < 16384, f"disabled recorder allocated {peak} bytes peak"
    assert current < 16384, f"disabled recorder retained {current} bytes"
    assert len(rec) == 0


def test_span_nesting_per_track_and_quantum_stamp():
    rec = TraceRecorder(clock=iter(range(1000)).__next__)
    rec.quantum = 3
    rec.begin("outer", tid=0)
    rec.quantum = 4
    rec.event("inner.mark", tid=0)
    rec.begin("inner", tid=0)
    rec.begin("other-track", tid=1)     # stacks are independent per tid
    rec.end(1)
    rec.end(0)                          # closes inner
    rec.end(0)                          # closes outer
    evs = rec.events()
    by_name = {e["name"]: e for e in evs}
    assert by_name["outer"]["q"] == 3       # span keeps its OPENING quantum
    assert by_name["inner"]["q"] == 4
    assert by_name["inner.mark"]["q"] == 4
    # nesting: inner closed before outer, both complete events
    assert by_name["inner"]["ph"] == by_name["outer"]["ph"] == "X"
    assert by_name["outer"]["dur"] >= by_name["inner"]["dur"]
    with pytest.raises(RuntimeError):
        rec.end(0)                      # nothing left open on this track


def test_ring_buffer_drops_oldest():
    rec = TraceRecorder(capacity=4)
    for i in range(10):
        rec.event(f"e{i}")
    assert len(rec) == 4
    assert rec.dropped == 6
    assert [e["name"] for e in rec.events()] == ["e6", "e7", "e8", "e9"]


def test_chrome_export_schema_and_validation():
    rec = TraceRecorder()
    rec.quantum = 1
    rec.lifecycle(3, "QUEUED", {"prompt": 5, "gen": 2})
    with rec.span("decode.block", "engine", ENGINE_TRACK, {"n": 4}):
        pass
    with rec.span("sched.quantum", "sched", SCHED_TRACK):
        pass
    blob = json.loads(json.dumps(rec.to_chrome()))   # full JSON round trip
    validate_chrome(blob)
    names = {e["tid"]: e["args"]["name"] for e in blob["traceEvents"]
             if e["ph"] == "M"}
    assert names[ENGINE_TRACK] == "engine"
    assert names[SCHED_TRACK] == "scheduler"
    assert names[REQ_TRACK_BASE + 3] == "req 3"
    inst = [e for e in blob["traceEvents"] if e["ph"] == "i"]
    assert inst and all(e["s"] == "t" and "q" in e["args"] for e in inst)
    # rejections
    for bad in (
        [],                                              # not an object
        {"traceEvents": {}},                             # not a list
        {"traceEvents": [{"name": "x", "ph": "Z", "pid": 1, "tid": 0}]},
        {"traceEvents": [{"name": "x", "ph": "i", "pid": 1, "tid": 0,
                          "ts": -1.0, "args": {"q": 0}}]},
        {"traceEvents": [{"name": "x", "ph": "i", "pid": 1, "tid": 0,
                          "ts": 0.0, "args": {}}]},      # missing q
    ):
        with pytest.raises(ValueError):
            validate_chrome(bad)


# ---------------------------------------------------------------------------
# layer 2: lifecycle completeness + deterministic replay on a fake engine
# ---------------------------------------------------------------------------

class _Susp:
    """Fake suspension: enough state to restore the slot, plus the nbytes
    the SwapStore accounts."""

    def __init__(self, req, written, emitted):
        self.req, self.written, self.emitted = req, written, emitted
        self.n_tokens = written
        self.nbytes = written * 4


class SwappableFakeEngine:
    """Engine-protocol fake over a real PagePool, with deterministic tokens
    (token j of request r is ``(r.rid * 1009 + j) % 65521``) and the
    suspend/resume extension so the scheduler's swap path traces."""

    def __init__(self, *, slots=3, num_pages=10, page_size=4, max_len=64,
                 decode_block=4):
        self.slots = slots
        self.page_size = page_size
        self.max_len = max_len
        self.decode_block = decode_block
        self.pool = PagePool(num_pages, page_size)
        self.pool_capacity = self.pool.capacity
        self.bt = BlockTables(slots, pages_needed(max_len, page_size))
        self.state: dict[int, list] = {}  # slot -> [req, written, emitted]

    @staticmethod
    def tok(req: Request, j: int) -> int:
        return (req.rid * 1009 + j) % 65521

    def admit(self, slot, req):
        assert slot not in self.state
        pages = self.pool.alloc(pages_needed(len(req.prompt),
                                             self.page_size))
        self.bt.append(slot, pages)
        self.state[slot] = [req, len(req.prompt), 1]
        return self.tok(req, 0)

    def decode(self, slots):
        slots = [s for s in slots if s in self.state]
        if not slots:
            return {}
        n = max(1, min([self.decode_block]
                       + [st[0].gen - st[2] for st in
                          (self.state[s] for s in slots)]))
        for s in slots:
            req, written, _ = self.state[s]
            need = pages_needed(written + n, self.page_size) \
                - self.bt.num_pages(s)
            if need > 0:
                self.bt.append(s, self.pool.alloc(need))
        out = {}
        for s in slots:
            st = self.state[s]
            out[s] = [self.tok(st[0], st[2] + k) for k in range(n)]
            st[1] += n
            st[2] += n
        return out

    def _drop(self, slot):
        self.pool.release(self.bt.drop(slot))
        del self.state[slot]

    def finish(self, slot):
        self._drop(slot)

    def preempt(self, slot):
        self._drop(slot)

    # -- the swap extension --------------------------------------------------

    def suspend_bytes(self, slot) -> int:
        return self.state[slot][1] * 4

    def suspend(self, slot) -> _Susp:
        req, written, emitted = self.state[slot]
        self._drop(slot)
        return _Susp(req, written, emitted)

    def resume(self, slot, susp: _Susp) -> None:
        assert slot not in self.state
        pages = self.pool.alloc(pages_needed(susp.written, self.page_size))
        self.bt.append(slot, pages)
        self.state[slot] = [susp.req, susp.written, susp.emitted]


def _run_faulty_trace(seed: int):
    """An undersized pool + a seeded FaultPlan, fully traced; returns the
    (scheduler, trace, registry, done) tuple."""
    reg = Registry()
    trace = TraceRecorder()
    eng = SwappableFakeEngine(slots=3, num_pages=9, page_size=4, max_len=48)
    plan = FaultPlan(seed, p_admit=0.15, p_growth=0.1, p_transient=0.1,
                     metrics=reg, trace=trace)
    sched = Scheduler(FaultyEngine(eng, plan), host_swap_bytes=None,
                      metrics=reg, trace=trace)
    rng = np.random.default_rng(seed)
    for _ in range(9):
        gen = int(rng.integers(4, 20))
        plen = int(rng.integers(2, 12))
        sched.submit([int(t) for t in rng.integers(1, 1000, plen)], gen)
    done = sched.run_until_done()
    assert eng.pool.num_live == 0
    eng.pool.check()
    return sched, trace, reg, done


@pytest.mark.parametrize("seed", [0, 3])
def test_every_request_reaches_exactly_one_terminal_state(seed):
    sched, trace, reg, done = _run_faulty_trace(seed)
    tracks: dict[int, list[str]] = {}
    for name, ph, cat, tid, q, args in trace.signature():
        if tid >= REQ_TRACK_BASE:
            tracks.setdefault(tid - REQ_TRACK_BASE, []).append(name)
    # every submitted request has a track, starting QUEUED, ending at its
    # single terminal transition — no request vanishes, none dies twice
    assert set(tracks) == {r.rid for r in done}
    for rid, names in tracks.items():
        assert names[0] == "QUEUED", (rid, names)
        terminal = [n for n in names if n in TERMINAL_STATES]
        assert len(terminal) == 1, (rid, names)
        assert names[-1] == terminal[0], (rid, names)
    # the pool pressure + swap budget actually exercised the paths the
    # trace claims to cover
    flat = [n for names in tracks.values() for n in names]
    assert "SUSPENDED" in flat and "RESUMED" in flat
    assert int(reg.value("sched_preemptions_total")) > 0
    # terminal counters agree with the trace
    for s, n in ((s, int(reg.value("sched_requests_total", state=s.value)))
                 for s in (State.FINISHED, State.FAILED)):
        assert n == sum(1 for names in tracks.values()
                        if names[-1] == s.name)


def test_trace_signature_replays_deterministically():
    _, t1, r1, d1 = _run_faulty_trace(5)
    _, t2, r2, d2 = _run_faulty_trace(5)
    assert t1.signature() == t2.signature()
    assert [r.output for r in d1] == [r.output for r in d2]
    assert r1.snapshot()["counters"] == r2.snapshot()["counters"]
    # sanity: a different seed produces a different fault/evict history
    _, t3, _, _ = _run_faulty_trace(6)
    assert t1.signature() != t3.signature()


def test_scheduler_quantum_clock_on_every_event():
    _, trace, _, _ = _run_faulty_trace(0)
    sig = trace.signature()
    qs = [q for _, _, _, _, q, _ in sig]
    assert max(qs) > 1                       # the logical clock advanced
    assert all(isinstance(q, int) and q >= 0 for q in qs)
    # quantum spans land on the scheduler track, one per step, q strictly
    # increasing (each span keeps the quantum it opened under)
    sched_q = [q for name, ph, _, tid, q, _ in sig
               if tid == SCHED_TRACK and name == "sched.quantum"]
    assert sched_q == sorted(sched_q)
    assert len(set(sched_q)) == len(sched_q)


# ---------------------------------------------------------------------------
# layer 3: thin-view parity + the real-engine acceptance run
# ---------------------------------------------------------------------------

def test_swapstore_views_are_registry_counters():
    reg = Registry()
    sw = SwapStore(budget_bytes=100, metrics=reg)
    sw.put(1, "susp", 60)
    assert not sw.fits(60)                   # 60 + 60 > 100: refused
    sw.pop(1)
    sw.put(2, "susp", 30)
    sw.drop(2)
    assert sw.swapped_out == int(reg.value("swap_out_total")) == 2
    assert sw.swapped_in == int(reg.value("swap_in_total")) == 1
    assert sw.dropped == int(reg.value("swap_dropped_total")) == 1
    assert sw.refused == int(reg.value("swap_refused_total")) == 1
    assert sw.used_bytes == int(reg.value("swap_used_bytes")) == 0
    assert isinstance(sw.used_bytes, int)    # byte accounting stays exact


def test_faultplan_views_are_registry_counters():
    reg = Registry()
    plan = FaultPlan(0, p_admit=1.0, p_nan=1.0, metrics=reg)
    with pytest.raises(PoolExhausted):
        plan.on_admit()
    lg = np.zeros((4, 8), np.float32)
    plan.corrupt_logits(lg, "decode")
    st = plan.stats()
    assert st["admit_faults"] == int(reg.value("fault_admit_total")) == 1
    assert st["nan_rows"] == int(reg.value("fault_nan_rows_total")) == 4
    assert plan.total == 5


def test_tuningcache_stats_are_registry_counters(tmp_path):
    from repro.core.coarsening import CoarseningConfig
    from repro.tune import KernelSpec, TuningCache
    reg = Registry()
    cache = TuningCache(path=str(tmp_path / "t.json"), autoload=False,
                        metrics=reg)
    spec = KernelSpec.make("ew_stream", (4096,), block=256)
    assert cache.get(spec) is None
    cache.put(spec, CoarseningConfig(), modeled_s=1e-3, persist=False)
    assert cache.get(spec) is not None
    assert cache.stats == {"hits": 1, "misses": 1}
    assert int(reg.value("tune_cache_hits_total")) == 1
    assert int(reg.value("tune_cache_misses_total")) == 1


def test_real_engine_traced_fault_run(tmp_path):
    """The --trace-out acceptance pin: a fault-injected serve run on the
    real PagedEngine produces a schema-valid Chrome trace with complete
    request lifecycles and engine spans, and the registry's numbers are
    bitwise the legacy engine attributes."""
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.serve import PagedEngine

    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    reg = Registry()
    trace = TraceRecorder()
    eng = PagedEngine(cfg, params, slots=2, num_pages=8, page_size=8,
                      max_len=32, chunk=8, decode_block=4, metrics=reg,
                      trace=trace)
    plan = FaultPlan(7, p_transient=0.1, p_nan=0.05, metrics=reg,
                     trace=trace)
    sched = Scheduler(FaultyEngine(eng, plan), metrics=reg, trace=trace)
    rng = np.random.default_rng(0)
    for _ in range(3):
        sched.submit([int(t) for t in rng.integers(1, cfg.vocab, 6)], 8)
    done = sched.run_until_done()
    assert len(done) == 3
    assert all(r.state is State.FINISHED for r in done)
    eng.pool.check()

    # registry <-> legacy-attribute parity, bitwise
    assert int(reg.value("engine_prefill_steps_total")) == eng.prefill_steps
    assert int(reg.value("engine_decode_steps_total")) == eng.decode_steps
    assert int(reg.value("engine_prefill_tokens_total")) \
        == eng.prefill_tokens
    assert int(reg.value("engine_decode_tokens_total")) \
        == eng.decoded_tokens
    assert int(reg.value("engine_nan_rescues_total")) == eng.nan_rescues
    assert int(reg.value("sched_decode_faults_total")) == sched.decode_faults
    # device timers exist and are bounded by something sane
    assert eng.prefill_device_s > 0 and eng.decode_device_s > 0

    # lifecycle completeness on the real stack
    tracks: dict[int, list[str]] = {}
    for name, ph, cat, tid, q, args in trace.signature():
        if tid >= REQ_TRACK_BASE:
            tracks.setdefault(tid - REQ_TRACK_BASE, []).append(name)
    assert set(tracks) == {0, 1, 2}
    for names in tracks.values():
        assert names[0] == "QUEUED" and names[-1] == "FINISHED"
        assert sum(n in TERMINAL_STATES for n in names) == 1

    # engine spans made it onto the engine/slot tracks
    span_names = {name for name, ph, *_ in trace.signature() if ph == "X"}
    assert "prefill.chunk" in span_names
    assert "decode.block" in span_names
    assert "sched.quantum" in span_names

    # the dumped file is a valid, Perfetto-loadable Chrome trace
    out = tmp_path / "TRACE_serve.json"
    trace.dump(str(out))
    validate_chrome(json.loads(out.read_text()))
