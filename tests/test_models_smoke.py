"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step + one decode step on CPU; asserts shapes and finiteness.
The FULL configs are exercised only via the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    tok = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    if cfg.is_encdec:
        batch["src_frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, 16, cfg.d_model)) * 0.1
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (b, s, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = M.lm_init(KEY, cfg)
    batch = _batch(cfg)

    hidden, aux = jax.jit(lambda p, b: M.lm_apply(p, b, cfg))(params, batch)
    assert hidden.shape == (2, 32, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()

    ocfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, b):
        (loss, m), g = jax.value_and_grad(
            lambda pp: M.lm_loss(pp, b, cfg), has_aux=True)(p)
        p2, o2, gn = adamw_update(p, g, o, ocfg)
        return p2, o2, loss, gn

    p2, o2, loss, gn = step(params, opt, batch)
    assert np.isfinite(float(loss)) and np.isfinite(float(gn))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, p2)
    assert max(jax.tree.leaves(moved)) > 0

    # loss decreases over a few steps on the structured synthetic stream
    l0 = float(loss)
    b2 = batch
    p, o = p2, o2
    for _ in range(3):
        p, o, loss, _ = step(p, o, b2)
    assert float(loss) < l0 + 0.5       # no explosion


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = M.lm_init(KEY, cfg)
    b, s_max = 2, 64
    cache = M.lm_init_cache(cfg, b, s_max, enc_len=16)
    if cfg.is_encdec:
        # provide encoder kv (stub: zeros is fine for shape/finite checks)
        pass
    tok = jnp.ones((b, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, po: M.lm_decode_step(p, c, t, po, cfg))
    logits, cache = step(params, cache, tok, jnp.zeros((b,), jnp.int32))
    assert logits.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    logits2, cache = step(params, cache, tok, jnp.ones((b,), jnp.int32))
    assert np.isfinite(np.asarray(logits2)).all()


def test_decode_matches_forward_qwen3():
    """Teacher-forced decode must reproduce the parallel forward logits."""
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(KEY, cfg)
    b, s = 1, 8
    tok = jax.random.randint(jax.random.PRNGKey(9), (b, s), 0, cfg.vocab)
    hidden, _ = M.lm_apply(params, {"tokens": tok}, cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    full_logits = np.asarray((hidden @ head.astype(hidden.dtype))
                             .astype(jnp.float32))

    cache = M.lm_init_cache(cfg, b, s)
    step = jax.jit(lambda p, c, t, po: M.lm_decode_step(p, c, t, po, cfg))
    for t in range(s):
        logits, cache = step(params, cache, tok[:, t:t + 1],
                             jnp.full((b,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits), full_logits[:, t],
                                   rtol=3e-2, atol=3e-2)


def test_decode_matches_forward_ssm():
    """Same property for the recurrent family (state correctness)."""
    cfg = get_config("mamba2-370m").reduced()
    params = M.lm_init(KEY, cfg)
    b, s = 1, 8
    tok = jax.random.randint(jax.random.PRNGKey(9), (b, s), 0, cfg.vocab)
    hidden, _ = M.lm_apply(params, {"tokens": tok}, cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    full_logits = np.asarray((hidden @ head.astype(hidden.dtype))
                             .astype(jnp.float32))
    cache = M.lm_init_cache(cfg, b, s)
    step = jax.jit(lambda p, c, t, po: M.lm_decode_step(p, c, t, po, cfg))
    for t in range(s):
        logits, cache = step(params, cache, tok[:, t:t + 1],
                             jnp.full((b,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits), full_logits[:, t],
                                   rtol=3e-2, atol=3e-2)


def test_mrope_positions_change_output():
    cfg = get_config("qwen2-vl-7b").reduced()
    params = M.lm_init(KEY, cfg)
    b, s = 1, 16
    tok = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, cfg.vocab)
    pos_text = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pos3_a = jnp.stack([pos_text] * 3, axis=1)     # (B,3,S)
    pos3_b = pos3_a.at[:, 1].add(5)      # different spatial positions
    ha, _ = M.lm_apply(params, {"tokens": tok, "pos3": pos3_a}, cfg)
    hb, _ = M.lm_apply(params, {"tokens": tok, "pos3": pos3_b}, cfg)
    assert float(jnp.max(jnp.abs(ha - hb))) > 1e-4


@pytest.mark.parametrize("arch", ["gemma3-1b", "recurrentgemma-2b"])
def test_windowed_arch_sparse_backend_matches_ref(arch,
                                                  scratch_default_cache):
    """The windowed architectures default to attn_sparse="auto": under
    attn_backend="pallas" their local-attention prefill routes the
    block-sparse live-index kernel, which must track the ref path at bf16
    tolerance; attn_sparse="off" (dense-mask kernel) must agree too, and
    attn_global_stride must actually change the pattern."""
    import dataclasses
    from repro.tune.cache import default_cache
    base = get_config(arch).reduced()
    assert base.attn_sparse == "auto" and base.window
    params = M.lm_init(KEY, base)
    tok = jax.random.randint(jax.random.PRNGKey(6), (1, 32), 0, base.vocab)
    want, _ = M.lm_apply(params, {"tokens": tok},
                         dataclasses.replace(base, attn_backend="ref"))
    want = np.asarray(want, np.float32)
    for sparse in ("auto", "off"):
        cfg = dataclasses.replace(base, attn_backend="pallas",
                                  attn_sparse=sparse)
        got, _ = M.lm_apply(params, {"tokens": tok}, cfg)
        d = float(np.abs(np.asarray(got, np.float32) - want).max())
        assert d < 0.25, (sparse, d)
    fams = {key.split("|", 1)[0] for key in default_cache().entries}
    assert "flash_attention_sparse" in fams
    gcfg = dataclasses.replace(base, attn_backend="pallas",
                               attn_global_stride=8)
    hg, _ = M.lm_apply(params, {"tokens": tok}, gcfg)
    assert float(np.abs(np.asarray(hg, np.float32) - want).max()) > 1e-5


def test_local_vs_global_attention_differs():
    cfg = get_config("gemma3-1b").reduced(window=4)
    params = M.lm_init(KEY, cfg)
    tok = jax.random.randint(jax.random.PRNGKey(5), (1, 32), 0, cfg.vocab)
    h1, _ = M.lm_apply(params, {"tokens": tok}, cfg)
    cfg_g = cfg.reduced(window=32)       # window = seq -> effectively global
    h2, _ = M.lm_apply(params, {"tokens": tok}, cfg_g)
    assert float(jnp.max(jnp.abs(h1 - h2))) > 1e-5
