"""Deterministic fault injection: the executable robustness claims.

The acceptance gate from the robustness PR: under a seeded FaultPlan
(forced PoolExhausted at admit and page growth, transient decode faults,
NaN-poisoned logit rows), every request that completes must produce a
greedy stream BITWISE identical to the fault-free run, and the pool must
drain clean (free + live == capacity).  Faults either raise before any
state change (admit/decode sites) or are rescued by re-running the same
jitted graph (NaN site) — so the only observable difference is scheduling.
"""
import jax
import numpy as np
import pytest

from repro.serve import (FaultPlan, FaultyEngine, PagedEngine, Request,
                         Scheduler, State)
from tests.test_scheduler import FakeEngine


@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, **over):
    kw = dict(slots=3, num_pages=10, page_size=8, max_len=32, chunk=8,
              decode_block=4)
    kw.update(over)
    return PagedEngine(cfg, params, **kw)


def _run(engine, prompts, gen, **sched_kw):
    sched = Scheduler(engine, **sched_kw)
    for p in prompts:
        sched.submit(p, gen)
    done = sched.run_until_done()
    return sched, {r.rid: r.output for r in done
                   if r.state is State.FINISHED}


def test_faulty_trace_is_bitwise_identical_to_fault_free(tiny_model):
    cfg, params = tiny_model
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, 6)))
               for _ in range(4)]
    _, ref = _run(_engine(cfg, params), prompts, 10)
    assert len(ref) == 4

    plan = FaultPlan(7, p_admit=0.7, p_growth=0.2, p_transient=0.15,
                     p_nan=0.03)
    eng = _engine(cfg, params)
    sched, out = _run(FaultyEngine(eng, plan), prompts, 10)
    # the trace must actually exercise every fault site
    assert plan.admit_faults > 0, plan.stats()
    assert plan.growth_faults > 0, plan.stats()
    assert plan.transient_faults > 0, plan.stats()
    assert plan.nan_rows > 0, plan.stats()
    assert eng.nan_rescues > 0 and sched.decode_faults > 0
    assert out == ref, "injected faults changed a completed output"
    assert eng.pool.num_free + eng.pool.num_live == eng.pool.capacity
    assert eng.pool.num_live == 0
    eng.pool.check()


def test_nan_poison_alone_is_rescued_bitwise(tiny_model):
    """Only the NaN site armed: the guard re-runs the SAME jitted decode
    block (idempotent cache rewrite), so the emitted tokens are those of
    the clean run — the spec.py rescue idiom at the base-engine level."""
    cfg, params = tiny_model
    rng = np.random.default_rng(1)
    prompt = list(map(int, rng.integers(1, cfg.vocab, 8)))

    ref_eng = _engine(cfg, params, num_pages=16)
    req = Request(rid=0, prompt=prompt, gen=12)
    ref = [ref_eng.admit(0, req)]
    while len(ref) < 12:
        ref.extend(ref_eng.decode([0])[0])

    # hot enough to fire across a 12-token run, cool enough that a rescue
    # re-run is unlikely to be re-poisoned 5 times in a row
    plan = FaultPlan(3, p_nan=0.15)
    eng = _engine(cfg, params, num_pages=16)
    FaultyEngine(eng, plan)                # arms engine.fault_hook
    req = Request(rid=0, prompt=prompt, gen=12)
    out = [eng.admit(0, req)]
    while len(out) < 12:
        out.extend(eng.decode([0])[0])
    assert plan.nan_rows > 0 and eng.nan_rescues > 0
    assert out[:12] == ref[:12]


def test_persistent_nan_becomes_decode_fault_then_loud_failure(tiny_model):
    """A NaN that never clears exhausts the in-engine rescue budget
    (DecodeFault), and a DecodeFault that never clears exhausts the
    scheduler's retry budget — a loud RuntimeError, not a hang."""
    cfg, params = tiny_model
    plan = FaultPlan(0, p_nan=1.0, max_faults=None)
    eng = _engine(cfg, params, num_pages=16)
    sched = Scheduler(FaultyEngine(eng, plan), max_decode_faults=2)
    sched.submit([3, 1, 4, 1, 5], 8)
    with pytest.raises(RuntimeError, match="not transient"):
        sched.run_until_done()
    assert sched.decode_faults == 3        # initial + 2 retries


def test_injected_admit_faults_never_leak_pages():
    """Fake-engine sweep: heavy admit-site injection across seeds — every
    request reaches a terminal state, completed ones carry the exact solo
    stream, and the pool drains clean regardless of the fault trace."""
    for seed in range(5):
        plan = FaultPlan(seed, p_admit=0.4, p_growth=0.2, p_transient=0.2)
        eng = FakeEngine(slots=2, num_pages=10, page_size=4)
        sched = Scheduler(FaultyEngine(eng, plan))
        rng = np.random.default_rng(seed)
        for _ in range(6):
            gen = int(rng.integers(2, 10))
            sched.submit([int(t) for t in rng.integers(1, 100, 4)], gen)
        done = sched.run_until_done()
        assert len(done) == 6 and all(r.done for r in done)
        for r in done:
            if r.state is State.FINISHED:
                assert r.output == FakeEngine.expected(r)
        assert eng.pool.num_free + eng.pool.num_live == eng.pool.capacity
        assert eng.pool.num_live == 0
        eng.pool.check()
