"""End-to-end behaviour tests for the full system."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.train import train
from repro.launch.serve import BatchedServer
from repro.models import model as M


def test_training_reduces_loss(tmp_path):
    cfg = get_config("qwen3-0.6b").reduced()
    losses, _ = train(cfg, steps=25, batch=8, seq=64,
                      ckpt_dir=str(tmp_path), save_every=1000, log_every=1000)
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_resume_is_exact(tmp_path):
    """Stop/resume must reproduce the uninterrupted run's losses."""
    cfg = get_config("qwen3-0.6b").reduced()
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    full, _ = train(cfg, steps=8, batch=4, seq=32, ckpt_dir=d1,
                    save_every=4, log_every=1000, seed=3)
    train(cfg, steps=4, batch=4, seq=32, ckpt_dir=d2,
          save_every=4, log_every=1000, seed=3)
    part2, _ = train(cfg, steps=8, batch=4, seq=32, ckpt_dir=d2,
                     save_every=4, log_every=1000, seed=3)
    np.testing.assert_allclose(full[4:], part2, rtol=1e-4, atol=1e-4)


def test_training_survives_injected_failure():
    cfg = get_config("qwen3-0.6b").reduced()
    losses, _ = train(cfg, steps=6, batch=4, seq=32, ckpt_dir=None,
                      log_every=1000, fail_at_step=3)
    assert len(losses) == 6          # retry absorbed the simulated failure


def test_batched_server_matches_unbatched_decode():
    """Continuous batching must produce the same greedy tokens as plain
    one-sequence-at-a-time decoding."""
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, 5))) for _ in range(3)]
    gen = 6

    step = jax.jit(lambda p, c, t, po: M.lm_decode_step(p, c, t, po, cfg))
    want = []
    for prompt in prompts:
        cache = M.lm_init_cache(cfg, 1, 64)
        out: list[int] = []
        t = 0
        while len(out) < gen:
            cur = prompt[t] if t < len(prompt) else out[-1]
            logits, cache = step(params, cache,
                                 jnp.asarray([[cur]], jnp.int32),
                                 jnp.asarray([t], jnp.int32))
            if t >= len(prompt) - 1:
                out.append(int(jnp.argmax(logits[0])))
            t += 1
        want.append(out)

    server = BatchedServer(cfg, params, slots=2, max_len=64)
    pending = list(prompts)
    while pending or server.any_active:
        while pending and server.try_admit(pending[0], gen):
            pending.pop(0)
        if not server.any_active:
            break
        server.step()
    got = sorted(tuple(o[:gen]) for o in server.completed)
    assert got == sorted(tuple(w) for w in want), (got, want)
