"""Block-sparse coarsened flash attention (kernels/sparse_attention.py):
builder exactness as hypothesis properties, kernel parity vs the dense-mask
oracle across patterns x coarsening kinds x degrees x GQA, NULL-slot
immunity on poisoned/permuted synthetic indices, the long-context
visit-reduction gate, and the ops-level dispatch + custom-VJP grads."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoarseningConfig, KIND_CONSECUTIVE, KIND_GAPPED
from repro.kernels import ops
from repro.kernels import sparse_attention as SA

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                      # container without dev extras
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(11)
B, H, HKV, S, D = 1, 4, 2, 256, 16
BQ = BKV = 32

CONFIGS = [CoarseningConfig(),
           CoarseningConfig(KIND_CONSECUTIVE, 2),
           CoarseningConfig(KIND_CONSECUTIVE, 8),
           CoarseningConfig(KIND_GAPPED, 2),
           CoarseningConfig(KIND_GAPPED, 8)]

PATTERNS = {
    "causal": dict(causal=True, window=None, global_stride=None),
    "window": dict(causal=True, window=64, global_stride=None),
    "window+gstride": dict(causal=True, window=64, global_stride=96),
    "noncausal": dict(causal=False, window=None, global_stride=None),
}


def _qkv(key=KEY, b=B, h=H, hkv=HKV, s=S, d=D, sk=None):
    ks = jax.random.split(key, 3)
    sk = sk or s
    return (jax.random.normal(ks[0], (b, h, s, d), jnp.float32),
            jax.random.normal(ks[1], (b, hkv, sk, d), jnp.float32),
            jax.random.normal(ks[2], (b, hkv, sk, d), jnp.float32))


# ---------------------------------------------------------------------------
# builder properties: the closed-form block liveness is EXACT
# ---------------------------------------------------------------------------

def _index_from_element_mask(sq, sk, bq, bkv, pat, pad_multiple=8):
    """Oracle index: brute-force elementwise mask -> block liveness."""
    em = np.asarray(SA._element_mask(np.arange(sq)[:, None],
                                     np.arange(sk)[None, :], **pat))
    nq, nk = sq // bq, sk // bkv
    bl = em.reshape(nq, bq, nk, bkv).any(axis=(1, 3))
    return [np.nonzero(bl[i])[0] for i in range(nq)]


@pytest.mark.parametrize("pat", PATTERNS.values(), ids=PATTERNS.keys())
def test_builder_matches_brute_force(pat):
    idx = SA.build_block_index(S, S, BQ, BKV, **pat)
    want = _index_from_element_mask(S, S, BQ, BKV, pat)
    for i, row in enumerate(want):
        got = idx[i][idx[i] >= 0]
        np.testing.assert_array_equal(got, row)


if HAVE_HYPOTHESIS:
    _geoms = st.tuples(
        st.integers(1, 6), st.integers(1, 6),           # nq, nk blocks
        st.sampled_from([8, 16, 32]),                   # bq
        st.sampled_from([8, 16, 32]),                   # bkv
        st.booleans(),                                  # causal
        st.one_of(st.none(), st.integers(1, 128)),      # window
        st.one_of(st.none(), st.integers(1, 96)),       # global_stride
    )

    @settings(max_examples=80, deadline=None)
    @given(g=_geoms)
    def test_builder_properties(g):
        """Every live (q, k) pair's block listed exactly once, no dead block
        ever listed, NULL padding is a contiguous tail, and the padded width
        divides by every tuner degree."""
        nq, nk, bq, bkv, causal, window, gstride = g
        sq, sk = nq * bq, nk * bkv
        pat = dict(causal=causal, window=window,
                   global_stride=gstride if window else None)
        idx = SA.build_block_index(sq, sk, bq, bkv, **pat)
        want = _index_from_element_mask(sq, sk, bq, bkv, pat)
        assert idx.shape[0] == nq and idx.dtype == np.int32
        # degree-divisibility legality for the whole tuner degree set
        assert idx.shape[1] % 8 == 0
        for i in range(nq):
            row = idx[i]
            live = row[row >= 0]
            # exact liveness: coverage (every live block listed) AND no dead
            # block (nothing extra), each exactly once and ascending
            np.testing.assert_array_equal(live, want[i])
            assert len(np.unique(live)) == len(live)
            # NULL padding is a contiguous tail of NULL_BLOCK only
            tail = row[len(live):]
            assert (tail == SA.NULL_BLOCK).all()
            assert (live < nk).all() and (live >= 0).all()


def test_builder_rejects_untileable():
    with pytest.raises(ValueError):
        SA.build_block_index(100, 100, 32, 32)


def test_max_live_blocks_matches_builder():
    for pat in PATTERNS.values():
        idx = SA.build_block_index(S, S, BQ, BKV, **pat)
        assert SA.max_live_blocks(S, S, BQ, BKV, **pat) == idx.shape[1]


# ---------------------------------------------------------------------------
# kernel parity vs the dense-mask oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.label)
@pytest.mark.parametrize("pat", PATTERNS.values(), ids=PATTERNS.keys())
def test_kernel_matches_oracle(pat, cfg):
    q, k, v = _qkv()
    idx = SA.build_block_index(S, S, BQ, BKV, **pat)
    run = SA.make_kernel(B, H, HKV, S, D, cfg, bq=BQ, bkv=BKV,
                         max_live=idx.shape[1], **pat)
    got = run(q, k, v, idx)
    want = SA.ref_sparse_attention(q, k, v, **pat)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_kernel_residuals_bit_match_dense_flash():
    """The sparse forward's (m, l) residuals equal the dense-mask flash
    kernel's bit-for-bit on window patterns — the invariant that lets
    ops.flash_attention_sparse reuse the dense backward kernels."""
    from repro.kernels import flash_attention as FA
    pat = dict(causal=True, window=64, global_stride=None)
    q, k, v = _qkv()
    idx = SA.build_block_index(S, S, BQ, BKV, **pat)
    sp = SA.make_kernel(B, H, HKV, S, D, CoarseningConfig(KIND_CONSECUTIVE, 2),
                        bq=BQ, bkv=BKV, max_live=idx.shape[1],
                        return_residuals=True, **pat)
    dn = FA.make_kernel(B, H, HKV, S, D, CoarseningConfig(), bq=BQ, bkv=BKV,
                        causal=True, window=64, return_residuals=True)
    so, sm, sl = sp(q, k, v, idx)
    do, dm, dl = dn(q, k, v)
    assert float(jnp.abs(sm - dm).max()) == 0.0
    assert float(jnp.abs(sl - dl).max()) == 0.0
    np.testing.assert_allclose(np.asarray(so), np.asarray(do),
                               rtol=1e-6, atol=1e-6)


def test_poisoned_dead_blocks_never_loaded():
    """NULL-skip is structural, not a mask: kv blocks absent from the index
    hold NaN and the output must be NaN-free and equal the index-derived
    oracle.  (A masked-but-loaded implementation would propagate the NaNs:
    0 * NaN = NaN.)  Uses a synthetic non-causal pattern because causal
    patterns rarely have globally-dead blocks."""
    nkb = S // BKV
    nq = S // BQ
    # each q block attends exactly blocks {0, qi}: every block > nq//2 with
    # odd id stays globally dead once we list only even ids past the first
    rng = np.random.default_rng(3)
    max_live = 4
    idx = np.full((nq, max_live), SA.NULL_BLOCK, np.int32)
    dead = {3, 5, 7}
    for i in range(nq):
        picks = sorted(rng.choice([bid for bid in range(nkb)
                                   if bid not in dead],
                                  size=rng.integers(1, max_live + 1),
                                  replace=False))
        idx[i, :len(picks)] = picks
    q, k, v = _qkv()
    poison = np.zeros((B, HKV, S, D), np.float32)
    for bid in dead:
        poison[:, :, bid * BKV:(bid + 1) * BKV] = np.nan
    k = jnp.where(jnp.isnan(jnp.asarray(poison)), jnp.nan, k)
    v = jnp.where(jnp.isnan(jnp.asarray(poison)), jnp.nan, v)

    pat = dict(causal=False, window=None, global_stride=None)
    for cfg in (CoarseningConfig(KIND_CONSECUTIVE, 2),
                CoarseningConfig(KIND_GAPPED, 4)):
        run = SA.make_kernel(B, H, HKV, S, D, cfg, bq=BQ, bkv=BKV,
                             max_live=max_live, **pat)
        got = np.asarray(run(q, k, v, jnp.asarray(idx)))
        assert np.isfinite(got).all()
        # index-derived oracle: mask (sq, sk) from the block list
        mask = np.zeros((S, S), bool)
        for i in range(nq):
            for bid in idx[i][idx[i] >= 0]:
                mask[i * BQ:(i + 1) * BQ, bid * BKV:(bid + 1) * BKV] = True
        kk = jnp.nan_to_num(jnp.repeat(k, H // HKV, axis=1))
        vv = jnp.nan_to_num(jnp.repeat(v, H // HKV, axis=1))
        lg = jnp.einsum("bhqd,bhkd->bhqk", q, kk) / np.sqrt(D)
        lg = jnp.where(jnp.asarray(mask), lg, SA.NEG)
        want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(lg, -1), vv)
        np.testing.assert_allclose(got, np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_permuted_index_rows_invariant():
    """Online softmax is order-free: shuffling each row's live entries (the
    fragmented-allocation analog) cannot change the output."""
    pat = dict(causal=True, window=64, global_stride=None)
    q, k, v = _qkv()
    idx = np.array(SA.build_block_index(S, S, BQ, BKV, **pat))
    rng = np.random.default_rng(5)
    perm = idx.copy()
    for i in range(perm.shape[0]):
        live = perm[i][perm[i] >= 0]
        perm[i, :len(live)] = rng.permutation(live)
    cfg = CoarseningConfig(KIND_GAPPED, 2)
    run = SA.make_kernel(B, H, HKV, S, D, cfg, bq=BQ, bkv=BKV,
                         max_live=idx.shape[1], **pat)
    a = np.asarray(run(q, k, v, jnp.asarray(idx)))
    bb = np.asarray(run(q, k, v, jnp.asarray(perm)))
    np.testing.assert_allclose(a, bb, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# the long-context gate: live visits vs the dense grid
# ---------------------------------------------------------------------------

def test_visit_reduction_at_32k_window512():
    """ISSUE acceptance: at 32k context with window=512 the sparse kernel
    visits >= 8x fewer KV blocks than the dense causal grid."""
    s, bq, bkv, w = 32768, 128, 128, 512
    idx = SA.build_block_index(s, s, bq, bkv, causal=True, window=w)
    sparse_visits = int((idx >= 0).sum())
    nq = s // bq
    # dense kernel causal-live steps (generous: credits its causal skip)
    dense_visits = sum((i * bq + bq - 1) // bkv + 1 for i in range(nq))
    assert dense_visits / sparse_visits >= 8.0, (dense_visits, sparse_visits)


# ---------------------------------------------------------------------------
# ops-level dispatch + grads
# ---------------------------------------------------------------------------

def test_ops_parity_and_fallback(scratch_default_cache):
    q, k, v = _qkv()
    for patname in ("window", "window+gstride"):
        pat = PATTERNS[patname]
        got = ops.flash_attention_sparse(q, k, v, "auto", bq=BQ, bkv=BKV,
                                         **pat)
        want = ops.flash_attention_sparse(q, k, v, bq=BQ, bkv=BKV,
                                          backend="ref", **pat)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_ops_grads_match_oracle(scratch_default_cache):
    """custom-VJP: window patterns ride the dense backward kernels (exact
    vs the dense op's grads); global-stride patterns differentiate the jnp
    oracle."""
    q, k, v = _qkv(s=128)
    cfg = CoarseningConfig(KIND_CONSECUTIVE, 2)

    def loss_sparse(q, k, v, **pat):
        return ops.flash_attention_sparse(q, k, v, cfg, bq=BQ, bkv=BKV,
                                          **pat).sum()

    # window: sparse grads == dense-mask op grads
    pat = PATTERNS["window"]
    gs = jax.grad(functools.partial(loss_sparse, **pat), argnums=(0, 1, 2))(
        q, k, v)
    gd = jax.grad(lambda q, k, v: ops.flash_attention(
        q, k, v, CoarseningConfig(), bq=BQ, bkv=BKV, causal=True,
        window=64).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gs, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    # global stride: grads vs jax.vjp of the oracle
    pat = PATTERNS["window+gstride"]
    gs = jax.grad(functools.partial(loss_sparse, **pat), argnums=(0, 1, 2))(
        q, k, v)
    gr = jax.grad(lambda q, k, v: SA.ref_sparse_attention(
        q, k, v, **pat).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gs, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_layer_dispatch_routes_sparse(scratch_default_cache):
    """layers.flash_attention with backend="pallas" + window routes the
    sparse kernel (the tuning cache records the family) and matches the
    mea/ref fallback; sparse="off" pins the dense-mask kernel."""
    from repro.models import layers as L
    from repro.tune.cache import default_cache
    b, s, h, hkv, d = 1, 128, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    kw = dict(causal=True, window=32, bq=32, bkv=32, pos_trivial=True)
    want = L.flash_attention(q, k, v, backend="ref", **kw)
    got = L.flash_attention(q, k, v, backend="pallas", **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)
    fams = {key.split("|", 1)[0] for key in default_cache().entries}
    assert "flash_attention_sparse" in fams
    off = L.flash_attention(q, k, v, backend="pallas", sparse="off", **kw)
    np.testing.assert_allclose(np.asarray(off), np.asarray(want),
                               rtol=2e-2, atol=2e-2)
