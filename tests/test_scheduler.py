"""Scheduler simulation suite.

Layer 1 — FakeEngine (no model, no device): the scheduling invariants on
synthetic mixed-length traces.  The fake emits a deterministic per-request
token stream (a pure function of rid and position), so "outputs identical
to running alone" reduces to an exact-sequence check however the trace is
admitted, preempted, and requeued:

  * every submitted request completes,
  * FCFS: each admission picks the oldest waiting request,
  * no starvation: the oldest running request is never the preemption
    victim, and bounded preemptions under heavy pool pressure,
  * page conservation: the pool is clean after the trace drains.

Layer 2 — the real PagedEngine on the tiny reduced qwen3 (greedy): a
pool sized to force preemption must reproduce, token for token, each
request's solo run on the contiguous BatchedServer; shared-prefix
admission increfs instead of recomputing and frees at refcount zero.

Plus the prompt-truncation pin: BatchedServer.try_admit and
Scheduler.submit must REJECT oversized prompts loudly (the old behavior
silently dropped tokens past max_len-1)."""
import jax
import numpy as np
import pytest

from repro.serve import (BlockTables, DecodeFault, PagePool, PoolExhausted,
                         Request, Scheduler, State, pages_needed)


# ---------------------------------------------------------------------------
# layer 1: the fake engine
# ---------------------------------------------------------------------------

class FakeEngine:
    """Implements the engine protocol over a real PagePool/BlockTables, with
    a deterministic token stream per request: token j of request r is
    ``(r.rid * 1009 + j) % 65521`` — what the request would produce running
    alone, so any co-tenancy leak shows up as a wrong sequence."""

    def __init__(self, *, slots=3, num_pages=12, page_size=4, max_len=64,
                 decode_block=4):
        self.slots = slots
        self.page_size = page_size
        self.max_len = max_len
        self.decode_block = decode_block
        self.pool = PagePool(num_pages, page_size)
        self.pool_capacity = self.pool.capacity
        self.bt = BlockTables(slots, pages_needed(max_len, page_size))
        self.state: dict[int, list] = {}  # slot -> [req, written, emitted]
        self.admit_log: list[int] = []
        self.preempt_log: list[int] = []

    @staticmethod
    def tok(req: Request, j: int) -> int:
        return (req.rid * 1009 + j) % 65521

    @staticmethod
    def expected(req: Request) -> list[int]:
        return [FakeEngine.tok(req, j) for j in range(req.gen)]

    def admit(self, slot, req):
        assert slot not in self.state
        pages = self.pool.alloc(pages_needed(len(req.prompt),
                                             self.page_size))
        self.bt.append(slot, pages)
        self.state[slot] = [req, len(req.prompt), 1]
        self.admit_log.append(req.rid)
        return self.tok(req, 0)

    def decode(self, slots):
        slots = [s for s in slots if s in self.state]
        if not slots:
            return {}
        n = max(1, min([self.decode_block]
                       + [st[0].gen - st[2] for st in
                          (self.state[s] for s in slots)]))
        for s in slots:             # grow BEFORE emitting, like the engine
            req, written, _ = self.state[s]
            need = pages_needed(written + n, self.page_size) \
                - self.bt.num_pages(s)
            if need > 0:
                self.bt.append(s, self.pool.alloc(need))
        out = {}
        for s in slots:
            st = self.state[s]
            out[s] = [self.tok(st[0], st[2] + k) for k in range(n)]
            st[1] += n
            st[2] += n
        return out

    def _drop(self, slot):
        self.pool.release(self.bt.drop(slot))
        del self.state[slot]

    def finish(self, slot):
        self._drop(slot)

    def preempt(self, slot):
        self.preempt_log.append(self.state[slot][0].rid)
        self._drop(slot)


def _trace(rng, n, max_len=64, min_gen=1, max_gen=24):
    out = []
    for _ in range(n):
        gen = int(rng.integers(min_gen, max_gen + 1))
        plen = int(rng.integers(1, max_len - gen))
        out.append(([int(t) for t in rng.integers(1, 1000, plen)], gen))
    return out


@pytest.mark.parametrize("seed", range(6))
def test_every_request_completes_with_exact_solo_outputs(seed):
    """Mixed-length random traces through a small pool: all complete, each
    with exactly the token stream it would produce running alone."""
    rng = np.random.default_rng(seed)
    eng = FakeEngine(slots=3, num_pages=int(rng.integers(12, 24)),
                     page_size=4, max_len=40)
    sched = Scheduler(eng)
    reqs = [sched.submit(p, g) for p, g in _trace(rng, 12, max_len=40)]
    done = sched.run_until_done()
    assert len(done) == len(reqs)
    for req in done:
        assert req.output == FakeEngine.expected(req), req.rid
    assert eng.pool.num_live == 0
    assert eng.pool.num_free == eng.pool.capacity
    eng.pool.check()


def test_fcfs_admission_order():
    """Without preemption pressure, requests are admitted strictly in
    arrival order even when slots free up out of order."""
    eng = FakeEngine(slots=2, num_pages=64, page_size=4)
    sched = Scheduler(eng)
    rng = np.random.default_rng(1)
    for p, g in _trace(rng, 8):
        sched.submit(p, g)
    sched.run_until_done()
    assert eng.admit_log == sorted(eng.admit_log)
    assert not eng.preempt_log


def test_oldest_running_request_is_never_the_victim():
    """Heavy pool pressure: preemptions happen, but each victim is the
    youngest running request at that moment — the no-starvation induction."""
    eng = FakeEngine(slots=3, num_pages=10, page_size=4, decode_block=4)

    victims_vs_running = []
    orig = Scheduler._preempt_youngest

    def spy(self):
        running = sorted(r.key for r in self.running.values())
        orig(self)
        victims_vs_running.append(
            (eng.preempt_log[-1], [k[1] for k in running]))

    Scheduler._preempt_youngest = spy
    try:
        sched = Scheduler(eng)
        rng = np.random.default_rng(2)
        for p, g in _trace(rng, 10, max_len=32, min_gen=8, max_gen=20):
            sched.submit(p, g)
        done = sched.run_until_done()
    finally:
        Scheduler._preempt_youngest = orig
    assert eng.preempt_log, "scenario failed to force preemption"
    for victim, running_rids in victims_vs_running:
        assert victim == max(running_rids), \
            f"preempted {victim}, running {running_rids}"
    for req in done:
        assert req.output == FakeEngine.expected(req)
    assert eng.pool.num_live == 0


def test_preempted_request_restarts_clean_and_completes():
    eng = FakeEngine(slots=2, num_pages=8, page_size=4, decode_block=8)
    sched = Scheduler(eng)
    sched.submit([1] * 4, 16)
    sched.submit([2] * 4, 16)
    done = sched.run_until_done()
    assert sum(r.preemptions for r in done) > 0
    for req in done:
        assert req.output == FakeEngine.expected(req)
        assert len(req.output) == req.gen


def test_submit_rejects_request_that_could_never_fit():
    eng = FakeEngine(slots=2, num_pages=4, page_size=2, max_len=64)
    sched = Scheduler(eng)
    with pytest.raises(ValueError, match="even running alone"):
        sched.submit([1] * 20, 10)      # 15 pages vs capacity 3


def test_submit_rejects_oversized_prompt_instead_of_truncating():
    """The truncation pin (scheduler side): prompt+gen past max_len is an
    explicit error, not a silent drop of prompt tokens."""
    eng = FakeEngine(slots=2, num_pages=64, page_size=4, max_len=32)
    sched = Scheduler(eng)
    with pytest.raises(ValueError, match="rejecting instead of truncating"):
        sched.submit([1] * 30, 8)
    sched.submit([1] * 24, 8)           # exactly max_len fits


def test_gen_one_request_finishes_at_admission():
    eng = FakeEngine(slots=1, num_pages=8, page_size=4)
    sched = Scheduler(eng)
    sched.submit([5, 6, 7], 1)
    done = sched.run_until_done()
    assert done[0].output == [FakeEngine.tok(done[0], 0)]
    assert eng.pool.num_live == 0


# ---------------------------------------------------------------------------
# layer 1b: robustness — swap eviction, deadlines, backpressure, faults
# ---------------------------------------------------------------------------

class FakeSusp:
    """What SwapFakeEngine hands the SwapStore: enough to resume (request,
    progress cursors) plus a byte size for the budget accounting."""

    def __init__(self, req, written, emitted, nbytes):
        self.req, self.written, self.emitted = req, written, emitted
        self.nbytes = nbytes


class SwapFakeEngine(FakeEngine):
    """FakeEngine + the optional suspend/resume surface: suspension frees
    the pool pages (they went "to host") and resume re-allocates exactly
    the pages the written prefix needs — the same pool contract as the real
    PagedEngine, minus the device arrays."""

    susp_bytes = 64

    def __init__(self, **kw):
        super().__init__(**kw)
        self.suspends = self.resumes = 0

    def suspend_bytes(self, slot):
        return self.susp_bytes

    def suspend(self, slot):
        req, written, emitted = self.state[slot]
        self.pool.release(self.bt.drop(slot))
        del self.state[slot]
        self.suspends += 1
        return FakeSusp(req, written, emitted, self.susp_bytes)

    def resume(self, slot, susp):
        pages = self.pool.alloc(pages_needed(susp.written, self.page_size))
        self.bt.append(slot, pages)
        self.state[slot] = [susp.req, susp.written, susp.emitted]
        self.resumes += 1


def test_swap_eviction_keeps_output_and_never_readmits():
    """The resumable-preemption contract at the scheduler level: under pool
    pressure with swapping on, evicted requests keep their partial output,
    are admitted exactly once (no re-prefill), and still finish with the
    exact solo stream."""
    eng = SwapFakeEngine(slots=3, num_pages=10, page_size=4, decode_block=4)
    sched = Scheduler(eng)
    rng = np.random.default_rng(2)
    for p, g in _trace(rng, 10, max_len=32, min_gen=8, max_gen=20):
        sched.submit(p, g)
    done = sched.run_until_done()
    swapped = [r for r in done if r.swaps > 0]
    assert swapped, "scenario failed to force a swap eviction"
    assert eng.suspends == eng.resumes == sum(r.swaps for r in done)
    for req in done:
        assert req.state is State.FINISHED
        assert req.output == FakeEngine.expected(req), req.rid
        assert req.preemptions >= req.swaps
        # one admission per request: resume never re-runs the prefill path
        assert eng.admit_log.count(req.rid) == 1
    assert eng.pool.num_live == 0
    assert sched.swap.used_bytes == 0 and len(sched.swap) == 0
    sched.swap.check()
    eng.pool.check()


def test_zero_swap_budget_forces_recompute():
    """host_swap_bytes=0 disables swapping: every eviction takes the
    recompute path (refused by the store, output reset, re-admitted)."""
    eng = SwapFakeEngine(slots=2, num_pages=8, page_size=4, decode_block=8)
    sched = Scheduler(eng, host_swap_bytes=0)
    sched.submit([1] * 4, 16)
    sched.submit([2] * 4, 16)
    done = sched.run_until_done()
    assert sum(r.preemptions for r in done) > 0
    assert eng.suspends == 0 and sched.swap.refused > 0
    assert all(r.swaps == 0 for r in done)
    for req in done:
        assert req.output == FakeEngine.expected(req)


def test_oldest_is_never_the_victim_with_swap_enabled():
    """The no-starvation induction must survive the swap policy: victims
    are still the youngest running request."""
    eng = SwapFakeEngine(slots=3, num_pages=10, page_size=4, decode_block=4)
    victims = []
    orig = Scheduler._preempt_youngest

    def spy(self):
        running = sorted(r.key for r in self.running.values())
        orig(self)
        victims.append((max(running)[1], [k[1] for k in running]))

    Scheduler._preempt_youngest = spy
    try:
        sched = Scheduler(eng)
        rng = np.random.default_rng(4)
        for p, g in _trace(rng, 10, max_len=32, min_gen=8, max_gen=20):
            sched.submit(p, g)
        done = sched.run_until_done()
    finally:
        Scheduler._preempt_youngest = orig
    assert victims and eng.suspends > 0
    for victim, running_rids in victims:
        assert victim == max(running_rids)
    for req in done:
        assert req.output == FakeEngine.expected(req)


def test_max_preemptions_overflow_fails_request_not_server():
    """The satellite pin: eviction-count overflow is a terminal per-request
    FAILED status with pages freed — run_until_done does NOT raise."""
    eng = FakeEngine(slots=2, num_pages=8, page_size=4, decode_block=8)
    sched = Scheduler(eng, max_preemptions=0)
    sched.submit([1] * 4, 16)
    sched.submit([2] * 4, 16)
    done = sched.run_until_done()          # no RuntimeError
    failed = [r for r in done if r.state is State.FAILED]
    assert len(failed) == 1 and "livelock" in failed[0].error
    assert failed[0].rid == 1              # the younger request
    ok = [r for r in done if r.state is State.FINISHED]
    assert len(ok) == 1
    assert ok[0].output == FakeEngine.expected(ok[0])
    assert eng.pool.num_live == 0
    eng.pool.check()


def test_deadline_cancels_queued_and_running():
    """Requests past their deadline end CANCELLED wherever they are, with
    pages freed and partial output kept on the running one."""
    eng = FakeEngine(slots=1, num_pages=32, page_size=4, decode_block=2)
    sched = Scheduler(eng)
    a = sched.submit([1] * 4, 40, deadline=3)     # cancels while RUNNING
    b = sched.submit([2] * 4, 8, deadline=2)      # cancels while QUEUED
    c = sched.submit([3] * 4, 4)                  # no deadline: finishes
    done = sched.run_until_done()
    assert a.state is State.CANCELLED and "running" in a.error
    assert 0 < len(a.output) < a.gen              # partial output kept
    assert a.output == FakeEngine.expected(a)[: len(a.output)]
    assert b.state is State.CANCELLED and "queued" in b.error
    assert c.state is State.FINISHED
    assert c.output == FakeEngine.expected(c)
    assert len(done) == 3 and eng.pool.num_live == 0
    eng.pool.check()


def test_max_queue_wait_rejects_with_retry_after():
    eng = FakeEngine(slots=1, num_pages=32, page_size=4, decode_block=2)
    sched = Scheduler(eng)
    a = sched.submit([1] * 4, 30)
    b = sched.submit([2] * 4, 8, max_queue_wait=2)
    done = sched.run_until_done()
    assert a.state is State.FINISHED
    assert b.state is State.REJECTED
    assert b.retry_after is not None and b.retry_after >= 1
    assert b.output == [] and len(done) == 2
    eng.pool.check()


def test_backpressure_sheds_submits_past_the_queue_bound():
    eng = FakeEngine(slots=1, num_pages=32, page_size=4)
    sched = Scheduler(eng, max_waiting=1)
    a = sched.submit([1] * 4, 8)
    b = sched.submit([2] * 4, 8)           # queue holds 1 -> shed
    assert a.state is State.WAITING
    assert b.state is State.REJECTED and b.retry_after >= 1
    assert b in sched.finished             # terminal immediately, no step
    done = sched.run_until_done()
    assert a.state is State.FINISHED and len(done) == 2


def test_drain_cancels_everything_and_frees_pages():
    """Graceful shutdown: every in-flight and queued request terminates
    CANCELLED with partial output kept; the pool is clean."""
    eng = SwapFakeEngine(slots=2, num_pages=32, page_size=4, decode_block=2)
    sched = Scheduler(eng)
    for i in range(5):
        sched.submit([i + 1] * 4, 20)
    for _ in range(3):
        sched.step()
    done = sched.drain()
    assert len(done) == 5
    assert not sched.waiting and not sched.running and len(sched.swap) == 0
    for req in done:
        assert req.done
        assert req.output == FakeEngine.expected(req)[: len(req.output)]
    assert any(r.output for r in done)     # the running ones kept progress
    assert eng.pool.num_live == 0
    eng.pool.check()


class FlakyEngine(FakeEngine):
    """Raises DecodeFault on the first ``flakes`` decode calls, then works."""

    def __init__(self, flakes, **kw):
        super().__init__(**kw)
        self.flakes = flakes
        self.decode_calls = 0

    def decode(self, slots):
        self.decode_calls += 1
        if self.decode_calls <= self.flakes:
            raise DecodeFault(f"flake {self.decode_calls}")
        return super().decode(slots)


def test_transient_decode_faults_are_retried():
    eng = FlakyEngine(3, slots=2, num_pages=32, page_size=4)
    sched = Scheduler(eng)
    sched.submit([1] * 4, 8)
    done = sched.run_until_done()
    assert sched.decode_faults == 3
    assert done[0].state is State.FINISHED
    assert done[0].output == FakeEngine.expected(done[0])


def test_nontransient_decode_fault_gives_up_loudly():
    eng = FlakyEngine(10_000, slots=1, num_pages=32, page_size=4)
    sched = Scheduler(eng, max_decode_faults=5)
    sched.submit([1] * 4, 8)
    with pytest.raises(RuntimeError, match="not transient"):
        sched.run_until_done()


# ---------------------------------------------------------------------------
# layer 2: the real PagedEngine (greedy determinism + shared prefixes)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _solo(cfg, params, prompt, gen, max_len=32):
    from repro.launch.serve import BatchedServer
    srv = BatchedServer(cfg, params, slots=1, max_len=max_len, chunk=8,
                        decode_block=4)
    assert srv.try_admit(list(prompt), gen)
    while srv.any_active:
        srv.step()
    return srv.completed[0][:gen]


def test_paged_engine_matches_solo_contiguous_under_preemption(tiny_model):
    """The acceptance gate: short prompts + long generations through a pool
    small enough to force preemption — every request's greedy output equals
    its solo run on the CONTIGUOUS server (cross-layout oracle)."""
    from repro.serve import PagedEngine
    cfg, params = tiny_model
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, 6)))
               for _ in range(3)]
    gen = 18
    solo = [_solo(cfg, params, p, gen) for p in prompts]
    eng = PagedEngine(cfg, params, slots=3, num_pages=8, page_size=8,
                      max_len=32, chunk=8, decode_block=4)
    sched = Scheduler(eng)
    for p in prompts:
        sched.submit(p, gen)
    done = sched.run_until_done()
    assert sum(r.preemptions for r in done) > 0, \
        "pool failed to force preemption — weaken num_pages"
    for req, want in zip(done, solo):
        assert req.output == want, req.rid
    assert eng.pool.num_live == 0 and not eng.active.any()
    eng.pool.check()


def test_shared_prefix_refcount_lifecycle(tiny_model):
    """Registered prefix pages are increfed per admit (never recomputed or
    leaked), survive their tenants, and free exactly at drop_prefix."""
    from repro.serve import PagedEngine
    cfg, params = tiny_model
    rng = np.random.default_rng(3)
    prefix = list(map(int, rng.integers(1, cfg.vocab, 16)))
    tail = list(map(int, rng.integers(1, cfg.vocab, 5)))
    eng = PagedEngine(cfg, params, slots=2, num_pages=16, page_size=8,
                      max_len=48, chunk=8, decode_block=4)
    reg = eng.register_prefix("sys", prefix)
    assert reg == 16                       # page-aligned registration
    pages = eng.prefixes["sys"].pages
    assert all(eng.pool.refcount[p] == 1 for p in pages)
    free0 = eng.pool.num_free

    solo = _solo(cfg, params, prefix + tail, 6, max_len=48)
    sched = Scheduler(eng)
    sched.submit(prefix + tail, 6, prefix="sys")
    sched.submit(prefix + tail[:2], 4, prefix="sys")
    # while admitted, shared pages carry registry + tenant refs
    sched._admit_waiting()
    assert all(eng.pool.refcount[p] >= 2 for p in pages)
    done = sched.run_until_done()
    assert done[0].output == solo          # prefix reuse is exact
    assert eng.pool.num_free == free0      # tenants released, registry holds
    assert all(eng.pool.refcount[p] == 1 for p in pages)
    eng.drop_prefix("sys")
    assert eng.pool.num_live == 0
    eng.pool.check()


def test_prefix_registry_lru_evicts_oldest_unreferenced(tiny_model):
    """Bounded registry: registering past ``max_prefixes`` evicts the
    least-recently-used prefix whose pages only the registry holds; a
    prefix pinned by a running slot is skipped, and a full registry of
    in-use prefixes raises instead of evicting."""
    from repro.serve import PagedEngine
    cfg, params = tiny_model
    rng = np.random.default_rng(9)
    mk = lambda: list(map(int, rng.integers(1, cfg.vocab, 8)))
    eng = PagedEngine(cfg, params, slots=2, num_pages=24, page_size=8,
                      max_len=48, chunk=8, decode_block=4)
    eng.max_prefixes = 2
    eng.register_prefix("a", mk())
    eng.register_prefix("b", mk())
    eng.register_prefix("c", mk())         # full -> evicts "a" (oldest)
    assert set(eng.prefixes) == {"b", "c"}
    assert eng.prefix_evictions == 1

    # an admit hit refreshes recency: touch "b", then "c" is the victim
    sched = Scheduler(eng)
    tail = mk()[:3]
    sched.submit(list(eng.prefixes["b"].tokens) + tail, 4, prefix="b")
    sched.run_until_done()
    eng.register_prefix("d", mk())
    assert set(eng.prefixes) == {"b", "d"}

    # a prefix pinned by a RUNNING slot is never the victim
    sched.submit(list(eng.prefixes["b"].tokens) + tail, 30, prefix="b")
    sched._admit_waiting()                 # running, pages refcount >= 2
    eng.register_prefix("e", mk())         # skips "b", evicts "d"
    assert set(eng.prefixes) == {"b", "e"}

    # both remaining prefixes in use -> loud failure, no eviction
    sched.submit(list(eng.prefixes["e"].tokens) + tail, 30, prefix="e")
    sched._admit_waiting()
    with pytest.raises(RuntimeError, match="every prefix is referenced"):
        eng.register_prefix("f", mk())
    assert set(eng.prefixes) == {"b", "e"}
    sched.run_until_done()
    for name in list(eng.prefixes):
        eng.drop_prefix(name)
    assert eng.pool.num_live == 0
    eng.pool.check()


def test_batched_server_rejects_long_prompt_instead_of_truncating(tiny_model):
    """The launch/serve.py pin: the contiguous server must raise on a
    prompt that exceeds its cache rather than silently dropping tokens."""
    from repro.launch.serve import BatchedServer
    cfg, params = tiny_model
    srv = BatchedServer(cfg, params, slots=1, max_len=16, chunk=8)
    with pytest.raises(ValueError, match="rejecting instead of truncating"):
        srv.try_admit(list(range(1, 18)), 4)
    assert not srv.any_active
    assert srv.try_admit(list(range(1, 16)), 1)   # max_len-1 still admits
