"""Hypothesis property tests for the coarsening framework's invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import (CoarseningConfig, plan_stream, KIND_CONSECUTIVE,
                        KIND_GAPPED, KIND_NONE)
from repro.core import analysis
from repro.kernels import ops, ref

SETTINGS = dict(max_examples=25, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


kinds = st.sampled_from([KIND_NONE, KIND_CONSECUTIVE, KIND_GAPPED])
degrees = st.sampled_from([1, 2, 4, 8])


# --- THE system invariant: results independent of coarsening config --------

@given(kind=kinds, degree=degrees, seed=st.integers(0, 10),
       ai=st.integers(1, 8))
@settings(**SETTINGS)
def test_coarsening_never_changes_results(kind, degree, seed, ai):
    cfg = CoarseningConfig(kind, degree)
    n = 4096
    inputs = tuple(
        jax.random.normal(jax.random.PRNGKey(seed * 31 + i), (n,))
        for i in range(4))
    expected = ref.ew_stream(list(inputs), ai=ai)
    got = ops.ew_stream(inputs, cfg, ai=ai, block=128)
    np.testing.assert_allclose(got, expected, rtol=1e-5, atol=1e-5)


@given(kind=kinds, degree=st.sampled_from([1, 2, 4]), seed=st.integers(0, 5))
@settings(**SETTINGS)
def test_matmul_coarsening_invariance(kind, degree, seed):
    cfg = CoarseningConfig(kind, degree)
    a = jax.random.normal(jax.random.PRNGKey(seed), (256, 128))
    b = jax.random.normal(jax.random.PRNGKey(seed + 99), (128, 128))
    got = ops.matmul(a, b, cfg, bm=32, bn=128, bk=128)
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=2e-4, atol=2e-4)


# --- plan invariants ---------------------------------------------------------

@given(kind=kinds, degree=degrees,
       logn=st.integers(13, 18), logb=st.integers(7, 10))
@settings(**SETTINGS)
def test_stream_plan_partitions_work(kind, degree, logn, logb):
    n, block = 2 ** logn, 2 ** logb
    cfg = CoarseningConfig(kind, degree)
    plan = plan_stream(n, cfg, block=block)
    # every element covered exactly once
    assert plan.grid * cfg.degree * plan.block == n
    # LSU-count analog: consecutive = 1 wide DMA, gapped = degree narrow ones
    if cfg.kind == KIND_GAPPED:
        assert plan.dmas_per_operand == cfg.degree
        assert plan.dma_elems == plan.block
    else:
        assert plan.dmas_per_operand == 1
        assert plan.dma_elems == cfg.degree * plan.block
    # view shape is a permutation-free reshape of n
    assert int(np.prod(plan.view_shape)) == n


@given(degree=st.sampled_from([2, 4, 8]))
@settings(**SETTINGS)
def test_consecutive_coalesces_better_than_gapped(degree):
    """Paper F1 as a property: for regular streams the modeled DMA time of
    consecutive coarsening is <= gapped at the same degree."""
    n = 2 ** 16
    con = analysis.stream_cost(
        plan_stream(n, CoarseningConfig(KIND_CONSECUTIVE, degree)),
        n_loads=8, arith_per_elem=6.0)
    gap = analysis.stream_cost(
        plan_stream(n, CoarseningConfig(KIND_GAPPED, degree)),
        n_loads=8, arith_per_elem=6.0)
    assert con.dma_s_per_step <= gap.dma_s_per_step
    assert con.dmas_per_step < gap.dmas_per_step


@given(kind=kinds, degree=degrees, repl=st.sampled_from([1, 2, 4]),
       vw=st.sampled_from([1, 2]))
@settings(**SETTINGS)
def test_parse_label_roundtrip(kind, degree, repl, vw):
    cfg = CoarseningConfig(kind, degree, repl, vw)
    again = CoarseningConfig.parse(cfg.label)
    assert again == cfg


def test_parse_spec_forms():
    assert CoarseningConfig.parse("consecutive:4").degree == 4
    assert CoarseningConfig.parse("gapped:8").kind == KIND_GAPPED
    assert CoarseningConfig.parse("con4+pipe2+simd2") == CoarseningConfig(
        KIND_CONSECUTIVE, 4, 2, 2)
    assert CoarseningConfig.parse("none") == CoarseningConfig()
    with pytest.raises((KeyError, ValueError)):
        CoarseningConfig.parse("bogus3")


def test_degree1_normalizes_to_none():
    assert CoarseningConfig(KIND_CONSECUTIVE, 1).kind == KIND_NONE


# --- cost model directional properties (the paper's findings) ---------------

def _mb_cost(spec, **kw):
    cfg = CoarseningConfig.parse(spec)
    plan = plan_stream(2 ** 22, cfg, block=1024)
    base = dict(n_loads=8, arith_per_elem=6.0)
    base.update(kw)
    return analysis.stream_cost(plan, **base)


def test_f1_consecutive_wins_on_regular():
    base = _mb_cost("none")
    con8 = _mb_cost("con8")
    gap8 = _mb_cost("gap8")
    assert con8.modeled_s < base.modeled_s          # coarsening helps
    assert con8.modeled_s <= gap8.modeled_s         # consecutive >= gapped


def test_f3_low_ai_benefits_more():
    s1 = _mb_cost("none", arith_per_elem=1.0).modeled_s / \
        _mb_cost("con8", arith_per_elem=1.0).modeled_s
    s10 = _mb_cost("none", arith_per_elem=10.0).modeled_s / \
        _mb_cost("con8", arith_per_elem=10.0).modeled_s
    assert s1 >= s10                                 # paper Fig. 11 trend


def test_f4_divergence_hurts():
    clean = _mb_cost("con8")
    div = _mb_cost("con8", divergence_paths=4)
    uniform = _mb_cost("con8", divergence_paths=4, divergence_uniform=True)
    assert div.modeled_s > clean.modeled_s
    assert uniform.modeled_s < div.modeled_s         # id-divergence recoverable


def test_f5_resource_cost_ordering():
    """Coarsening control resources < replication at equal degree: R x fewer
    DMA queues/semaphores (the ALUT analog); VMEM totals are equal (the
    paper's RAM-block saving does not transfer — DESIGN.md §2)."""
    con = _mb_cost("con4")
    pipe = _mb_cost("pipe4")
    assert con.dma_sems * 4 == pipe.dma_sems
    assert con.vmem_bytes == pipe.vmem_bytes


def test_f2_gapped_wins_on_irregular():
    """Irregular access: gapped (cached narrow LSUs w/ miss overlap) beats
    consecutive, paper Fig. 10 bottom."""
    n = 2 ** 20
    kw = dict(n_loads=8, arith_per_elem=6.0, hit_rate=0.85, window_elems=8192)
    con = analysis.gather_cost(
        plan_stream(n, CoarseningConfig.parse("con8")), **kw)
    gap = analysis.gather_cost(
        plan_stream(n, CoarseningConfig.parse("gap8")), **kw)
    assert gap.modeled_s <= con.modeled_s
