"""Property tests on the analytic perf model (the §Roofline source)."""
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import ARCHS, get_config
from repro.core.perfmodel import (MeshInfo, train_step_terms,
                                  decode_step_terms, prefill_step_terms)

MESH = MeshInfo(dp=16, tp=16)
SET = dict(max_examples=15, deadline=None)


@given(arch=st.sampled_from(["qwen3-0.6b", "yi-34b", "mamba2-370m",
                             "olmoe-1b-7b"]),
       logb=st.integers(4, 9))
@settings(**SET)
def test_flops_linear_in_batch(arch, logb):
    cfg = get_config(arch)
    t1 = train_step_terms(cfg, seq=4096, batch=2 ** logb, mesh=MESH)
    t2 = train_step_terms(cfg, seq=4096, batch=2 ** (logb + 1), mesh=MESH)
    assert t2.flops == pytest.approx(2 * t1.flops, rel=0.01)


@given(nm=st.sampled_from([1, 2, 4, 8]))
@settings(**SET)
def test_collectives_increase_with_microbatching(nm):
    cfg = get_config("yi-34b")
    t1 = train_step_terms(cfg, seq=4096, batch=256, mesh=MESH, n_micro=nm)
    t2 = train_step_terms(cfg, seq=4096, batch=256, mesh=MESH, n_micro=2 * nm)
    assert t2.coll_bytes > t1.coll_bytes          # more param re-gathers


def test_sp_reduces_tp_wire():
    cfg = get_config("mamba2-370m")
    t0 = train_step_terms(cfg, seq=4096, batch=256, mesh=MESH)
    t1 = train_step_terms(cfg, seq=4096, batch=256, mesh=MESH,
                          sp_activations=True)
    assert t1.notes["tp_allreduce"] == pytest.approx(
        0.5 * t0.notes["tp_allreduce"])


def test_int8_reduces_rs_bytes_4x():
    cfg = get_config("yi-34b")
    t0 = train_step_terms(cfg, seq=4096, batch=256, mesh=MESH)
    t1 = train_step_terms(cfg, seq=4096, batch=256, mesh=MESH,
                          grad_compression="int8")
    assert t1.notes["fsdp_rs"] == pytest.approx(0.25 * t0.notes["fsdp_rs"])
    assert t1.notes["fsdp_ag"] == t0.notes["fsdp_ag"]   # gathers unchanged


def test_bucketing_cuts_op_count():
    cfg = get_config("yi-34b")
    t0 = train_step_terms(cfg, seq=4096, batch=256, mesh=MESH)
    t1 = train_step_terms(cfg, seq=4096, batch=256, mesh=MESH,
                          bucket_bytes=64 * 2 ** 20)
    assert t1.notes["coll_ops"] < t0.notes["coll_ops"]


def test_replicated_serve_weights_drop_gather():
    cfg = get_config("olmoe-1b-7b")
    t0 = decode_step_terms(cfg, seq=32768, batch=128, mesh=MESH)
    t1 = decode_step_terms(cfg, seq=32768, batch=128, mesh=MESH,
                           replicate_serve_weights=True)
    assert "fsdp_ag" in t0.notes and "fsdp_ag" not in t1.notes
    assert t1.coll_bytes < 0.1 * t0.coll_bytes


@given(arch=st.sampled_from(list(ARCHS)))
@settings(**SET)
def test_all_terms_finite_positive(arch):
    cfg = get_config(arch)
    for fn, kw in ((train_step_terms, dict(seq=4096, batch=256)),
                   (prefill_step_terms, dict(seq=32768, batch=32)),
                   (decode_step_terms, dict(seq=32768, batch=128))):
        t = fn(cfg, mesh=MESH, **kw)
        assert t.flops > 0 and t.hbm_bytes > 0 and t.coll_bytes >= 0


def test_window_attention_cheaper_than_global():
    g3 = get_config("gemma3-1b")           # 5:1 local:global, window 512
    t_local = train_step_terms(g3, seq=32768, batch=32, mesh=MESH)
    # hypothetical all-global variant of the same config
    import dataclasses
    kv = {f.name: getattr(g3, f.name) for f in dataclasses.fields(g3)}
    kv["pattern_period"] = None
    g3_global = type(g3)(**kv)
    t_global = train_step_terms(g3_global, seq=32768, batch=32, mesh=MESH)
    assert t_local.flops < t_global.flops
