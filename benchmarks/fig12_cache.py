"""Paper Fig. 12 analog: LSU-cache hit rate sweep on indirect kernels.

Hit rate maps to gather-window locality (DESIGN.md §2): the VMEM-resident
window serves `hit_rate` of accesses; misses pay per-element HBM latency.
Rates {0,40,60,70,80,90}% as in the paper (10-30% unachievable there)."""
from __future__ import annotations

from repro.core import CoarseningConfig, plan_stream
from repro.core import analysis as A
from benchmarks.common import emit

N_MODEL = 1 << 26
RATES = (0.0, 0.4, 0.6, 0.7, 0.8, 0.9)
DEGREES = (2, 4, 8)


def main():
    for rate in RATES:
        kw = dict(n_loads=8, arith_per_elem=6.0, hit_rate=rate,
                  window_elems=8192)
        base = A.gather_cost(plan_stream(N_MODEL, CoarseningConfig(),
                                         block=1024), **kw)
        for fam in ("con", "gap", "pipe"):
            best = None
            for d in DEGREES:
                c = A.gather_cost(
                    plan_stream(N_MODEL, CoarseningConfig.parse(f"{fam}{d}"),
                                block=1024), **kw)
                if best is None or c.modeled_s < best[1].modeled_s:
                    best = (d, c)
            d, c = best
            emit(f"fig12,hit{int(rate * 100)},{fam}{d}", -1,
                 c.modeled_s * 1e6,
                 speedup=round(base.modeled_s / c.modeled_s, 2))


if __name__ == "__main__":
    main()
