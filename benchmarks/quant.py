"""Quantization table: dense bf16 vs dequant-fused int8/int4 kernels at
fixed coarsening degrees and AUTO, plus the int8-KV decode row.

For the model-scale grouped-expert MoE point and the FFN matmul point emit:

  bf16,conN      the dense kernel at fixed consecutive degrees
  int8/int4,conN the dequant-fused kernel: packed weight panes (2-4x fewer
                 bytes per pane), per-program VMEM dequant
  *,AUTO[label]  the repro.tune pick over the full (kind, degree) space —
                 quantized specs carry wbits/group and can (and do) pick a
                 DIFFERENT degree than the dense spec of the same geometry,
                 because the packed panes move the memory/compute crossover

`derived` is the modeled v5e time (core/analysis with the quant byte +
dequant terms); `us_per_call` is CPU interpret wall time at a reduced
geometry (transparency only; -1 when not measured).  The acceptance
direction: every quantized AUTO row beats its dense AUTO counterpart in
modeled time, and at least one geometry shows distinct winning degrees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import CoarseningConfig
from repro.core.analysis import (decode_attention_cost, matmul_cost,
                                 moe_ffn_cost)
from repro.kernels import ops
from repro.models.layers import moe_default_capacity
from repro.quant import quantize, quantize_kv
from repro.tune import KernelSpec, search
from benchmarks.common import wall_us, emit

# modeled (paper-scale) geometries
MOE = (64, 128, 2048, 1024)            # e, cap, d, f  (olmoe-like)
MM = (4096, 2048, 4096)                # m, n, k       (ffn matmul tile)
DEC = (8, 16, 4, 4096, 128)            # b, h, hkv, s, d
# measured (CPU interpret) geometry
ME, MCAP, MD, MF = 16, 8, 64, 128
DEGREES = (1, 2, 4, 8)
MODES = (None, 8, 4)                   # wbits: dense, int8, int4


def _mode_name(wbits):
    return {None: "bf16", 8: "int8", 4: "int4"}[wbits]


def _moe_measured(cfg, wbits):
    key = jax.random.PRNGKey(0)
    xe = jax.random.normal(key, (ME, MCAP, MD)) * 0.5
    w1 = jax.random.normal(jax.random.fold_in(key, 1), (ME, MD, MF)) / 8
    w3 = jax.random.normal(jax.random.fold_in(key, 2), (ME, MD, MF)) / 8
    w2 = jax.random.normal(jax.random.fold_in(key, 3), (ME, MF, MD)) / 11
    wts = jax.random.uniform(jax.random.fold_in(key, 4), (ME, MCAP))
    if ME % cfg.degree:
        return -1.0
    if wbits is None:
        return wall_us(lambda: ops.moe_ffn(xe, w1, w3, w2, wts, cfg))
    mode = "int8" if wbits == 8 else "int4"
    q1, q3, q2 = (quantize(w, mode) for w in (w1, w3, w2))
    return wall_us(lambda: ops.quant_moe_ffn(xe, q1, q3, q2, wts, cfg))


def _spec(family, shape, wbits, **params):
    if wbits:
        params.update(wbits=wbits, group=32 if wbits == 4 else 0)
    return KernelSpec.make(family, shape, dtype="bfloat16", **params)


def main() -> None:
    # ---- grouped-expert MoE FFN ----
    e, cap, d, f = MOE
    base = moe_ffn_cost(e, cap, d, f, CoarseningConfig()).modeled_s
    for wbits in MODES:
        kw = {"wbits": wbits, "group": 32} if wbits else {}
        name = f"quant,moe,E{e}xC{cap},{_mode_name(wbits)}"
        for deg in DEGREES:
            cfg = CoarseningConfig.parse(f"con{deg}" if deg > 1 else "none")
            c = moe_ffn_cost(e, cap, d, f, cfg, **kw)
            emit(f"{name},con{deg}",
                 _moe_measured(cfg, wbits), c.modeled_s * 1e6,
                 speedup=round(base / c.modeled_s, 2))
        best = search(_spec("moe_ffn", MOE, wbits)).best
        c = moe_ffn_cost(e, cap, d, f, best, **kw)
        emit(f"{name},AUTO[{best.label}]",
             _moe_measured(best, wbits), c.modeled_s * 1e6,
             speedup=round(base / c.modeled_s, 2))

    # ---- blocked FFN matmul (quantized B operand) ----
    m, n, k = MM
    base = matmul_cost(m, n, k, CoarseningConfig(), bk=256).modeled_s
    for wbits in MODES:
        kw = {"wbits": wbits, "group": 32} if wbits else {}
        name = f"quant,matmul,{m}x{n}x{k},{_mode_name(wbits)}"
        for deg in (1, 4, 8):
            cfg = CoarseningConfig.parse(f"con{deg}" if deg > 1 else "none")
            c = matmul_cost(m, n, k, cfg, bk=256, **kw)
            emit(f"{name},con{deg}", -1.0, c.modeled_s * 1e6,
                 speedup=round(base / c.modeled_s, 2))
        best = search(_spec("matmul", MM, wbits, bm=128, bn=128, bk=256)).best
        c = matmul_cost(m, n, k, best, bk=256, **kw)
        emit(f"{name},AUTO[{best.label}]", -1.0, c.modeled_s * 1e6,
             speedup=round(base / c.modeled_s, 2))

    # ---- int8-KV split-KV decode attention ----
    b, h, hkv, s, dd = DEC
    base = decode_attention_cost(b, h, hkv, s, dd, CoarseningConfig()).modeled_s
    for kv_bits in (None, 8):
        kw = {} if kv_bits is None else {"kv_bits": kv_bits}
        nm = f"quant,decode,S{s},{'bf16' if kv_bits is None else 'int8kv'}"
        for deg in (1, 4, 8):
            cfg = CoarseningConfig.parse(f"con{deg}" if deg > 1 else "none")
            c = decode_attention_cost(b, h, hkv, s, dd, cfg, **kw)
            emit(f"{nm},con{deg}", _decode_measured(cfg, kv_bits),
                 c.modeled_s * 1e6, speedup=round(base / c.modeled_s, 2))
        spec = KernelSpec.make("decode_attention", DEC,
                               dtype="int8" if kv_bits else "bfloat16",
                               bkv=128, window=0, **kw)
        best = search(spec).best
        c = decode_attention_cost(b, h, hkv, s, dd, best, **kw)
        emit(f"{nm},AUTO[{best.label}]", _decode_measured(best, kv_bits),
             c.modeled_s * 1e6, speedup=round(base / c.modeled_s, 2))


def _decode_measured(cfg, kv_bits, *, b=2, h=4, hkv=2, s=256, d=32, bkv=64):
    key = jax.random.PRNGKey(0)
    if s % (bkv * cfg.degree):
        return -1.0
    q = jax.random.normal(key, (b, 1, h, d))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    pos = jnp.full((b,), s - 1, jnp.int32)
    if kv_bits:
        kq, ks = quantize_kv(kc)
        vq, vs = quantize_kv(vc)
        return wall_us(lambda: ops.decode_attention(
            q, kq, vq, pos, cfg, bkv=bkv, k_scale=ks, v_scale=vs))
    return wall_us(lambda: ops.decode_attention(q, kc, vc, pos, cfg, bkv=bkv))


if __name__ == "__main__":
    main()
