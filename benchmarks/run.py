# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

  PYTHONPATH=src python -m benchmarks.run [--only fig11,fig12]

Tables (one per paper figure):
  fig8   — application suite x {Con,Gap,Pipe,SIMD} x degree (Fig. 8/9)
  fig10  — memory-access type x control-flow divergence (Fig. 10)
  fig11  — arithmetic-intensity sweep (Fig. 11)
  fig12  — LSU-cache hit-rate sweep (Fig. 12)
  fig13  — divergence-degree sweep (Fig. 13)
  coll   — beyond-paper: collective bucket-coarsening
  roofline — §Roofline per (arch x shape), analytic terms
  tuned  — autotuner pick vs base vs the paper's fixed degrees
  decode — dense einsum baseline vs coarsened split-KV decode attention
  moe    — unfused einsum baseline vs the fused grouped-expert MoE FFN
  attention — mea baseline vs the custom-VJP coarsened flash kernel
              (fwd and fwd·bwd rows; fwd/bwd degrees tuned independently)
  quant  — dense bf16 vs dequant-fused int8/int4 weight kernels and the
           int8-KV decode path, fixed degrees vs AUTO (quantized specs can
           pick different winning degrees than dense ones)
  paging — paged-KV serving: admitted tokens at a fixed HBM budget vs the
           contiguous per-slot cache (heterogeneous trace), block-table
           paged decode kernel cost, end-to-end scheduler tok/s
  specdecode — speculative decoding: per-family winning degrees at one
           geometry (decode vs verify vs prefill), short-q verify kernel
           cost across draft depths, end-to-end SpecPagedEngine parity +
           acceptance under forced rejections and a self-draft
  sparse_attention — block-sparse long-context attention: live-block
           visits and modeled cost vs the dense causal grid across 4k-64k
           contexts, the two families' distinct winners at the pinned
           shape, and the gemma3-1b shrink 8k-context CI smoke
  robustness — serving under pressure: swap-resume vs recompute eviction
           (recovered vs re-prefilled tokens, gate recovery_x >= 2),
           goodput under deadline load, suspend/resume overhead, and a
           seeded fault-injection trace pinned bitwise to the clean run

--json additionally writes each selected table's rows to
experiments/BENCH_<name>.json as an append-only trajectory artifact, so
later PRs can track (e.g.) serving perf across the stack's history.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (fig8_apps, fig10_mem_divergence, fig11_ai,
                        fig12_cache, fig13_divdeg, collectives_coarsening,
                        roofline, tuned, decode, moe, attention, quant,
                        paging, specdecode, sparse_attention, robustness)
from benchmarks.common import ROWS

TABLES = {
    "fig8": fig8_apps.main,
    "fig10": fig10_mem_divergence.main,
    "fig11": fig11_ai.main,
    "fig12": fig12_cache.main,
    "fig13": fig13_divdeg.main,
    "coll": collectives_coarsening.main,
    "roofline": roofline.main,
    "tuned": tuned.main,
    "decode": decode.main,
    "moe": moe.main,
    "attention": attention.main,
    "quant": quant.main,
    "paging": paging.main,
    "specdecode": specdecode.main,
    "sparse_attention": sparse_attention.main,
    "robustness": robustness.main,
}

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")


def _append_trajectory(name: str, rows: list) -> str:
    """Append this run's rows for one table to its BENCH_<name>.json
    trajectory file (a list of runs, newest last)."""
    path = os.path.join(EXPERIMENTS, f"BENCH_{name}.json")
    runs = []
    try:
        with open(path) as f:
            prev = json.load(f)
        if isinstance(prev, list):
            runs = prev
    except (OSError, ValueError):
        pass
    runs.append({"run": len(runs), "rows": rows})
    os.makedirs(EXPERIMENTS, exist_ok=True)
    with open(path, "w") as f:
        json.dump(runs, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated table subset")
    ap.add_argument("--json", action="store_true",
                    help="write per-table BENCH_<name>.json trajectories")
    args, _ = ap.parse_known_args()
    names = args.only.split(",") if args.only else list(TABLES)
    print("name,us_per_call,derived")
    for name in names:
        print(f"# --- {name} ---")
        start = len(ROWS)
        TABLES[name]()
        if args.json:
            path = _append_trajectory(name, ROWS[start:])
            print(f"# appended {len(ROWS) - start} rows to {path}")
    out = os.path.join(EXPERIMENTS, "bench_rows.json")
    os.makedirs(EXPERIMENTS, exist_ok=True)
    with open(out, "w") as f:
        json.dump(ROWS, f, indent=1)
    print(f"# wrote {len(ROWS)} rows")


if __name__ == '__main__':
    main()
