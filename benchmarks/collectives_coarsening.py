"""Beyond-paper: the coalescing insight applied to ICI collectives.

Compares per-tensor all-reduce (many narrow) vs bucket-coarsened (few wide)
on (a) HLO collective-op count, (b) CPU wall time on an 8-device fake mesh is
not possible here (main process holds 1 device), so we report the modeled ICI
time: t = n_ops * latency + bytes/bw, latency ~ 1us/op, bw 50GB/s."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import plan_buckets
from benchmarks.common import emit

LAT = 1e-6
BW = 50e9


def main():
    # gradient set shaped like qwen3-0.6b per-device shards
    rng = np.random.default_rng(0)
    shapes = [(151936 // 16, 64), (1024, 192), (1024, 64), (128,), (1024,),
              (192, 1024), (64, 1024)] * 28
    grads = {f"g{i}": jnp.zeros(s) for i, s in enumerate(shapes)}
    total_bytes = sum(int(np.prod(s)) * 4 for s in shapes)

    t_narrow = len(shapes) * LAT + total_bytes / BW
    emit("coll,pertensor", -1, t_narrow * 1e6, ops=len(shapes),
         mbytes=round(total_bytes / 1e6, 1))
    for mb in (8, 64, 256):
        plan = plan_buckets(grads, bucket_bytes=mb * 2 ** 20)
        n = len(plan.sizes)
        t = n * LAT + total_bytes / BW
        emit(f"coll,bucket{mb}MB", -1, t * 1e6, ops=n,
             speedup=round(t_narrow / t, 2))


if __name__ == "__main__":
    main()
