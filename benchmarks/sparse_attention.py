"""Block-sparse long-context attention table: live-block visits and modeled
cost vs the dense causal grid across context lengths 4k-64k.

Per context length S (window=512 local attention) emit:

  visits      dense causal-live kv-block steps vs the NULL-padded live
              index's non-null entries — the traffic the sparse kernel
              actually issues (`ratio` is the visit reduction; the ISSUE
              gate is >= 8x at 32k)
  dense/...   modeled v5e time of the dense-mask flash kernel at its AUTO
              degree (its own `flash_attention` family pick)
  sparse/...  modeled time of the block-sparse kernel at fixed live-slot
              degrees and at the `flash_attention_sparse` family's AUTO
              pick; `speedup` is vs the dense AUTO row (gate: >= 2x at 32k)

Then two pinned rows:

  winners     the two families' AUTO picks at S=33280 (260 q-blocks): the
              dense family's q-row coarsening cannot tile degree 8 there
              while the sparse family's slot axis can — the degrees MUST
              differ (test_tune.py::test_sparse_family_picks_its_own_degree
              pins the same shape)
  wall        CPU-interpret wall time sparse vs dense kernel at a reduced
              geometry (transparency only, as everywhere in benchmarks/)

And the long-context CI smoke: a gemma3-1b shrink-profile forward at 8k
context under attn_backend="pallas" (sparse routing on its window=16 local
layers), asserting finite output — the row CI reads from
BENCH_sparse_attention.json.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CoarseningConfig
from repro.core.analysis import flash_attention_sparse_cost
from repro.kernels import ops
from repro.kernels.sparse_attention import build_block_index, make_kernel
from repro.tune import KernelSpec, search
from benchmarks.common import wall_us, emit

# modeled (paper-scale) geometry
B, HKV, G, D, BQ, BKV = 1, 4, 4, 128, 128, 128
H = HKV * G
WINDOW = 512
LENGTHS = (4096, 8192, 16384, 32768, 65536)
DEGREES = (1, 2, 4, 8)

# measured (CPU interpret) geometry
MB, MHKV, MG, MD, MBQ, MBKV = 1, 2, 2, 32, 64, 64
MH = MHKV * MG
MS, MW = 1024, 128


def _dense_visits(s: int, bq: int, bkv: int) -> int:
    """Causal-live kv-block steps of the dense grid (credits its causal
    early-exit; the window-dead steps are the waste the index removes)."""
    return sum((i * bq + bq - 1) // bkv + 1 for i in range(s // bq))


def _sparse_auto(s: int, ml: int, nl: int):
    spec = KernelSpec.make("flash_attention_sparse", (B, H, HKV, s, s, D),
                           dtype="bfloat16", bq=BQ, bkv=BKV, causal=True,
                           window=WINDOW, gstride=0, max_live=ml, n_live=nl)
    return search(spec).best


def _dense_auto(s: int):
    spec = KernelSpec.make("flash_attention", (B, H, HKV, s, s, D),
                           dtype="bfloat16", bq=BQ, bkv=BKV, causal=True,
                           window=0)
    return search(spec).best


def _wall_rows() -> None:
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (MB, MH, MS, MD), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (MB, MHKV, MS, MD), jnp.float32) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (MB, MHKV, MS, MD), jnp.float32)
    idx = build_block_index(MS, MS, MBQ, MBKV, causal=True, window=MW)
    cfg = CoarseningConfig.parse("con2")
    sp = make_kernel(MB, MH, MHKV, MS, MD, cfg, bq=MBQ, bkv=MBKV,
                     max_live=idx.shape[1], causal=True, window=MW)
    f_sp = jax.jit(lambda a, b2, c: sp(a, b2, c, idx))
    us_sp = wall_us(lambda: f_sp(q, k, v))
    f_dn = jax.jit(lambda a, b2, c: ops.flash_attention(
        a, b2, c, cfg, bq=MBQ, bkv=MBKV, causal=True, window=MW))
    us_dn = wall_us(lambda: f_dn(q, k, v))
    emit(f"sparse_attn,wall,S{MS},w{MW},dense/con2", us_dn, -1.0)
    emit(f"sparse_attn,wall,S{MS},w{MW},sparse/con2", us_sp, -1.0,
         speedup=round(us_dn / us_sp, 2))


def _ci_smoke() -> None:
    """gemma3-1b shrink profile, 8k-token prefill forward through the
    sparse-routed pallas backend (the long-context CI smoke)."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("gemma3-1b").reduced(),
                              attn_backend="pallas")
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, 8192), 0, cfg.vocab)
    f = jax.jit(lambda p, b: M.lm_apply(p, b, cfg)[0])
    us = wall_us(lambda: f(params, {"tokens": tok}), reps=1)
    hidden = np.asarray(f(params, {"tokens": tok}), np.float32)
    ok = bool(np.isfinite(hidden).all())
    emit("sparse_attn,smoke,gemma3-1b-shrink,S8192", us, -1.0,
         status="ok" if ok else "FAIL")
    assert ok


def main() -> None:
    for s in LENGTHS:
        idx = build_block_index(s, s, BQ, BKV, causal=True, window=WINDOW)
        ml, nl = int(idx.shape[1]), int((idx >= 0).sum())
        dv = _dense_visits(s, BQ, BKV)
        emit(f"sparse_attn,S{s},visits", -1.0, -1.0, dense=dv, sparse=nl,
             ratio=round(dv / nl, 1))
        best_d = _dense_auto(s)
        from repro.core.analysis import flash_attention_cost
        cd = flash_attention_cost(B, H, HKV, s, s, D, best_d, bq=BQ, bkv=BKV)
        emit(f"sparse_attn,S{s},dense/AUTO[{best_d.label}]", -1.0,
             cd.modeled_s * 1e6, speedup=1.0)
        for deg in DEGREES:
            if ml % deg:
                emit(f"sparse_attn,S{s},sparse/con{deg}", -1, -1, status="NA")
                continue
            cfg = CoarseningConfig.parse(f"con{deg}" if deg > 1 else "none")
            cs = flash_attention_sparse_cost(B, H, HKV, s, s, D, cfg, bq=BQ,
                                             bkv=BKV, max_live=ml, n_live=nl)
            emit(f"sparse_attn,S{s},sparse/con{deg}", -1.0,
                 cs.modeled_s * 1e6,
                 speedup=round(cd.modeled_s / cs.modeled_s, 2))
        best_s = _sparse_auto(s, ml, nl)
        cs = flash_attention_sparse_cost(B, H, HKV, s, s, D, best_s, bq=BQ,
                                         bkv=BKV, max_live=ml, n_live=nl)
        emit(f"sparse_attn,S{s},sparse/AUTO[{best_s.label}]", -1.0,
             cs.modeled_s * 1e6,
             speedup=round(cd.modeled_s / cs.modeled_s, 2))

    # pinned distinct-winner shape (shared with tests/test_tune.py)
    s = 33280
    idx = build_block_index(s, s, BQ, BKV, causal=True, window=WINDOW)
    ml, nl = int(idx.shape[1]), int((idx >= 0).sum())
    best_s, best_d = _sparse_auto(s, ml, nl), _dense_auto(s)
    emit(f"sparse_attn,S{s},winners", -1.0, -1.0,
         sparse=best_s.label, dense=best_d.label,
         distinct=best_s.degree != best_d.degree)

    _wall_rows()
    _ci_smoke()


if __name__ == "__main__":
    main()
