"""Paper Fig. 13 analog: work-item divergence degree {0,2,4} x direct/indirect."""
from __future__ import annotations

import jax

from repro.core import CoarseningConfig, plan_stream
from repro.core import analysis as A
from repro.kernels import ops
from benchmarks.common import wall_us, emit

N_MODEL = 1 << 26
N = 1 << 15
DEGREES = (2, 4, 8)
DIV = (0, 2, 4)


def main():
    key = jax.random.PRNGKey(0)
    inputs = tuple(jax.random.normal(jax.random.fold_in(key, i), (N,))
                   for i in range(8))
    for deg in DIV:
        paths = max(1, deg)
        base = A.stream_cost(plan_stream(N_MODEL, CoarseningConfig(),
                                         block=1024),
                             n_loads=8, arith_per_elem=6.0,
                             divergence_paths=paths)
        for fam in ("con", "gap", "pipe"):
            best = None
            for d in DEGREES:
                c = A.stream_cost(
                    plan_stream(N_MODEL, CoarseningConfig.parse(f"{fam}{d}"),
                                block=1024),
                    n_loads=8, arith_per_elem=6.0, divergence_paths=paths)
                if best is None or c.modeled_s < best[1].modeled_s:
                    best = (d, c)
            d, c = best
            us = -1.0
            if fam == "con" and deg in (0, 2, 4):
                variant = {0: "base", 2: "div2", 4: "div4"}[deg]
                us = wall_us(lambda *xs: ops.ew_stream(
                    xs, CoarseningConfig.parse(f"con{d}"), ai=6,
                    variant=variant, block=512), *inputs)
            emit(f"fig13,div{deg},direct,{fam}{d}", us, c.modeled_s * 1e6,
                 speedup=round(base.modeled_s / c.modeled_s, 2))
        base_i = A.gather_cost(plan_stream(N_MODEL, CoarseningConfig(),
                                           block=1024),
                               n_loads=8, arith_per_elem=6.0 * paths,
                               hit_rate=0.854, window_elems=8192)
        for fam in ("con", "gap", "pipe"):
            best = None
            for d in DEGREES:
                c = A.gather_cost(
                    plan_stream(N_MODEL, CoarseningConfig.parse(f"{fam}{d}"),
                                block=1024),
                    n_loads=8, arith_per_elem=6.0 * paths,
                    hit_rate=0.854, window_elems=8192)
                if best is None or c.modeled_s < best[1].modeled_s:
                    best = (d, c)
            d, c = best
            emit(f"fig13,div{deg},indirect,{fam}{d}", -1, c.modeled_s * 1e6,
                 speedup=round(base_i.modeled_s / c.modeled_s, 2))


if __name__ == "__main__":
    main()
