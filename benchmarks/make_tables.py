"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run artifacts + the analytic perf model.

    PYTHONPATH=src python -m benchmarks.make_tables > experiments/tables.md
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, SHAPES, get_config
from repro.core.perfmodel import MeshInfo
from repro.core.rooflines import PEAK_FLOPS_BF16, HBM_BW, LINK_BW
from benchmarks.roofline import roofline_row, cell_terms

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_artifacts():
    out = {}
    for p in glob.glob(os.path.join(ART, "*.json")):
        d = json.load(open(p))
        key = (d["arch"], d["shape"], d["mesh"],
               tuple(sorted(d.get("overrides", {}).items())))
        out[key] = d
    return out


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def dryrun_table(arts) -> str:
    lines = [
        "| arch | shape | mesh | temp GiB/dev | args GiB/dev | HLO collectives "
        "(static count) | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                d = arts.get((arch, shape, mesh, ()))
                if d is None:
                    continue
                lines.append(
                    f"| {arch} | {shape} | {mesh} | "
                    f"{fmt_bytes(d['temp_size'])} | "
                    f"{fmt_bytes(d['argument_size'])} | "
                    f"{d['collectives']['count']} | {d['compile_s']} |")
    return "\n".join(lines)


def roofline_table() -> str:
    mesh = MeshInfo(dp=16, tp=16)
    lines = [
        "| arch | shape | compute s | memory s | collective s | bound | "
        "roofline frac | MODEL_FLOPS/HLO | one-line fix |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    fixes = {
        "collective": "cut TP AR wire (Megatron-SP) / compress+bucket DP grads",
        "memory": "decode: KV-cache bound — quantize KV or widen batch",
        "compute": "at roofline — increase arithmetic efficiency (fusion)",
    }
    for arch in ARCHS:
        for shape in SHAPES:
            r = roofline_row(arch, shape, mesh)
            if r is None:
                lines.append(f"| {arch} | {shape} | - | - | - | SKIP "
                             f"(full attention @500k, DESIGN.md) | - | - | - |")
                continue
            lines.append(
                f"| {arch} | {shape} | {r['compute_s']:.3e} | "
                f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                f"{r['bound']} | {r['roofline_frac']:.3f} | "
                f"{r['useful_ratio']:.2f} | {fixes[r['bound']]} |")
    return "\n".join(lines)


def optimized_table() -> str:
    """Same cells with the §Perf levers on: SP residuals + int8/bucketed DP
    grads for train/prefill, replicated serve weights for decode."""
    mesh = MeshInfo(dp=16, tp=16)
    lines = [
        "| arch | shape | baseline frac | optimized frac | bound after |",
        "|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            base = roofline_row(arch, shape, mesh)
            if base is None:
                continue
            kind = SHAPES[shape]["kind"]
            if kind == "decode":
                opt = roofline_row(arch, shape, mesh,
                                   replicate_serve_weights=True)
            elif kind == "train":
                opt = roofline_row(arch, shape, mesh, sp_activations=True,
                                   grad_compression="int8",
                                   bucket_bytes=64 * 2 ** 20,
                                   n_micro=2, moe_combine_bf16=True)
            else:
                opt = roofline_row(arch, shape, mesh, sp_activations=True)
            lines.append(
                f"| {arch} | {shape} | {base['roofline_frac']:.3f} | "
                f"{opt['roofline_frac']:.3f} | {opt['bound']} |")
    return "\n".join(lines)


def main():
    arts = load_artifacts()
    print("## §Dry-run artifacts (compiled on the production meshes)\n")
    print(dryrun_table(arts))
    print(f"\n({len(arts)} artifacts in experiments/dryrun/)\n")
    print("## §Roofline (single-pod 16x16, per device per step)\n")
    print(roofline_table())
    print("\n## §Perf optimized configuration (same cells, levers on)\n")
    print(optimized_table())


if __name__ == "__main__":
    main()
