"""§Perf hillclimb report: before/after roofline terms for the hillclimbed
cells, combining the analytic model (per-step truth for scanned programs)
with the dry-run artifacts (structural evidence: collective inventory,
memory fit).

    PYTHONPATH=src python -m benchmarks.perf_report
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config
from repro.core.perfmodel import (MeshInfo, train_step_terms,
                                  decode_step_terms)
from repro.core.rooflines import PEAK_FLOPS_BF16, HBM_BW, LINK_BW

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
MESH = MeshInfo(dp=16, tp=16)


def frac(t):
    c = t.flops / PEAK_FLOPS_BF16
    m = t.hbm_bytes / HBM_BW
    x = t.coll_bytes / LINK_BW
    step = max(c, m, x)
    return c, m, x, c / step, {c: "compute", m: "memory", x: "collective"}[step]


def art(name):
    p = os.path.join(ART, name + ".json")
    if not os.path.exists(p):
        return None
    return json.load(open(p))


def row(label, t, artifact=None):
    c, m, x, f, bound = frac(t)
    extra = ""
    if artifact:
        extra = (f" | HLO-static coll {artifact['collective_bytes'] / 2**30:.1f}GiB"
                 f" ({artifact['collectives']['count']} ops),"
                 f" temp {artifact['temp_size'] / 2**30:.1f}GiB")
    print(f"{label:54s} compute={c:9.4f}s memory={m:9.4f}s coll={x:9.4f}s "
          f"bound={bound:10s} roofline_frac={f:.3f}{extra}")
    return f


def kernel_cell():
    """Cell 0: the paper's own microbenchmark kernel (kernel-level §Perf
    loop): iterate block size x coarsening degree against the v5e DMA
    pipeline model; stop when <5% on the dominant (DMA) term."""
    from repro.core import CoarseningConfig, plan_stream
    from repro.core import analysis as A

    n = 1 << 26
    print("== Cell 0: ew_stream microbenchmark (paper-representative) ==")
    path = [
        ("baseline block=1024 (4KiB DMA/operand)", "none", 1024),
        ("con8 (one 32KiB DMA; 8x fewer descriptors)", "con8", 1024),
        ("con8 + block=4096 (128KiB DMA)", "con8", 4096),
        ("con8 + block=32768 (1MiB DMA)", "con8", 32768),
        ("con8 + block=131072 (4MiB DMA; 72MiB VMEM)", "con8", 131072),
        ("con8 + block=262144 (8MiB DMA; >VMEM if dbl-buf 9 streams)",
         "con8", 262144),
    ]
    floor = n * 9 * 4 / 819e9
    prev = None
    for label, spec, block in path:
        cfg = CoarseningConfig.parse(spec)
        c = A.stream_cost(plan_stream(n, cfg, block=block), n_loads=8,
                          arith_per_elem=6.0)
        delta = "" if prev is None else f"  ({prev / c.modeled_s:.2f}x vs prev)"
        fit = "" if c.vmem_bytes <= 128 * 2**20 else "  [VMEM OVER -> reject]"
        print(f"  {label:58s} dma/step={c.dma_s_per_step * 1e6:7.2f}us "
              f"modeled={c.modeled_s * 1e3:8.2f}ms vmem={c.vmem_bytes >> 20}MiB"
              f"{delta}{fit}")
        prev = c.modeled_s
    print(f"  HBM bandwidth floor = {floor * 1e3:.2f}ms; stop at block=131072 "
          f"(1.2x floor; the only faster candidate violates the 128MiB VMEM "
          f"budget -> the working-set constraint binds, as in the paper's "
          f"FPGA resource-fit rejections)\n")


def main():
    kernel_cell()
    print("== Cell 1: mamba2-370m x train_4k (worst baseline fraction) ==")
    cfg = get_config("mamba2-370m")
    kw = dict(seq=4096, batch=256, mesh=MESH)
    f0 = row("baseline (n_micro=4)", train_step_terms(cfg, n_micro=4, **kw),
             art("mamba2-370m_train_4k_16x16"))
    row("+ Megatron-SP residuals",
        train_step_terms(cfg, n_micro=4, sp_activations=True, **kw),
        art("mamba2-370m_train_4k_16x16_sp_activations-True"))
    row("+ SP and n_micro=2 (memory headroom -> fewer gathers)",
        train_step_terms(cfg, n_micro=2, sp_activations=True, **kw),
        art("mamba2-370m_train_4k_16x16_n_micro-2_sp_activations-True"))
    f1 = row("+ int8 EF grad compression + 64MB buckets",
             train_step_terms(cfg, n_micro=2, sp_activations=True,
                              grad_compression="int8",
                              bucket_bytes=64 * 2**20, **kw))
    print(f"   -> dominant-term improvement {f1 / f0:.2f}x on roofline frac\n")

    print("== Cell 2: seamless-m4t x train_4k (most collective-bound) ==")
    cfg = get_config("seamless-m4t-large-v2")
    f0 = row("baseline (pre vocab-pad; HLO showed 191GiB static coll)",
             train_step_terms(cfg, n_micro=4, **kw))
    row("+ vocab pad-to-256 (logits shardable) + ckpt loss chunk",
        train_step_terms(cfg, n_micro=4, **kw),
        art("seamless-m4t-large-v2_train_4k_16x16"))
    f1 = row("+ int8 EF + buckets",
             train_step_terms(cfg, n_micro=4, grad_compression="int8",
                              bucket_bytes=64 * 2**20, **kw))
    print()

    print("== Cell 3: olmoe-1b-7b x decode_32k (serving; paper-insight cell) ==")
    cfg = get_config("olmoe-1b-7b")
    kwd = dict(seq=32768, batch=128, mesh=MESH)
    f0 = row("baseline (FSDP-sharded serve weights)",
             decode_step_terms(cfg, **kwd),
             art("olmoe-1b-7b_decode_32k_16x16"))
    f1 = row("+ replicated serve weights (no per-step param AG)",
             decode_step_terms(cfg, replicate_serve_weights=True, **kwd),
             art("olmoe-1b-7b_decode_32k_16x16_replicate_serve_weights-True"))
    print(f"   -> roofline frac {f0:.4f} -> {f1:.4f}\n")

    print("== Cell 4: yi-34b x train_4k (largest model; bucket coarsening) ==")
    cfg = get_config("yi-34b")
    f0 = row("baseline n_micro=16 (fit-constrained)",
             train_step_terms(cfg, n_micro=16, **kw),
             art("yi-34b_train_4k_16x16"))
    row("n_micro=8 (pre-M6 did not fit; post-M6 21.1GiB still over)",
        train_step_terms(cfg, n_micro=8, **kw),
        art("yi-34b_train_4k_16x16_n_micro-8"))
    row("n_micro=8 + SP residuals (6.1GiB -> fits)",
        train_step_terms(cfg, n_micro=8, sp_activations=True, **kw),
        art("yi-34b_train_4k_16x16_n_micro-8_sp_activations-True"))
    f1 = row("n_micro=2 + SP (12.1GiB -> fits; 8x fewer param gathers)",
             train_step_terms(cfg, n_micro=2, sp_activations=True, **kw),
             art("yi-34b_train_4k_16x16_n_micro-2_sp_activations-True"))
    f2 = row("n_micro=2 + SP + int8 EF + 64MB buckets",
             train_step_terms(cfg, n_micro=2, sp_activations=True,
                              grad_compression="int8",
                              bucket_bytes=64 * 2**20, **kw))
    print(f"   -> roofline frac {f0:.3f} -> {f1:.3f} -> {f2:.3f}")


if __name__ == "__main__":
    main()
