"""Paper Fig. 10 analog: memory-access type (direct/indirect) x control-flow
divergence, best config per optimization family."""
from __future__ import annotations

import jax

from repro.core import CoarseningConfig, plan_stream
from repro.core import analysis as A
from repro.kernels import ops
from benchmarks.common import wall_us, emit

N_MODEL = 1 << 26
N = 1 << 15

# (variant, divergence_paths, uniform?, bounded_trip)
VARIANTS = [
    ("base", 1, False, 1.0),
    ("if_id", 2, True, 1.0),
    ("if_in", 2, False, 1.0),
    ("for_const_if_id", 2, True, 1.0),
    ("for_in_if_in", 2, False, 1.6),     # worst-case bounded trips
]
FAMS = ["con", "gap", "pipe"]
DEGREES = (2, 4, 8)


def _best(fam: str, **kw):
    best = None
    for d in DEGREES:
        cfg = CoarseningConfig.parse(f"{fam}{d}")
        plan = plan_stream(N_MODEL, cfg, block=1024)
        if "hit_rate" in kw:
            c = A.gather_cost(plan, **kw)
        else:
            c = A.stream_cost(plan, **kw)
        if best is None or c.modeled_s < best[1].modeled_s:
            best = (d, c)
    return best


def main():
    key = jax.random.PRNGKey(0)
    inputs = tuple(jax.random.normal(jax.random.fold_in(key, i), (N,))
                   for i in range(8))
    for variant, paths, uniform, trips in VARIANTS:
        base_direct = A.stream_cost(
            plan_stream(N_MODEL, CoarseningConfig(), block=1024),
            n_loads=8, arith_per_elem=6.0, divergence_paths=paths,
            divergence_uniform=uniform, bounded_trip_factor=trips)
        base_ind = A.gather_cost(
            plan_stream(N_MODEL, CoarseningConfig(), block=1024),
            n_loads=8, arith_per_elem=6.0 * paths * trips,
            hit_rate=0.854, window_elems=8192)
        for fam in FAMS:
            d, c = _best(fam, n_loads=8, arith_per_elem=6.0,
                         divergence_paths=paths, divergence_uniform=uniform,
                         bounded_trip_factor=trips)
            us = -1.0
            if fam != "pipe":
                cfg = CoarseningConfig.parse(f"{fam}{d}")
                us = wall_us(lambda *xs: ops.ew_stream(
                    xs, cfg, ai=6, variant=variant, block=512), *inputs)
            emit(f"fig10,direct,{variant},{fam}{d}", us, c.modeled_s * 1e6,
                 speedup=round(base_direct.modeled_s / c.modeled_s, 2))
            di, ci = _best(fam, n_loads=8, arith_per_elem=6.0 * paths * trips,
                           hit_rate=0.854, window_elems=8192)
            emit(f"fig10,indirect,{variant},{fam}{di}", -1,
                 ci.modeled_s * 1e6,
                 speedup=round(base_ind.modeled_s / ci.modeled_s, 2))


if __name__ == "__main__":
    main()
