"""Training-attention table: the pure-jnp mea baseline vs the coarsened
custom-VJP flash kernel at fixed degrees vs AUTO, across sequence lengths,
for both the forward and the full fwd·bwd (training) step.

For each sequence length S emit a ``fwd`` and a ``fwdbwd`` row group:

  mea            the XLA chunked-flash baseline (models/layers.mea_attention):
                 the per-chunk (p, m, l, acc) carry round-trips HBM between
                 scan steps, and the backward jax.checkpoint-recomputes the
                 forward with f32 probability round trips
  con1/2/4/8     the Pallas kernel at fixed consecutive degrees — the fwd
                 row coarsens the q-row axis, the fwdbwd row additionally
                 coarsens the backward dK/dV pass on the KV-BLOCK axis at
                 the same degree
  AUTO           the repro.tune picks over the full (kind, degree) spaces —
                 forward and backward resolved INDEPENDENTLY through their
                 own families, summed for the fwdbwd row
  sparse-w*      the block-sparse live-index kernel at a window=S/4 local
                 pattern, its own family's AUTO pick — the short-context
                 end of the crossover (benchmarks/sparse_attention.py has
                 the long-context side, where it wins)

`derived` is the modeled v5e time (core/analysis.flash_attention_cost +
flash_attention_bwd_cost); `us_per_call` is CPU interpret wall time at a
reduced geometry (transparency only).  The acceptance bar: at least one
coarsened degree beats mea on the fwdbwd row at every S, and AUTO matches
or beats every fixed degree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import CoarseningConfig
from repro.core.analysis import flash_attention_cost, flash_attention_bwd_cost
from repro.kernels import ops
from repro.models.layers import mea_attention
from repro.tune import KernelSpec, search
from benchmarks.common import wall_us, emit

# modeled (paper-scale) geometry
B, HKV, G, D, BQ, BKV = 8, 4, 4, 128, 128, 128
H = HKV * G
# measured (CPU interpret) geometry
MB, MHKV, MG, MD, MBQ, MBKV = 1, 2, 2, 32, 64, 64
MH = MHKV * MG
LENGTHS = (512, 1024, 2048, 4096)
DEGREES = (1, 2, 4, 8)


def _operands(s):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (MB, MH, s, MD), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (MB, MHKV, s, MD), jnp.float32) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (MB, MHKV, s, MD), jnp.float32)
    return q, k, v


def _measured(s, cfg, bwd_cfg, grad: bool):
    """CPU interpret wall time; cfg=None times the mea baseline."""
    q, k, v = _operands(s)
    if cfg is None:
        # mea takes the (B,S,H,D) model layout
        qm, km, vm = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
        f = jax.jit(lambda a, b, c: jnp.sum(mea_attention(a, b, c,
                                                          causal=True)))
        fn = (jax.jit(jax.grad(f, argnums=(0, 1, 2))) if grad else f)
        return wall_us(lambda: fn(qm, km, vm))
    if s % (MBQ * cfg.degree) or s % (MBKV * bwd_cfg.degree):
        return -1.0
    f = jax.jit(lambda a, b, c: jnp.sum(ops.flash_attention(
        a, b, c, cfg, bwd_cfg=bwd_cfg, bq=MBQ, bkv=MBKV, causal=True)))
    fn = (jax.jit(jax.grad(f, argnums=(0, 1, 2))) if grad else f)
    return wall_us(lambda: fn(q, k, v))


def main() -> None:
    for s in LENGTHS:
        measurable = s <= 512
        dense_f = flash_attention_cost(B, H, HKV, s, s, D, CoarseningConfig(),
                                       bq=BQ, bkv=BKV, dense=True)
        dense_b = flash_attention_bwd_cost(B, H, HKV, s, s, D,
                                           CoarseningConfig(), bq=BQ,
                                           bkv=BKV, dense=True)
        dense_fb = dense_f.modeled_s + dense_b.modeled_s
        emit(f"attn,S{s},fwd,mea",
             _measured(s, None, None, False) if measurable else -1.0,
             dense_f.modeled_s * 1e6, speedup=1.0)
        emit(f"attn,S{s},fwdbwd,mea",
             _measured(s, None, None, True) if measurable else -1.0,
             dense_fb * 1e6, speedup=1.0)
        for deg in DEGREES:
            if s % (BQ * deg) or s % (BKV * deg):
                emit(f"attn,S{s},fwd,con{deg}", -1, -1, status="NA")
                emit(f"attn,S{s},fwdbwd,con{deg}", -1, -1, status="NA")
                continue
            cfg = CoarseningConfig.parse(f"con{deg}" if deg > 1 else "none")
            cf = flash_attention_cost(B, H, HKV, s, s, D, cfg, bq=BQ, bkv=BKV)
            cb = flash_attention_bwd_cost(B, H, HKV, s, s, D, cfg,
                                          q_cfg=cfg, bq=BQ, bkv=BKV)
            emit(f"attn,S{s},fwd,con{deg}",
                 _measured(s, cfg, CoarseningConfig(), False)
                 if measurable else -1.0,
                 cf.modeled_s * 1e6,
                 speedup=round(dense_f.modeled_s / cf.modeled_s, 2))
            fb = cf.modeled_s + cb.modeled_s
            emit(f"attn,S{s},fwdbwd,con{deg}",
                 _measured(s, cfg, cfg, True) if measurable else -1.0,
                 fb * 1e6, speedup=round(dense_fb / fb, 2))
        # AUTO: forward and backward tuned independently (different axes)
        spec_f = KernelSpec.make("flash_attention", (B, H, HKV, s, s, D),
                                 dtype="bfloat16", bq=BQ, bkv=BKV,
                                 causal=True)
        spec_b = KernelSpec.make("flash_attention_bwd", (B, H, HKV, s, s, D),
                                 dtype="bfloat16", bq=BQ, bkv=BKV,
                                 causal=True)
        best_f, best_b = search(spec_f).best, search(spec_b).best
        cf = flash_attention_cost(B, H, HKV, s, s, D, best_f, bq=BQ, bkv=BKV)
        emit(f"attn,S{s},fwd,AUTO[{best_f.label}]", -1.0,
             cf.modeled_s * 1e6,
             speedup=round(dense_f.modeled_s / cf.modeled_s, 2))
        cb = flash_attention_bwd_cost(B, H, HKV, s, s, D, best_b,
                                      q_cfg=best_f, bq=BQ, bkv=BKV)
        fb = cf.modeled_s + cb.modeled_s
        emit(f"attn,S{s},fwdbwd,AUTO[{best_f.label}/{best_b.label}]", -1.0,
             fb * 1e6, speedup=round(dense_fb / fb, 2))
        # the block-sparse family at a window=S/4 local pattern: its own
        # AUTO pick over live-SLOT degrees, modeled against the dense fwd
        # AUTO row (the full table lives in benchmarks/sparse_attention.py)
        from repro.core.analysis import flash_attention_sparse_cost
        from repro.kernels.sparse_attention import build_block_index
        w = s // 4
        sidx = build_block_index(s, s, BQ, BKV, causal=True, window=w)
        ml, nl = int(sidx.shape[1]), int((sidx >= 0).sum())
        spec_s = KernelSpec.make("flash_attention_sparse", (B, H, HKV, s, s, D),
                                 dtype="bfloat16", bq=BQ, bkv=BKV, causal=True,
                                 window=w, gstride=0, max_live=ml, n_live=nl)
        best_s = search(spec_s).best
        cs = flash_attention_sparse_cost(B, H, HKV, s, s, D, best_s, bq=BQ,
                                         bkv=BKV, max_live=ml, n_live=nl)
        emit(f"attn,S{s},fwd,sparse-w{w}/AUTO[{best_s.label}]", -1.0,
             cs.modeled_s * 1e6,
             speedup=round(dense_f.modeled_s / cs.modeled_s, 2))


if __name__ == "__main__":
    main()
