"""Decode-attention table: dense full-length einsum baseline vs the
coarsened split-KV kernel at fixed degrees vs AUTO, across cache lengths.

For each cache length S in 128..4k (decode pos at the end of the cache —
the hardest case for the split kernel, since length-awareness saves
nothing) emit:

  dense          the unfused XLA einsum path: full-length scan + f32
                 logits/probability HBM round-trips (models/layers.py)
  con1/2/4/8     the split-KV kernel, kv-block coarsening at fixed degrees
  AUTO           the repro.tune pick over the full candidate space

`derived` is the modeled v5e time (core/analysis.decode_attention_cost);
`us_per_call` is CPU interpret wall time at a reduced geometry (transparency
only).  The acceptance bar: every coarsened row beats dense at S >= 512 and
AUTO matches or beats every fixed degree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import CoarseningConfig
from repro.core.analysis import decode_attention_cost
from repro.kernels import ops
from repro.tune import KernelSpec, search
from benchmarks.common import wall_us, emit

# modeled (paper-scale) geometry
B, HKV, G, D, BKV = 8, 8, 4, 128, 128
H = HKV * G
# measured (CPU interpret) geometry
MB, MHKV, MG, MD, MBKV = 2, 2, 2, 32, 64
MH = MHKV * MG
LENGTHS = (128, 256, 512, 1024, 2048, 4096)
DEGREES = (1, 2, 4, 8)


def _measured_fn(s, cfg):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (MB, 1, MH, MD), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1),
                           (MB, s, MHKV, MD), jnp.float32)
    vc = jax.random.normal(jax.random.fold_in(key, 2),
                           (MB, s, MHKV, MD), jnp.float32)
    pos = jnp.full((MB,), s - 1, jnp.int32)
    if cfg is None:
        from repro.kernels import ref
        return wall_us(lambda: ref.decode_attention(q, kc, vc, pos))
    if s % (MBKV * cfg.degree):
        return -1.0
    return wall_us(lambda: ops.decode_attention(q, kc, vc, pos, cfg,
                                                bkv=MBKV))


def main() -> None:
    for s in LENGTHS:
        pos = s - 1
        dense = decode_attention_cost(B, H, HKV, s, D, CoarseningConfig(),
                                      bkv=BKV, dense=True)
        emit(f"decode,S{s},dense",
             _measured_fn(s, None) if s <= 1024 else -1.0,
             dense.modeled_s * 1e6, speedup=1.0)
        for deg in DEGREES:
            if s % (BKV * deg):
                emit(f"decode,S{s},con{deg}", -1, -1, status="NA")
                continue
            cfg = CoarseningConfig.parse(f"con{deg}" if deg > 1 else "none")
            c = decode_attention_cost(B, H, HKV, s, D, cfg, bkv=BKV,
                                      kv_len=pos + 1)
            emit(f"decode,S{s},con{deg}",
                 _measured_fn(s, cfg) if s <= 1024 else -1.0,
                 c.modeled_s * 1e6,
                 speedup=round(dense.modeled_s / c.modeled_s, 2))
        spec = KernelSpec.make("decode_attention", (B, H, HKV, s, D),
                               dtype="bfloat16", bkv=BKV, window=0)
        best = search(spec).best
        c = decode_attention_cost(B, H, HKV, s, D, best, bkv=BKV,
                                  kv_len=pos + 1)
        emit(f"decode,S{s},AUTO[{best.label}]", -1.0, c.modeled_s * 1e6,
             speedup=round(dense.modeled_s / c.modeled_s, 2))


if __name__ == "__main__":
    main()
