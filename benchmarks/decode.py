"""Decode-attention table: dense full-length einsum baseline vs the
coarsened split-KV kernel at fixed degrees vs AUTO, across cache lengths.

For each cache length S in 128..4k (decode pos at the end of the cache —
the hardest case for the split kernel, since length-awareness saves
nothing) emit:

  dense          the unfused XLA einsum path: full-length scan + f32
                 logits/probability HBM round-trips (models/layers.py)
  con1/2/4/8     the split-KV kernel, kv-block coarsening at fixed degrees
  AUTO           the repro.tune pick over the full candidate space

`derived` is the modeled v5e time (core/analysis.decode_attention_cost);
`us_per_call` is CPU interpret wall time at a reduced geometry (transparency
only).  The acceptance bar: every coarsened row beats dense at S >= 512 and
AUTO matches or beats every fixed degree.

Drafted-K speculative rows (`decode,spec,...`) extend the trajectory:

  decode,spec,K<k>,a<alpha>    modeled decode tok/s speedup of a drafted-K
                               verify step over K+1 plain decode steps at
                               paper scale: E(alpha,K) tokens per verify
                               against the verify-kernel + draft-chain cost
                               (attention terms; target 28 layers, draft 4).
  decode,spec,serve,...        measured (CPU interpret) engine decode tok/s
                               + acceptance: the contiguous BatchedServer,
                               the non-spec PagedEngine, and SpecPagedEngine
                               at K in {2,4,8} with a self-draft (the
                               acceptance upper bound) on one trace.

The acceptance bar: some modeled K row clears 2x at the measured self-draft
acceptance's alpha bracket.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import CoarseningConfig
from repro.core.analysis import (decode_attention_cost,
                                 flash_attention_verify_cost)
from repro.kernels import ops
from repro.tune import KernelSpec, search
from benchmarks.common import wall_us, emit

# modeled (paper-scale) geometry
B, HKV, G, D, BKV = 8, 8, 4, 128, 128
H = HKV * G
# measured (CPU interpret) geometry
MB, MHKV, MG, MD, MBKV = 2, 2, 2, 32, 64
MH = MHKV * MG
LENGTHS = (128, 256, 512, 1024, 2048, 4096)
DEGREES = (1, 2, 4, 8)


def _measured_fn(s, cfg):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (MB, 1, MH, MD), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1),
                           (MB, s, MHKV, MD), jnp.float32)
    vc = jax.random.normal(jax.random.fold_in(key, 2),
                           (MB, s, MHKV, MD), jnp.float32)
    pos = jnp.full((MB,), s - 1, jnp.int32)
    if cfg is None:
        from repro.kernels import ref
        return wall_us(lambda: ref.decode_attention(q, kc, vc, pos))
    if s % (MBKV * cfg.degree):
        return -1.0
    return wall_us(lambda: ops.decode_attention(q, kc, vc, pos, cfg,
                                                bkv=MBKV))


def main() -> None:
    for s in LENGTHS:
        pos = s - 1
        dense = decode_attention_cost(B, H, HKV, s, D, CoarseningConfig(),
                                      bkv=BKV, dense=True)
        emit(f"decode,S{s},dense",
             _measured_fn(s, None) if s <= 1024 else -1.0,
             dense.modeled_s * 1e6, speedup=1.0)
        for deg in DEGREES:
            if s % (BKV * deg):
                emit(f"decode,S{s},con{deg}", -1, -1, status="NA")
                continue
            cfg = CoarseningConfig.parse(f"con{deg}" if deg > 1 else "none")
            c = decode_attention_cost(B, H, HKV, s, D, cfg, bkv=BKV,
                                      kv_len=pos + 1)
            emit(f"decode,S{s},con{deg}",
                 _measured_fn(s, cfg) if s <= 1024 else -1.0,
                 c.modeled_s * 1e6,
                 speedup=round(dense.modeled_s / c.modeled_s, 2))
        spec = KernelSpec.make("decode_attention", (B, H, HKV, s, D),
                               dtype="bfloat16", bkv=BKV, window=0)
        best = search(spec).best
        c = decode_attention_cost(B, H, HKV, s, D, best, bkv=BKV,
                                  kv_len=pos + 1)
        emit(f"decode,S{s},AUTO[{best.label}]", -1.0, c.modeled_s * 1e6,
             speedup=round(dense.modeled_s / c.modeled_s, 2))
    spec_modeled_rows()
    spec_serve_rows()


# -- speculative decoding: drafted-K batched verify ---------------------------

SPEC_S, SPEC_PS = 2048, 128            # cache length / page size (modeled)
L_TARGET, L_DRAFT = 28, 4              # layer counts, paper-scale target
DH, DHKV, DD = 8, 2, 64                # draft attention geometry


def _auto_cost(family, shape, cost_fn, **params):
    from repro.tune import KernelSpec as KS
    best = search(KS.make(family, shape, dtype="bfloat16", **params)).best
    return best, cost_fn(best)


def spec_modeled_rows() -> None:
    """Modeled tok/s speedup of drafted-K decode at paper scale: one verify
    pass (T = K+1 short-q rows, tuned degree) plus a K+1-step draft chain
    replaces E(alpha, K) = (1-alpha^(K+1))/(1-alpha) decode steps of the
    target.  Attention terms only — the same convention as every other
    modeled row in this table."""
    npp = SPEC_S // SPEC_PS
    _, dec = _auto_cost(
        "decode_attention_paged", (B, H, HKV, npp, D),
        lambda cfg: decode_attention_cost(
            B, H, HKV, SPEC_S, D, cfg, bkv=SPEC_PS, kv_len=SPEC_S,
            page_size=SPEC_PS),
        page_size=SPEC_PS, window=0)
    _, ddec = _auto_cost(
        "decode_attention_paged", (B, DH, DHKV, npp, DD),
        lambda cfg: decode_attention_cost(
            B, DH, DHKV, SPEC_S, DD, cfg, bkv=SPEC_PS, kv_len=SPEC_S,
            page_size=SPEC_PS),
        page_size=SPEC_PS, window=0)
    step_base = L_TARGET * dec.modeled_s
    for k in (2, 4, 8):
        vbest, ver = _auto_cost(
            "flash_attention_verify", (B, H, HKV, k + 1, npp, D),
            lambda cfg: flash_attention_verify_cost(
                B, H, HKV, k + 1, SPEC_S, D, cfg, bkv=SPEC_PS,
                kv_len=SPEC_S, page_size=SPEC_PS),
            page_size=SPEC_PS, window=0)
        step_spec = L_TARGET * ver.modeled_s \
            + (k + 1) * L_DRAFT * ddec.modeled_s
        for alpha in (0.5, 0.8):
            e_tok = (1 - alpha ** (k + 1)) / (1 - alpha)
            emit(f"decode,spec,K{k},a{alpha},AUTO[{vbest.label}]", -1.0,
                 step_spec * 1e6 / e_tok,
                 tok_per_step=round(e_tok, 2),
                 speedup=round(e_tok * step_base / step_spec, 2))


def spec_serve_rows() -> None:
    """Measured (CPU interpret) engine decode tok/s on one trace: contiguous
    and paged non-spec baselines vs SpecPagedEngine at K in {2,4,8} with the
    target as its own draft — the acceptance-rate upper bound, bounded below
    1.0 only by the tie guard (see repro/serve/spec.py)."""
    import numpy as np
    from repro.configs import get_config
    from repro.models import model as M
    from repro.launch.serve import BatchedServer
    from repro.serve import PagedEngine, Scheduler, SpecPagedEngine

    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    slots, max_len, gen, ps = 3, 64, 16, 8
    prompts = [list(map(int, rng.integers(1, cfg.vocab, int(n))))
               for n in rng.integers(5, 25, 5)]

    srv = BatchedServer(cfg, params, slots=slots, max_len=max_len, chunk=16,
                        decode_block=1)
    pending = list(prompts)
    while pending or srv.any_active:
        while pending and srv.try_admit(pending[0], gen):
            pending.pop(0)
        if not srv.any_active:
            break
        srv.step()
    emit("decode,spec,serve,contiguous", -1.0, -1.0,
         decode_tok_s=round(srv.decoded_tokens / max(srv.decode_s, 1e-9), 1))

    def paged(make):
        eng = make()
        sched = Scheduler(eng)
        for p in prompts:
            sched.submit(p, gen)
        sched.run_until_done()
        return eng

    kw = dict(slots=slots, num_pages=slots * (max_len // ps) + 1,
              page_size=ps, max_len=max_len, chunk=16)
    eng = paged(lambda: PagedEngine(cfg, params, decode_block=1, **kw))
    base_tok_s = eng.decoded_tokens / max(eng.decode_s, 1e-9)
    emit("decode,spec,serve,paged", -1.0, -1.0,
         decode_tok_s=round(base_tok_s, 1))
    for k in (2, 4, 8):
        eng = paged(lambda: SpecPagedEngine(
            cfg, params, spec_k=k, draft_cfg=cfg, draft_params=params, **kw))
        tok_s = eng.decoded_tokens / max(eng.decode_s, 1e-9)
        emit(f"decode,spec,serve,K{k}", -1.0, -1.0,
             decode_tok_s=round(tok_s, 1),
             acceptance=round(eng.acceptance_rate, 3),
             tok_per_step=round(
                 eng.decoded_tokens / max(eng.spec_steps, 1), 2),
             speedup=round(tok_s / max(base_tok_s, 1e-9), 2))


if __name__ == "__main__":
    main()
