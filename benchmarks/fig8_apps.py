"""Paper Fig. 8/9 analog: the application suite under each optimization.

Apps (Rodinia/Pannotia analog, per DESIGN.md §3):
  matmul   — dense linear algebra (LU / Gaussian / NN)
  stencil  — structured grid (Hotspot)
  dp_scan  — dynamic programming (Pathfinder; sequential carry == barrier)
  gather   — graph traversal (BFS / PageRank; irregular access)

For each app x {Con,Gap,Pipe,SIMD} x degree {2,4,8}: modeled v5e time (the
speedup chart) + VMEM/DMA resource proxies (the ALUT/RAM charts).  N/A cells
mirror the paper's empty columns (gapped on sequential kernels, SIMD on
divergent kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import CoarseningConfig, plan_stream
from repro.core import analysis as A
from repro.kernels import ops
from repro.kernels import gather_stream as gs
from benchmarks.common import wall_us, emit

DEGREES = (2, 4, 8)
N = 1 << 16          # measured size (CPU interpret); model uses 64M
N_MODEL = 1 << 26    # paper: 64M-element arrays


def _variants():
    out = [("base", CoarseningConfig())]
    for d in DEGREES:
        out.append((f"con{d}", CoarseningConfig.parse(f"con{d}")))
        out.append((f"gap{d}", CoarseningConfig.parse(f"gap{d}")))
        out.append((f"pipe{d}", CoarseningConfig.parse(f"pipe{d}")))
        out.append((f"simd{d}", CoarseningConfig.parse(f"simd{d}")))
    # combined mechanisms (paper §IV.B: "not mutually exclusive")
    out.append(("con4+pipe2", CoarseningConfig.parse("con4+pipe2")))
    out.append(("con2+simd2", CoarseningConfig.parse("con2+simd2")))
    return out


def bench_matmul():
    m = n = k = 512
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k))
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    base_cost = A.matmul_cost(2048, 2048, 2048, CoarseningConfig())
    for name, cfg in _variants():
        cfgm = cfg
        try:
            us = wall_us(lambda aa, bb: ops.matmul(
                aa, bb, cfgm, bm=64, bn=128, bk=128), a, b)
        except ValueError:
            emit(f"fig8,matmul,{name}", -1, -1, status="NA")
            continue
        cost = A.matmul_cost(2048, 2048, 2048, cfgm)
        emit(f"fig8,matmul,{name}", us, cost.modeled_s * 1e6,
             speedup=round(base_cost.modeled_s / cost.modeled_s, 2),
             vmem=cost.vmem_bytes, dmas=cost.dmas_per_step)


def bench_stencil():
    rows, cols = 256, 512
    x = jax.random.normal(jax.random.PRNGKey(2), (rows, cols))
    base = A.stream_cost(plan_stream(N_MODEL, CoarseningConfig(), block=1024),
                         n_loads=3, arith_per_elem=9.0)
    for name, cfg in _variants():
        if cfg.replication > 1 or cfg.vector_width > 1:
            plan = plan_stream(N_MODEL, cfg, block=1024)
            cost = A.stream_cost(plan, n_loads=3, arith_per_elem=9.0)
            emit(f"fig8,stencil,{name}", -1, cost.modeled_s * 1e6,
                 speedup=round(base.modeled_s / cost.modeled_s, 2),
                 vmem=cost.vmem_bytes, dmas=cost.dmas_per_step)
            continue
        us = wall_us(lambda xx: ops.stencil5(xx, cfg, block_rows=8), x)
        cost = A.stream_cost(plan_stream(N_MODEL, cfg, block=1024),
                             n_loads=3, arith_per_elem=9.0)
        emit(f"fig8,stencil,{name}", us, cost.modeled_s * 1e6,
             speedup=round(base.modeled_s / cost.modeled_s, 2),
             vmem=cost.vmem_bytes, dmas=cost.dmas_per_step)


def bench_dp_scan():
    rows, cols = 128, 1024
    c = jax.random.uniform(jax.random.PRNGKey(3), (rows, cols))
    base = A.scan_cost(1_000_000, 1000 * 1024, CoarseningConfig())
    for name, cfg in _variants():
        cost = A.scan_cost(1_000_000, 1000 * 1024, cfg)
        if cost is None or cfg.vector_width > 8:
            emit(f"fig8,dp_scan,{name}", -1, -1, status="NA(gapped-carry)")
            continue
        us = -1.0
        if cfg.replication == 1 and cfg.vector_width == 1:
            us = wall_us(lambda cc: ops.dp_scan(cc, cfg), c)
        emit(f"fig8,dp_scan,{name}", us, cost.modeled_s * 1e6,
             speedup=round(base.modeled_s / cost.modeled_s, 2),
             vmem=cost.vmem_bytes, dmas=cost.dmas_per_step)


def bench_gather():
    n, table = N, 1 << 14
    idx = jnp.asarray(gs.make_indices(n, table, 4096, seed=1))
    tables = tuple(jax.random.normal(jax.random.fold_in(
        jax.random.PRNGKey(4), i), (table,)) for i in range(8))
    kw = dict(n_loads=8, arith_per_elem=6.0, hit_rate=0.854,
              window_elems=8192)
    base = A.gather_cost(plan_stream(N_MODEL, CoarseningConfig(), block=1024),
                         **kw)
    for name, cfg in _variants():
        plan = plan_stream(N_MODEL, cfg, block=1024)
        cost = A.gather_cost(plan, **kw)
        us = -1.0
        if cfg.replication == 1 and cfg.vector_width == 1:
            us = wall_us(lambda ii, *tt: ops.gather_stream(
                ii, tt, cfg, block=512), idx, *tables)
        emit(f"fig8,gather,{name}", us, cost.modeled_s * 1e6,
             speedup=round(base.modeled_s / cost.modeled_s, 2),
             vmem=cost.vmem_bytes, dmas=cost.dmas_per_step)


def main():
    bench_matmul()
    bench_stencil()
    bench_dp_scan()
    bench_gather()


if __name__ == "__main__":
    main()
