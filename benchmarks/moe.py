"""Grouped-expert MoE FFN table: unfused einsum baseline vs the fused
kernel at fixed expert-coarsening degrees vs AUTO, across (tokens,
experts, top_k) routing points.

For each point (model-scale d=2048, ff=1024, capacity = the layers.moe
default 1.5 * k * T / E) emit:

  dense          the unfused XLA path: three per-expert einsums with the
                 (E, C, ff) gate/up intermediates round-tripping HBM in f32
  con1/2/4/8     the fused grouped-expert kernel, expert-axis coarsening at
                 fixed consecutive degrees (one wide weight DMA per operand)
  AUTO           the repro.tune pick over the full (kind, degree) space

`derived` is the modeled v5e time (core/analysis.moe_ffn_cost);
`us_per_call` is CPU interpret wall time at a reduced geometry
(transparency only).  The acceptance bar: at every point with E >= 16 at
least one coarsened degree beats dense, and AUTO matches or beats every
fixed degree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import CoarseningConfig
from repro.core.analysis import moe_ffn_cost
from repro.kernels import ops, ref
from repro.models.layers import moe_default_capacity
from repro.tune import KernelSpec, search
from benchmarks.common import wall_us, emit

# modeled (paper-scale) geometry
D, FF = 2048, 1024
# measured (CPU interpret) geometry
MD, MF, MCAP = 64, 128, 8
# (tokens, experts, top_k): small routed, olmoe-1b-7b, qwen2-moe (60->64
# padded), and a wide-expert point
POINTS = ((256, 16, 2), (1024, 64, 8), (1024, 64, 4), (4096, 128, 8))
DEGREES = (1, 2, 4, 8)


def _measured_fn(e, cfg):
    key = jax.random.PRNGKey(0)
    xe = jax.random.normal(key, (e, MCAP, MD)) * 0.5
    w1 = jax.random.normal(jax.random.fold_in(key, 1), (e, MD, MF)) / 8
    w3 = jax.random.normal(jax.random.fold_in(key, 2), (e, MD, MF)) / 8
    w2 = jax.random.normal(jax.random.fold_in(key, 3), (e, MF, MD)) / 11
    wts = jax.random.uniform(jax.random.fold_in(key, 4), (e, MCAP))
    if cfg is None:
        fn = jax.jit(ref.moe_ffn)
        return wall_us(lambda: fn(xe, w1, w3, w2, wts))
    if e % cfg.degree:
        return -1.0
    return wall_us(lambda: ops.moe_ffn(xe, w1, w3, w2, wts, cfg))


def main() -> None:
    for t, e, k in POINTS:
        cap = moe_default_capacity(t, e, k)
        name = f"moe,T{t}xE{e}xK{k}"
        measurable = e <= 64
        dense = moe_ffn_cost(e, cap, D, FF, CoarseningConfig(),
                             dense=True)
        emit(f"{name},dense",
             _measured_fn(e, None) if measurable else -1.0,
             dense.modeled_s * 1e6, speedup=1.0)
        for deg in DEGREES:
            if e % deg:
                emit(f"{name},con{deg}", -1, -1, status="NA")
                continue
            cfg = CoarseningConfig.parse(f"con{deg}" if deg > 1 else "none")
            c = moe_ffn_cost(e, cap, D, FF, cfg)
            emit(f"{name},con{deg}",
                 _measured_fn(e, cfg) if measurable else -1.0,
                 c.modeled_s * 1e6,
                 speedup=round(dense.modeled_s / c.modeled_s, 2))
        spec = KernelSpec.make("moe_ffn", (e, cap, D, FF), dtype="bfloat16")
        best = search(spec).best
        c = moe_ffn_cost(e, cap, D, FF, best)
        emit(f"{name},AUTO[{best.label}]",
             _measured_fn(e, best) if measurable else -1.0,
             c.modeled_s * 1e6,
             speedup=round(dense.modeled_s / c.modeled_s, 2))


if __name__ == "__main__":
    main()
