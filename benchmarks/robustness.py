"""Robust-serving table: what graceful degradation buys under pressure.

Rows (all CPU interpret-scale; trends, not absolute numbers):

  robustness,preempt,<policy>   the SAME undersized-pool trace served with
                                swap-resume eviction vs recompute eviction.
                                ``recovered_tokens`` counts cache rows
                                restored from host without recompute;
                                ``redone_tokens`` counts the rows a policy
                                re-paid (re-prefilled prompt rows plus
                                re-decoded output rows).  recovery_x =
                                recovered / max(1, redone) for the run:
                                recompute recovers nothing and redoes
                                everything at stake (x = 0); the PR gate is
                                recovery_x >= 2 on the swap row — swap
                                recovers at least 2x more useful tokens
                                than it re-pays, where recompute re-pays
                                all of them.
  robustness,deadline,...       oversubscribed trace under deadlines +
                                queue-wait bounds: terminal-state mix and
                                goodput (completed output tokens per
                                scheduler quantum) vs the unbounded run.
  robustness,swap_overhead      wall us of one suspend+resume round trip
                                vs re-running the prefill it avoids, and
                                the host bytes one suspension holds.
  robustness,faults             a seeded FaultPlan trace (admit + growth
                                exhaustion, transient decode faults, NaN
                                rows) vs the fault-free run: injected-fault
                                counts, bitwise_equal flag, pages leaked.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit

SLOTS, PAGE, MAX_LEN, CHUNK = 3, 8, 32, 8


def _model():
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("qwen3-0.6b").reduced()
    return cfg, M.lm_init(jax.random.PRNGKey(0), cfg)


def _engine(cfg, params, num_pages, metrics=None):
    from repro.serve import PagedEngine
    return PagedEngine(cfg, params, slots=SLOTS, num_pages=num_pages,
                       page_size=PAGE, max_len=MAX_LEN, chunk=CHUNK,
                       decode_block=4, metrics=metrics)


def _trace(cfg, n, plen, rng):
    return [list(map(int, rng.integers(1, cfg.vocab, plen)))
            for _ in range(n)]


def preempt_rows(cfg, params) -> None:
    """Undersized pool (forces eviction every few quanta), long gens (lots
    of work at stake per eviction): swap vs recompute on the same trace."""
    from repro.obs import Registry
    from repro.serve import Scheduler
    prompts = _trace(cfg, 3, 6, np.random.default_rng(0))
    gen = 22
    stats = {}
    for policy, budget in (("swap", None), ("recompute", 0)):
        reg = Registry()
        eng = _engine(cfg, params, num_pages=8, metrics=reg)
        sched = Scheduler(eng, host_swap_bytes=budget, metrics=reg)
        for p in prompts:
            sched.submit(p, gen)
        t0 = time.perf_counter()
        done = sched.run_until_done()
        dt = time.perf_counter() - t0
        useful = sum(len(r.output) for r in done)
        # swap/preemption numbers come from the obs registry; the legacy
        # engine attributes are views over the same counters, asserted
        # bitwise so the two reporting paths can never drift
        recovered = int(reg.value("engine_swapped_tokens_total"))
        preempts = int(reg.value("sched_preemptions_total"))
        prefill_tok = int(reg.value("engine_prefill_tokens_total"))
        decode_tok = int(reg.value("engine_decode_tokens_total"))
        assert recovered == eng.swapped_out_tokens
        assert preempts == sum(r.preemptions for r in done)
        assert prefill_tok == eng.prefill_tokens
        assert decode_tok == eng.decoded_tokens
        # work this policy re-paid because of evictions: prompt rows
        # prefilled again + tokens emitted more than once.  Every admission
        # emits one token from the prefill logits (a recompute eviction
        # re-admits; a swap resume does not), the rest come from decode.
        admits = len(done) + sum(r.preemptions - r.swaps for r in done)
        redone = (prefill_tok - sum(len(p) for p in prompts)) \
            + (decode_tok + admits - useful)
        stats[policy] = dict(
            completed=len([r for r in done if not r.error]),
            preemptions=preempts,
            recovered_tokens=recovered,
            redone_tokens=redone,
            recovery_x=round(recovered / max(1, redone), 2),
            prefill_steps=int(reg.value("engine_prefill_steps_total")),
            decode_steps=int(reg.value("engine_decode_steps_total")),
            outputs=[r.output for r in sorted(done, key=lambda r: r.rid)],
            wall_s=dt)
        assert eng.pool.num_live == 0
        eng.pool.check()
    assert stats["swap"]["outputs"] == stats["recompute"]["outputs"], \
        "eviction policy changed a greedy stream"
    assert stats["recompute"]["redone_tokens"] > 0, \
        "trace failed to force a recompute re-prefill — weaken the pool"
    assert stats["swap"]["recovery_x"] >= 2, \
        f"swap recovery below the 2x gate: {stats['swap']}"
    for policy in ("swap", "recompute"):
        st = stats[policy]
        emit(f"robustness,preempt,{policy}", st["wall_s"] * 1e6, -1.0,
             completed=st["completed"], preemptions=st["preemptions"],
             recovered_tokens=st["recovered_tokens"],
             redone_tokens=st["redone_tokens"],
             recovery_x=st["recovery_x"],
             prefill_steps=st["prefill_steps"],
             decode_steps=st["decode_steps"])


def deadline_rows(cfg, params) -> None:
    """2x oversubscription: without bounds everything eventually finishes
    (high latency); with deadlines + queue-wait bounds the scheduler sheds
    the tail and spends its quanta on requests that can still make it."""
    from repro.obs import Registry
    from repro.serve import Scheduler, State
    prompts = _trace(cfg, 6, 6, np.random.default_rng(1))
    gen = 14
    for label, kw in (("unbounded", {}),
                      ("bounded", dict(deadline=8, max_queue_wait=3))):
        reg = Registry()
        eng = _engine(cfg, params, num_pages=10, metrics=reg)
        sched = Scheduler(eng, metrics=reg)
        for p in prompts:
            sched.submit(p, gen, **kw)
        done = sched.run_until_done()
        out_tokens = sum(len(r.output) for r in done
                         if r.state is State.FINISHED)
        # terminal-state mix from the registry, pinned against the request
        # list so the counters and the objects cannot disagree
        by = {s: int(reg.value("sched_requests_total", state=s.value))
              for s in (State.FINISHED, State.CANCELLED, State.REJECTED)}
        for s, n in by.items():
            assert n == sum(r.state is s for r in done)
        quanta = int(reg.value("sched_quanta_total"))
        assert quanta == sched.time
        emit(f"robustness,deadline,{label}", -1.0, -1.0,
             finished=by[State.FINISHED], cancelled=by[State.CANCELLED],
             rejected=by[State.REJECTED], quanta=quanta,
             goodput=round(out_tokens / max(1, quanta), 2))
        assert eng.pool.num_live == 0
        eng.pool.check()


def swap_overhead_row(cfg, params) -> None:
    """One suspend+resume round trip vs the prefill it replaces."""
    from repro.serve import Request
    eng = _engine(cfg, params, num_pages=16)
    prompt = list(map(int, np.random.default_rng(2).integers(
        1, cfg.vocab, 16)))
    req = Request(rid=0, prompt=prompt, gen=12)
    eng.admit(0, req)
    eng.decode([0])
    prefill_us = eng.prefill_s * 1e6          # what recompute re-pays
    t0 = time.perf_counter()
    susp = eng.suspend(0)
    eng.resume(1, susp)
    swap_us = (time.perf_counter() - t0) * 1e6
    emit("robustness,swap_overhead", swap_us, -1.0,
         prefill_us=round(prefill_us, 1),
         suspension_kib=round(susp.nbytes / 1024, 1),
         tokens=susp.n_tokens)
    eng.finish(1)
    eng.pool.check()


def fault_row(cfg, params) -> None:
    from repro.obs import Registry
    from repro.serve import FaultPlan, FaultyEngine, Scheduler
    prompts = _trace(cfg, 4, 6, np.random.default_rng(3))
    gen = 10

    def run(wrap, reg=None):
        eng = _engine(cfg, params, num_pages=10, metrics=reg)
        sched = Scheduler(wrap(eng), metrics=reg)
        for p in prompts:
            sched.submit(p, gen)
        done = sched.run_until_done()
        assert eng.pool.num_live == 0
        eng.pool.check()
        return eng, [r.output for r in sorted(done, key=lambda r: r.rid)]

    _, ref = run(lambda e: e)
    reg = Registry()
    plan = FaultPlan(7, p_admit=0.7, p_growth=0.2, p_transient=0.15,
                     p_nan=0.03, metrics=reg)
    eng, out = run(lambda e: FaultyEngine(e, plan), reg=reg)
    # fault numbers come from the shared obs registry; plan.stats() reads
    # the same counters, asserted bitwise so the views cannot drift
    faults = {k: int(reg.value(f"fault_{k}_total"))
              for k in ("admit", "growth", "transient")}
    faults["nan_rows"] = int(reg.value("fault_nan_rows_total"))
    st = plan.stats()
    assert faults["admit"] == st["admit_faults"]
    assert faults["growth"] == st["growth_faults"]
    assert faults["transient"] == st["transient_faults"]
    assert faults["nan_rows"] == st["nan_rows"]
    rescues = int(reg.value("engine_nan_rescues_total"))
    assert rescues == eng.nan_rescues
    emit("robustness,faults", -1.0, -1.0,
         bitwise_equal=int(out == ref), pages_leaked=eng.pool.num_live,
         nan_rescues=rescues, seed=st["seed"],
         admit_faults=faults["admit"], growth_faults=faults["growth"],
         transient_faults=faults["transient"], nan_rows=faults["nan_rows"])


def main() -> None:
    cfg, params = _model()
    preempt_rows(cfg, params)
    deadline_rows(cfg, params)
    swap_overhead_row(cfg, params)
    fault_row(cfg, params)


if __name__ == "__main__":
    main()
