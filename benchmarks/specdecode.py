"""Speculative-decode table: the short-q verify kernel family + the engine.

Rows (the CI spec-decode smoke job uploads this table as
experiments/BENCH_specdecode.json):

  specdecode,winner,<family>      the AUTO winning degree per attention
                                  family at ONE shared paper-scale geometry
                                  — decode (t=1), verify (t=K+1) and
                                  prefill pick different degrees, the
                                  tentpole's tuner story (pinned in
                                  tests/test_tune.py).
  specdecode,kernel,T<t>,...      modeled verify cost across draft depths
                                  and degrees, plus CPU interpret wall time
                                  at a reduced geometry for transparency.
  specdecode,engine,...           tiny end-to-end SpecPagedEngine runs:
                                  forced rejections (fresh random draft —
                                  acceptance ~0, pure overhead path) and a
                                  self-draft (acceptance upper bound), each
                                  checked bitwise against the non-spec
                                  PagedEngine on the same trace (`parity`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CoarseningConfig
from repro.core.analysis import (decode_attention_cost, flash_attention_cost,
                                 flash_attention_verify_cost)
from repro.kernels import ops
from repro.tune import KernelSpec, search
from benchmarks.common import wall_us, emit

# paper-scale geometry shared across the family-winner rows: a small-batch
# GQA serving shape where the three attention families split three ways
# (decode con4, verify con8, prefill con2 — pinned in tests/test_tune.py)
B, HKV, G, D = 2, 4, 8, 128
H = HKV * G
S, PS = 2048, 128
NPP = S // PS
SPEC_K = 4
SQ, PRE_BQ = 512, 256                  # prompt length / prefill q-tile

# reduced measured geometry (CPU interpret)
MB, MHKV, MG, MD, MPS = 2, 2, 2, 32, 64
MH = MHKV * MG
MS = 256


def winner_rows() -> None:
    fams = [
        ("decode_attention_paged", (B, H, HKV, NPP, D),
         dict(page_size=PS, window=0)),
        ("flash_attention_verify", (B, H, HKV, SPEC_K + 1, NPP, D),
         dict(page_size=PS, window=0)),
        ("flash_attention", (B, H, HKV, SQ, SQ, D),
         dict(causal=True, window=0, bq=PRE_BQ, bkv=128)),
    ]
    for fam, shape, params in fams:
        res = search(KernelSpec.make(fam, shape, dtype="bfloat16", **params))
        emit(f"specdecode,winner,{fam}", -1.0,
             res.candidates[0].score * 1e6, winner=res.best.label)


def kernel_rows() -> None:
    key = jax.random.PRNGKey(0)
    n_pages = MB * (MS // MPS) + 1
    kp = jax.random.normal(jax.random.fold_in(key, 1),
                           (n_pages, MPS, MHKV, MD), jnp.float32)
    vp = jax.random.normal(jax.random.fold_in(key, 2),
                           (n_pages, MPS, MHKV, MD), jnp.float32)
    perm = np.random.default_rng(0).permutation(np.arange(1, n_pages))
    bt = jnp.asarray(perm.reshape(MB, MS // MPS), jnp.int32)
    for t in (3, 5, 9):                      # K in {2, 4, 8}
        q = jax.random.normal(key, (MB, t, MH, MD), jnp.float32)
        pos0 = jnp.full((MB,), MS - t, jnp.int32)
        for label in ("none", "con2", "gap2"):
            cfg = CoarseningConfig.parse(label) if label != "none" \
                else CoarseningConfig()
            c = flash_attention_verify_cost(B, H, HKV, t, S, D, cfg,
                                            bkv=PS, kv_len=S, page_size=PS)
            emit(f"specdecode,kernel,T{t},{label}",
                 wall_us(lambda: ops.flash_attention_verify(
                     q, kp, vp, bt, pos0, cfg)),
                 c.modeled_s * 1e6)


def engine_rows() -> None:
    from repro.configs import get_config
    from repro.models import model as M
    from repro.serve import PagedEngine, Scheduler, SpecPagedEngine

    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, int(n))))
               for n in (9, 17, 6)]
    gens = [12, 8, 10]
    kw = dict(slots=2, num_pages=17, page_size=8, max_len=64, chunk=8)

    def run(make):
        eng = make()
        sched = Scheduler(eng)
        for p, g in zip(prompts, gens):
            sched.submit(p, g)
        done = sched.run_until_done()
        eng.pool.check()
        return eng, [r.output for r in done]

    base, base_out = run(lambda: PagedEngine(cfg, params, decode_block=1,
                                             **kw))
    variants = [
        ("reject", dict(rng=jax.random.PRNGKey(7))),     # fresh random draft
        ("selfdraft", dict(draft_cfg=cfg, draft_params=params)),
    ]
    for name, dkw in variants:
        eng, out = run(lambda: SpecPagedEngine(cfg, params, spec_k=SPEC_K,
                                               **dkw, **kw))
        emit(f"specdecode,engine,{name}", -1.0, -1.0,
             parity=out == base_out,
             acceptance=round(eng.acceptance_rate, 3),
             tok_per_step=round(
                 eng.decoded_tokens / max(eng.spec_steps, 1), 2),
             rescues=eng.rescue_steps, leak_free=eng.pool.num_live == 0)


def main() -> None:
    winner_rows()
    kernel_rows()
    engine_rows()


if __name__ == "__main__":
    main()
