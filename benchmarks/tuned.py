"""Autotuner validation table: auto-selected config vs base vs the paper's
fixed degrees.

For each kernel family the paper sweeps, emit one row per config in
{base, con2, con4, con8, gap2, gap4, gap8, AUTO} with modeled v5e time and
speedup over base, plus measured CPU wall time for the configs that run at
the small measured size.  AUTO is whatever `repro.tune.search` picks from
the FULL candidate space (including replication/SIMD combos the fixed-degree
rows exclude) — the table exists to show the tuner matching or beating every
fixed degree on every access pattern.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import CoarseningConfig, plan_stream
from repro.kernels import ops
from repro.kernels import gather_stream as gs
from repro.tune import KernelSpec, TuningCache, model_cost, search
from benchmarks.common import wall_us, emit

FIXED = ("con2", "con4", "con8", "gap2", "gap4", "gap8")
N_MODEL = 1 << 26          # paper-scale modeled size
N = 1 << 15                # measured size (CPU interpret)


def _spec_modeled(spec: KernelSpec):
    """base + fixed-degree modeled costs, skipping geometry-invalid ones."""
    rows = [("base", CoarseningConfig())]
    for label in FIXED:
        cfg = CoarseningConfig.parse(label)
        try:
            model_cost(spec, cfg)
        except ValueError:
            continue
        rows.append((label, cfg))
    return rows


def _table(name: str, spec: KernelSpec, measured_fn=None):
    base_s = model_cost(spec, CoarseningConfig())
    for label, cfg in _spec_modeled(spec):
        s = model_cost(spec, cfg)
        if not math.isfinite(s):         # e.g. gapped on a sequential carry
            emit(f"tuned,{name},{label}", -1, -1, status="NA")
            continue
        us = measured_fn(cfg) if measured_fn else -1.0
        emit(f"tuned,{name},{label}", us, s * 1e6,
             speedup=round(base_s / s, 2))
    # the tuner's pick over the full space (repl/simd included), resolved
    # through a scratch cache to exercise the production cache path
    res = search(spec)
    cache = TuningCache(path="/tmp/repro-tuned-bench.json", autoload=False)
    cache.put(spec, res.best, modeled_s=res.candidates[0].modeled_s,
              source=res.source, persist=False)
    best = cache.get(spec)
    s = model_cost(spec, best)
    us = measured_fn(best) if measured_fn else -1.0
    emit(f"tuned,{name},AUTO[{best.label}]", us, s * 1e6,
         speedup=round(base_s / s, 2))


def main() -> None:
    key = jax.random.PRNGKey(0)

    # direct streaming (paper F1: consecutive wins, tuner should agree)
    spec = KernelSpec.make("ew_stream", (N_MODEL,), n_loads=8, ai=6,
                           variant="base", block=1024)
    inputs = tuple(jax.random.normal(jax.random.fold_in(key, i), (N,))
                   for i in range(8))

    def measure_ew(cfg):
        # legality at the measured size comes from the canonical plan, not a
        # re-derived rule: plan_stream raises on indivisible geometry, and
        # replication needs the grid to split evenly
        try:
            plan = plan_stream(N, cfg, block=1024)
        except ValueError:
            return -1.0
        if cfg.replication > 1 and plan.grid % cfg.replication:
            return -1.0
        return wall_us(lambda *xs: ops.ew_stream(xs, cfg, ai=6, block=1024),
                       *inputs)

    _table("ew_stream", spec, measure_ew)
    # the paper-scale AUTO pick may not fit the small measured size, so also
    # tune AT the measured geometry and wall-time that winner against base
    spec_n = KernelSpec.make("ew_stream", (N,), n_loads=8, ai=6,
                             variant="base", block=1024)
    best_n = search(spec_n).best
    emit(f"tuned,ew_stream,AUTO@measured[{best_n.label}]",
         measure_ew(best_n), model_cost(spec_n, best_n) * 1e6,
         speedup=round(model_cost(spec_n, CoarseningConfig())
                       / model_cost(spec_n, best_n), 2))

    # irregular gather (paper F2: coarsening wins collapse; gapped keeps a
    # small cached-LSU edge)
    _table("gather", KernelSpec.make(
        "gather_stream", (N_MODEL, 1 << 14), n_loads=8, ai=6, block=1024,
        hit_rate=0.854, window_elems=8192))

    # dense matmul (row-block coarsening vs MXU efficiency)
    _table("matmul", KernelSpec.make(
        "matmul", (4096, 4096, 4096), dtype="bfloat16",
        bm=128, bn=128, bk=512))

    # sequential carry (gapped illegal; the tuner must never pick it)
    _table("dp_scan", KernelSpec.make("dp_scan", (1 << 20, 1024)))


if __name__ == "__main__":
    main()
