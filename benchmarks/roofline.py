"""§Roofline table: per (arch x shape x mesh) three-term roofline.

Terms come from core/perfmodel.py closed forms (exact for the loops we emit;
see tests/test_rooflines.py for the while-loop undercount proof + validation)
and are cross-referenced with the dry-run artifacts in experiments/dryrun/
(memory fit + collective inventory) when present.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, SHAPES, TRAIN_N_MICRO, get_config
from repro.core.perfmodel import (MeshInfo, train_step_terms,
                                  decode_step_terms, prefill_step_terms)
from repro.core.rooflines import PEAK_FLOPS_BF16, HBM_BW, LINK_BW
from benchmarks.common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def cell_terms(arch: str, shape: str, mesh: MeshInfo, **kw):
    cfg = get_config(arch)
    if kw.pop("moe_combine_bf16", False) and cfg.n_experts:
        import dataclasses
        cfg = dataclasses.replace(cfg, moe_combine_dtype="bfloat16")
    sh = SHAPES[shape]
    if sh["kind"] == "train":
        kw.setdefault("n_micro", TRAIN_N_MICRO.get(arch, 4))
        return train_step_terms(cfg, seq=sh["seq"], batch=sh["batch"],
                                mesh=mesh, **kw)
    if sh["kind"] == "prefill":
        return prefill_step_terms(
            cfg, seq=sh["seq"], batch=sh["batch"], mesh=mesh,
            sp_activations=kw.get("sp_activations", False))
    return decode_step_terms(cfg, seq=sh["seq"], batch=sh["batch"], mesh=mesh,
                             **kw)


def roofline_row(arch: str, shape: str, mesh: MeshInfo, **kw):
    cfg = get_config(arch)
    sh = SHAPES[shape]
    if shape == "long_500k" and not cfg.is_subquadratic:
        return None
    t = cell_terms(arch, shape, mesh, **kw)
    compute_s = t.flops / PEAK_FLOPS_BF16
    memory_s = t.hbm_bytes / HBM_BW
    coll_s = t.coll_bytes / LINK_BW
    step = max(compute_s, memory_s, coll_s)
    bound = {compute_s: "compute", memory_s: "memory",
             coll_s: "collective"}[step]
    tokens = sh["batch"] * (sh["seq"] if sh["kind"] in ("train", "prefill")
                            else 1)
    mult = 6 if sh["kind"] == "train" else 2
    model_flops = mult * cfg.active_param_count() * tokens / mesh.chips
    return {
        "arch": arch, "shape": shape, "mesh": f"{mesh.dp}x{mesh.tp}",
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "bound": bound,
        "roofline_frac": compute_s / step if step else 0.0,
        "model_flops": model_flops,
        "useful_ratio": model_flops / t.flops if t.flops else 0.0,
        "notes": t.notes,
    }


def main():
    mesh = MeshInfo(dp=16, tp=16)
    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            r = roofline_row(arch, shape, mesh)
            if r is None:
                emit(f"roofline,{arch},{shape}", -1, -1, status="SKIP")
                continue
            rows.append(r)
            emit(f"roofline,{arch},{shape}", -1,
                 max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
                 bound=r["bound"], frac=round(r["roofline_frac"], 3),
                 compute_us=round(r["compute_s"] * 1e6, 1),
                 memory_us=round(r["memory_s"] * 1e6, 1),
                 coll_us=round(r["collective_s"] * 1e6, 1))
    # correlate with dry-run artifacts when available
    arts = glob.glob(os.path.join(ART, "*.json"))
    emit("roofline,artifacts", -1, float(len(arts)), found=len(arts))
    return rows


if __name__ == "__main__":
    main()
