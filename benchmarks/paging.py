"""Paged-KV serving table: effective capacity at a fixed HBM budget plus
paged-vs-contiguous decode cost.

Rows:

  paging,capacity,B<budget>   admission sim on a heterogeneous-length trace
                              at a fixed KV-token budget.  The contiguous
                              cache reserves a full max_len stripe per slot,
                              so short requests strand the tail of their
                              stripe; the paged pool holds page-granular
                              allocations, so the same budget admits more
                              live tokens.  ``ratio`` (paged/contiguous
                              admitted tokens) is the acceptance headline —
                              the PR gate is ratio >= 1.5 on this trace.
  paging,kernel,...           contiguous split-KV decode vs the block-table
                              paged kernel at the same geometry: wall us
                              (CPU interpret) + modeled v5e us (paged pays
                              per-page descriptors + table-lookup latency).
  paging,serve,...            end-to-end tok/s of the BatchedServer vs the
                              Scheduler+PagedEngine on the SAME trace, with
                              the paged pool sized to HALF the contiguous
                              footprint (forcing page pressure); both are
                              CPU interpret-scale, reported for trend only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CoarseningConfig
from repro.core.analysis import decode_attention_cost
from repro.kernels import ops
from repro.serve import pages_needed
from benchmarks.common import wall_us, emit

MAX_LEN = 512           # contiguous per-slot reservation
GEN = 64                # generation budget per request
PAGE = 16               # paged allocation granularity


def _trace(rng, n: int) -> list[int]:
    """Heterogeneous request lengths (prompt+gen), lognormal-ish: mostly
    short, a heavy tail near max_len — the shape that starves a contiguous
    cache."""
    lens = np.exp(rng.normal(4.6, 0.8, n)).astype(int) + GEN
    return [int(min(max(v, GEN + 8), MAX_LEN)) for v in lens]


def capacity_rows(rng) -> None:
    lens = _trace(rng, 256)
    for slots in (2, 4, 8):
        budget = slots * MAX_LEN                      # KV tokens of HBM
        # contiguous: a request occupies a whole max_len stripe
        cont = lens[:slots]
        # paged: worst-case (fully generated) page footprint per request
        pool, paged = budget // PAGE, []
        for ln in lens:
            need = pages_needed(ln, PAGE)
            if need > pool:
                break
            pool -= need
            paged.append(ln)
        ratio = sum(paged) / max(sum(cont), 1)
        emit(f"paging,capacity,B{budget}", -1.0, -1.0,
             contiguous_reqs=len(cont), paged_reqs=len(paged),
             contiguous_tokens=sum(cont), paged_tokens=sum(paged),
             ratio=round(ratio, 2))


def kernel_rows() -> None:
    b, h, hkv, d, s = 2, 8, 4, 32, 256
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, 1, h, d))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    pos = jnp.full((b,), s - 1, jnp.int32)
    ps = 64
    npp = s // ps
    n_pages = b * npp + 1
    kp = jnp.zeros((n_pages, ps, hkv, d), kc.dtype)
    vp = jnp.zeros((n_pages, ps, hkv, d), vc.dtype)
    perm = np.random.default_rng(0).permutation(np.arange(1, n_pages))
    bt = jnp.asarray(perm.reshape(b, npp), jnp.int32)
    for bb in range(b):
        for lp in range(npp):
            pg = int(bt[bb, lp])
            kp = kp.at[pg].set(kc[bb, lp * ps:(lp + 1) * ps])
            vp = vp.at[pg].set(vc[bb, lp * ps:(lp + 1) * ps])
    for label in ("none", "con2", "gap2"):
        cfg = CoarseningConfig.parse(label) if label != "none" \
            else CoarseningConfig()
        c_cont = decode_attention_cost(b, h, hkv, s, d, cfg, bkv=ps)
        c_page = decode_attention_cost(b, h, hkv, s, d, cfg, bkv=ps,
                                       page_size=ps)
        emit(f"paging,kernel,contig,S{s},{label}",
             wall_us(lambda: ops.decode_attention(q, kc, vc, pos, cfg,
                                                  bkv=ps)),
             c_cont.modeled_s * 1e6)
        emit(f"paging,kernel,paged,S{s},{label}",
             wall_us(lambda: ops.paged_decode_attention(q, kp, vp, bt, pos,
                                                        cfg)),
             c_page.modeled_s * 1e6,
             overhead=round(c_page.modeled_s / c_cont.modeled_s, 3))


def serve_rows(rng) -> None:
    from repro.configs import get_config
    from repro.models import model as M
    from repro.launch.serve import BatchedServer
    from repro.obs import Registry
    from repro.serve import PagedEngine, Scheduler

    cfg = get_config("qwen3-0.6b").reduced()
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    slots, max_len, gen, ps = 3, 48, 8, 8
    prompts = [list(map(int, rng.integers(1, cfg.vocab, int(n))))
               for n in rng.integers(5, 33, 6)]

    srv = BatchedServer(cfg, params, slots=slots, max_len=max_len,
                        chunk=16, decode_block=4)
    pending = list(prompts)
    while pending or srv.any_active:
        while pending and srv.try_admit(pending[0], gen):
            pending.pop(0)
        if not srv.any_active:
            break
        srv.step()
    # tok/s over device time (the jitted decode calls + sync) so the row
    # measures the kernel path, not host bookkeeping
    emit("paging,serve,contiguous", -1.0, -1.0,
         decode_tok_s=round(
             srv.decoded_tokens / max(srv.decode_device_s, 1e-9), 1),
         kv_tokens=slots * max_len)

    # paged pool at HALF the contiguous KV footprint
    reg = Registry()
    num_pages = (slots * max_len) // (2 * ps) + 1
    eng = PagedEngine(cfg, params, slots=slots, num_pages=num_pages,
                      page_size=ps, max_len=max_len, chunk=16,
                      decode_block=4, metrics=reg)
    sched = Scheduler(eng, metrics=reg)
    for p in prompts:
        sched.submit(p, gen)
    done = sched.run_until_done()
    dec_tok = int(reg.value("engine_decode_tokens_total"))
    assert dec_tok == eng.decoded_tokens
    emit("paging,serve,paged", -1.0, -1.0,
         decode_tok_s=round(
             dec_tok / max(eng.decode_device_s, 1e-9), 1),
         kv_tokens=eng.pool.tokens_capacity,
         preemptions=int(reg.value("sched_preemptions_total")),
         completed=len(done))


def main() -> None:
    rng = np.random.default_rng(0)
    capacity_rows(rng)
    kernel_rows()
    serve_rows(rng)


if __name__ == "__main__":
    main()
