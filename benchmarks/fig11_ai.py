"""Paper Fig. 11 analog: arithmetic intensity {1,4,6,10} x direct/indirect,
best coarsening/replication speedup at each AI."""
from __future__ import annotations

import jax

from repro.core import CoarseningConfig, plan_stream
from repro.core import analysis as A
from repro.kernels import ops
from benchmarks.common import wall_us, emit

N_MODEL = 1 << 26
N = 1 << 15
AIS = (1, 4, 6, 10)
DEGREES = (2, 4, 8)


def main():
    key = jax.random.PRNGKey(0)
    inputs = tuple(jax.random.normal(jax.random.fold_in(key, i), (N,))
                   for i in range(8))
    for ai in AIS:
        base = A.stream_cost(plan_stream(N_MODEL, CoarseningConfig(),
                                         block=1024),
                             n_loads=8, arith_per_elem=float(ai))
        for fam in ("con", "gap", "pipe"):
            best = None
            for d in DEGREES:
                cfg = CoarseningConfig.parse(f"{fam}{d}")
                c = A.stream_cost(plan_stream(N_MODEL, cfg, block=1024),
                                  n_loads=8, arith_per_elem=float(ai))
                if best is None or c.modeled_s < best[1].modeled_s:
                    best = (d, c)
            d, c = best
            us = -1.0
            if fam == "con":
                us = wall_us(lambda *xs: ops.ew_stream(
                    xs, CoarseningConfig.parse(f"con{d}"), ai=ai,
                    block=512), *inputs)
            emit(f"fig11,AI{ai},direct,{fam}{d}", us, c.modeled_s * 1e6,
                 speedup=round(base.modeled_s / c.modeled_s, 2))
        base_i = A.gather_cost(plan_stream(N_MODEL, CoarseningConfig(),
                                           block=1024),
                               n_loads=8, arith_per_elem=float(ai),
                               hit_rate=0.854, window_elems=8192)
        for fam in ("con", "gap", "pipe"):
            best = None
            for d in DEGREES:
                cfg = CoarseningConfig.parse(f"{fam}{d}")
                c = A.gather_cost(plan_stream(N_MODEL, cfg, block=1024),
                                  n_loads=8, arith_per_elem=float(ai),
                                  hit_rate=0.854, window_elems=8192)
                if best is None or c.modeled_s < best[1].modeled_s:
                    best = (d, c)
            d, c = best
            emit(f"fig11,AI{ai},indirect,{fam}{d}", -1, c.modeled_s * 1e6,
                 speedup=round(base_i.modeled_s / c.modeled_s, 2))


if __name__ == "__main__":
    main()
