"""Shared benchmark helpers: CPU wall timing + modeled v5e time + CSV rows.

Every row reports:
  us_per_call — median wall time of the jit'd kernel on THIS CPU (interpret
                mode; reported for transparency, not used for claims)
  derived     — modeled TPU-v5e microseconds from core/analysis.py (the
                LSU/DMA pipeline model; the quantity the paper-trend
                validation uses)
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

ROWS: list[dict] = []


def wall_us(fn: Callable, *args, reps: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived_us: float, **extra):
    row = {"name": name, "us_per_call": round(us_per_call, 1),
           "derived": round(derived_us, 2), **extra}
    ROWS.append(row)
    extras = ",".join(f"{k}={v}" for k, v in extra.items())
    print(f"{name},{row['us_per_call']},{row['derived']}"
          + (f",{extras}" if extras else ""))
    return row
