"""Fault-tolerance demo: train, get preempted (SIGTERM), restart elastically
on a DIFFERENT mesh shape from the checkpoint, keep training.

    PYTHONPATH=src python examples/elastic_restart.py
(re-executes itself with 8 fake host devices to build the two meshes)
"""
import os
import subprocess
import sys

BODY = r"""
import os, signal, tempfile, threading
import jax
from repro.configs import get_config
from repro.launch.train import train

cfg = get_config("qwen3-0.6b").reduced()
ckpt = tempfile.mkdtemp(prefix="elastic_")

print("phase 1: mesh (4,2), SIGTERM arrives mid-run")
m1 = jax.make_mesh((4, 2), ("data", "model"))
timer = threading.Timer(10.0, lambda: signal.raise_signal(signal.SIGTERM))
timer.start()
l1, _ = train(cfg, steps=400, batch=8, seq=64, ckpt_dir=ckpt,
              save_every=5, mesh=m1, log_every=5)
timer.cancel()
print(f"  preempted after {len(l1)} steps; checkpointed")

print("phase 2: node lost -> restart on mesh (8,1) from the checkpoint")
m2 = jax.make_mesh((8, 1), ("data", "model"))
l2, _ = train(cfg, steps=len(l1) + 10, batch=8, seq=64, ckpt_dir=ckpt,
              save_every=100, mesh=m2, log_every=5)
assert l2[0] < l1[0] + 0.5, "must continue, not restart"
print(f"  resumed + {len(l2)} more steps on the new mesh; "
      f"loss {l1[0]:.3f} -> {l2[-1]:.3f}")
print("elastic restart OK")
"""

if __name__ == "__main__":
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    r = subprocess.run([sys.executable, "-c", BODY], env=env)
    sys.exit(r.returncode)
