"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the synthetic pipeline, with checkpointing + fault handling.

Full run (~100M params; takes a while on CPU):
    PYTHONPATH=src python examples/train_lm.py --steps 200
Quick run (CI-scale):
    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 40
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train

# ~100M-param qwen3-family config (12 x 768, GQA 12/4, tied embeddings)
PRESETS = {
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab=50304, head_dim=64),
    "25m": dict(n_layers=8, d_model=384, n_heads=8, n_kv_heads=4,
                d_ff=1024, vocab=32000, head_dim=48),
    "tiny": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                 d_ff=256, vocab=2048, head_dim=32),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="25m", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("qwen3-0.6b")
    kv = {f.name: getattr(base, f.name)
          for f in dataclasses.fields(base)}
    kv.update(PRESETS[args.preset], name=f"qwen3-{args.preset}")
    cfg = type(base)(**kv)
    print(f"training {cfg.name}: ~{cfg.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps x batch {args.batch} x seq {args.seq}")
    losses, _ = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                      ckpt_dir=args.ckpt_dir, lr=args.lr, save_every=100,
                      log_every=10)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({len(losses)} steps)")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
