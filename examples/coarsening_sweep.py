"""Reproduce the paper's full microbenchmark study (Figs. 10-13 analogs)
and print the finding-by-finding comparison with the paper.

    PYTHONPATH=src python examples/coarsening_sweep.py
"""
import sys

from repro.core import CoarseningConfig, plan_stream
from repro.core import analysis as A

N = 1 << 26


def best(fam, make_cost):
    out = None
    for d in (2, 4, 8):
        c = make_cost(CoarseningConfig.parse(f"{fam}{d}"))
        if c is not None and (out is None or c.modeled_s < out[1].modeled_s):
            out = (d, c)
    return out


def regular(cfg, ai=6.0, **kw):
    return A.stream_cost(plan_stream(N, cfg, block=1024), n_loads=8,
                         arith_per_elem=ai, **kw)


def irregular(cfg, ai=6.0, hit=0.854):
    return A.gather_cost(plan_stream(N, cfg, block=1024), n_loads=8,
                         arith_per_elem=ai, hit_rate=hit, window_elems=8192)


checks = []

# F1: regular access -> consecutive wins big, beats gapped
b = regular(CoarseningConfig()).modeled_s
dc, cc = best("con", regular)
dg, cg = best("gap", regular)
s_con, s_gap = b / cc.modeled_s, b / cg.modeled_s
checks.append(("F1 consecutive>=gapped on regular (paper: 5.8x vs less)",
               s_con >= s_gap and s_con > 2.0,
               f"con{dc}={s_con:.2f}x gap{dg}={s_gap:.2f}x"))

# F2: irregular access -> wins collapse, gapped >= consecutive.
# TPU divergence (DESIGN.md §2): the FPGA's per-LSU miss caches give gapped
# its edge; TPU DMA engines already overlap misses for every variant, so
# both kinds' wins collapse and gapped keeps only a small queue-depth edge.
bi = irregular(CoarseningConfig()).modeled_s
dci, cci = best("con", irregular)
dgi, cgi = best("gap", irregular)
si_con, si_gap = bi / cci.modeled_s, bi / cgi.modeled_s
checks.append(("F2 irregular: wins collapse; gapped >= consecutive "
               "(paper: 1.34x gap)",
               si_gap >= si_con and si_gap < 2.0,
               f"con{dci}={si_con:.2f}x gap{dgi}={si_gap:.2f}x"))

# F3: lower AI -> bigger coarsening win.  TPU divergence: the VPU is so fast
# relative to HBM that AI 1-10 never flips the bound — the trend is
# non-increasing but nearly flat (on the Arria 10 arithmetic consumed
# fabric, so the paper saw a real slope).
wins = []
for ai in (1.0, 4.0, 6.0, 10.0):
    bb = regular(CoarseningConfig(), ai=ai).modeled_s
    _, c = best("con", lambda cfg: regular(cfg, ai=ai))
    wins.append(bb / c.modeled_s)
checks.append(("F3 speedup non-increasing with AI (paper Fig. 11; "
               "flat on TPU — memory-bound at every tested AI)",
               all(wins[i] >= wins[i + 1] - 1e-9 for i in range(3)),
               " ".join(f"AI{a}={w:.2f}x" for a, w in
                        zip((1, 4, 6, 10), wins))))

# F4: divergence hurts; id-divergence partially recoverable.  TPU
# divergence: predication is a COMPUTE-side penalty, and the whole
# microbenchmark family is DMA-bound on v5e at the paper's AI range — so we
# assert the ordering on the compute term (where it provably holds) and
# record that the end-to-end time hides it (a genuine architectural
# difference vs. the Arria 10, where the divergent datapath consumed
# fabric and clock).
clean = regular(CoarseningConfig.parse("con8"))
div_in = regular(CoarseningConfig.parse("con8"), divergence_paths=4)
div_id = regular(CoarseningConfig.parse("con8"), divergence_paths=4,
                 divergence_uniform=True)
checks.append(("F4 if-in > if-id > none on the compute term "
               "(paper Fig. 10); total hidden under DMA on TPU",
               div_in.compute_s_per_step > div_id.compute_s_per_step
               > clean.compute_s_per_step
               and div_in.modeled_s <= clean.modeled_s * 1.01,
               f"compute/step: clean={clean.compute_s_per_step * 1e6:.3f}us "
               f"id={div_id.compute_s_per_step * 1e6:.3f}us "
               f"in={div_in.compute_s_per_step * 1e6:.3f}us; "
               f"total {div_in.modeled_s * 1e3:.1f}ms == DMA-bound"))

# F5: coarsening cheaper than replication at similar speedup.  TPU analog of
# the ALUT saving: R x fewer DMA queues/semaphores; the RAM-block saving
# does NOT transfer (resident VMEM totals are equal) — documented.
cost_con = regular(CoarseningConfig.parse("con4"))
cost_pipe = regular(CoarseningConfig.parse("pipe4"))
checks.append(("F5 coarsening control resources < replication "
               "(paper Fig. 9; TPU: queue count, VMEM parity)",
               cost_con.dma_sems < cost_pipe.dma_sems
               and cost_con.vmem_bytes == cost_pipe.vmem_bytes
               and cost_con.modeled_s <= cost_pipe.modeled_s * 1.2,
               f"sems con4={cost_con.dma_sems} pipe4={cost_pipe.dma_sems}; "
               f"vmem equal={cost_con.vmem_bytes == cost_pipe.vmem_bytes}"))

# F6: mechanisms compose
combo = regular(CoarseningConfig.parse("con4+pipe2"))
alone = min(regular(CoarseningConfig.parse("con4")).modeled_s,
            regular(CoarseningConfig.parse("pipe2")).modeled_s)
checks.append(("F6 con4+pipe2 <= best alone (paper: Backprop 3.2x)",
               combo.modeled_s <= alone * 1.05,
               f"combo={combo.modeled_s * 1e3:.1f}ms alone={alone * 1e3:.1f}ms"))

fails = 0
for name, ok, detail in checks:
    print(f"[{'PASS' if ok else 'FAIL'}] {name}\n       {detail}")
    fails += 0 if ok else 1
sys.exit(1 if fails else 0)
