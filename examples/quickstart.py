"""Quickstart: the paper's technique in 60 lines.

1. Build the paper's Fig. 6 microbenchmark kernel.
2. Apply consecutive / gapped coarsening + the two competing mechanisms.
3. Show the LSU-analog analysis (DMA count/width, modeled v5e time).
4. Verify every variant computes the identical result.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import CoarseningConfig, plan_stream
from repro.core import analysis
from repro.kernels import ops, ref

N, N_LOADS, AI = 1 << 15, 8, 6

key = jax.random.PRNGKey(0)
inputs = tuple(jax.random.normal(jax.random.fold_in(key, i), (N,))
               for i in range(N_LOADS))
expected = ref.ew_stream(list(inputs), ai=AI)

print(f"{'variant':>8} | {'DMAs/step':>9} | {'DMA bytes':>9} | "
      f"{'modeled v5e':>11} | {'speedup':>7} | correct")
base = None
for spec in ["none", "con2", "con4", "con8", "gap2", "gap4", "gap8",
             "pipe4", "simd4"]:
    cfg = CoarseningConfig.parse(spec)
    plan = plan_stream(1 << 26, cfg, block=1024)     # paper-scale model
    cost = analysis.stream_cost(plan, n_loads=N_LOADS, arith_per_elem=AI)
    if base is None:
        base = cost.modeled_s
    if cfg.replication == 1:                         # runnable on this CPU
        got = ops.ew_stream(inputs, cfg, ai=AI, block=512)
        ok = bool(jax.numpy.allclose(got, expected, rtol=1e-5, atol=1e-5))
    else:
        ok = "-"
    print(f"{spec:>8} | {cost.dmas_per_step:>9} | {int(cost.dma_bytes):>9} | "
          f"{cost.modeled_s * 1e6:>9.1f}us | {base / cost.modeled_s:>6.2f}x | {ok}")

print("\nPaper F1 reproduced: consecutive coarsening coalesces 8 narrow DMAs "
      "into 1 wide one (per operand) and wins; gapped keeps narrow DMAs.")
