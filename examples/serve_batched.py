"""Serve a small model with batched requests (continuous batching).

    PYTHONPATH=src python examples/serve_batched.py --requests 8 --slots 4
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main()
