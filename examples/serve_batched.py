"""Client of the serving API: submit a mixed-length request trace to the
paged scheduler/engine (or the contiguous BatchedServer with --cache
contiguous) and print per-request outputs.

    PYTHONPATH=src python examples/serve_batched.py --requests 8 --slots 4

Undersize the pool to watch preemption + requeue keep every request's
output identical to running it alone:

    PYTHONPATH=src python examples/serve_batched.py --requests 8 --slots 4 \
        --num-pages 12 --page-size 8

Weight-only quantization + int8 KV pool, coarsened paged decode kernel:

    PYTHONPATH=src python examples/serve_batched.py --requests 8 --slots 4 \
        --quant int8 --kv-quant int8 --decode-backend pallas
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch.serve import BatchedServer
from repro.models import model as M
from repro.serve import PagedEngine, Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCHS)
    ap.add_argument("--cache", default="paged",
                    choices=["paged", "contiguous"])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--min-prompt", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--gen-tokens", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool pages incl. null (default: fits all slots)")
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--decode-block", type=int, default=4)
    ap.add_argument("--decode-backend", default=None,
                    choices=[None, "ref", "pallas"])
    ap.add_argument("--quant", default=None,
                    choices=[None, "none", "int8", "int4"])
    ap.add_argument("--kv-quant", default=None,
                    choices=[None, "none", "int8"])
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(
        1, cfg.vocab, int(rng.integers(args.min_prompt,
                                       args.max_prompt + 1)))))
               for _ in range(args.requests)]

    t0 = time.perf_counter()
    if args.cache == "paged":
        num_pages = args.num_pages if args.num_pages is not None else \
            args.slots * -(-args.max_len // args.page_size) + 1
        engine = PagedEngine(cfg, params, slots=args.slots,
                             num_pages=num_pages, page_size=args.page_size,
                             max_len=args.max_len, chunk=args.chunk,
                             decode_block=args.decode_block,
                             decode_backend=args.decode_backend,
                             quant=args.quant, kv_quant=args.kv_quant)
        sched = Scheduler(engine)
        for p in prompts:
            sched.submit(p, args.gen_tokens)
        done = sched.run_until_done()
        dt = time.perf_counter() - t0
        for r in done:
            tag = f" ({r.preemptions} preemptions)" if r.preemptions else ""
            print(f"req {r.rid}: prompt[{len(r.prompt)}] -> "
                  f"{r.output[:8]}...{tag}")
        rate = engine.decoded_tokens / max(engine.decode_s, 1e-9)
        print(f"{len(done)} requests in {dt:.2f}s | pool "
              f"{engine.pool.capacity} pages x {engine.page_size} tok | "
              f"decode {rate:.1f} tok/s (CPU interpret-scale)")
    else:
        server = BatchedServer(cfg, params, slots=args.slots,
                               max_len=args.max_len, chunk=args.chunk,
                               decode_block=args.decode_block,
                               decode_backend=args.decode_backend,
                               quant=args.quant, kv_quant=args.kv_quant)
        pending = list(prompts)
        while pending or server.any_active:
            while pending and server.try_admit(pending[0], args.gen_tokens):
                pending.pop(0)
            if not server.any_active:
                break
            server.step()
        dt = time.perf_counter() - t0
        for i, out in enumerate(server.completed):
            print(f"req {i}: -> {out[:8]}...")
        print(f"{len(server.completed)} requests in {dt:.2f}s | decode "
              f"{server.decoded_tokens / max(server.decode_s, 1e-9):.1f} "
              f"tok/s (CPU interpret-scale)")


if __name__ == "__main__":
    main()
