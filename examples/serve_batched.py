"""Serve a small model with batched requests (continuous batching).

    PYTHONPATH=src python examples/serve_batched.py --requests 8 --slots 4

Weight-only quantization + int8 KV cache (the driver prints the weight and
cache-memory saving next to the prefill/decode tok/s):

    PYTHONPATH=src python examples/serve_batched.py --requests 8 --slots 4 \
        --quant int8 --kv-quant int8 --decode-backend pallas
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main()
