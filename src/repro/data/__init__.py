"""Data substrate: deterministic synthetic token pipeline."""
from .pipeline import DataConfig, TokenPipeline
