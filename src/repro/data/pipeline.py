"""Deterministic, shardable, checkpointable synthetic token pipeline.

The stream is a counter-based PRNG (threefry via jax.random.fold_in), so the
pipeline state is just (seed, step): restart-exactness is trivial, any host
can compute any shard, and elastic rescaling only changes the shard slicing,
never the global stream — the property a 1000-node data plane needs.

Sequences are Zipf-ish token draws with a learnable structure (periodic
copy motifs) so that small-model training loss decreases visibly.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    # sharding: this host produces batch rows [row_start, row_start+rows)
    row_start: int = 0
    rows: Optional[int] = None          # default: all rows
    frontend: Optional[str] = None      # 'vision'|'audio' adds stub embeds
    d_model: int = 0
    src_len: int = 0                    # enc-dec source length
    is_encdec: bool = False


class TokenPipeline:
    """state = (seed, step); fully deterministic."""

    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step

    # --- checkpointable state ------------------------------------------
    def state_dict(self) -> dict:
        return {"seed": self.cfg.seed, "step": self.step}

    def load_state_dict(self, st: dict) -> None:
        assert st["seed"] == self.cfg.seed, "stream identity changed"
        self.step = int(st["step"])

    # --- batch generation ----------------------------------------------
    def _tokens(self, step: int, rows: int, row0: int, length: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, row0, length]))
        # zipf-ish marginal + copy motif every `period` tokens: the second
        # half of each motif repeats the first half shifted by +1 (mod V)
        base = rng.zipf(1.3, size=(rows, length)).astype(np.int64)
        toks = (base % (cfg.vocab - 2)) + 1
        period = 16
        half = period // 2
        full = (length // period) * period
        view = toks[:, :full].reshape(rows, -1, period)
        view[:, :, half:] = (view[:, :, :half] + 1) % (cfg.vocab - 2) + 1
        return toks.astype(np.int32)

    def next_batch(self) -> dict:
        cfg = self.cfg
        rows = cfg.rows or cfg.global_batch
        toks = self._tokens(self.step, rows, cfg.row_start, cfg.seq_len + 1)
        batch = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        if cfg.is_encdec:
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, self.step, 7]))
            batch["src_frames"] = jnp.asarray(
                rng.standard_normal((rows, cfg.src_len, cfg.d_model),
                                    dtype=np.float32) * 0.1)
        elif cfg.frontend is not None:
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, self.step, 9]))
            emb = rng.standard_normal((rows, cfg.seq_len, cfg.d_model),
                                      dtype=np.float32) * 0.02
            batch["frontend_embeds"] = jnp.asarray(emb)
        self.step += 1
        return batch

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()
