"""LM / Enc-Dec model wrappers with period-scanned layer stacks.

Layers at the same position inside the repeating pattern period are stacked
(leading n_periods axis) and the forward pass `lax.scan`s over periods —
compile time is O(|period|) regardless of depth, which keeps the 80-cell
dry-run tractable.  Remainder layers (n_layers % |period|) run unrolled.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig, ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, SSM
from repro.models import blocks as B
from repro.models import layers as L
from repro.models.layers import ShardCtx, NOSHARD

AUX_LOSS_WEIGHT = 0.01
LOSS_CHUNK = 512


def _period(cfg: ModelConfig):
    period = cfg.pattern_period or (ATTN_GLOBAL,)
    n_periods = cfg.n_layers // len(period)
    tail = cfg.layer_kinds()[n_periods * len(period):]
    return tuple(period), n_periods, tuple(tail)


def _block_init(kind: str, key, cfg: ModelConfig):
    if cfg.is_encdec:
        return B.dec_block_init(key, cfg)
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        return B.attn_block_init(key, cfg)
    if kind == RECURRENT:
        return B.rglru_block_init(key, cfg)
    if kind == SSM:
        return B.mamba_block_init(key, cfg)
    raise ValueError(kind)


def _block_apply(kind: str, p, x, cfg, *, pos, mrope_pos3, shard, moe_capacity,
                 pos_trivial=False):
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        return B.attn_block(p, x, cfg, kind=kind, pos=pos,
                            mrope_pos3=mrope_pos3, shard=shard,
                            moe_capacity=moe_capacity,
                            pos_trivial=pos_trivial)
    if kind == RECURRENT:
        return B.rglru_block(p, x, cfg, shard=shard)
    if kind == SSM:
        return B.mamba_block(p, x, cfg, shard=shard)
    raise ValueError(kind)


def _block_decode(kind: str, p, x, cfg, cache, *, pos, shard,
                  block_table=None, write_mask=None):
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        if block_table is not None:
            return B.attn_block_decode_paged(p, x, cfg, cache, kind=kind,
                                             pos=pos,
                                             block_table=block_table,
                                             write_mask=write_mask,
                                             shard=shard)
        return B.attn_block_decode(p, x, cfg, cache, kind=kind, pos=pos,
                                   shard=shard)
    if kind == RECURRENT:
        return B.rglru_block_decode(p, x, cfg, cache, pos=pos)
    if kind == SSM:
        return B.mamba_block_decode(p, x, cfg, cache, pos=pos)
    raise ValueError(kind)


def _block_verify(kind: str, p, x, cfg, cache, *, pos0, block_table,
                  valid_len, shard):
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        return B.attn_block_verify_paged(p, x, cfg, cache, kind=kind,
                                         pos0=pos0, block_table=block_table,
                                         valid_len=valid_len, shard=shard)
    # recurrent/SSM state is a running summary — rejected drafted tokens
    # cannot be rolled out of it, so speculative decoding is attention-only
    raise NotImplementedError(
        f"lm_verify_step: {kind!r} layers carry unrewindable state; "
        f"speculative decoding supports attention-only stacks")


def _block_cache(kind: str, cfg, b, s_max, dtype):
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        return B.attn_cache_init(cfg, b, s_max, dtype)
    if kind == RECURRENT:
        return B.rglru_cache_init(cfg, b, dtype)
    if kind == SSM:
        return B.mamba_cache_init(cfg, b, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def lm_init(key, cfg: ModelConfig):
    period, n_periods, tail = _period(cfg)
    ks = jax.random.split(key, len(period) + len(tail) + 3)
    params: dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (cfg.vocab_padded, cfg.d_model),
                                   jnp.float32) * 0.02,
        "final_norm": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[1], cfg.d_model, cfg.vocab_padded)
    params["blocks"] = [
        jax.vmap(lambda k: _block_init(kind, k, cfg))(
            jax.random.split(ks[2 + j], n_periods))
        for j, kind in enumerate(period)
    ]
    params["tail"] = [
        _block_init(kind, ks[2 + len(period) + j], cfg)
        for j, kind in enumerate(tail)
    ]
    if cfg.is_encdec:
        params["enc"] = _encoder_init(ks[-1], cfg)
    return params


def _encoder_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "blocks": jax.vmap(lambda k: B.enc_block_init(k, cfg))(
            jax.random.split(ks[0], cfg.n_enc_layers)),
        "norm": L.rmsnorm_init(cfg.d_model),
    }


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _compute_dtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _embed(params, tokens, cfg, batch):
    x = jnp.take(params["embed"], tokens, axis=0).astype(_compute_dtype(cfg))
    fe = batch.get("frontend_embeds")
    if fe is not None:
        x = x + fe.astype(x.dtype)        # modality stub: precomputed embeds
    return x


def _run_stack(params, x, cfg, *, pos, mrope_pos3, shard, moe_capacity,
               remat: str = "none", pos_trivial: bool = False):
    period, n_periods, tail = _period(cfg)

    def period_body(carry, xs):
        x, aux = carry
        xs = shard.constrain_params(xs)   # keep FSDP gather inside the loop
        for j, kind in enumerate(period):
            x, a = _block_apply(kind, xs[j], x, cfg, pos=pos,
                                mrope_pos3=mrope_pos3, shard=shard,
                                moe_capacity=moe_capacity,
                                pos_trivial=pos_trivial)
            aux = aux + a
        # Megatron-SP: residuals sequence-sharded on the TP axis between
        # blocks (shard.sp='model'); GSPMD then emits one RS+AG pair per
        # boundary instead of two ARs.  No-op when sp is None.
        x = shard.constrain(x, lambda P, c: P(c.dp, c.sp, None))
        return (x, aux), None

    body = period_body
    if remat == "full":
        body = jax.checkpoint(period_body, prevent_cse=False)
    elif remat == "dots":
        # save the Pallas attention output ("flash_attn_out") alongside the
        # dot products: the kernel is opaque to the dots policy, so without
        # the name the WHOLE pallas_call would re-run in the backward —
        # right before the backward kernels recompute from its residuals
        body = jax.checkpoint(
            period_body, prevent_cse=False,
            policy=jax.checkpoint_policies.save_from_both_policies(
                jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
                jax.checkpoint_policies.save_only_these_names(
                    "flash_attn_out")))

    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                           tuple(params["blocks"]))
    for p_t, kind in zip(params["tail"], _period(cfg)[2]):
        x, a = _block_apply(kind, p_t, x, cfg, pos=pos, mrope_pos3=mrope_pos3,
                            shard=shard, moe_capacity=moe_capacity,
                            pos_trivial=pos_trivial)
        aux = aux + a
    return x, aux


def lm_apply(params, batch, cfg: ModelConfig, *, shard: ShardCtx = NOSHARD,
             moe_capacity=None, remat: str = "none",
             xkv_precompute: bool = False):
    """-> final hidden states (B,S,d), moe aux loss."""
    if cfg.is_encdec:
        return _encdec_apply(params, batch, cfg, shard=shard,
                             moe_capacity=moe_capacity, remat=remat,
                             xkv_precompute=xkv_precompute)
    tokens = batch["tokens"]
    b, s = tokens.shape
    pos = batch.get("positions")
    # statically-known trivial positions (row i IS global row i) are what
    # lets the flash kernel's causal mask stand in for the q_pos mask;
    # batches carrying explicit positions (packing, ragged starts) keep the
    # mea path
    pos_trivial = pos is None
    if pos is None:
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed(params, tokens, cfg, batch)
    x = shard.constrain(x, lambda P, c: P(c.dp, c.sp, None))
    pos3 = batch.get("pos3")
    if pos3 is not None:
        pos3 = pos3.transpose(1, 0, 2)      # batch convention (B,3,S)->(3,B,S)
    x, aux = _run_stack(params, x, cfg, pos=pos,
                        mrope_pos3=pos3, shard=shard,
                        moe_capacity=moe_capacity, remat=remat,
                        pos_trivial=pos_trivial)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def _encdec_apply(params, batch, cfg, *, shard, moe_capacity, remat,
                  xkv_precompute: bool = False):
    frames = batch["src_frames"].astype(_compute_dtype(cfg))   # audio stub
    bsz, s_src, _ = frames.shape
    pos_src = jnp.broadcast_to(jnp.arange(s_src, dtype=jnp.int32)[None],
                               (bsz, s_src))

    def enc_body(x, p):
        return B.enc_block(p, x, cfg, pos=pos_src, shard=shard), None

    enc_fn = enc_body if remat == "none" else jax.checkpoint(enc_body,
                                                             prevent_cse=False)
    enc_x, _ = lax.scan(enc_fn, frames, params["enc"]["blocks"])
    enc_x = L.rmsnorm(params["enc"]["norm"], enc_x, cfg.norm_eps)

    tokens = batch["tokens"]
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed(params, tokens, cfg, batch)

    xs = params["blocks"][0]
    if xkv_precompute:
        # §Perf lever: project encoder K/V for ALL decoder layers in one
        # batched einsum BEFORE the scan, so enc_x (the big activation) is
        # consumed once instead of being re-broadcast into every loop
        # iteration.
        # asdense: the stacked xattn projections are QTensors when the
        # params are weight-quantized (dense-dequant fallback path)
        wk = L.asdense(xs["xattn"]["wk"], enc_x.dtype)   # (L, d, kv*hd)
        wv = L.asdense(xs["xattn"]["wv"], enc_x.dtype)
        se = enc_x.shape[1]
        ek = jnp.einsum("bsd,ldh->lbsh", enc_x, wk)
        ev = jnp.einsum("bsd,ldh->lbsh", enc_x, wv)
        ek = ek.reshape(ek.shape[0], b, se, cfg.n_kv_heads, cfg.hd)
        ev = ev.reshape(ev.shape[0], b, se, cfg.n_kv_heads, cfg.hd)
        scan_xs = (xs, (ek, ev))

        def dec_body(carry, inp):
            p, kv = inp
            x, aux = carry
            x, a = B.dec_block(p, x, cfg, pos=pos, enc_out=enc_x,
                               shard=shard, enc_kv_pre=kv, pos_trivial=True)
            return (x, aux + a), None
    else:
        scan_xs = xs

        def dec_body(carry, p):
            x, aux = carry
            x, a = B.dec_block(p, x, cfg, pos=pos, enc_out=enc_x, shard=shard,
                               pos_trivial=True)
            return (x, aux + a), None

    dec_fn = dec_body if remat == "none" else jax.checkpoint(dec_body,
                                                             prevent_cse=False)
    (x, aux), _ = lax.scan(dec_fn, (x, jnp.zeros((), jnp.float32)),
                           scan_xs)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def _head(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def lm_loss(params, batch, cfg: ModelConfig, *, shard: ShardCtx = NOSHARD,
            moe_capacity=None, remat: str = "none",
            xkv_precompute: bool = False):
    """Chunked cross-entropy; returns (loss, metrics)."""
    hidden, aux = lm_apply(params, batch, cfg, shard=shard,
                           moe_capacity=moe_capacity, remat=remat,
                           xkv_precompute=xkv_precompute)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(labels, dtype=jnp.float32)
    b, s, d = hidden.shape
    head = _head(params, cfg)
    chunk = min(LOSS_CHUNK, s)
    n = s // chunk if s % chunk == 0 else 1
    chunk = s // n

    hs = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, n, chunk).transpose(1, 0, 2)

    vmask = jnp.arange(head.shape[-1]) < cfg.vocab    # mask pad-vocab ids

    # checkpointed: the (chunk, vocab) logits are recomputed in the backward
    # instead of being stashed per chunk (a 60+GiB saving at vocab 256k)
    @jax.checkpoint
    def ce_chunk(carry, xs):
        tot, cnt = carry
        h, lab, m = xs
        logits = (h @ head.astype(h.dtype)).astype(jnp.float32)
        logits = jnp.where(vmask, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((lse - gold) * m)
        cnt = cnt + jnp.sum(m)
        return (tot, cnt), None

    (tot, cnt), _ = lax.scan(ce_chunk, (0.0, 0.0), (hs, ls, ms))
    ce = tot / jnp.maximum(cnt, 1.0)
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux, "tokens": cnt}


# ---------------------------------------------------------------------------
# decode / serve
# ---------------------------------------------------------------------------

def lm_init_cache(cfg: ModelConfig, b: int, s_max: int, dtype=jnp.bfloat16,
                  enc_len: int | None = None):
    period, n_periods, tail = _period(cfg)
    cache: dict[str, Any] = {
        "blocks": [
            jax.tree.map(lambda a: jnp.zeros((n_periods,) + a.shape, a.dtype),
                         _block_cache(kind, cfg, b, s_max, dtype))
            for kind in period
        ],
        "tail": [_block_cache(kind, cfg, b, s_max, dtype) for kind in tail],
    }
    if cfg.is_encdec:
        el = enc_len or s_max
        # per-layer cross K/V — stored stacked, consumed inside the scan.
        # kv_quant="int8" quantizes this cache too: the encoder K/V is
        # written once at prefill and read back every decode step, so it
        # gets the same payload+scale split as the self-attn caches.
        kvs = (n_periods, b, el, cfg.n_kv_heads)
        for c in cache["blocks"]:
            if cfg.kv_quant == "int8":
                c["enc_k"] = jnp.zeros(kvs + (cfg.hd,), jnp.int8)
                c["enc_v"] = jnp.zeros(kvs + (cfg.hd,), jnp.int8)
                c["enc_k_scale"] = jnp.zeros(kvs, jnp.float32)
                c["enc_v_scale"] = jnp.zeros(kvs, jnp.float32)
            else:
                c["enc_k"] = jnp.zeros(kvs + (cfg.hd,), dtype)
                c["enc_v"] = jnp.zeros(kvs + (cfg.hd,), dtype)
    return cache


def lm_init_cache_paged(cfg: ModelConfig, b: int, num_pages: int,
                        page_size: int, dtype=jnp.bfloat16):
    """Paged decode cache: attention K/V lives in GLOBAL page pools shared
    by every slot (stacked (n_periods, P, page_size, kv, hd) leaves — no
    batch axis; a slot's rows are reached through its block table), while
    recurrent/SSM state keeps the per-slot batch layout.  Every attention
    layer shares ONE page id space: page p means row p of each layer's
    pool, so the allocator hands out ids once and they apply stack-wide.

    Enc-dec models are out of scope (the cross K/V cache is inherently
    per-slot and the decoder self-attn path has no paged twin)."""
    if cfg.is_encdec:
        raise NotImplementedError("paged KV cache: enc-dec models are not "
                                  "supported (use lm_init_cache)")
    period, n_periods, tail = _period(cfg)

    def cache_for(kind, stacked_n=None):
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            c = B.attn_cache_init_paged(cfg, num_pages, page_size, dtype)
        else:
            c = _block_cache(kind, cfg, b, s_max=0, dtype=dtype)
        if stacked_n is None:
            return c
        return jax.tree.map(
            lambda a: jnp.zeros((stacked_n,) + a.shape, a.dtype), c)

    return {
        "blocks": [cache_for(kind, n_periods) for kind in period],
        "tail": [cache_for(kind) for kind in tail],
    }


def lm_decode_step(params, cache, tokens, pos, cfg: ModelConfig, *,
                   shard: ShardCtx = NOSHARD, block_table=None,
                   write_mask=None):
    """tokens: (B,1) int32; pos: (B,) int32 -> (logits (B,V), new cache).

    ``block_table`` (B, npp) int32 switches the attention layers to the
    PAGED cache layout (pool leaves + table-routed scatters; see
    lm_init_cache_paged) — non-attention state is unaffected.
    ``write_mask`` (B,) bool (paged only) suppresses a slot's cache write
    — the speculative draft scan's padding guard."""
    period, n_periods, tail = _period(cfg)
    x = _embed(params, tokens, cfg, {"tokens": tokens})

    kinds = period

    # the stacked caches ride in the scan CARRY and are updated in place
    # (dynamic_update_index_in_dim); stacking them as scan ys instead makes
    # XLA materialize a second full-cache buffer (observed as an f32 copy).
    def period_body(carry, pblk):
        x, caches, i = carry
        cblk = [jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False), c)
            for c in caches]
        newc = []
        for j, kind in enumerate(kinds):
            if cfg.is_encdec:
                x, nc = B.dec_block_decode(pblk[j], x, cfg,
                                           {**cblk[j]}, pos=pos)
            else:
                x, nc = _block_decode(kind, pblk[j], x, cfg, cblk[j],
                                      pos=pos, shard=shard,
                                      block_table=block_table,
                                      write_mask=write_mask)
            newc.append(nc)
        caches = [jax.tree.map(
            lambda a, u: lax.dynamic_update_index_in_dim(a, u, i, 0), c, nc)
            for c, nc in zip(caches, newc)]
        return (x, caches, i + 1), None

    (x, new_blocks, _), _ = lax.scan(
        period_body, (x, list(cache["blocks"]), jnp.asarray(0, jnp.int32)),
        tuple(params["blocks"]))
    new_tail = []
    for p_t, c_t, kind in zip(params["tail"], cache["tail"], tail):
        x, nc = _block_decode(kind, p_t, x, cfg, c_t, pos=pos, shard=shard,
                              block_table=block_table, write_mask=write_mask)
        new_tail.append(nc)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x[:, 0] @ _head(params, cfg).astype(x.dtype)).astype(jnp.float32)
    logits = logits[:, : cfg.vocab]               # drop pad-vocab ids
    return logits, {"blocks": list(new_blocks), "tail": new_tail}


def lm_verify_step(params, cache, tokens, pos0, cfg: ModelConfig, *,
                   block_table, valid_len=None, shard: ShardCtx = NOSHARD):
    """Speculative-decode batched verify: score T drafted tokens per slot
    in ONE pass.  tokens: (B,T) int32 — token t sits at cache position
    ``pos0[b] + t``; block_table: (B, npp) int32 (paged cache only);
    valid_len: optional (B,) int32 — rows ``t >= valid_len[b]`` are batch
    padding (their cache writes are suppressed and their logits garbage).
    Returns (logits (B,T,vocab) f32 — row t scores position pos0+t+1 — and
    the new cache, with rows [pos0, pos0+T) appended).

    Attention-only stacks: recurrent/SSM layers raise (their state cannot
    be rewound past rejected rows).  Row t's logits equal what
    `lm_decode_step` at pos0+t would produce given the same cache prefix —
    the exactness property the greedy accept rule builds on.
    """
    period, n_periods, tail = _period(cfg)
    if cfg.is_encdec:
        raise NotImplementedError("lm_verify_step: enc-dec models are not "
                                  "supported")
    x = _embed(params, tokens, cfg, {"tokens": tokens})
    kinds = period

    def period_body(carry, pblk):
        x, caches, i = carry
        cblk = [jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False), c)
            for c in caches]
        newc = []
        for j, kind in enumerate(kinds):
            x, nc = _block_verify(kind, pblk[j], x, cfg, cblk[j], pos0=pos0,
                                  block_table=block_table,
                                  valid_len=valid_len, shard=shard)
            newc.append(nc)
        caches = [jax.tree.map(
            lambda a, u: lax.dynamic_update_index_in_dim(a, u, i, 0), c, nc)
            for c, nc in zip(caches, newc)]
        return (x, caches, i + 1), None

    (x, new_blocks, _), _ = lax.scan(
        period_body, (x, list(cache["blocks"]), jnp.asarray(0, jnp.int32)),
        tuple(params["blocks"]))
    new_tail = []
    for p_t, c_t, kind in zip(params["tail"], cache["tail"], tail):
        x, nc = _block_verify(kind, p_t, x, cfg, c_t, pos0=pos0,
                              block_table=block_table, valid_len=valid_len,
                              shard=shard)
        new_tail.append(nc)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ _head(params, cfg).astype(x.dtype)).astype(jnp.float32)
    logits = logits[:, :, : cfg.vocab]            # drop pad-vocab ids
    return logits, {"blocks": list(new_blocks), "tail": new_tail}


def _block_prefill(kind: str, p, x, cfg, cache, *, pos0, block_table=None):
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        if block_table is not None:
            return B.attn_block_prefill_paged(p, x, cfg, cache, kind=kind,
                                              pos0=pos0,
                                              block_table=block_table)
        return B.attn_block_prefill(p, x, cfg, cache, kind=kind, pos0=pos0)
    if kind == RECURRENT:
        return B.rglru_block_prefill(p, x, cfg, cache, pos0=pos0)
    if kind == SSM:
        return B.mamba_block_prefill(p, x, cfg, cache, pos0=pos0)
    raise ValueError(kind)


def _select_slots(mask, new, old, *, batch_axis: int):
    """Commit `new` cache leaves only for slots where mask is True."""
    def sel(n, o):
        shape = [1] * n.ndim
        shape[batch_axis] = mask.shape[0]
        return jnp.where(mask.reshape(shape), n, o)
    return jax.tree.map(sel, new, old)


def _prefill_enc_cache(params, batch, cfg, cache):
    """Run the encoder once and persist every decoder layer's cross K/V into
    the stacked enc cache (the xkv_precompute trick, cached for decode)."""
    frames = batch["src_frames"].astype(_compute_dtype(cfg))
    bsz, s_src, _ = frames.shape
    pos_src = jnp.broadcast_to(jnp.arange(s_src, dtype=jnp.int32)[None],
                               (bsz, s_src))

    def enc_body(x, p):
        return B.enc_block(p, x, cfg, pos=pos_src), None

    enc_x, _ = lax.scan(enc_body, frames, params["enc"]["blocks"])
    enc_x = L.rmsnorm(params["enc"]["norm"], enc_x, cfg.norm_eps)

    blk = cache["blocks"][0]
    el = blk["enc_k"].shape[2]
    if s_src > el:
        raise ValueError(f"encoder length {s_src} exceeds enc cache {el}")
    xs = params["blocks"][0]
    wk = L.asdense(xs["xattn"]["wk"], enc_x.dtype)           # (L, d, kv*hd)
    wv = L.asdense(xs["xattn"]["wv"], enc_x.dtype)
    ek = jnp.einsum("bsd,ldh->lbsh", enc_x, wk)
    ev = jnp.einsum("bsd,ldh->lbsh", enc_x, wv)
    np_, kvh, hd = ek.shape[0], cfg.n_kv_heads, cfg.hd
    ek = ek.reshape(np_, bsz, s_src, kvh, hd)
    ev = ev.reshape(np_, bsz, s_src, kvh, hd)
    if "enc_k_scale" in blk:
        # quantized cross cache: same quantize-on-append as the self-attn
        # path, done once here since the encoder K/V never changes after
        # prefill; rows past s_src keep payload 0 / scale 0 (dequant -> 0)
        from repro.quant.qtypes import quantize_kv
        ek, eks = quantize_kv(ek.astype(jnp.float32))
        ev, evs = quantize_kv(ev.astype(jnp.float32))
        blk = {**blk,
               "enc_k": blk["enc_k"].at[:, :, :s_src].set(ek),
               "enc_v": blk["enc_v"].at[:, :, :s_src].set(ev),
               "enc_k_scale": blk["enc_k_scale"].at[:, :, :s_src].set(eks),
               "enc_v_scale": blk["enc_v_scale"].at[:, :, :s_src].set(evs)}
    else:
        blk = {**blk,
               "enc_k": blk["enc_k"].at[:, :, :s_src]
                   .set(ek.astype(blk["enc_k"].dtype)),
               "enc_v": blk["enc_v"].at[:, :, :s_src]
                   .set(ev.astype(blk["enc_v"].dtype))}
    return {**cache, "blocks": [blk] + list(cache["blocks"][1:])}


def lm_prefill(params, batch, cfg: ModelConfig, s_max: int | None = None, *,
               cache=None, pos0=None, mask=None, shard: ShardCtx = NOSHARD,
               dtype=jnp.bfloat16, block_table=None):
    """Chunked prefill: push a (B, T) token chunk through the stack, FILLING
    the decode caches (attention K/V rows [pos0, pos0+T), recurrent/SSM/conv
    states advanced T steps, enc-dec cross K/V from src_frames).

    Call repeatedly with increasing ``pos0`` to ingest a long prompt in
    chunks; composes exactly with per-token `lm_decode_step`, which is the
    parity invariant tests/test_prefill.py asserts.

    cache: existing decode cache to continue (created fresh from ``s_max``
    when None).  pos0: (B,) chunk start positions (default zeros).
    mask: optional (B,) bool — only masked slots commit cache/state updates
    (the continuous-batching admit path: other slots' caches are untouched).
    block_table: (B, npp) int32 — PAGED attention caches (see
    lm_init_cache_paged); attention writes route through the table (the
    caller nulls non-admitted slots' rows, which IS their write protection,
    so ``mask`` only guards the per-slot recurrent/SSM leaves).
    Returns (last-chunk-token logits (B, vocab) f32, new cache).
    """
    tokens = batch["tokens"]
    b, t = tokens.shape
    if cache is None:
        if s_max is None:
            raise ValueError("lm_prefill needs either a cache or s_max")
        if block_table is not None:
            raise ValueError("paged prefill needs an explicit cache from "
                             "lm_init_cache_paged")
        cache = lm_init_cache(cfg, b, s_max, dtype)
    if pos0 is None:
        pos0 = jnp.zeros((b,), jnp.int32)
    old_cache = cache

    period, n_periods, tail = _period(cfg)
    if cfg.is_encdec and batch.get("src_frames") is not None:
        cache = _prefill_enc_cache(params, batch, cfg, cache)

    x = _embed(params, tokens, cfg, batch)
    kinds = period

    def period_body(carry, pblk):
        x, caches, i = carry
        cblk = [jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False), c)
            for c in caches]
        newc = []
        for j, kind in enumerate(kinds):
            if cfg.is_encdec:
                x, nc = B.dec_block_prefill(pblk[j], x, cfg, {**cblk[j]},
                                            pos0=pos0)
            else:
                x, nc = _block_prefill(kind, pblk[j], x, cfg, cblk[j],
                                       pos0=pos0, block_table=block_table)
            newc.append(nc)
        caches = [jax.tree.map(
            lambda a, u: lax.dynamic_update_index_in_dim(a, u, i, 0), c, nc)
            for c, nc in zip(caches, newc)]
        return (x, caches, i + 1), None

    (x, new_blocks, _), _ = lax.scan(
        period_body, (x, list(cache["blocks"]), jnp.asarray(0, jnp.int32)),
        tuple(params["blocks"]))
    new_tail = []
    for p_t, c_t, kind in zip(params["tail"], cache["tail"], tail):
        x, nc = _block_prefill(kind, p_t, x, cfg, c_t, pos0=pos0,
                               block_table=block_table)
        new_tail.append(nc)

    new_cache = {"blocks": list(new_blocks), "tail": new_tail}
    if mask is not None:
        def committed(n, o, kind, batch_axis):
            # paged attention pools have NO batch axis — the null-routed
            # block table already confined the writes, so the new pool is
            # committed as-is; everything per-slot keeps the mask select
            if block_table is not None and kind in (ATTN_GLOBAL, ATTN_LOCAL):
                return n
            return _select_slots(mask, n, o, batch_axis=batch_axis)

        new_cache = {
            "blocks": [committed(n, o, kind, 1)
                       for n, o, kind in zip(new_cache["blocks"],
                                             old_cache["blocks"], period)],
            "tail": [committed(n, o, kind, 0)
                     for n, o, kind in zip(new_cache["tail"],
                                           old_cache["tail"], tail)],
        }

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x[:, -1] @ _head(params, cfg).astype(x.dtype)).astype(jnp.float32)
    return logits[:, : cfg.vocab], new_cache
