"""Unified model configuration covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# layer kinds for layer_pattern
ATTN_GLOBAL = "G"        # full (causal) attention
ATTN_LOCAL = "L"         # sliding-window attention
RECURRENT = "R"          # RG-LRU recurrent block
SSM = "S"                # Mamba-2 SSD block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|hybrid|ssm|encdec-audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None    # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, ...]] = None   # qwen2-vl M-RoPE

    # layer pattern: period repeated; remainder truncated from the left of a
    # final partial period.  None -> all ATTN_GLOBAL.
    pattern_period: Optional[Tuple[str, ...]] = None
    window: Optional[int] = None      # local attention window

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    # §Perf lever: dtype of the expert combine — the EP psum wire on the
    # shardmap path AND the combine-scatter accumulator on the single-shard
    # path (bf16 halves both at negligible quality cost — the contributions
    # are already bf16 activations upcast for the scatter)
    moe_combine_dtype: str = "float32"
    # expert-FFN kernel dispatch: "ref" = three per-expert einsums (the
    # CPU/test oracle path), "pallas" = fused grouped-expert kernel
    # (kernels/moe_ffn.py) with the EXPERT axis as the coarsening axis;
    # moe_ffn_cfg is a coarsening spec label or "auto" (repro.tune).
    # Geometries the kernel can't tile fall back to the einsum path;
    # shared experts stay on the dense ffn() path.
    moe_backend: str = "ref"
    moe_ffn_cfg: str = "auto"

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    expand: int = 2

    # recurrent (rg-lru)
    lru_width: Optional[int] = None

    # enc-dec
    is_encdec: bool = False
    n_enc_layers: int = 0

    # modality frontend stub: inputs include precomputed embeddings
    frontend: Optional[str] = None    # 'vision' | 'audio'

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    compute_dtype: str = "bfloat16"

    # decode-attention kernel dispatch: "ref" = dense full-length einsum (the
    # CPU/test path), "pallas" = coarsened split-KV kernel (kernels/
    # decode_attention.py); decode_attn_cfg is a coarsening spec label or
    # "auto" (repro.tune); decode_bkv is the kv block row count.
    decode_backend: str = "ref"
    decode_attn_cfg: str = "auto"
    decode_bkv: int = 128

    # dense-FFN matmul dispatch: every ffn() gate/up/down matmul routes
    # through ops.matmul with this backend ("ref" = dtype-preserving
    # passthrough for CPU training; "pallas" = the coarsenable blocked
    # kernel, cfg="auto" through repro.tune)
    ffn_backend: str = "ref"

    # training/prefill attention dispatch: "ref" = the pure-jnp chunked
    # mea_attention (the CPU/test oracle), "pallas" = the coarsened flash
    # kernel with a custom VJP (kernels/flash_attention.py).  attn_cfg
    # coarsens the FORWARD (and the backward dQ pass) on the q-row axis;
    # attn_bwd_cfg coarsens the backward dK/dV pass on the kv-block axis —
    # independent degrees, since the two passes stream different axes.
    # Both accept a spec label or "auto" (repro.tune).  Ragged q_pos /
    # k_len / untileable geometries fall back to mea_attention.
    attn_backend: str = "ref"
    attn_cfg: str = "auto"
    attn_bwd_cfg: str = "auto"
    attn_bq: int = 128
    attn_bkv: int = 128

    # block-sparse long-context prefill (kernels/sparse_attention.py): when
    # attn_backend="pallas" and a layer has a window (ATTN_LOCAL), "auto"
    # routes eligible geometries to the block-sparse kernel — each q-block
    # program walks only the kv blocks named by a precomputed live index,
    # coarsened over the live-slot axis by attn_sparse_cfg (spec label or
    # "auto" through the flash_attention_sparse tuner family).  "off" pins
    # the dense-mask kernel.  attn_global_stride=g additionally keeps every
    # g-th kv position visible past the window on local layers
    # (LongFormer-style global columns; needs window; training through a
    # strided pattern differentiates the jnp oracle — dense cost).
    attn_sparse: str = "auto"
    attn_sparse_cfg: str = "auto"
    attn_global_stride: Optional[int] = None

    # weight-only quantization (repro.quant): "none" | "int8" (per-channel
    # symmetric) | "int4" (group-wise, quant_group rows per scale).  The
    # field records the format `quantize_params` applied to this model's
    # FFN / MoE-expert / attention-projection weights; dispatch then uses
    # the dequant-fused kernels where the backend+geometry allow and the
    # dense-dequant fallback (the parity oracle) everywhere else.
    quant: str = "none"
    quant_group: int = 32
    # KV-cache quantization: "none" | "int8" — int8 caches store an int8
    # payload plus per-(token, kv-head) f32 scales, quantize on append
    # (decode and prefill) and dequantize fused inside the split-KV kernel
    # (or densely on the ref path).  Halves the decode hot path's dominant
    # traffic AND the bytes that bound slots*max_len per host.
    kv_quant: str = "none"

    # ---- derived ----
    @property
    def vocab_padded(self) -> int:
        """Embedding/logits rows padded to 256 (Megatron-style) so the vocab
        axis always shards evenly on the TP axis; the loss and decode mask
        the pad ids."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def n_experts_padded(self) -> int:
        """Expert axis padded to 16 (the production TP degree) so expert
        parameters shard exactly; pad experts receive -inf router logits."""
        return ((self.n_experts + 15) // 16) * 16 if self.n_experts else 0

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // max(1, self.n_heads)

    @property
    def d_inner(self) -> int:          # mamba2 inner width
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def d_rnn(self) -> int:
        return self.lru_width or self.d_model

    def layer_kinds(self) -> Tuple[str, ...]:
        if self.pattern_period is None:
            return (ATTN_GLOBAL,) * self.n_layers
        p = self.pattern_period
        kinds = []
        while len(kinds) < self.n_layers:
            kinds.extend(p)
        return tuple(kinds[: self.n_layers])

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid / windowed)."""
        kinds = self.layer_kinds()
        return all(k != ATTN_GLOBAL for k in kinds) or (
            sum(k == ATTN_GLOBAL for k in kinds) <= len(kinds) // 5
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.hd
        n_q, n_kv = self.n_heads, self.n_kv_heads
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
        per_ffn = 3 * d * self.d_ff
        per_moe = (self.n_experts + self.n_shared_experts) * 3 * d * self.moe_d_ff \
            + d * self.n_experts
        per_rnn = 2 * d * self.d_rnn + self.d_rnn * d + 3 * self.d_rnn
        din = self.d_inner
        per_ssm = d * (2 * din + 2 * self.ssm_groups * self.ssm_state
                       + self.ssm_heads) + din * d + 2 * din
        total = emb
        for kind in self.layer_kinds():
            if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                total += per_attn
            elif kind == RECURRENT:
                total += per_rnn
            elif kind == SSM:
                total += per_ssm
            if kind == SSM:
                pass                      # mamba blocks have no separate FFN
            elif self.n_experts:
                total += per_moe
            else:
                total += per_ffn
            total += 2 * d                # norms
        if self.is_encdec:
            # encoder layers (self-attn + ffn) + decoder cross-attn
            total += self.n_enc_layers * (per_attn + per_ffn + 2 * d)
            total += self.n_layers * (per_attn + d)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * (
            self.n_experts * 3 * d * self.moe_d_ff)
        active_moe = self.n_layers * (self.top_k * 3 * d * self.moe_d_ff)
        return dense + active_moe

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        shrink = dict(
            n_layers=min(self.n_layers, 4 if self.pattern_period is None
                         else 2 * len(self.pattern_period)),
            d_model=128,
            n_heads=max(2, min(4, self.n_heads)),
            n_kv_heads=1 if self.n_kv_heads < self.n_heads else 2,
            d_ff=256,
            vocab=512,
            head_dim=32,
        )
        if self.n_experts:
            shrink.update(n_experts=8, top_k=min(2, self.top_k),
                          moe_d_ff=64,
                          n_shared_experts=min(1, self.n_shared_experts))
        if self.ssm_state:
            shrink.update(ssm_state=16, ssm_headdim=32)
        if self.window:
            shrink.update(window=16)
        if self.is_encdec:
            shrink.update(n_enc_layers=2)
        if self.lru_width:
            shrink.update(lru_width=128)
        if self.mrope_sections:
            # scale sections to the reduced head_dim (pairs must sum to hd/2)
            pairs = shrink["head_dim"] // 2
            tot = sum(self.mrope_sections)
            sec = [max(1, s * pairs // tot) for s in self.mrope_sections]
            sec[0] += pairs - sum(sec)
            shrink.update(mrope_sections=tuple(sec))
        shrink.update(overrides)
        kv = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        kv.update(shrink)
        # keep GQA divisibility
        if kv["n_heads"] % kv["n_kv_heads"]:
            kv["n_kv_heads"] = 1
        return ModelConfig(**kv)
