"""Model building blocks (pure functional JAX): norms, RoPE/M-RoPE, causal
depthwise conv, memory-efficient GQA attention, SwiGLU FFN, MoE.

All matmul-bearing blocks route through coarsenable kernels when
``backend='pallas'`` (small shapes / TPU); the default XLA path ('ref') is
used for CPU training, tests and the dry-run lowering.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.quant.qtypes import QTensor, asdense, dequantize, dequantize_kv

Params = dict


# --------------------------------------------------------------------------
# sharding context: axis names used for with_sharding_constraint hooks
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    dp: Any = None            # data-parallel axis name(s), e.g. ('pod','data')
    tp: Any = None            # tensor-parallel axis name, e.g. 'model'
    sp: Any = None            # sequence axis for long-context cells
    tp_size: int = 1
    dp_size: int = 1
    enabled: bool = False
    mesh: Any = None          # jax Mesh (needed by shard_map code paths)
    # optional (path, leaf) -> PartitionSpec used to re-constrain per-layer
    # parameter slices INSIDE the period scan, keeping the FSDP all-gather
    # in the loop body instead of hoisted over the whole stacked tensor
    param_spec_fn: Any = None

    def constrain_params(self, tree):
        if not self.enabled or self.param_spec_fn is None:
            return tree
        import jax
        return jax.tree_util.tree_map_with_path(
            lambda p, l: lax.with_sharding_constraint(
                l, self.param_spec_fn(p, l)), tree)

    def constrain(self, x, spec_fn):
        if not self.enabled:
            return x
        from jax.sharding import PartitionSpec as P
        return lax.with_sharding_constraint(x, spec_fn(P, self))

    def constrain_heads(self, x, n_heads: int):
        """Shard a (B,S,H,D) tensor's head axis on tp — only when it divides
        evenly (a non-divisible constraint fights GSPMD's propagation and
        triggers involuntary remat/replication)."""
        if not self.enabled or n_heads % max(1, self.tp_size):
            return x
        from jax.sharding import PartitionSpec as P
        return lax.with_sharding_constraint(x, P(self.dp, None, self.tp, None))


NOSHARD = ShardCtx()


def act(x, spec):
    return x


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale)


def rmsnorm_init(d):
    return {"scale": jnp.zeros((d,), dtype=jnp.float32)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"])).astype(dt)


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (B,S,H,D); pos: (B,S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (D/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs    # (B,S,D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    dt = x.dtype
    x1, x2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(dt)


def apply_mrope(x: jax.Array, pos3: jax.Array, theta: float,
                sections: tuple) -> jax.Array:
    """Qwen2-VL multimodal RoPE.  pos3: (3,B,S) (temporal, height, width);
    sections give the number of frequency *pairs* drawn from each component."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                       # (D/2,)
    # choose the position component per frequency-pair index
    comp = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])                                                  # (D/2,)
    pos_sel = pos3.transpose(1, 2, 0)[..., comp].astype(jnp.float32)  # (B,S,D/2)
    ang = pos_sel * freqs[None, None, :]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    dt = x.dtype
    x1, x2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(dt)


# --------------------------------------------------------------------------
# causal depthwise conv1d (mamba2 / griffin), with decode cache
# --------------------------------------------------------------------------

def conv1d_init(key, channels, width):
    return {"w": jax.random.normal(key, (width, channels), jnp.float32)
            / math.sqrt(width),
            "b": jnp.zeros((channels,), jnp.float32)}


def causal_conv1d(p, x):
    """x: (B,S,C) -> (B,S,C); causal depthwise window sum."""
    w = p["w"]
    width = w.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        shift = width - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi.astype(jnp.float32) * w[i]
    return (out + p["b"]).astype(x.dtype)


def causal_conv1d_step(p, state, xt):
    """state: (B,width-1,C) trailing inputs; xt: (B,C) -> (yt, new_state)."""
    w, b = p["w"], p["b"]
    width = w.shape[0]
    buf = jnp.concatenate([state, xt[:, None, :]], axis=1)   # (B,width,C)
    yt = jnp.einsum("bwc,wc->bc", buf.astype(jnp.float32), w) + b
    return yt.astype(xt.dtype), buf[:, 1:]


def causal_conv1d_prefill(p, state, x):
    """Chunked form: state (B,width-1,C) left context; x (B,T,C) ->
    (y (B,T,C), new_state) — matches T applications of causal_conv1d_step."""
    w = p["w"]
    width = w.shape[0]
    t = x.shape[1]
    buf = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B,w-1+T,C)
    out = jnp.zeros(x.shape, jnp.float32)
    for i in range(width):
        out = out + buf[:, i:i + t].astype(jnp.float32) * w[i]
    return (out + p["b"]).astype(x.dtype), buf[:, t:].astype(state.dtype)


# --------------------------------------------------------------------------
# memory-efficient GQA attention (pure-jnp flash; the XLA model path)
# --------------------------------------------------------------------------

def mea_attention(q, k, v, *, causal=True, window=None, q_pos=None,
                  k_len=None, q_chunk=512, kv_chunk=512, scale=None):
    """Chunked (flash-style) attention in pure jnp.

    q: (B,Sq,H,D); k,v: (B,Sk,Hkv,D).  q_pos: (B,Sq) global row positions
    (defaults to arange).  k_len: optional valid kv length (decode).
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32)[None], (b, sq))

    # keep K/V in their storage dtype (bf16): a full f32 upconversion of the
    # cache doubles+ the live set; the MXU accumulates in f32 via
    # preferred_element_type instead.
    qg = (q.reshape(b, sq, hkv, g, d) * jnp.asarray(scale, q.dtype))
    kf, vf = k, v

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq = (sq + q_chunk - 1) // q_chunk
    nk = (sk + kv_chunk - 1) // kv_chunk
    # pad to multiples
    def padto(x, n, axis):
        pad = n - x.shape[axis]
        if pad == 0:
            return x
        cfg = [(0, 0)] * x.ndim
        cfg[axis] = (0, pad)
        return jnp.pad(x, cfg)

    qg = padto(qg, nq * q_chunk, 1)
    qp = padto(q_pos, nq * q_chunk, 1)
    kf = padto(kf, nk * kv_chunk, 1)
    vf = padto(vf, nk * kv_chunk, 1)

    kpos = jnp.arange(nk * kv_chunk, dtype=jnp.int32)
    if k_len is not None and jnp.ndim(k_len) > 0:
        valid_k = kpos[None, :] < k_len[:, None]          # (B, Sk)
    else:                                                 # scalar or None
        valid_k = kpos < (sk if k_len is None else k_len)
        valid_k = jnp.broadcast_to(valid_k[None], (b, nk * kv_chunk))

    qg = qg.reshape(b, nq, q_chunk, hkv, g, d)
    qp = qp.reshape(b, nq, q_chunk)
    kc = kf.reshape(b, nk, kv_chunk, hkv, d)
    vc = vf.reshape(b, nk, kv_chunk, hkv, d)
    kpc = kpos.reshape(nk, kv_chunk)
    vkc = valid_k.reshape(b, nk, kv_chunk)

    def q_step(_, qi):
        qblk, qpos_blk = qi                               # (B,qc,hkv,g,d),(B,qc)

        # checkpointed: without this the backward saves every (q,kv) chunk's
        # probability block — i.e. the full S^2 attention matrix.  With it
        # only the per-chunk (m,l,acc) carry survives and s/p are recomputed
        # in the backward, flash-attention style.
        @jax.checkpoint
        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kp, vk = ki
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk, kblk,
                           preferred_element_type=jnp.float32)
            mask = vk[:, None, :]                         # (B,1,Sk)
            if causal:
                mask = mask & (kp[None, None, :] <= qpos_blk[:, :, None])
            if window is not None:
                mask = mask & (kp[None, None, :] > qpos_blk[:, :, None] - window)
            mask = mask[:, :, None, None, :]              # (B,q,1,1,k)
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None]) * mask
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, q_chunk, hkv, g), -1e30),
                jnp.zeros((b, q_chunk, hkv, g)),
                jnp.zeros((b, q_chunk, hkv, g, d)))
        (m, l, acc), _ = lax.scan(
            kv_step, init,
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             kpc, vkc.transpose(1, 0, 2)))
        l = jnp.where(l == 0.0, 1.0, l)
        return None, acc / l[..., None]

    _, out = lax.scan(jax.checkpoint(q_step), None,
                      (qg.transpose(1, 0, 2, 3, 4, 5), qp.transpose(1, 0, 2)))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * q_chunk, h, d)
    return out[:, :sq].astype(q.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, q_pos=None,
                    k_len=None, pos_trivial=False, scale=None,
                    backend: str = "ref", cfg="auto", bwd_cfg="auto",
                    bq: int = 128, bkv: int = 128, global_stride=None,
                    sparse: str = "auto", sparse_cfg="auto"):
    """Training/prefill attention dispatch.  q: (B,Sq,H,D);
    k, v: (B,Sk,Hkv,D) -> (B,Sq,H,D).

    backend="pallas" dispatches the coarsened custom-VJP flash kernel
    (kernels/flash_attention.py; cfg/bwd_cfg resolved through repro.tune
    for "auto" — forward q-row axis and backward kv-block axis tune
    independent degrees).  Everything the kernel cannot serve falls back to
    ``mea_attention`` — which is also the parity oracle it is tested
    against:

      * causal/window masking needs Sq == Sk and statically trivial row
        positions (``pos_trivial=True``: q row i IS global row i) — ragged
        ``q_pos`` (chunked prefill, packed batches) falls back
      * ``k_len`` (valid-prefix masking against a padded cache) falls back
      * Sq/Sk must tile by the bq/bkv blocks (and the resolved degrees)

    When a ``window`` is set (local-attention layers) and the geometry is
    kernel-eligible, ``sparse="auto"`` routes to the BLOCK-SPARSE kernel
    (`ops.flash_attention_sparse`): each q-block program walks only the kv
    blocks its precomputed live index lists, so a long-context prefill
    pays live traffic instead of the dense causal grid.  ``sparse="off"``
    pins the dense-mask kernel.  ``global_stride=g`` adds LongFormer-style
    global columns (every g-th kv position visible past the window) to the
    pattern — only meaningful together with ``window``.  Backward through
    the sparse path reuses the dense-mask backward kernels (identical
    (m, l) residuals); a global-stride pattern differentiates the jnp
    oracle instead — and when the sparse path is ineligible, a
    global-stride pattern falls back to that oracle too, since neither the
    dense kernel nor mea can express the strided columns.

    The kernel output is checkpoint-named "flash_attn_out" so the
    remat="dots" policy saves it instead of re-running the whole Pallas
    kernel in the backward.
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    if (backend == "pallas" and sparse != "off" and k_len is None
            and causal and window is not None and sq == sk and pos_trivial
            and h % hkv == 0):
        blk_q, blk_k = min(bq, sq), min(bkv, sk)
        if sq % blk_q == 0 and sk % blk_k == 0:
            from repro.core.coarsening import CoarseningConfig
            from repro.kernels import ops
            from repro.kernels.sparse_attention import max_live_blocks
            ml = max_live_blocks(sq, sk, blk_q, blk_k, causal=True,
                                 window=window, global_stride=global_stride)
            rsp = sparse_cfg if isinstance(sparse_cfg, str) \
                and sparse_cfg == "auto" \
                else (sparse_cfg if isinstance(sparse_cfg, CoarseningConfig)
                      else CoarseningConfig.parse(sparse_cfg))
            # an explicit slot degree the padded index can't tile falls
            # through to the dense path ("auto" legality guarantees a fit)
            if rsp == "auto" or ml % rsp.degree == 0:
                o = ops.flash_attention_sparse(
                    q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3), rsp, bwd_cfg=bwd_cfg,
                    bq=blk_q, bkv=blk_k, causal=True, window=window,
                    global_stride=global_stride, scale=scale)
                from jax.ad_checkpoint import checkpoint_name
                o = checkpoint_name(o, "flash_attn_out")
                return o.transpose(0, 2, 1, 3).astype(q.dtype)
    if (global_stride and window is not None and k_len is None
            and sq == sk and (pos_trivial or q_pos is None)):
        # the strided global columns exist in no other backend's mask:
        # dense flash and mea would silently drop them — take the jnp
        # oracle (dense cost, exact semantics).  Ragged positions (chunked
        # prefill) keep the plain-window mea path below: the stride only
        # defines extra VISIBLE columns, and chunked prefill already
        # re-attends the full prefix per chunk.
        from repro.kernels import ops
        o = ops.flash_attention_sparse(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), backend="ref", causal=causal,
            window=window, global_stride=global_stride, scale=scale)
        return o.transpose(0, 2, 1, 3).astype(q.dtype)
    if backend == "pallas" and k_len is None:
        blk_q, blk_k = min(bq, sq), min(bkv, sk)
        ok = h % hkv == 0 and sq % blk_q == 0 and sk % blk_k == 0
        if causal or window is not None:
            ok = ok and sq == sk and pos_trivial
        if ok:
            from repro.core.coarsening import CoarseningConfig
            from repro.kernels import ops
            rcfg = ops.resolve_cfg(cfg, "flash_attention",
                                   (b, h, hkv, sq, sk, d),
                                   dtype=q.dtype.name, backend="pallas",
                                   bq=blk_q, bkv=blk_k, causal=bool(causal))
            # the bwd cfg stays "auto" (unresolved) on the default path:
            # the family's legality guarantees a tileable pick and the
            # flash_attention_bwd search only runs when a backward trace
            # does — forward-only model calls (eval, enc, cross) pay
            # nothing.  Only an EXPLICIT bwd label needs the degree guard.
            rbwd = bwd_cfg if isinstance(bwd_cfg, str) and bwd_cfg == "auto" \
                else (bwd_cfg if isinstance(bwd_cfg, CoarseningConfig)
                      else CoarseningConfig.parse(bwd_cfg))
            bwd_ok = rbwd == "auto" or sk % (blk_k * rbwd.degree) == 0
            # an explicit degree the geometry can't tile falls back too
            if sq % (blk_q * rcfg.degree) == 0 and bwd_ok:
                o = ops.flash_attention(
                    q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3), rcfg, bwd_cfg=rbwd,
                    bq=blk_q, bkv=blk_k, causal=causal, window=window,
                    scale=scale)
                from jax.ad_checkpoint import checkpoint_name
                o = checkpoint_name(o, "flash_attn_out")
                return o.transpose(0, 2, 1, 3).astype(q.dtype)
    return mea_attention(q, k, v, causal=causal, window=window, q_pos=q_pos,
                         k_len=k_len, scale=scale)


def decode_attention(q, k_cache, v_cache, pos, *, window=None, scale=None,
                     backend: str = "ref", cfg="auto", bkv: int = 128,
                     k_scale=None, v_scale=None):
    """Single-token attention against a cache.  q: (B,1,H,D);
    caches: (B,S,Hkv,D); pos: (B,) current position (0-based).

    backend="pallas" dispatches to the coarsened split-KV kernel
    (kernels/decode_attention.py, cfg resolved through repro.tune for
    "auto") when the cache geometry tiles; anything the kernel cannot
    serve falls back to the dense full-length einsum below — which is also
    the parity oracle the kernel is tested against.

    ``k_scale``/``v_scale`` (B,S,Hkv) mark an int8-quantized cache
    (cfg.kv_quant="int8"): the kernel fuses the dequant into its VMEM pass
    (kv_bits=8 — a separate tuner cache key from the bf16 geometry); the
    dense fallback dequantizes the whole cache first.
    """
    b, _, h, d = q.shape
    if backend == "pallas":
        s_all, hkv_all = k_cache.shape[1], k_cache.shape[2]
        blk = min(bkv, s_all)
        if h % hkv_all == 0 and s_all % blk == 0:
            from repro.kernels import ops
            params = dict(bkv=blk, window=window or 0)
            if k_scale is not None:
                params["kv_bits"] = 8
            rcfg = ops.resolve_cfg(cfg, "decode_attention",
                                   (b, h, hkv_all, s_all, d),
                                   dtype=k_cache.dtype.name,
                                   backend="pallas", **params)
            # an explicit degree the cache length can't tile falls back too
            if s_all % (blk * rcfg.degree) == 0:
                return ops.decode_attention(q, k_cache, v_cache, pos, rcfg,
                                            bkv=blk, window=window,
                                            scale=scale, k_scale=k_scale,
                                            v_scale=v_scale)
    if k_scale is not None:
        # dense-dequant fallback (and the parity oracle for the fused path)
        k_cache = dequantize_kv(k_cache, k_scale)
        v_cache = dequantize_kv(v_cache, v_scale)
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    # never upconvert the cache (it is the dominant buffer at decode);
    # accumulate in f32 via preferred_element_type instead
    qg = (q.reshape(b, hkv, g, d) * jnp.asarray(scale, q.dtype)
          ).astype(k_cache.dtype)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    kpos = jnp.arange(s, dtype=jnp.int32)
    mask = kpos[None, :] <= pos[:, None]
    if window is not None:
        mask = mask & (kpos[None, :] > pos[:, None] - window)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def paged_decode_attention(q, k_pool, v_pool, block_table, pos, *,
                           window=None, scale=None, backend: str = "ref",
                           cfg="auto", k_scale=None, v_scale=None):
    """Single-token attention against a PAGED cache.  q: (B,1,H,D);
    pools: (P, page_size, Hkv, D) shared by every slot; block_table:
    (B, npp) int32 per-slot logical->physical page map; pos: (B,).

    backend="pallas" dispatches the block-table split-KV kernel
    (kernels/decode_attention.make_paged_kernel; the kv block IS the page,
    cfg resolved through the "decode_attention_paged" tuner family — page
    size joins the spec key).  The fallback gathers the table into a
    contiguous per-slot view and runs the dense einsum path — which is also
    the parity oracle the paged kernel is tested against.

    ``k_scale``/``v_scale`` (P, page_size, Hkv) mark int8 pools
    (cfg.kv_quant="int8"): dequant is fused into the kernel pass; the
    fallback dequantizes the gathered view first.
    """
    b, _, h, d = q.shape
    n_pages, ps, hkv, _ = k_pool.shape
    npp = block_table.shape[1]
    if backend == "pallas" and h % hkv == 0:
        from repro.kernels import ops
        params = dict(page_size=ps, window=window or 0)
        if k_scale is not None:
            params["kv_bits"] = 8
        rcfg = ops.resolve_cfg(cfg, "decode_attention_paged",
                               (b, h, hkv, npp, d),
                               dtype=k_pool.dtype.name,
                               backend="pallas", **params)
        # an explicit degree the per-slot page count can't tile falls back
        if npp % rcfg.degree == 0:
            return ops.paged_decode_attention(
                q, k_pool, v_pool, block_table, pos, rcfg, window=window,
                scale=scale, k_scale=k_scale, v_scale=v_scale)
    # gather-to-contiguous fallback (and the paged kernel's parity oracle)
    bt = block_table.astype(jnp.int32)
    k_view = k_pool[bt].reshape(b, npp * ps, hkv, d)
    v_view = v_pool[bt].reshape(b, npp * ps, hkv, d)
    ks = vs = None
    if k_scale is not None:
        ks = k_scale[bt].reshape(b, npp * ps, hkv)
        vs = v_scale[bt].reshape(b, npp * ps, hkv)
    return decode_attention(q, k_view, v_view, pos, window=window,
                            scale=scale, backend="ref",
                            k_scale=ks, v_scale=vs)


def verify_attention(q, k_pool, v_pool, block_table, pos0, *, window=None,
                     scale=None, backend: str = "ref", cfg="auto",
                     k_scale=None, v_scale=None):
    """Batched-verify attention against a PAGED cache (speculative decode).
    q: (B,T,H,D) — row t attends at cache position ``pos0[b] + t``; pools /
    block_table / scales as in `paged_decode_attention`; pos0: (B,).

    backend="pallas" dispatches the short-q block-table kernel
    (kernels/decode_attention.make_verify_kernel, tuned under the
    "flash_attention_verify" family — its own cache key: scoring T*G rows
    per fetched page moves the winning degree away from the decode
    family's).  The fallback gathers the table into a contiguous view and
    runs the decode dense contraction with one extra row axis — each row is
    the exact computation `decode_attention`'s fallback would do at that
    position, which is what makes greedy verify bitwise-exact against
    sequential decode on the ref backend.
    """
    b, t, h, d = q.shape
    n_pages, ps, hkv, _ = k_pool.shape
    npp = block_table.shape[1]
    if backend == "pallas" and h % hkv == 0:
        from repro.kernels import ops
        params = dict(page_size=ps, window=window or 0)
        if k_scale is not None:
            params["kv_bits"] = 8
        rcfg = ops.resolve_cfg(cfg, "flash_attention_verify",
                               (b, h, hkv, t, npp, d),
                               dtype=k_pool.dtype.name,
                               backend="pallas", **params)
        # an explicit degree the per-slot page count can't tile falls back
        if npp % rcfg.degree == 0:
            return ops.flash_attention_verify(
                q, k_pool, v_pool, block_table, pos0, rcfg, window=window,
                scale=scale, k_scale=k_scale, v_scale=v_scale)
    # gather-to-contiguous fallback (and the verify kernel's parity oracle)
    bt = block_table.astype(jnp.int32)
    k_view = k_pool[bt].reshape(b, npp * ps, hkv, d)
    v_view = v_pool[bt].reshape(b, npp * ps, hkv, d)
    if k_scale is not None:
        k_view = dequantize_kv(k_view, k_scale[bt].reshape(b, npp * ps, hkv))
        v_view = dequantize_kv(v_view, v_scale[bt].reshape(b, npp * ps, hkv))
    s = npp * ps
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = (q.reshape(b, t, hkv, g, d) * jnp.asarray(scale, q.dtype)
          ).astype(k_view.dtype)
    logits = jnp.einsum("bthgd,bshd->bthgs", qg, k_view,
                        preferred_element_type=jnp.float32)
    kpos = jnp.arange(s, dtype=jnp.int32)
    rows = pos0[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]  # (B,T)
    mask = kpos[None, None, :] <= rows[:, :, None]                  # (B,T,S)
    if window is not None:
        mask = mask & (kpos[None, None, :] > rows[:, :, None] - window)
    logits = jnp.where(mask[:, :, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bthgs,bshd->bthgd", p.astype(v_view.dtype), v_view,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, t, h, d).astype(q.dtype)


# --------------------------------------------------------------------------
# attention block params
# --------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, d_model=None):
    d = d_model or cfg.d_model
    hd, nq, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, nq * hd),
        "wk": dense_init(ks[1], d, nkv * hd),
        "wv": dense_init(ks[2], d, nkv * hd),
        "wo": dense_init(ks[3], nq * hd, d, scale=1.0 / math.sqrt(nq * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((nkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((nkv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["qnorm"] = rmsnorm_init(hd)
        p["knorm"] = rmsnorm_init(hd)
    return p


def qkv_project(p, x, cfg: ModelConfig, pos, *, mrope_pos3=None):
    """x: (B,S,d) -> q (B,S,H,hd), k,v (B,S,Hkv,hd) with rope applied."""
    b, s, _ = x.shape
    hd, nq, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    # asdense: quantized (QTensor) projections take the dense-dequant path —
    # the qkv matmuls are a small slice of a step next to FFN/cache traffic
    q = x @ asdense(p["wq"], x.dtype)
    k = x @ asdense(p["wk"], x.dtype)
    v = x @ asdense(p["wv"], x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, nq, hd)
    k = k.reshape(b, s, nkv, hd)
    v = v.reshape(b, s, nkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["qnorm"], q, cfg.norm_eps)
        k = rmsnorm(p["knorm"], k, cfg.norm_eps)
    if cfg.mrope_sections is not None:
        pos3 = mrope_pos3
        if pos3 is None:
            pos3 = jnp.broadcast_to(pos[None], (3,) + pos.shape)
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


# --------------------------------------------------------------------------
# FFN
# --------------------------------------------------------------------------

def ffn_init(key, d, d_ff):
    ks = jax.random.split(key, 3)
    return {"w1": dense_init(ks[0], d, d_ff),
            "w3": dense_init(ks[1], d, d_ff),
            "w2": dense_init(ks[2], d_ff, d, scale=1.0 / math.sqrt(d_ff))}


def ffn(p, x, *, backend: str = "ref", cfg="auto"):
    """SwiGLU FFN.  The gate/up/down matmuls route through ops.matmul so
    dense-FFN models hit the coarsening tuner too: backend="ref" is a
    dtype-preserving passthrough (the CPU-training path — numerics
    unchanged); backend="pallas" dispatches the blocked coarsenable kernel
    with cfg="auto" resolved through repro.tune.  Geometries the kernel's
    default (bm=128, bn=128, bk=256) blocks can't tile fall back to the
    passthrough.

    Quantized weights (QTensor leaves, written by repro.quant
    ``quantize_params``) dispatch the dequant-fused kernel through
    ops.quant_matmul when backend="pallas" and the geometry tiles —
    packed weight panes, dequant in VMEM, its own tuner cache key — and
    otherwise take the dense-dequant fallback, which is also the parity
    oracle tests/test_quant.py checks the kernel against."""
    from repro.kernels import ops
    shp = x.shape
    xt = x.reshape(-1, shp[-1])
    t, d = xt.shape
    if isinstance(p["w1"], QTensor):
        d_ff = p["w1"].shape[-1]
        g = p["w1"].group or 256
        if backend == "pallas" and not (t % 128 or d % 256 or d_ff % 256
                                        or 256 % g):
            qmm = lambda a, qw: ops.quant_matmul(a, qw, cfg).astype(x.dtype)
            h = jax.nn.silu(qmm(xt, p["w1"])) * qmm(xt, p["w3"])
            return qmm(h, p["w2"]).reshape(shp)
    w1 = asdense(p["w1"], x.dtype)
    w3 = asdense(p["w3"], x.dtype)
    w2 = asdense(p["w2"], x.dtype)
    d_ff = w1.shape[1]
    be = backend
    if be == "pallas" and (t % 128 or d % 256 or d_ff % 256):
        be = "ref"
    mm = lambda a, b: ops.matmul(a, b, cfg, backend=be).astype(x.dtype)
    h = jax.nn.silu(mm(xt, w1)) * mm(xt, w3)
    return mm(h, w2).reshape(shp)


# --------------------------------------------------------------------------
# MoE (top-k, optional shared experts) — capacity-based EP-shardable dispatch
# --------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig):
    d, e, ff = cfg.d_model, cfg.n_experts_padded, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, e),
        "w1": jax.random.normal(ks[1], (e, d, ff)) / math.sqrt(d),
        "w3": jax.random.normal(ks[2], (e, d, ff)) / math.sqrt(d),
        "w2": jax.random.normal(ks[3], (e, ff, d)) / math.sqrt(ff),
    }
    if cfg.n_shared_experts:
        sf = cfg.n_shared_experts * ff
        ks2 = jax.random.split(ks[4], 4)
        p["shared"] = ffn_init(ks2[0], d, sf)
        p["shared_gate"] = dense_init(ks2[1], d, 1)
    return p


def moe_default_capacity(t: int, e: int, k: int) -> int:
    """The moe() default per-expert capacity (factor 1.5, floor 8, clamped
    to the token count).  Shared by tune.warm and benchmarks/moe.py so
    warmed/modeled kernel specs match the geometry the layer dispatches."""
    return min(t, max(8, int(1.5 * k * t / e)))


def moe_expert_ffn(xe, w1, w3, w2, comb, cfg: ModelConfig):
    """Per-expert gate/up/down over the padded dispatch buffer, scaled by
    the combine weights: xe (E,C,d), w1/w3 (E,d,F), w2 (E,F,d), comb (E,C)
    -> (E,C,d) float32.

    cfg.moe_backend="pallas" dispatches the fused grouped-expert kernel
    (kernels/moe_ffn.py) with the EXPERT axis as the coarsening axis
    (cfg.moe_ffn_cfg resolved through repro.tune for "auto"); the einsum
    chain below is the oracle the kernel is tested against and the
    automatic fallback for degrees the expert count can't tile.

    Quantized expert weights (QTensor) dispatch the dequant-fused variant
    (ops.quant_moe_ffn: packed expert panes + per-program VMEM dequant)
    when the backend and int4 group geometry allow, else they dequantize
    densely and run the einsum oracle.
    """
    e, c, d = xe.shape
    f = w1.shape[-1]
    quant = isinstance(w1, QTensor)
    if cfg.moe_backend == "pallas":
        from repro.kernels import ops
        if quant:
            if w1.bits == 8 or (d % w1.group == 0 and f % w1.group == 0):
                rcfg = ops.resolve_cfg(cfg.moe_ffn_cfg, "moe_ffn",
                                       (e, c, d, f), dtype=xe.dtype.name,
                                       backend="pallas", wbits=w1.bits,
                                       group=w1.group)
                if e % rcfg.degree == 0:
                    return ops.quant_moe_ffn(xe, w1, w3, w2, comb, rcfg)
        else:
            rcfg = ops.resolve_cfg(cfg.moe_ffn_cfg, "moe_ffn", (e, c, d, f),
                                   dtype=xe.dtype.name, backend="pallas")
            # an explicit degree the expert axis can't tile falls back too
            if e % rcfg.degree == 0:
                return ops.moe_ffn(xe, w1.astype(xe.dtype),
                                   w3.astype(xe.dtype),
                                   w2.astype(xe.dtype), comb, rcfg)
    w1, w3, w2 = (asdense(w) for w in (w1, w3, w2))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w1.astype(xe.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w3.astype(xe.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, w2.astype(xe.dtype))
    return (ye * comb[..., None].astype(ye.dtype)).astype(jnp.float32)


def moe(p, x, cfg: ModelConfig, *, capacity: int | None = None,
        renorm: bool = True, shard: ShardCtx = NOSHARD):
    """x: (B,S,d) -> (B,S,d), aux load-balance loss.

    Dispatch: per-expert top-capacity gather (EP-shardable on the expert
    axis; no (T,E,C) one-hot).  Overflow tokens are dropped (capacity
    factor 1.5 by default), standard for large-scale EP.

    When a mesh is attached (shard.mesh) the computation runs under
    shard_map: each (data, model) shard routes its LOCAL tokens to its LOCAL
    experts and the contributions are psum'd over the expert ('model') axis —
    gathers and the combine-scatter stay device-local, which is what keeps
    the dispatch buffers from being replicated by GSPMD.
    """
    if shard.enabled and shard.mesh is not None and shard.tp_size > 1:
        return _moe_shardmap(p, x, cfg, capacity=capacity, renorm=renorm,
                             shard=shard)
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    e_pad = cfg.n_experts_padded
    xt = x.reshape(t, d)
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    if e_pad != e:
        logits = jnp.where(jnp.arange(e_pad) < e, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, k)                          # (T,k)
    if renorm:
        w = w / (w.sum(-1, keepdims=True) + 1e-9)

    # aux loss (Switch): e * sum_e f_e * P_e  (pad experts contribute ~0)
    onehot = jax.nn.one_hot(idx, e_pad, dtype=jnp.float32)   # (T,k,E_pad)
    f = onehot.sum(axis=(0, 1)) / t                          # fraction routed
    pmean = probs.mean(axis=0)
    aux = e * jnp.sum(f * pmean)

    cap = capacity if capacity is not None else moe_default_capacity(t, e, k)
    cap = min(cap, t)
    # per-expert token weights (E_pad, T) — shardable on E (model axis)
    tokw = jnp.einsum("tke,tk->et", onehot, w)
    tokw = shard.constrain(tokw, lambda P, c: P(c.tp, None))
    topw, topi = lax.top_k(tokw, cap)                     # (E_pad,C)
    live = topw > 0.0
    xe = jnp.take(xt, topi.reshape(-1), axis=0).reshape(e_pad, cap, d)
    xe = xe * live[..., None]
    xe = shard.constrain(xe, lambda P, c: P(c.tp, None, None))
    ye = moe_expert_ffn(xe, p["w1"], p["w3"], p["w2"], topw * live, cfg)
    # combine-scatter in cfg.moe_combine_dtype (bf16 halves the accumulator
    # traffic, mirroring the EP psum wire saving on the shardmap path)
    cdt = jnp.dtype(cfg.moe_combine_dtype)
    y = jnp.zeros((t, d), dtype=cdt).at[topi.reshape(-1)].add(
        ye.reshape(-1, d).astype(cdt))
    y = y.astype(x.dtype)
    y = shard.constrain(y, lambda P, c: P(c.dp, None))

    if cfg.n_shared_experts:
        gate = jax.nn.sigmoid((xt @ p["shared_gate"].astype(xt.dtype))
                              .astype(jnp.float32)).astype(x.dtype)
        y = y + ffn(p["shared"], xt, backend=cfg.ffn_backend) * gate
    return y.reshape(b, s, d), aux


def _moe_shardmap(p, x, cfg: ModelConfig, *, capacity, renorm,
                  shard: ShardCtx):
    """Expert-parallel MoE via shard_map (see `moe` docstring)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    e_pad = cfg.n_experts_padded
    tp, tp_axis, dp = shard.tp_size, shard.tp, shard.dp
    if e_pad % tp:
        raise ValueError(f"padded experts {e_pad} not divisible by tp={tp}")
    e_l = e_pad // tp

    xt = x.reshape(t, d)
    # quantized expert weights dequantize up front on the shard_map path:
    # QTensor leaves can't ride through the per-axis PartitionSpecs below
    # (payload and scales shard differently), so EP keeps the dense-dequant
    # fallback; the single-shard path gets the fused quantized kernel
    w1, w3, w2 = (asdense(p[k]) for k in ("w1", "w3", "w2"))
    router = p["router"]

    def body(xt_l, router_, w1_l, w3_l, w2_l):
        t_l = xt_l.shape[0]
        logits = (xt_l @ router_.astype(xt_l.dtype)).astype(jnp.float32)
        if e_pad != e:
            logits = jnp.where(jnp.arange(e_pad) < e, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = lax.top_k(probs, k)
        if renorm:
            w = w / (w.sum(-1, keepdims=True) + 1e-9)
        onehot = jax.nn.one_hot(idx, e_pad, dtype=jnp.float32)
        f = onehot.sum(axis=(0, 1)) / t_l
        aux = e * jnp.sum(f * probs.mean(axis=0))
        for ax in (dp if isinstance(dp, tuple) else (dp,)):
            aux = lax.pmean(aux, ax)

        cap = capacity if capacity is not None \
            else moe_default_capacity(t_l, e, k)
        cap = min(cap, t_l)
        j = lax.axis_index(tp_axis)
        ids_local = j * e_l + jnp.arange(e_l)              # global expert ids
        sel = idx[None] == ids_local[:, None, None]        # (E_l, T_l, k)
        tokw = jnp.einsum("etk,tk->et", sel.astype(jnp.float32), w)
        topw, topi = lax.top_k(tokw, cap)                  # (E_l, C)
        live = (topw > 0.0)
        xe = jnp.take(xt_l, topi.reshape(-1), axis=0).reshape(e_l, cap, d)
        xe = xe * live[..., None]
        ye = moe_expert_ffn(xe, w1_l, w3_l, w2_l, topw * live, cfg)
        y_l = jnp.zeros((t_l, d), jnp.float32).at[topi.reshape(-1)].add(
            ye.reshape(-1, d))
        # combine experts across the EP axis; bf16 halves the wire (§Perf)
        y_l = lax.psum(y_l.astype(jnp.dtype(cfg.moe_combine_dtype)), tp_axis)
        return y_l.astype(xt_l.dtype), aux

    y, aux = shard_map(
        body, mesh=shard.mesh,
        in_specs=(P(dp, None), P(), P(tp_axis, None, None),
                  P(tp_axis, None, None), P(tp_axis, None, None)),
        out_specs=(P(dp, None), P()),
        check_rep=False,
    )(xt, router, w1, w3, w2)

    if cfg.n_shared_experts:
        gate = jax.nn.sigmoid((xt @ p["shared_gate"].astype(xt.dtype))
                              .astype(jnp.float32)).astype(x.dtype)
        y = y + ffn(p["shared"], xt, backend=cfg.ffn_backend) * gate
    return y.reshape(b, s, d), aux
