"""Residual blocks for every assigned architecture family.

Layer stacking uses *period scanning* (models/model.py): parameters of layers
at the same position within the repeating pattern period are stacked and the
model scans over periods — compile-time stays O(period), not O(n_layers),
which keeps 80 dry-run compiles tractable.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig, ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, SSM
from repro.models import layers as L
from repro.models.layers import ShardCtx, NOSHARD


# ---------------------------------------------------------------------------
# attention + (ffn | moe) transformer block
# ---------------------------------------------------------------------------

def attn_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {"ln1": L.rmsnorm_init(cfg.d_model),
         "attn": L.attn_init(ks[0], cfg),
         "ln2": L.rmsnorm_init(cfg.d_model)}
    if cfg.n_experts:
        p["moe"] = L.moe_init(ks[1], cfg)
    else:
        p["ffn"] = L.ffn_init(ks[1], cfg.d_model, cfg.d_ff)
    return p


def _attn_kw(cfg: ModelConfig) -> dict:
    """The flash-attention dispatch knobs every attention site forwards."""
    return dict(backend=cfg.attn_backend, cfg=cfg.attn_cfg,
                bwd_cfg=cfg.attn_bwd_cfg, bq=cfg.attn_bq, bkv=cfg.attn_bkv)


def attn_block(p, x, cfg: ModelConfig, *, kind: str, pos, mrope_pos3=None,
               shard: ShardCtx = NOSHARD, moe_capacity=None,
               pos_trivial: bool = False):
    window = cfg.window if kind == ATTN_LOCAL else None
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg, pos, mrope_pos3=mrope_pos3)
    q = shard.constrain_heads(q, cfg.n_heads)
    k = shard.constrain_heads(k, cfg.n_kv_heads)
    # sparse knobs ride only the LOCAL self-attention site (not _attn_kw:
    # cross/enc attention must never see a window-derived live index)
    o = L.flash_attention(q, k, v, causal=True, window=window, q_pos=pos,
                          pos_trivial=pos_trivial,
                          global_stride=(cfg.attn_global_stride
                                         if kind == ATTN_LOCAL else None),
                          sparse=cfg.attn_sparse,
                          sparse_cfg=cfg.attn_sparse_cfg, **_attn_kw(cfg))
    o = o.reshape(x.shape[0], x.shape[1], -1) @ L.asdense(p["attn"]["wo"], x.dtype)
    x = x + o
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = 0.0
    if cfg.n_experts:
        y, aux = L.moe(p["moe"], h, cfg, shard=shard, capacity=moe_capacity)
    else:
        h2 = shard.constrain(h, lambda P, c: P(c.dp, None, None))
        y = L.ffn(p["ffn"], h2, backend=cfg.ffn_backend)
    return x + y, aux


def attn_block_decode(p, x, cfg: ModelConfig, cache, *, kind: str, pos,
                      shard: ShardCtx = NOSHARD):
    """x: (B,1,d); cache: {'k','v'[,'k_scale','v_scale']} (B,S,kv,hd);
    pos: (B,).  A quantized cache (cfg.kv_quant="int8", marked by the scale
    leaves) QUANTIZES ON APPEND: the new row is absmax-scaled per kv-head
    before the scatter, and the scales ride to the attention dispatch."""
    window = cfg.window if kind == ATTN_LOCAL else None
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg, pos[:, None])
    bidx = jnp.arange(x.shape[0])
    quant = "k_scale" in cache
    # barrier: stops XLA from fusing the (f32 rope) -> bf16 convert into the
    # cache scatter, which would materialize the WHOLE cache in f32
    k_upd, v_upd = jax.lax.optimization_barrier((k[:, 0], v[:, 0]))
    kscale = vscale = None
    if quant:
        from repro.quant.qtypes import quantize_kv
        k_upd, ks_new = quantize_kv(k_upd.astype(jnp.float32))
        v_upd, vs_new = quantize_kv(v_upd.astype(jnp.float32))
        kscale = cache["k_scale"].at[bidx, pos].set(ks_new)
        vscale = cache["v_scale"].at[bidx, pos].set(vs_new)
    kc = cache["k"].at[bidx, pos].set(k_upd)
    vc = cache["v"].at[bidx, pos].set(v_upd)
    o = L.decode_attention(q, kc, vc, pos, window=window,
                           backend=cfg.decode_backend,
                           cfg=cfg.decode_attn_cfg, bkv=cfg.decode_bkv,
                           k_scale=kscale, v_scale=vscale)
    o = o.reshape(x.shape[0], 1, -1) @ L.asdense(p["attn"]["wo"], x.dtype)
    x = x + o
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.n_experts:
        y, _ = L.moe(p["moe"], h, cfg, shard=shard,
                     capacity=max(4, min(x.shape[0], 4 * cfg.top_k)))
    else:
        y = L.ffn(p["ffn"], h, backend=cfg.ffn_backend)
    newc = {"k": kc, "v": vc}
    if quant:
        newc.update(k_scale=kscale, v_scale=vscale)
    return x + y, newc


def attn_block_prefill(p, x, cfg: ModelConfig, cache, *, kind: str, pos0):
    """Chunked prefill: x (B,T,d); cache {'k','v'} (B,S,kv,hd); pos0 (B,)
    starting position of the chunk.  Writes the chunk's K/V into the cache
    rows [pos0, pos0+T) and attends against the FULL cache with global
    row positions — earlier chunks are visible, later rows are masked by
    causality — so successive chunks compose exactly with per-token decode.
    """
    b, t, _ = x.shape
    window = cfg.window if kind == ATTN_LOCAL else None
    pos = pos0[:, None] + jnp.arange(t, dtype=jnp.int32)[None]     # (B,T)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg, pos)
    bidx = jnp.arange(b)
    quant = "k_scale" in cache
    newc = {}
    if quant:
        # quantize-on-append, chunk rows at once: (B,T,kv,hd) -> int8 +
        # per-(token, kv-head) scales, matching the decode step exactly so
        # chunked ingestion composes with per-token decode
        from repro.quant.qtypes import quantize_kv
        kq, ks_new = quantize_kv(k.astype(jnp.float32))
        vq, vs_new = quantize_kv(v.astype(jnp.float32))
        k_upd, v_upd = jax.lax.optimization_barrier((kq, vq))
        newc["k_scale"] = cache["k_scale"].at[bidx[:, None], pos].set(ks_new)
        newc["v_scale"] = cache["v_scale"].at[bidx[:, None], pos].set(vs_new)
    else:
        # same barrier as the decode step: keep the f32 rope -> storage-dtype
        # convert out of the cache scatter so the whole cache never goes f32
        k_upd, v_upd = jax.lax.optimization_barrier(
            (k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)))
    kc = cache["k"].at[bidx[:, None], pos].set(k_upd)
    vc = cache["v"].at[bidx[:, None], pos].set(v_upd)
    if quant:
        from repro.quant.qtypes import dequantize_kv
        ka = dequantize_kv(kc, newc["k_scale"]).astype(x.dtype)
        va = dequantize_kv(vc, newc["v_scale"]).astype(x.dtype)
    else:
        ka, va = kc, vc
    # chunk rows sit at ragged global positions inside a padded cache: the
    # dispatch always falls back to mea here (pos_trivial=False), by design
    o = L.flash_attention(q, ka, va, causal=True, window=window, q_pos=pos,
                          **_attn_kw(cfg))
    o = o.reshape(b, t, -1) @ L.asdense(p["attn"]["wo"], x.dtype)
    x = x + o
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.n_experts:
        # capacity = all chunk tokens: prefill never drops, so chunked
        # ingestion can't diverge from per-token decode on routing overflow
        y, _ = L.moe(p["moe"], h, cfg, capacity=b * t)
    else:
        y = L.ffn(p["ffn"], h, backend=cfg.ffn_backend)
    return x + y, {"k": kc, "v": vc, **newc}


def attn_cache_init(cfg: ModelConfig, b: int, s_max: int, dtype=jnp.bfloat16):
    """Decode K/V cache.  cfg.kv_quant="int8" allocates int8 payloads plus
    per-(token, kv-head) f32 scales — ~half the bytes of a bf16 cache, which
    is what roughly doubles the slots*max_len a host can hold.  (Enc-dec
    self-attn caches stay dense: the decoder blocks there don't carry the
    quantize-on-append path.  The enc-dec CROSS cache does quantize — it is
    written once at prefill, see lm_init_cache/_prefill_enc_cache.)"""
    if cfg.kv_quant == "int8" and not cfg.is_encdec:
        return {"k": jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.hd), jnp.int8),
                "v": jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.hd), jnp.int8),
                "k_scale": jnp.zeros((b, s_max, cfg.n_kv_heads), jnp.float32),
                "v_scale": jnp.zeros((b, s_max, cfg.n_kv_heads), jnp.float32)}
    return {"k": jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.hd), dtype)}


# -- paged attention cache (repro.serve: global page pool + block tables) ----

def attn_cache_init_paged(cfg: ModelConfig, num_pages: int, page_size: int,
                          dtype=jnp.bfloat16):
    """Paged decode K/V: one global (P, page_size, Hkv, hd) pool per layer,
    shared by every slot through its block table.  Page 0 is the NULL page
    (repro.serve.paging): never allocated, and the write paths route
    inactive slots' scatters to it.  cfg.kv_quant="int8" composes — int8
    payload pools + per-(row, kv-head) f32 scale pools, double the pages
    per HBM byte."""
    shape = (num_pages, page_size, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_quant == "int8":
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _paged_rows(block_table, pos, page_size):
    """Physical (page, row) for logical cache rows ``pos``; pos may be (B,)
    or (B,T).  Rows past a slot's allocation resolve to the NULL page."""
    bidx = jnp.arange(block_table.shape[0])
    if pos.ndim == 2:
        bidx = bidx[:, None]
    return block_table[bidx, pos // page_size], pos % page_size


def attn_block_decode_paged(p, x, cfg: ModelConfig, cache, *, kind: str, pos,
                            block_table, write_mask=None,
                            shard: ShardCtx = NOSHARD):
    """Paged twin of attn_block_decode: the new row scatters through the
    block table into the shared pool and attention reads the pool through
    the same table.  cache: {'k','v'[,'k_scale','v_scale']} pools
    (P,ps,kv,hd); block_table: (B, npp) int32; pos: (B,).

    ``write_mask`` (B,) bool suppresses a slot's cache write (the
    speculative draft scan pads every slot to the batch-max draft length;
    padded steps run at positions past the slot's page coverage, where the
    table lookup CLAMPS and would alias a live page — so the page is routed
    to NULL before the scatter)."""
    window = cfg.window if kind == ATTN_LOCAL else None
    ps = cache["k"].shape[1]
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg, pos[:, None])
    quant = "k_scale" in cache
    k_upd, v_upd = jax.lax.optimization_barrier((k[:, 0], v[:, 0]))
    page, row = _paged_rows(block_table, pos, ps)
    if write_mask is not None:
        page = jnp.where(write_mask, page, 0)       # 0 == NULL_PAGE
    kscale = vscale = None
    if quant:
        from repro.quant.qtypes import quantize_kv
        k_upd, ks_new = quantize_kv(k_upd.astype(jnp.float32))
        v_upd, vs_new = quantize_kv(v_upd.astype(jnp.float32))
        kscale = cache["k_scale"].at[page, row].set(ks_new)
        vscale = cache["v_scale"].at[page, row].set(vs_new)
    kc = cache["k"].at[page, row].set(k_upd)
    vc = cache["v"].at[page, row].set(v_upd)
    o = L.paged_decode_attention(q, kc, vc, block_table, pos, window=window,
                                 backend=cfg.decode_backend,
                                 cfg=cfg.decode_attn_cfg,
                                 k_scale=kscale, v_scale=vscale)
    o = o.reshape(x.shape[0], 1, -1) @ L.asdense(p["attn"]["wo"], x.dtype)
    x = x + o
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.n_experts:
        y, _ = L.moe(p["moe"], h, cfg, shard=shard,
                     capacity=max(4, min(x.shape[0], 4 * cfg.top_k)))
    else:
        y = L.ffn(p["ffn"], h, backend=cfg.ffn_backend)
    newc = {"k": kc, "v": vc}
    if quant:
        newc.update(k_scale=kscale, v_scale=vscale)
    return x + y, newc


def attn_block_prefill_paged(p, x, cfg: ModelConfig, cache, *, kind: str,
                             pos0, block_table):
    """Paged twin of attn_block_prefill: the chunk's rows scatter through
    the block table; attention gathers the slot's logical view back out of
    the pool (mea fallback, as in the contiguous prefill).  Write
    protection for non-admitted slots comes from the table itself — the
    engine nulls their rows, so their scatters land on the null page."""
    b, t, _ = x.shape
    ps = cache["k"].shape[1]
    npp = block_table.shape[1]
    window = cfg.window if kind == ATTN_LOCAL else None
    pos = pos0[:, None] + jnp.arange(t, dtype=jnp.int32)[None]     # (B,T)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg, pos)
    page, row = _paged_rows(block_table, pos, ps)
    quant = "k_scale" in cache
    newc = {}
    if quant:
        from repro.quant.qtypes import quantize_kv
        kq, ks_new = quantize_kv(k.astype(jnp.float32))
        vq, vs_new = quantize_kv(v.astype(jnp.float32))
        k_upd, v_upd = jax.lax.optimization_barrier((kq, vq))
        newc["k_scale"] = cache["k_scale"].at[page, row].set(ks_new)
        newc["v_scale"] = cache["v_scale"].at[page, row].set(vs_new)
    else:
        k_upd, v_upd = jax.lax.optimization_barrier(
            (k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)))
    kc = cache["k"].at[page, row].set(k_upd)
    vc = cache["v"].at[page, row].set(v_upd)
    bt = block_table.astype(jnp.int32)
    ka = kc[bt].reshape(b, npp * ps, cfg.n_kv_heads, cfg.hd)
    va = vc[bt].reshape(b, npp * ps, cfg.n_kv_heads, cfg.hd)
    if quant:
        from repro.quant.qtypes import dequantize_kv
        ka = dequantize_kv(ka, newc["k_scale"][bt].reshape(b, npp * ps, -1)
                           ).astype(x.dtype)
        va = dequantize_kv(va, newc["v_scale"][bt].reshape(b, npp * ps, -1)
                           ).astype(x.dtype)
    o = L.flash_attention(q, ka, va, causal=True, window=window, q_pos=pos,
                          **_attn_kw(cfg))
    o = o.reshape(b, t, -1) @ L.asdense(p["attn"]["wo"], x.dtype)
    x = x + o
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.n_experts:
        y, _ = L.moe(p["moe"], h, cfg, capacity=b * t)
    else:
        y = L.ffn(p["ffn"], h, backend=cfg.ffn_backend)
    return x + y, {"k": kc, "v": vc, **newc}


def attn_block_verify_paged(p, x, cfg: ModelConfig, cache, *, kind: str,
                            pos0, block_table, valid_len=None,
                            shard: ShardCtx = NOSHARD):
    """Batched-verify twin of attn_block_decode_paged (speculative decode):
    T drafted rows per slot scatter through the block table at positions
    ``pos0[b] + t`` and attention scores all of them in one short-q pass
    (L.verify_attention — the flash_attention_verify tuner family).

    ``valid_len`` (B,) int32 marks rows ``t >= valid_len[b]`` as batch
    padding.  Their writes MUST be suppressed: JAX clamps out-of-bounds
    gathers, so a padded row past the slot's page coverage would resolve
    the table lookup to a LIVE page and the scatter would corrupt it —
    route the page to NULL before the scatter instead (scatters to row 0
    of the null page are harmless by construction)."""
    b, t, _ = x.shape
    ps = cache["k"].shape[1]
    window = cfg.window if kind == ATTN_LOCAL else None
    pos = pos0[:, None] + jnp.arange(t, dtype=jnp.int32)[None]     # (B,T)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg, pos)
    page, row = _paged_rows(block_table, pos, ps)
    if valid_len is not None:
        live = jnp.arange(t, dtype=jnp.int32)[None] < valid_len[:, None]
        page = jnp.where(live, page, 0)             # 0 == NULL_PAGE
    quant = "k_scale" in cache
    kscale = vscale = None
    if quant:
        from repro.quant.qtypes import quantize_kv
        kq, ks_new = quantize_kv(k.astype(jnp.float32))
        vq, vs_new = quantize_kv(v.astype(jnp.float32))
        k_upd, v_upd = jax.lax.optimization_barrier((kq, vq))
        kscale = cache["k_scale"].at[page, row].set(ks_new)
        vscale = cache["v_scale"].at[page, row].set(vs_new)
    else:
        k_upd, v_upd = jax.lax.optimization_barrier(
            (k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)))
    kc = cache["k"].at[page, row].set(k_upd)
    vc = cache["v"].at[page, row].set(v_upd)
    o = L.verify_attention(q, kc, vc, block_table, pos0, window=window,
                           backend=cfg.decode_backend,
                           cfg=cfg.decode_attn_cfg,
                           k_scale=kscale, v_scale=vscale)
    o = o.reshape(b, t, -1) @ L.asdense(p["attn"]["wo"], x.dtype)
    x = x + o
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.n_experts:
        # full capacity, as in chunked prefill: verify never drops on
        # routing overflow
        y, _ = L.moe(p["moe"], h, cfg, shard=shard, capacity=b * t)
    else:
        y = L.ffn(p["ffn"], h, backend=cfg.ffn_backend)
    newc = {"k": kc, "v": vc}
    if quant:
        newc.update(k_scale=kscale, v_scale=vscale)
    return x + y, newc


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------

def rglru_block_init(key, cfg: ModelConfig):
    d, dr = cfg.d_model, cfg.d_rnn
    ks = jax.random.split(key, 8)
    return {
        "ln1": L.rmsnorm_init(d),
        "wx": L.dense_init(ks[0], d, dr),
        "wgate": L.dense_init(ks[1], d, dr),
        "conv": L.conv1d_init(ks[2], dr, cfg.conv_width),
        "wr": L.dense_init(ks[3], dr, dr),
        "wi": L.dense_init(ks[4], dr, dr),
        "br": jnp.zeros((dr,), jnp.float32),
        "bi": jnp.zeros((dr,), jnp.float32),
        # softplus(a_param) ~ 0.08 -> decay a in the stable range
        "a_param": jnp.log(jnp.expm1(jnp.full((dr,), 0.08))),
        "wo": L.dense_init(ks[5], dr, d, scale=1.0 / math.sqrt(dr)),
        "ln2": L.rmsnorm_init(d),
        "ffn": L.ffn_init(ks[6], d, cfg.d_ff),
    }


def rglru_block(p, x, cfg: ModelConfig, *, shard: ShardCtx = NOSHARD):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    branch = h @ p["wx"].astype(h.dtype)
    gate = h @ p["wgate"].astype(h.dtype)
    bx = L.causal_conv1d(p["conv"], branch)
    r = (bx @ p["wr"].astype(bx.dtype)) + p["br"].astype(bx.dtype)
    i = (bx @ p["wi"].astype(bx.dtype)) + p["bi"].astype(bx.dtype)
    from repro.kernels import ref as KREF
    hseq = KREF.rglru(bx.astype(jnp.float32), r.astype(jnp.float32),
                      i.astype(jnp.float32), p["a_param"]).astype(x.dtype)
    y = (hseq * jax.nn.gelu(gate)) @ _rglru_out(p, x.dtype)
    x = x + y
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + L.ffn(p["ffn"], h, backend=cfg.ffn_backend), 0.0


def _rglru_out(p, dtype):
    # out proj: reuse wgate^T shape (dr, d) — stored lazily as its own param
    return L.asdense(p["wo"], dtype)


def rglru_block_decode(p, x, cfg: ModelConfig, cache, *, pos):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    branch = h @ p["wx"].astype(h.dtype)           # (B,1,dr)
    gate = h @ p["wgate"].astype(h.dtype)
    yt, conv_state = L.causal_conv1d_step(p["conv"], cache["conv"], branch[:, 0])
    bx = yt[:, None]
    r = (bx @ p["wr"].astype(bx.dtype)) + p["br"].astype(bx.dtype)
    i = (bx @ p["wi"].astype(bx.dtype)) + p["bi"].astype(bx.dtype)
    rg = jax.nn.sigmoid(r.astype(jnp.float32))
    ig = jax.nn.sigmoid(i.astype(jnp.float32))
    from repro.kernels.ref import RGLRU_C
    log_a = -RGLRU_C * jax.nn.softplus(p["a_param"])[None, None] * rg
    a_t = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    hnew = a_t[:, 0] * cache["h"] + mult[:, 0] * (
        ig[:, 0] * bx[:, 0].astype(jnp.float32))
    y = (hnew[:, None].astype(x.dtype) * jax.nn.gelu(gate)) @ _rglru_out(p, x.dtype)
    x = x + y
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + L.ffn(p["ffn"], h, backend=cfg.ffn_backend), \
        {"conv": conv_state, "h": hnew}


def rglru_block_prefill(p, x, cfg: ModelConfig, cache, *, pos0):
    """Chunked prefill: run T tokens through the recurrence starting from
    the cached (conv, h) state and return the advanced state."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    branch = h @ p["wx"].astype(h.dtype)
    gate = h @ p["wgate"].astype(h.dtype)
    bx, conv_state = L.causal_conv1d_prefill(p["conv"], cache["conv"], branch)
    r = (bx @ p["wr"].astype(bx.dtype)) + p["br"].astype(bx.dtype)
    i = (bx @ p["wi"].astype(bx.dtype)) + p["bi"].astype(bx.dtype)
    from repro.kernels import ref as KREF
    hseq, h_last = KREF.rglru_with_state(
        bx.astype(jnp.float32), r.astype(jnp.float32), i.astype(jnp.float32),
        p["a_param"], cache["h"])
    y = (hseq.astype(x.dtype) * jax.nn.gelu(gate)) @ _rglru_out(p, x.dtype)
    x = x + y
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + L.ffn(p["ffn"], h, backend=cfg.ffn_backend), \
        {"conv": conv_state, "h": h_last}


def rglru_cache_init(cfg: ModelConfig, b: int, dtype=jnp.bfloat16):
    return {"conv": jnp.zeros((b, cfg.conv_width - 1, cfg.d_rnn), dtype),
            "h": jnp.zeros((b, cfg.d_rnn), jnp.float32)}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) block
# ---------------------------------------------------------------------------

def mamba_block_init(key, cfg: ModelConfig):
    d, din = cfg.d_model, cfg.d_inner
    g, n, hh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_ch = din + 2 * g * n
    ks = jax.random.split(key, 6)
    return {
        "ln": L.rmsnorm_init(d),
        "in_proj": L.dense_init(ks[0], d, 2 * din + 2 * g * n + hh),
        "conv": L.conv1d_init(ks[1], conv_ch, cfg.conv_width),
        "dt_bias": jnp.zeros((hh,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, hh)),
        "d_skip": jnp.ones((hh,), jnp.float32),
        "gnorm": L.rmsnorm_init(din),
        "out_proj": L.dense_init(ks[2], din, d),
    }


def _mamba_split(cfg, zxbcdt):
    din, g, n, hh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * g * n], axis=-1)
    return z, xbc, dt


def mamba_block(p, x, cfg: ModelConfig, *, shard: ShardCtx = NOSHARD):
    b, s, d = x.shape
    din, g, n, hh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_headdim
    h0 = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    zxbcdt = h0 @ p["in_proj"].astype(h0.dtype)
    z, xbc, dt_raw = _mamba_split(cfg, zxbcdt)
    xbc = jax.nn.silu(L.causal_conv1d(p["conv"], xbc))
    xs, bc = jnp.split(xbc, [din], axis=-1)
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = -jnp.exp(p["a_log"])
    from repro.kernels import ref as KREF
    xh = xs.reshape(b, s, hh, ph).astype(jnp.float32)         # (B,S,H,P)
    chunk = 64 if s % 64 == 0 else (16 if s % 16 == 0 else 1)
    y = KREF.ssd_chunked(xh, dt, a,
                         bmat.reshape(b, s, g, n).astype(jnp.float32),
                         cmat.reshape(b, s, g, n).astype(jnp.float32),
                         chunk=chunk)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, din).astype(x.dtype)
    y = L.rmsnorm(p["gnorm"], y * jax.nn.silu(z), cfg.norm_eps)
    return x + y @ p["out_proj"].astype(x.dtype), 0.0


def mamba_block_decode(p, x, cfg: ModelConfig, cache, *, pos):
    b = x.shape[0]
    din, g, n, hh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_headdim
    h0 = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    zxbcdt = (h0 @ p["in_proj"].astype(h0.dtype))[:, 0]
    z, xbc, dt_raw = _mamba_split(cfg, zxbcdt)
    yt, conv_state = L.causal_conv1d_step(p["conv"], cache["conv"], xbc)
    xbc = jax.nn.silu(yt)
    xs, bc = jnp.split(xbc, [din], axis=-1)
    bmat, cmat = jnp.split(bc, 2, axis=-1)                    # (B, g*n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    a = -jnp.exp(p["a_log"])                                  # (H,)
    xh = xs.reshape(b, hh, ph).astype(jnp.float32)
    rep = hh // g
    bm = jnp.repeat(bmat.reshape(b, g, n), rep, axis=1).astype(jnp.float32)
    cm = jnp.repeat(cmat.reshape(b, g, n), rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt * a[None])                                # (B,H)
    state = cache["ssm"] * dA[..., None, None] + jnp.einsum(
        "bhn,bhp->bhpn", bm * dt[..., None], xh)
    y = jnp.einsum("bhn,bhpn->bhp", cm, state)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, din).astype(x.dtype)
    y = L.rmsnorm(p["gnorm"], y * jax.nn.silu(z[:, None]), cfg.norm_eps)
    return x + y @ p["out_proj"].astype(x.dtype), \
        {"conv": conv_state, "ssm": state}


def mamba_block_prefill(p, x, cfg: ModelConfig, cache, *, pos0):
    """Chunked prefill: advance (conv, ssm) state over T tokens at once via
    the chunked SSD scan seeded with the cached state."""
    b, t, _ = x.shape
    din, g, n, hh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_headdim
    h0 = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    zxbcdt = h0 @ p["in_proj"].astype(h0.dtype)
    z, xbc, dt_raw = _mamba_split(cfg, zxbcdt)
    yconv, conv_state = L.causal_conv1d_prefill(p["conv"], cache["conv"], xbc)
    xbc = jax.nn.silu(yconv)
    xs, bc = jnp.split(xbc, [din], axis=-1)
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,T,H)
    a = -jnp.exp(p["a_log"])
    from repro.kernels import ref as KREF
    xh = xs.reshape(b, t, hh, ph).astype(jnp.float32)
    chunk = 64 if t % 64 == 0 else (16 if t % 16 == 0 else 1)
    y, state = KREF.ssd_chunked(
        xh, dt, a,
        bmat.reshape(b, t, g, n).astype(jnp.float32),
        cmat.reshape(b, t, g, n).astype(jnp.float32),
        chunk=chunk, state0=cache["ssm"].transpose(0, 1, 3, 2),
        return_state=True)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(b, t, din).astype(x.dtype)
    y = L.rmsnorm(p["gnorm"], y * jax.nn.silu(z), cfg.norm_eps)
    return x + y @ p["out_proj"].astype(x.dtype), \
        {"conv": conv_state, "ssm": state.transpose(0, 1, 3, 2)}


def mamba_cache_init(cfg: ModelConfig, b: int, dtype=jnp.bfloat16):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {"conv": jnp.zeros((b, cfg.conv_width - 1, conv_ch), dtype),
            "ssm": jnp.zeros((b, cfg.ssm_heads, cfg.ssm_headdim,
                              cfg.ssm_state), jnp.float32)}


# ---------------------------------------------------------------------------
# encoder / decoder blocks (seamless-m4t)
# ---------------------------------------------------------------------------

def enc_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {"ln1": L.rmsnorm_init(cfg.d_model),
            "attn": L.attn_init(ks[0], cfg),
            "ln2": L.rmsnorm_init(cfg.d_model),
            "ffn": L.ffn_init(ks[1], cfg.d_model, cfg.d_ff)}


def enc_block(p, x, cfg: ModelConfig, *, pos, shard: ShardCtx = NOSHARD):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg, pos)
    # non-causal: mask-free, so kernel eligibility needs no trivial-pos proof
    o = L.flash_attention(q, k, v, causal=False, q_pos=pos, **_attn_kw(cfg))
    x = x + o.reshape(x.shape[0], x.shape[1], -1) @ L.asdense(p["attn"]["wo"], x.dtype)
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + L.ffn(p["ffn"], h, backend=cfg.ffn_backend)


def dec_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {"ln1": L.rmsnorm_init(cfg.d_model),
            "attn": L.attn_init(ks[0], cfg),
            "lnx": L.rmsnorm_init(cfg.d_model),
            "xattn": L.attn_init(ks[1], cfg),
            "ln2": L.rmsnorm_init(cfg.d_model),
            "ffn": L.ffn_init(ks[2], cfg.d_model, cfg.d_ff)}


def _cross_attention(p, x, enc_kv, cfg: ModelConfig, enc_scales=None):
    b, s, _ = x.shape
    hd, nq, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = (x @ L.asdense(p["wq"], x.dtype)).reshape(b, s, nq, hd)
    k, v = enc_kv
    if enc_scales is not None:
        # quantized cross cache (cfg.kv_quant="int8"): int8 payloads with
        # per-(token, kv-head) scales, dequantized to the activation dtype
        from repro.quant.qtypes import dequantize_kv
        ks, vs = enc_scales
        k = dequantize_kv(k, ks).astype(x.dtype)
        v = dequantize_kv(v, vs).astype(x.dtype)
    # non-causal cross attention: the kernel serves Sq != Sk geometries
    return L.flash_attention(q, k, v, causal=False,
                             **_attn_kw(cfg)).reshape(b, s, -1) \
        @ L.asdense(p["wo"], x.dtype)


def _enc_scales(cache):
    """(k_scale, v_scale) from a cross cache when quantized, else None."""
    if "enc_k_scale" in cache:
        return cache["enc_k_scale"], cache["enc_v_scale"]
    return None


def enc_kv(p, enc_out, cfg: ModelConfig):
    b, s, _ = enc_out.shape
    k = (enc_out @ L.asdense(p["wk"], enc_out.dtype)).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ L.asdense(p["wv"], enc_out.dtype)).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    return k, v


def dec_block(p, x, cfg: ModelConfig, *, pos, enc_out,
              shard: ShardCtx = NOSHARD, enc_kv_pre=None,
              pos_trivial: bool = False):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg, pos)
    o = L.flash_attention(q, k, v, causal=True, q_pos=pos,
                          pos_trivial=pos_trivial, **_attn_kw(cfg))
    x = x + o.reshape(x.shape[0], x.shape[1], -1) @ L.asdense(p["attn"]["wo"], x.dtype)
    h = L.rmsnorm(p["lnx"], x, cfg.norm_eps)
    kv = enc_kv_pre if enc_kv_pre is not None \
        else enc_kv(p["xattn"], enc_out, cfg)
    x = x + _cross_attention(p["xattn"], h, kv, cfg)
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + L.ffn(p["ffn"], h, backend=cfg.ffn_backend), 0.0


def dec_block_prefill(p, x, cfg: ModelConfig, cache, *, pos0):
    """Chunked prefill for the enc-dec decoder: fill the self-attn cache
    rows for the chunk and cross-attend the cached encoder K/V (which
    lm_prefill populates from src_frames when present)."""
    b, t, _ = x.shape
    pos = pos0[:, None] + jnp.arange(t, dtype=jnp.int32)[None]
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg, pos)
    bidx = jnp.arange(b)
    kc = cache["k"].at[bidx[:, None], pos].set(k.astype(cache["k"].dtype))
    vc = cache["v"].at[bidx[:, None], pos].set(v.astype(cache["v"].dtype))
    # ragged chunk positions against the padded cache: mea fallback, as in
    # attn_block_prefill
    o = L.flash_attention(q, kc, vc, causal=True, q_pos=pos,
                          **_attn_kw(cfg))
    x = x + o.reshape(b, t, -1) @ L.asdense(p["attn"]["wo"], x.dtype)
    h = L.rmsnorm(p["lnx"], x, cfg.norm_eps)
    x = x + _cross_attention(p["xattn"], h,
                             (cache["enc_k"], cache["enc_v"]), cfg,
                             enc_scales=_enc_scales(cache))
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    enc = {kk: cache[kk] for kk in cache if kk.startswith("enc_")}
    return x + L.ffn(p["ffn"], h, backend=cfg.ffn_backend), {"k": kc, "v": vc,
                                                             **enc}


def dec_block_decode(p, x, cfg: ModelConfig, cache, *, pos):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg, pos[:, None])
    bidx = jnp.arange(x.shape[0])
    kc = cache["k"].at[bidx, pos].set(k[:, 0])
    vc = cache["v"].at[bidx, pos].set(v[:, 0])
    o = L.decode_attention(q, kc, vc, pos, backend=cfg.decode_backend,
                           cfg=cfg.decode_attn_cfg, bkv=cfg.decode_bkv)
    x = x + o.reshape(x.shape[0], 1, -1) @ L.asdense(p["attn"]["wo"], x.dtype)
    h = L.rmsnorm(p["lnx"], x, cfg.norm_eps)
    x = x + _cross_attention(p["xattn"], h,
                             (cache["enc_k"], cache["enc_v"]), cfg,
                             enc_scales=_enc_scales(cache))
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    enc = {kk: cache[kk] for kk in cache if kk.startswith("enc_")}
    return x + L.ffn(p["ffn"], h, backend=cfg.ffn_backend), {"k": kc, "v": vc,
                                                             **enc}
