"""Model stack: all 10 assigned architectures through one functional API.

  config.py — ModelConfig (+ layer patterns, MoE/SSM/enc-dec fields)
  layers.py — norms, RoPE/M-RoPE, conv, chunked attention, FFN, MoE (+EP)
  blocks.py — attention / RG-LRU / Mamba-2 / enc-dec residual blocks
  model.py  — lm_init/lm_apply/lm_loss/lm_decode_step with period scanning
"""
from repro.models.config import ModelConfig
