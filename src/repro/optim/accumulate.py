"""Microbatch gradient accumulation via lax.scan.

Under GSPMD the per-microbatch reduce-scatter of gradients overlaps with the
next microbatch's compute (XLA schedules the collective async); accumulation
also shrinks the live activation set — the standard large-scale recipe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def accumulate_grads(loss_fn, params, batch, n_micro: int):
    """loss_fn(params, microbatch) -> (loss, metrics).

    batch leaves have leading dim B = n_micro * b_micro; returns mean loss,
    summed-then-averaged grads, metrics of the last microbatch.
    """
    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, grads, metrics

    def split(x):
        b = x.shape[0]
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = jax.tree.map(split, batch)

    def body(carry, mb):
        loss_acc, grads_acc = carry
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
        return (loss_acc + loss, grads_acc), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grads_sum), metrics = lax.scan(body, (0.0, zeros), micro)
    inv = 1.0 / n_micro
    grads = jax.tree.map(lambda g: g * inv, grads_sum)
    last_metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss_sum * inv, grads, last_metrics
