"""Optimizer substrate: AdamW, clipping, schedules, microbatch accumulation,
gradient compression + bucket coarsening."""
from .adamw import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from .schedule import wsd_schedule
from .accumulate import accumulate_grads
from .compression import (
    int8_compress_grads, bucket_coarsen, BucketPlan, plan_buckets)
