"""AdamW with decoupled weight decay and global-norm clipping (pure pytree).

Optimizer state inherits the parameter sharding (ZeRO-style: the dry-run
shards params 2D over (data, model), so m/v are sharded identically for free
under GSPMD).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params):
    return {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        newp = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return newp.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gn
