"""Warmup-Stable-Decay learning-rate schedule (scalar jnp, jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(step, *, warmup: int = 100, stable: int = 10_000,
                 decay: int = 1_000, floor: float = 0.1):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (s + 1) / max(1, warmup))
    past = jnp.maximum(0.0, s - (warmup + stable))
    dec = 1.0 - (1.0 - floor) * jnp.minimum(1.0, past / max(1, decay))
    return warm * dec
