"""Gradient compression + bucket coarsening for the DP all-reduce.

Bucket coarsening is the paper's core insight applied to collectives: many
narrow transactions (one all-reduce per parameter tensor) are strictly worse
than few wide ones (one all-reduce per ~64MB bucket), exactly as one 512-bit
burst-coalesced LSU beats eight 32-bit LSUs.  `plan_buckets`/`bucket_coarsen`
flatten the gradient pytree into contiguous buckets; under GSPMD this turns
per-tensor collectives into per-bucket collectives.

int8 error-feedback compression: quantize grads to int8 per-bucket scale,
carry the quantization residual to the next step (EF-SGD), cutting DP wire
bytes 4x at negligible quality cost.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    sizes: tuple            # flat element count per bucket
    treedef: Any
    shapes: tuple
    bucket_of: tuple        # leaf index -> bucket id
    offsets: tuple          # leaf index -> offset within bucket


def plan_buckets(params, bucket_bytes: int = 64 * 2 ** 20) -> BucketPlan:
    leaves, treedef = jax.tree.flatten(params)
    shapes = tuple(l.shape for l in leaves)
    bucket_of, offsets, sizes = [], [], []
    cur, cur_elems = 0, 0
    limit = bucket_bytes // 4
    for l in leaves:
        n = int(np.prod(l.shape)) if l.shape else 1
        if cur_elems and cur_elems + n > limit:
            sizes.append(cur_elems)
            cur += 1
            cur_elems = 0
        bucket_of.append(cur)
        offsets.append(cur_elems)
        cur_elems += n
    sizes.append(cur_elems)
    return BucketPlan(tuple(sizes), treedef, shapes,
                      tuple(bucket_of), tuple(offsets))


def bucket_coarsen(grads, plan: BucketPlan):
    """pytree -> list of flat buckets (the coalesced collective unit)."""
    leaves = jax.tree.leaves(grads)
    buckets = [[] for _ in plan.sizes]
    for i, l in enumerate(leaves):
        buckets[plan.bucket_of[i]].append(l.reshape(-1).astype(jnp.float32))
    return [jnp.concatenate(b) if len(b) > 1 else b[0] for b in buckets]


def bucket_restore(buckets, plan: BucketPlan):
    leaves = []
    for i, shape in enumerate(plan.shapes):
        n = int(np.prod(shape)) if shape else 1
        off = plan.offsets[i]
        leaves.append(buckets[plan.bucket_of[i]][off:off + n].reshape(shape))
    return jax.tree.unflatten(plan.treedef, leaves)


def int8_compress_grads(grads, residual):
    """Error-feedback int8 compression (per-leaf scale).

    Returns (qtree int8, scales f32, new_residual).  The int8 payload is what
    crosses the DP axis (4x fewer wire bytes); the quantization error is
    carried to the next step (EF-SGD), so the compression is unbiased over
    time.
    """
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                                grads)
    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_r = jax.tree.leaves(residual)
    qs, scales, resids = [], [], []
    for g, r in zip(leaves_g, leaves_r):
        g = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        qs.append(q)
        scales.append(scale)
        resids.append(g - q.astype(jnp.float32) * scale)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, resids))


def int8_decompress(qtree, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qtree, scales)
