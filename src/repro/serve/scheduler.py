"""FCFS + preemption continuous-batching scheduler, robust under pressure.

The scheduler is deliberately engine-agnostic: it talks to anything with the
protocol surface below, which makes every scheduling invariant (each
request reaches a terminal state, FCFS admission order, no starvation under
preemption, page conservation) property-testable against a fake engine with
no model or device in the loop — and the same loop then drives the real
``PagedEngine``.

Engine protocol::

    engine.slots            -> int, number of batch slots
    engine.admit(slot, request) -> first greedy token (from the prefill
                                     # logits) or None; may raise
                                     # PoolExhausted (no partial effects)
    engine.decode(slots)    -> {slot: [new_token, ...]} for the RUNNING
                                     # slots; may raise PoolExhausted when
                                     # page growth fails mid-decode (after
                                     # rolling back to a consistent state)
                                     # or DecodeFault (transient, cursors
                                     # unadvanced — just retry)
    engine.finish(slot)              # frees the slot's pages
    engine.preempt(slot)             # drop cache pages, forget progress
    # optional (resumable preemption — PagedEngine implements these):
    engine.suspend(slot)    -> suspension   # swap pages+state to host
    engine.resume(slot, suspension)         # restore, NO re-prefill;
                                            # may raise PoolExhausted
    engine.suspend_bytes(slot) -> int       # host bytes a swap would take

Eviction policy: on ``PoolExhausted`` the *youngest* running request
(latest arrival) is evicted and requeued at the head of the wait queue in
arrival order — the oldest request is never the victim, so it monotonically
keeps its pages and finishes; once it frees them the next-oldest holds the
same property.  That induction is the no-starvation guarantee, and it holds
as long as a lone worst-case request fits the pool (checked at submit).

HOW a victim is evicted is the swap-vs-recompute policy: when the engine
supports suspension and the suspended bytes fit the host SwapStore budget,
the slot is swapped to host memory and later resumed into fresh pages with
all its prefill + decode work intact; otherwise it is recompute-preempted
(pages dropped, output reset, prefill re-run at re-admission).  Either way
counts against ``max_preemptions`` — overflow is a per-request terminal
FAILED status, never a server crash.

Degradation ladder (each rung sheds load instead of falling off a cliff):
deadline'd requests cancel with pages freed; queue-wait overruns reject
with a retry-after hint; a full wait queue rejects at submit; repeated
eviction fails the one livelocked request; transient decode faults retry
bounded-many times.  ``drain()`` is the graceful-shutdown path: everything
in flight terminates CANCELLED with partial output kept and pages freed.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any

from repro.obs import NULL_TRACER, QUANTA_BUCKETS, Registry, SCHED_TRACK
from repro.serve.paging import (DecodeFault, PoolExhausted, SwapStore,
                                pages_needed)


class State(Enum):
    WAITING = "waiting"
    RUNNING = "running"
    SUSPENDED = "suspended"   # swapped to host; resumes with work intact
    PREEMPTED = "preempted"   # requeued after a cache drop; restarts clean
    FINISHED = "finished"
    CANCELLED = "cancelled"   # deadline expired / drained; partial output
    REJECTED = "rejected"     # load shed (queue full / wait overrun)
    FAILED = "failed"         # livelock eviction overflow / admit failures


TERMINAL = (State.FINISHED, State.CANCELLED, State.REJECTED, State.FAILED)


@dataclass
class Request:
    """One generation request. ``prefix`` optionally names a registered
    shared prefix whose pages are refcount-shared instead of recomputed.

    ``deadline`` (absolute scheduler-clock quantum) cancels the request
    wherever it is once the clock passes it; ``max_queue_wait`` (quanta
    since the last enqueue) rejects it with a ``retry_after`` hint while it
    waits.  Terminal states carry ``error`` (except FINISHED)."""
    rid: int
    prompt: list[int]
    gen: int
    prefix: str | None = None
    state: State = State.WAITING
    arrival: int = 0              # admission priority (FCFS ties by rid)
    deadline: int | None = None
    max_queue_wait: int | None = None
    preemptions: int = 0          # evictions of either kind
    swaps: int = 0                # evictions that went the suspend path
    output: list[int] = field(default_factory=list)
    error: str | None = None
    retry_after: int | None = None
    submitted_at: int = 0
    enqueued_at: int = 0
    admit_failures: int = 0
    submitted_wall: float = 0.0   # perf_counter at submit (TTFT histogram)
    first_tok_wall: float | None = None

    @property
    def key(self):
        return (self.arrival, self.rid)

    @property
    def done(self) -> bool:
        return self.state in TERMINAL


class Scheduler:
    """Drives an engine: admit waiting requests FCFS into free slots, decode
    the running set, evict the youngest on pool exhaustion (host-swap when
    the budget allows, recompute otherwise).

    ``host_swap_bytes``: SwapStore budget for suspended slots (None =
    unbounded — swap whenever the engine supports it; 0 disables swapping).
    ``max_waiting``: wait-queue bound; submits past it are shed with a
    terminal REJECTED status and a retry-after hint.
    """

    def __init__(self, engine, *, max_preemptions: int = 64,
                 host_swap_bytes: int | None = None,
                 max_waiting: int | None = None,
                 max_admit_retries: int = 8,
                 max_decode_faults: int = 16,
                 metrics: Registry | None = None, trace=None):
        self.engine = engine
        self.waiting: list[Request] = []
        self.running: dict[int, Request] = {}   # slot -> request
        self.finished: list[Request] = []       # every TERMINAL request
        self._clock = 0
        self._rid = 0
        self.max_preemptions = max_preemptions
        self.max_waiting = max_waiting
        self.max_admit_retries = max_admit_retries
        self.max_decode_faults = max_decode_faults
        self.obs = metrics if metrics is not None else Registry()
        self.trace = trace if trace is not None else NULL_TRACER
        self.swap = SwapStore(host_swap_bytes, metrics=self.obs)
        self.steps = 0
        self.time = 0                  # scheduler clock, one tick per step()
        self._consecutive_faults = 0
        o = self.obs
        self._m_submitted = o.counter("sched_submitted_total")
        self._m_preempt = o.counter("sched_preemptions_total",
                                    "evictions of either kind")
        self._m_evict = {
            "swap": o.counter("sched_evictions_total", policy="swap"),
            "recompute": o.counter("sched_evictions_total",
                                   policy="recompute")}
        self._m_faults = o.counter("sched_decode_faults_total",
                                   "transient decode faults retried")
        self._m_quanta = o.counter("sched_quanta_total")
        self._m_terminal = {s: o.counter("sched_requests_total",
                                         state=s.value) for s in TERMINAL}
        self._g_waiting = o.gauge("sched_waiting")
        self._g_running = o.gauge("sched_running")
        self._g_free_pages = o.gauge(
            "engine_free_pages", "free pool pages (lo = high-water usage)") \
            if hasattr(engine, "free_pages") else None
        self._h_queue_wait = o.histogram("sched_queue_wait_quanta",
                                         QUANTA_BUCKETS,
                                         "quanta from enqueue to admission")
        self._h_ttft = o.histogram("sched_ttft_seconds", help="wall seconds "
                                   "from submit to first output token")
        self._h_intertok = o.histogram(
            "sched_intertoken_seconds",
            help="wall seconds per emitted token, per slot, per quantum")
        self._h_swap_rt = o.histogram(
            "sched_swap_roundtrip_seconds",
            help="wall seconds from suspend to successful resume")
        self._suspend_wall: dict[int, float] = {}   # rid -> suspend time

    @property
    def decode_faults(self) -> int:
        return self._m_faults.value

    # -- submission ----------------------------------------------------------

    def submit(self, prompt, gen: int, *, prefix: str | None = None,
               deadline: int | None = None,
               max_queue_wait: int | None = None) -> Request:
        max_len = getattr(self.engine, "max_len", None)
        if max_len is not None and len(prompt) + gen > max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + gen ({gen}) tokens exceed max_len "
                f"{max_len}; rejecting instead of truncating")
        worst = pages_needed(len(prompt) + gen, self.engine.page_size) \
            if hasattr(self.engine, "page_size") else 0
        cap = getattr(self.engine, "pool_capacity", None)
        if cap is not None and worst > cap:
            raise ValueError(
                f"request needs {worst} pages even running alone; pool holds "
                f"{cap} — it could never be scheduled")
        req = Request(rid=self._rid, prompt=list(prompt), gen=int(gen),
                      prefix=prefix, arrival=self._clock, deadline=deadline,
                      max_queue_wait=max_queue_wait, submitted_at=self.time,
                      enqueued_at=self.time, submitted_wall=time.perf_counter())
        self._rid += 1
        self._clock += 1
        self._m_submitted.inc()
        self.trace.lifecycle(req.rid, "QUEUED",
                             {"prompt": len(req.prompt), "gen": req.gen})
        if self.max_waiting is not None \
                and len(self.waiting) >= self.max_waiting:
            # backpressure: shed load LOUDLY instead of queueing unboundedly
            # — the caller gets a terminal status plus a drain estimate to
            # retry against, and the running batch is never stalled
            req.retry_after = self.retry_after()
            self._terminate(req, State.REJECTED,
                            f"wait queue full ({self.max_waiting}); "
                            f"retry after ~{req.retry_after} quanta")
            return req
        self.waiting.append(req)
        return req

    def retry_after(self) -> int:
        """Rough quanta until the wait queue has room: queued decode work
        spread over the engine's slots.  Deterministic, intentionally
        coarse — a backoff hint, not a promise."""
        queued = sum(r.gen - len(r.output) for r in self.waiting)
        return max(1, queued // max(1, self.engine.slots))

    # -- terminal bookkeeping ------------------------------------------------

    def _terminate(self, req: Request, state: State, error=None) -> None:
        if req.rid in self.swap:
            self.swap.drop(req.rid)
            self._suspend_wall.pop(req.rid, None)
        req.state = state
        if error is not None:
            req.error = error
        self.finished.append(req)
        self._m_terminal[state].inc()
        self.trace.lifecycle(req.rid, state.name,
                             {"tokens": len(req.output)})

    @property
    def completed(self) -> list[Request]:
        return [r for r in self.finished if r.state is State.FINISHED]

    # -- scheduling ----------------------------------------------------------

    def _free_slots(self):
        return [s for s in range(self.engine.slots) if s not in self.running]

    def _admission_failed(self, req: Request) -> bool:
        """Admission raised PoolExhausted.  With co-residents the pressure
        resolves through decode progress — just wait.  With an EMPTY
        running set nothing will free pages by itself (submit checked the
        request fits alone), so retry bounded-many times (transient faults
        clear) and then fail the request rather than the server.  Returns
        True when the caller should stop admitting this quantum."""
        if self.running:
            return True
        req.admit_failures += 1
        if req.admit_failures > self.max_admit_retries:
            self.waiting.remove(req)
            self._terminate(
                req, State.FAILED,
                f"admission failed {req.admit_failures} times with no "
                f"co-residents to evict (injected faults or a pool "
                f"inconsistent with submit's worst-case check)")
            return False     # the queue may hold an admissible successor
        return True

    def _admit_waiting(self) -> None:
        """FCFS: oldest waiting request into lowest free slot; stop at the
        first admission failure (admitting younger over older would break
        arrival order).  SUSPENDED requests resume — same pool contract as
        admit, but no prefill and no output reset."""
        self.waiting.sort(key=lambda r: r.key)
        bound = getattr(self.engine, "step_growth_bound", None)
        while self.waiting and (free := self._free_slots()):
            req, slot = self.waiting[0], free[0]
            if bound is not None and self.running \
                    and self.engine.free_pages < bound(req):
                # admitting would leave the next decode step short of its
                # worst-case page growth (speculative verify appends K+1
                # rows at once) — hold the request until decode progress
                # frees pages.  Skipped when nothing is running: a lone
                # request must always make progress.
                break
            if req.state is State.SUSPENDED:
                try:
                    self.engine.resume(slot, self.swap.peek(req.rid))
                except PoolExhausted:
                    if self._admission_failed(req):
                        break
                    continue
                self.swap.pop(req.rid)
                t_susp = self._suspend_wall.pop(req.rid, None)
                if t_susp is not None:
                    self._h_swap_rt.observe(time.perf_counter() - t_susp)
                self.trace.lifecycle(req.rid, "RESUMED", {"slot": slot})
            else:
                try:
                    first = self.engine.admit(slot, req)
                except PoolExhausted:
                    if self._admission_failed(req):
                        break
                    continue
                if first is not None:
                    req.output.append(int(first))
                    self._first_token(req)
                self.trace.lifecycle(req.rid, "ADMITTED", {"slot": slot})
            self._h_queue_wait.observe(self.time - req.enqueued_at)
            req.state = State.RUNNING
            req.admit_failures = 0
            self.running[slot] = req
            self.waiting.pop(0)

    def _first_token(self, req: Request) -> None:
        if req.first_tok_wall is None:
            req.first_tok_wall = time.perf_counter()
            self._h_ttft.observe(req.first_tok_wall - req.submitted_wall)

    def _preempt_youngest(self) -> None:
        """Evict the youngest running request — swap when it fits the host
        budget, recompute otherwise; overflow of ``max_preemptions`` is a
        terminal per-request failure, never a server crash."""
        slot, req = max(self.running.items(), key=lambda kv: kv[1].key)
        req.preemptions += 1
        if req.preemptions > self.max_preemptions:
            self.engine.preempt(slot)
            del self.running[slot]
            req.output = []
            self._terminate(
                req, State.FAILED,
                f"evicted {req.preemptions} times — livelock (pool too "
                f"small for the running set?)")
            return
        self._m_preempt.inc()
        if hasattr(self.engine, "suspend") \
                and self.swap.fits(self.engine.suspend_bytes(slot)):
            susp = self.engine.suspend(slot)
            self.swap.put(req.rid, susp, getattr(susp, "nbytes", 0))
            self._suspend_wall[req.rid] = time.perf_counter()
            req.state = State.SUSPENDED
            req.swaps += 1
            self._m_evict["swap"].inc()
            self.trace.lifecycle(req.rid, "SUSPENDED", {"slot": slot})
        else:
            self.engine.preempt(slot)
            req.state = State.PREEMPTED
            req.output = []
            self._m_evict["recompute"].inc()
            self.trace.lifecycle(req.rid, "PREEMPTED", {"slot": slot})
        del self.running[slot]
        req.enqueued_at = self.time
        self.waiting.append(req)   # key() keeps original arrival order

    def _expire(self) -> None:
        """Deadline + queue-wait enforcement, both queues.  Cancelling a
        running slot frees its pages through finish(); cancelling a
        suspended request drops its host snapshot; partial output stays on
        the request (the pool sees no partial effects either way)."""
        now, keep = self.time, []
        for req in self.waiting:
            if req.deadline is not None and now >= req.deadline:
                self._terminate(req, State.CANCELLED,
                                "deadline expired while queued")
            elif req.max_queue_wait is not None \
                    and now - req.enqueued_at > req.max_queue_wait:
                req.retry_after = self.retry_after()
                self._terminate(
                    req, State.REJECTED,
                    f"queued longer than max_queue_wait="
                    f"{req.max_queue_wait}; retry after ~{req.retry_after}")
            else:
                keep.append(req)
        self.waiting = keep
        for slot in [s for s, r in self.running.items()
                     if r.deadline is not None and now >= r.deadline]:
            req = self.running.pop(slot)
            self.engine.finish(slot)
            self._terminate(req, State.CANCELLED,
                            "deadline expired while running")

    def _retire(self) -> None:
        for slot in [s for s, r in self.running.items()
                     if len(r.output) >= r.gen]:
            req = self.running.pop(slot)
            self.engine.finish(slot)
            req.output = req.output[: req.gen]
            self._terminate(req, State.FINISHED)

    def step(self) -> bool:
        """One scheduling quantum: expire, admit, decode, retire. Returns
        True while any work remains."""
        self.time += 1
        self.trace.quantum = self.time   # everything this step inherits it
        with self.trace.span("sched.quantum", "sched", SCHED_TRACK):
            more = self._step()
        self._m_quanta.inc()
        self._g_waiting.set(len(self.waiting))
        self._g_running.set(len(self.running))
        if self._g_free_pages is not None:
            self._g_free_pages.set(self.engine.free_pages)
        return more

    def _step(self) -> bool:
        self._expire()
        self._admit_waiting()
        self._retire()                      # a gen==1 request ends at admit
        if not self.running:
            return bool(self.waiting)
        self.steps += 1
        while True:
            try:
                t0 = time.perf_counter()
                new = self.engine.decode(sorted(self.running))
                dt = time.perf_counter() - t0
                self._consecutive_faults = 0
                break
            except PoolExhausted:
                self._preempt_youngest()
                if not self.running:
                    return bool(self.waiting)
            except DecodeFault as e:
                # transient, no cursor advanced — retry the quantum, but
                # give up loudly if the "transient" fault never clears
                self._m_faults.inc()
                self._consecutive_faults += 1
                if self._consecutive_faults > self.max_decode_faults:
                    raise RuntimeError(
                        f"{self._consecutive_faults} consecutive decode "
                        f"faults — not transient: {e}") from e
                return True
        for slot, toks in new.items():
            req = self.running[slot]
            req.output.extend(int(t) for t in toks)
            if toks:
                self._first_token(req)
                self._h_intertok.observe(dt / len(toks))
        self._retire()
        return bool(self.waiting or self.running)

    def run_until_done(self, *, max_steps: int = 100_000):
        while self.step():
            if self.steps > max_steps:
                raise RuntimeError("scheduler did not converge")
        assert not self.waiting and not self.running
        return sorted(self.finished, key=lambda r: r.rid)

    def drain(self, *, reason: str = "server drained"):
        """Graceful shutdown: cancel the queue, finish-and-cancel every
        running slot (pages freed through the engine), drop suspensions.
        Partial outputs stay on the requests.  Returns all terminal
        requests, like run_until_done."""
        for req in self.waiting:
            self._terminate(req, State.CANCELLED, reason)
        self.waiting = []
        for slot in sorted(self.running):
            req = self.running[slot]
            self.engine.finish(slot)
            self._terminate(req, State.CANCELLED, reason)
        self.running = {}
        return sorted(self.finished, key=lambda r: r.rid)
