"""FCFS + preemption continuous-batching scheduler.

The scheduler is deliberately engine-agnostic: it talks to anything with the
five-method surface below, which makes every scheduling invariant (each
request completes, FCFS admission order, no starvation under preemption,
page conservation) property-testable against a fake engine with no model or
device in the loop — and the same loop then drives the real ``PagedEngine``.

Engine protocol::

    engine.slots            -> int, number of batch slots
    engine.admit(slot, request) -> first greedy token (from the prefill
                                     # logits) or None; may raise
                                     # PoolExhausted (no partial effects)
    engine.decode(slots)    -> {slot: [new_token, ...]} for the RUNNING
                                     # slots; may raise PoolExhausted when
                                     # page growth fails mid-decode, after
                                     # rolling back to a consistent state
    engine.finish(slot)              # frees the slot's pages
    engine.preempt(slot)             # drop cache pages, forget progress

Preemption policy: on ``PoolExhausted`` the *youngest* running request
(latest arrival) is preempted and requeued at the head of the wait queue in
arrival order — the oldest request is never the victim, so it monotonically
keeps its pages and finishes; once it frees them the next-oldest holds the
same property.  That induction is the no-starvation guarantee, and it holds
as long as a lone worst-case request fits the pool (checked at submit).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum

from repro.serve.paging import PoolExhausted, pages_needed


class State(Enum):
    WAITING = "waiting"
    RUNNING = "running"
    PREEMPTED = "preempted"   # requeued after a cache drop; restarts clean
    FINISHED = "finished"


@dataclass
class Request:
    """One generation request. ``prefix`` optionally names a registered
    shared prefix whose pages are refcount-shared instead of recomputed."""
    rid: int
    prompt: list[int]
    gen: int
    prefix: str | None = None
    state: State = State.WAITING
    arrival: int = 0              # admission priority (FCFS ties by rid)
    preemptions: int = 0
    output: list[int] = field(default_factory=list)

    @property
    def key(self):
        return (self.arrival, self.rid)


class Scheduler:
    """Drives an engine: admit waiting requests FCFS into free slots, decode
    the running set, preempt the youngest on pool exhaustion."""

    def __init__(self, engine, *, max_preemptions: int = 64):
        self.engine = engine
        self.waiting: list[Request] = []
        self.running: dict[int, Request] = {}   # slot -> request
        self.finished: list[Request] = []
        self._clock = 0
        self._rid = 0
        self.max_preemptions = max_preemptions
        self.steps = 0

    # -- submission ----------------------------------------------------------

    def submit(self, prompt, gen: int, *, prefix: str | None = None) -> Request:
        max_len = getattr(self.engine, "max_len", None)
        if max_len is not None and len(prompt) + gen > max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + gen ({gen}) tokens exceed max_len "
                f"{max_len}; rejecting instead of truncating")
        worst = pages_needed(len(prompt) + gen, self.engine.page_size) \
            if hasattr(self.engine, "page_size") else 0
        cap = getattr(self.engine, "pool_capacity", None)
        if cap is not None and worst > cap:
            raise ValueError(
                f"request needs {worst} pages even running alone; pool holds "
                f"{cap} — it could never be scheduled")
        req = Request(rid=self._rid, prompt=list(prompt), gen=int(gen),
                      prefix=prefix, arrival=self._clock)
        self._rid += 1
        self._clock += 1
        self.waiting.append(req)
        return req

    # -- scheduling ----------------------------------------------------------

    def _free_slots(self):
        return [s for s in range(self.engine.slots) if s not in self.running]

    def _admit_waiting(self) -> None:
        """FCFS: oldest waiting request into lowest free slot; stop at the
        first admission failure (admitting younger over older would break
        arrival order)."""
        self.waiting.sort(key=lambda r: r.key)
        bound = getattr(self.engine, "step_growth_bound", None)
        while self.waiting and (free := self._free_slots()):
            req, slot = self.waiting[0], free[0]
            if bound is not None and self.running \
                    and self.engine.free_pages < bound(req):
                # admitting would leave the next decode step short of its
                # worst-case page growth (speculative verify appends K+1
                # rows at once) — hold the request until decode progress
                # frees pages.  Skipped when nothing is running: a lone
                # request must always make progress.
                break
            try:
                first = self.engine.admit(slot, req)
            except PoolExhausted:
                if not self.running:
                    # nothing to evict — must be admissible alone, so the
                    # engine's pool state is inconsistent with submit()'s
                    # worst-case check
                    raise
                break
            req.state = State.RUNNING
            if first is not None:
                req.output.append(int(first))
            self.running[slot] = req
            self.waiting.pop(0)

    def _preempt_youngest(self) -> None:
        slot, req = max(self.running.items(), key=lambda kv: kv[1].key)
        self.engine.preempt(slot)
        del self.running[slot]
        req.state = State.PREEMPTED
        req.preemptions += 1
        req.output = []
        if req.preemptions > self.max_preemptions:
            raise RuntimeError(
                f"request {req.rid} preempted {req.preemptions} times — "
                f"livelock (pool too small for the running set?)")
        self.waiting.append(req)   # key() keeps original arrival order

    def _retire(self) -> None:
        for slot in [s for s, r in self.running.items()
                     if len(r.output) >= r.gen]:
            req = self.running.pop(slot)
            self.engine.finish(slot)
            req.output = req.output[: req.gen]
            req.state = State.FINISHED
            self.finished.append(req)

    def step(self) -> bool:
        """One scheduling quantum: admit, decode, retire. Returns True while
        any work remains."""
        self._admit_waiting()
        self._retire()                      # a gen==1 request ends at admit
        if not self.running:
            return bool(self.waiting)
        self.steps += 1
        while True:
            try:
                new = self.engine.decode(sorted(self.running))
                break
            except PoolExhausted:
                self._preempt_youngest()
                if not self.running:
                    return bool(self.waiting)
        for slot, toks in new.items():
            self.running[slot].output.extend(int(t) for t in toks)
        self._retire()
        return bool(self.waiting or self.running)

    def run_until_done(self, *, max_steps: int = 100_000):
        while self.step():
            if self.steps > max_steps:
                raise RuntimeError("scheduler did not converge")
        assert not self.waiting and not self.running
        return sorted(self.finished, key=lambda r: r.rid)
