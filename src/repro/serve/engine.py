"""PagedEngine: the model-coupled paged-KV serving engine.

Owns the paged decode cache (repro.models.model.lm_init_cache_paged), the
page pool + block tables (repro.serve.paging), per-slot generation state,
and the jitted prefill/decode steps.  The Scheduler drives it through the
admit/decode/finish/preempt protocol (repro.serve.scheduler); it never
schedules on its own.

Key mechanics:

* **Admission** allocates exactly the pages the prompt needs, prefills the
  prompt in chunks through the block table (non-admitted slots' table rows
  are NULLed, so their garbage writes land on the null page — the paged
  replacement for the contiguous path's whole-cache mask select), and
  returns the first greedy token from the prefill logits.
* **Decode** grows each running slot's table on demand (pages covering the
  rows the next block will write) before launching a jitted on-device
  decode block; pool exhaustion surfaces as PoolExhausted for the scheduler
  to translate into a preemption.
* **Shared prefixes** are registered once (prefilled into their own pages +
  a snapshot of the non-paged per-slot state) and admitted by refcount:
  an admit whose prompt starts with the registered page-aligned token
  prefix increfs those pages instead of recomputing them.
* **Preempt/finish** release the slot's pages (decref — shared pages
  survive in the registry) and clear the slot.

Greedy sampling only: determinism (a request's outputs are identical to
running it alone, whatever the co-residents) is part of the contract the
scheduler simulation tests pin.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig, ATTN_GLOBAL, ATTN_LOCAL
from repro.obs import ENGINE_TRACK, NULL_TRACER, Registry
from repro.serve.paging import (BlockTables, DecodeFault, PagePool,
                                PoolExhausted, pages_needed)


@dataclasses.dataclass
class Suspension:
    """Host-resident snapshot of one suspended slot: the page rows its block
    table covered (gathered off-device), the non-paged per-slot state, and
    the generation cursor.  ``resume`` restores all of it into freshly
    allocated pages WITHOUT re-running prefill — the whole point of
    swap-preemption over recompute-preemption."""
    n_tokens: int           # cache rows live at suspend (= written)
    n_pages: int            # pages the snapshot covers
    last: int               # last sampled token
    remaining: int          # gen tokens left
    pages: Any              # {"blocks": [...], "tail": [...]} page gathers
    state: Any              # non-paged per-slot snapshot (recurrent/SSM)
    nbytes: int = 0

    def __post_init__(self):
        if not self.nbytes:
            self.nbytes = sum(
                int(a.nbytes) for a in jax.tree.leaves(
                    (self.pages, self.state)))


@dataclasses.dataclass
class PrefixRecord:
    """A registered shared prefix: its page-aligned token prefix, the pages
    holding those rows (registry keeps one refcount), and a snapshot of the
    non-paged per-slot state (recurrent/SSM/conv) after ingesting it."""
    tokens: tuple
    pages: list
    state: Any              # {"blocks": [leaf rows...], "tail": [...]}


def _tree_mib(tree) -> float:
    return sum(int(x.size) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree) if hasattr(x, "dtype")) / 2**20


class PagedEngine:
    """Paged-KV serving engine for one model instance.

    num_pages counts POOL pages including the reserved null page; the
    per-slot table holds ceil(max_len / page_size) entries and admission
    rejects any prompt_len + gen_tokens > max_len outright (the contiguous
    server's silent `max_len - 1` truncation has no paged analog)."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int,
                 num_pages: int, page_size: int, max_len: int,
                 chunk: int = 16, decode_block: int = 1,
                 tune: str | None = None, decode_backend: str | None = None,
                 moe_backend: str | None = None, quant: str | None = None,
                 kv_quant: str | None = None,
                 max_prefixes: int | None = None,
                 metrics: Registry | None = None, trace=None):
        if cfg.is_encdec:
            raise NotImplementedError("PagedEngine: enc-dec models are not "
                                      "supported")
        if decode_backend is not None:
            cfg = dataclasses.replace(cfg, decode_backend=decode_backend)
        if moe_backend is not None:
            cfg = dataclasses.replace(cfg, moe_backend=moe_backend)
        if quant is not None:
            cfg = dataclasses.replace(cfg, quant=quant)
        if kv_quant is not None:
            cfg = dataclasses.replace(cfg, kv_quant=kv_quant)
        self.quant_report = None
        if cfg.quant in ("int8", "int4"):
            from repro.quant import quantize_params
            params, self.quant_report = quantize_params(
                params, cfg.quant, group=cfg.quant_group)
        self.obs = metrics if metrics is not None else Registry()
        self.trace = trace if trace is not None else NULL_TRACER
        if tune:
            from repro.tune import warm_from_flag
            warm_from_flag(cfg, tune, seq=max_len, batch=slots,
                           page_size=page_size, metrics=self.obs)
        self.cfg, self.params = cfg, params
        self.slots = int(slots)
        self.max_len = int(max_len)
        self.chunk, self.decode_block = int(chunk), int(decode_block)
        self.pool = PagePool(num_pages, page_size)
        self.npp = pages_needed(max_len, page_size)
        self.bt = BlockTables(slots, self.npp)
        self.cache = M.lm_init_cache_paged(cfg, slots, num_pages, page_size)
        self.cache_mib = _tree_mib(self.cache)
        self.weight_mib = _tree_mib(params)

        self.active = np.zeros((slots,), bool)
        self.written = np.zeros((slots,), np.int32)   # cache rows filled
        self.last = np.zeros((slots,), np.int32)      # last sampled token
        self.remaining = np.zeros((slots,), np.int32)  # gen tokens left
        # LRU order: dict insertion order is recency (oldest first); a
        # shared-prefix admit hit moves its record to the end
        self.prefixes: dict[str, PrefixRecord] = {}
        self.max_prefixes = max_prefixes
        self.prefix_evictions = 0

        # engine counters live in the obs registry; the names below stay as
        # read-only properties so benchmarks/tests read the same ints
        o = self.obs
        self._c_prefill_steps = o.counter("engine_prefill_steps_total")
        self._c_decode_steps = o.counter("engine_decode_steps_total")
        self._c_prefill_tokens = o.counter("engine_prefill_tokens_total")
        self._c_decode_tokens = o.counter("engine_decode_tokens_total")
        self._c_suspends = o.counter("engine_suspends_total")
        self._c_resumes = o.counter("engine_resumes_total")
        self._c_swapped_tokens = o.counter(
            "engine_swapped_tokens_total",
            "cache rows carried across suspends")
        self._c_nan_rescues = o.counter(
            "engine_nan_rescues_total", "decode blocks re-run by the guard")
        # device-boundary timers: jitted call + block_until_ready ONLY (no
        # host bookkeeping) — what the tok/s lines should divide by
        self._c_prefill_dev = o.counter("engine_prefill_device_seconds_total")
        self._c_decode_dev = o.counter("engine_decode_device_seconds_total")
        self.prefill_s = self.decode_s = 0.0   # legacy whole-call timers
        self.fault_hook = None          # repro.serve.faults sets this
        self._attn_kinds = self._kind_flags(cfg)
        self._swap_page_bytes, self._swap_fixed_bytes = self._swap_layout()
        self._prefill = jax.jit(
            lambda p, c, t, po, m, bt: M.lm_prefill(
                p, {"tokens": t}, cfg, cache=c, pos0=po, mask=m,
                block_table=bt))
        self._decode_fns: dict[int, Any] = {}

    # -- static layout helpers ----------------------------------------------

    @staticmethod
    def _kind_flags(cfg):
        period, _, tail = M._period(cfg)
        attn = (ATTN_GLOBAL, ATTN_LOCAL)
        return ([k in attn for k in period], [k in attn for k in tail])

    # legacy counter names, now views over the obs registry ------------------

    @property
    def prefill_steps(self) -> int:
        return self._c_prefill_steps.value

    @property
    def decode_steps(self) -> int:
        return self._c_decode_steps.value

    @property
    def prefill_tokens(self) -> int:
        return self._c_prefill_tokens.value

    @property
    def decoded_tokens(self) -> int:
        return self._c_decode_tokens.value

    @property
    def suspends(self) -> int:
        return self._c_suspends.value

    @property
    def resumes(self) -> int:
        return self._c_resumes.value

    @property
    def swapped_out_tokens(self) -> int:
        return self._c_swapped_tokens.value

    @property
    def nan_rescues(self) -> int:
        return self._c_nan_rescues.value

    @property
    def prefill_device_s(self) -> float:
        return self._c_prefill_dev.value

    @property
    def decode_device_s(self) -> float:
        return self._c_decode_dev.value

    @property
    def page_size(self) -> int:
        return self.pool.page_size

    @property
    def pool_capacity(self) -> int:
        return self.pool.capacity

    @property
    def any_active(self) -> bool:
        return bool(self.active.any())

    def _device_table(self, active) -> jax.Array:
        return jnp.asarray(self.bt.device(active=active), jnp.int32)

    # -- per-slot non-paged state (recurrent/SSM/conv) ----------------------

    def _nonpaged(self, cache, fn_blocks, fn_tail):
        """Map over the NON-paged leaves only (paged pools pass through)."""
        blk_attn, tail_attn = self._attn_kinds
        blocks = [c if is_attn else jax.tree.map(fn_blocks, c)
                  for c, is_attn in zip(cache["blocks"], blk_attn)]
        tail = [c if is_attn else jax.tree.map(fn_tail, c)
                for c, is_attn in zip(cache["tail"], tail_attn)]
        return {"blocks": blocks, "tail": tail}

    def _slot_reset(self, slot: int):
        s = jnp.asarray(slot, jnp.int32)
        self.cache = self._nonpaged(
            self.cache,
            lambda a: a.at[:, s].set(jnp.zeros((), a.dtype)),
            lambda a: a.at[s].set(jnp.zeros((), a.dtype)))

    def _slot_snapshot(self, slot: int):
        return self._nonpaged(self.cache,
                              lambda a: a[:, slot], lambda a: a[slot])

    def _slot_load(self, slot: int, snap) -> None:
        blk_attn, tail_attn = self._attn_kinds
        s = jnp.asarray(slot, jnp.int32)
        blocks = [c if is_attn else jax.tree.map(
            lambda a, v: a.at[:, s].set(v), c, sc)
            for c, sc, is_attn in zip(self.cache["blocks"],
                                      snap["blocks"], blk_attn)]
        tail = [c if is_attn else jax.tree.map(
            lambda a, v: a.at[s].set(v), c, sc)
            for c, sc, is_attn in zip(self.cache["tail"],
                                      snap["tail"], tail_attn)]
        self.cache = {"blocks": blocks, "tail": tail}

    # -- resumable preemption: host swap of a slot's live pages -------------

    def _swap_layout(self) -> tuple[int, int]:
        """(bytes per swapped page, fixed per-slot bytes): paged leaves
        charge their page-axis row (axis 1 under the period stack, axis 0
        in the tail), non-paged leaves their slot row."""
        blk_attn, tail_attn = self._attn_kinds
        per_page = fixed = 0
        for c, attn in zip(self.cache["blocks"], blk_attn):
            for a in jax.tree.leaves(c):
                (per_page, fixed) = (per_page + a.nbytes // a.shape[1], fixed) \
                    if attn else (per_page, fixed + a.nbytes // a.shape[1])
        for c, attn in zip(self.cache["tail"], tail_attn):
            for a in jax.tree.leaves(c):
                (per_page, fixed) = (per_page + a.nbytes // a.shape[0], fixed) \
                    if attn else (per_page, fixed + a.nbytes // a.shape[0])
        return per_page, fixed

    def suspend_bytes(self, slot: int) -> int:
        """Host bytes suspend(slot) would take — the scheduler's swap-vs-
        recompute policy checks this against its SwapStore budget BEFORE
        deciding how to evict."""
        return self._swap_fixed_bytes + self._swap_page_bytes \
            * pages_needed(int(self.written[slot]), self.page_size)

    def _gather_pages(self, idx):
        """Copy the page-axis rows ``idx`` of every PAGED cache leaf to host
        memory; non-paged leaves map to None (the slot snapshot covers
        them).  SpecPagedEngine extends this with the draft pools."""
        i = jnp.asarray(idx, jnp.int32)
        blk_attn, tail_attn = self._attn_kinds
        return {
            "blocks": [jax.tree.map(lambda a: np.asarray(a[:, i]), c)
                       if attn else None
                       for c, attn in zip(self.cache["blocks"], blk_attn)],
            "tail": [jax.tree.map(lambda a: np.asarray(a[i]), c)
                     if attn else None
                     for c, attn in zip(self.cache["tail"], tail_attn)],
        }

    def _scatter_pages(self, idx, saved) -> None:
        """Write a _gather_pages snapshot back at (freshly allocated) page
        ids ``idx`` — the resume half of the swap."""
        i = jnp.asarray(idx, jnp.int32)
        self.cache = {
            "blocks": [c if sv is None else jax.tree.map(
                lambda a, v: a.at[:, i].set(v), c, sv)
                for c, sv in zip(self.cache["blocks"], saved["blocks"])],
            "tail": [c if sv is None else jax.tree.map(
                lambda a, v: a.at[i].set(v), c, sv)
                for c, sv in zip(self.cache["tail"], saved["tail"])],
        }

    def suspend(self, slot: int) -> Suspension:
        """Swap a running slot's state to host and free its device pages.
        Unlike ``preempt``, NO work is lost: ``resume`` restores the cache
        rows bitwise, so generation continues exactly where it stopped
        without re-running prefill.  Shared-prefix pages are copied too
        (they resume as private pages — sharing is not re-established)."""
        if not self.active[slot]:
            raise RuntimeError(f"suspend of inactive slot {slot}")
        n_tok = int(self.written[slot])
        # decode may have grown the table past the written rows before an
        # exhaustion elsewhere aborted the step; rows >= written are always
        # rewritten before any read, so only the covering pages swap out
        self.pool.release(self.bt.truncate(
            slot, pages_needed(n_tok, self.page_size)))
        pages = list(self.bt[slot])
        # the slot snapshot passes attention entries through by reference
        # (they live in the paged pools, gathered above) — null them so
        # only the non-paged per-slot rows copy to host
        snap, (blk_attn, tail_attn) = self._slot_snapshot(slot), \
            self._attn_kinds
        state = {
            "blocks": [None if attn else jax.tree.map(np.asarray, c)
                       for c, attn in zip(snap["blocks"], blk_attn)],
            "tail": [None if attn else jax.tree.map(np.asarray, c)
                     for c, attn in zip(snap["tail"], tail_attn)],
        }
        with self.trace.span("swap.gather", "swap", slot,
                             {"tokens": n_tok, "pages": len(pages)}):
            susp = Suspension(
                n_tokens=n_tok, n_pages=len(pages), last=int(self.last[slot]),
                remaining=int(self.remaining[slot]),
                pages=self._gather_pages(pages), state=state)
        self._drop(slot)
        self._c_suspends.inc()
        self._c_swapped_tokens.inc(n_tok)
        return susp

    def resume(self, slot: int, susp: Suspension) -> None:
        """Restore a suspension into freshly allocated pages.  Raises
        PoolExhausted with NO partial effects when the pool cannot serve
        the allocation right now (the caller keeps the suspension and
        retries later).  Runs zero prefill steps."""
        if self.active[slot]:
            raise RuntimeError(f"slot {slot} is already running")
        fresh = self.pool.alloc(susp.n_pages)   # raises, no side effects
        self.bt.append(slot, fresh)
        with self.trace.span("swap.scatter", "swap", slot,
                             {"tokens": susp.n_tokens,
                              "pages": susp.n_pages}):
            self._scatter_pages(fresh, susp.pages)
            self._slot_reset(slot)
            self._slot_load(slot, susp.state)
        self.active[slot] = True
        self.written[slot] = susp.n_tokens
        self.last[slot] = susp.last
        self.remaining[slot] = susp.remaining
        self._c_resumes.inc()

    # -- prefill ------------------------------------------------------------

    def _run_prefill(self, slot: int, tokens, pos_start: int, rid=None):
        """Chunked prefill of ``tokens`` into ``slot`` starting at row
        ``pos_start``; returns the final chunk's logits row."""
        mask = jnp.zeros((self.slots,), bool).at[slot].set(True)
        only = np.zeros((self.slots,), bool)
        only[slot] = True
        bt_dev = self._device_table(only)    # other slots' writes -> null
        logits = None
        t0 = time.perf_counter()
        for i in range(0, len(tokens), self.chunk):
            piece = tokens[i:i + self.chunk]
            buf = np.zeros((self.slots, len(piece)), np.int32)
            buf[slot] = piece
            pos0 = jnp.asarray(self.written, jnp.int32).at[slot].set(
                pos_start + i)
            with self.trace.span("prefill.chunk", "engine", slot,
                                 {"rid": rid, "pos": pos_start + i,
                                  "n": len(piece)}):
                td = time.perf_counter()
                logits, self.cache = self._prefill(
                    self.params, self.cache, jnp.asarray(buf), pos0, mask,
                    bt_dev)
                jax.block_until_ready(logits)
                self._c_prefill_dev.inc(time.perf_counter() - td)
            self._c_prefill_steps.inc()
        self.prefill_s += time.perf_counter() - t0
        self._c_prefill_tokens.inc(len(tokens))
        return logits[slot]

    # -- engine protocol ----------------------------------------------------

    def admit(self, slot: int, req) -> int:
        """Allocate pages, ingest the prompt, return the first greedy token.
        Raises ValueError for prompts that can never fit, PoolExhausted when
        the pool can't serve the prompt right now (no partial effects)."""
        if self.active[slot]:
            raise RuntimeError(f"slot {slot} is already running")
        prompt, gen = list(req.prompt), int(req.gen)
        if not prompt or gen < 1:
            raise ValueError("admit needs a non-empty prompt and gen >= 1")
        if len(prompt) + gen > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + gen ({gen}) tokens exceed "
                f"max_len {self.max_len}; rejecting instead of truncating")

        pre = self.prefixes.get(req.prefix) if req.prefix else None
        start = 0
        shared: list[int] = []
        if pre is not None and len(pre.tokens) <= len(prompt) - 1 \
                and tuple(prompt[: len(pre.tokens)]) == pre.tokens:
            start, shared = len(pre.tokens), pre.pages
            # LRU touch: a hit is a use — move to the recency tail
            self.prefixes[req.prefix] = self.prefixes.pop(req.prefix)
        fresh = self.pool.alloc(pages_needed(len(prompt), self.page_size)
                                - len(shared))   # raises, no side effects
        self.pool.incref(shared)
        self.bt.append(slot, list(shared) + fresh)

        self._slot_reset(slot)
        if start:
            self._slot_load(slot, pre.state)
        logits = self._run_prefill(slot, prompt[start:], start,
                                   rid=getattr(req, "rid", None))
        first = int(jnp.argmax(logits))
        self.active[slot] = True
        self.written[slot] = len(prompt)
        self.last[slot] = first
        self.remaining[slot] = gen - 1
        return first

    def _decode_fn(self, n: int):
        fn = self._decode_fns.get(n)
        if fn is not None:
            return fn
        cfg = self.cfg

        def run(params, cache, tok, pos, bt):
            def body(carry, _):
                tok, pos, cache = carry
                logits, cache = M.lm_decode_step(params, cache, tok, pos,
                                                 cfg, block_table=bt)
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                return (nxt[:, None], pos + 1, cache), (nxt, logits)

            (_, _, cache), (toks, lgs) = jax.lax.scan(
                body, (tok, pos, cache), jnp.arange(n))
            # (slots, n) tokens + (slots, n, V) per-step logits: the host-
            # visible logits feed the NaN guard below
            return toks.T, jnp.moveaxis(lgs, 0, 1), cache

        fn = self._decode_fns[n] = jax.jit(run)
        return fn

    def decode(self, slots) -> dict[int, list[int]]:
        """Run a decode block for the running ``slots``; returns the new
        greedy tokens per slot.  Page growth happens BEFORE the launch;
        PoolExhausted propagates to the scheduler (slots whose growth
        already succeeded keep their pages — consistent, not leaked).

        NaN guard: a step whose host-visible logits hold a NaN row (a
        transient fault — the injection harness poisons exactly here) is
        DISCARDED and re-run through the SAME jitted function: the rewrite
        of cache rows [written, written+n) is bitwise idempotent (same
        graph, same inputs; stale rows past ``written`` are pos-masked), so
        a rescued block's tokens are exactly the fault-free ones.  Retries
        are bounded; exhaustion raises DecodeFault with the per-slot
        cursors unadvanced (the scheduler retries the quantum)."""
        slots = [s for s in slots if self.active[s]]
        if not slots:
            return {}
        n = max(1, min(self.decode_block,
                       *(int(self.remaining[s]) for s in slots)))
        for s in slots:
            need = pages_needed(int(self.written[s]) + n, self.page_size) \
                - self.bt.num_pages(s)
            if need > 0:
                self.bt.append(s, self.pool.alloc(need))
        tokens = np.zeros((self.slots, 1), np.int32)
        tokens[slots, 0] = self.last[slots]
        t0 = time.perf_counter()

        def launch():
            td = time.perf_counter()
            toks, lgs, self.cache = self._decode_fn(n)(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(self.written, jnp.int32),
                self._device_table(self.active))
            jax.block_until_ready(lgs)
            self._c_decode_dev.inc(time.perf_counter() - td)
            lg = np.asarray(lgs)
            if self.fault_hook is not None:
                lg = self.fault_hook.corrupt_logits(lg, site="decode")
            return np.asarray(toks), lg

        with self.trace.span("decode.block", "engine", ENGINE_TRACK,
                             {"slots": len(slots), "n": n}):
            toks, lg = launch()
            retries = 0
            while np.isnan(lg[slots]).any():
                retries += 1
                if retries > 4:
                    self.decode_s += time.perf_counter() - t0
                    raise DecodeFault(
                        f"non-finite logits persisted through {retries - 1} "
                        f"rescue re-runs")
                self._c_nan_rescues.inc()
                self.trace.event("nan.rescue", "engine", ENGINE_TRACK,
                                 {"retry": retries})
                toks, lg = launch()
        self.decode_s += time.perf_counter() - t0
        self._c_decode_steps.inc(n)
        self._c_decode_tokens.inc(n * len(slots))
        out = {}
        for s in slots:
            out[s] = [int(v) for v in toks[s]]
            self.last[s] = toks[s, -1]
            self.written[s] += n
            self.remaining[s] -= n
        return out

    # -- admission accounting ------------------------------------------------

    @property
    def free_pages(self) -> int:
        return self.pool.num_free

    def _step_rows(self) -> int:
        """Worst-case cache rows one decode step appends per slot.  The
        speculative engine overrides this (K+1 rows per verify step) and
        exposes `step_growth_bound` to the scheduler's admission check."""
        return self.decode_block

    def _growth_bound(self, req=None) -> int:
        """Worst-case pages the NEXT decode step may allocate across the
        running slots — plus, when ``req`` is given, the pages admitting it
        would take (prompt, counted un-shared) and its own first step's
        growth."""
        n, ps = self._step_rows(), self.page_size
        total = 0
        for s in range(self.slots):
            if self.active[s]:
                total += max(0, pages_needed(int(self.written[s]) + n, ps)
                             - self.bt.num_pages(s))
        if req is not None:
            total += pages_needed(len(req.prompt) + n, ps)
        return total

    def _drop(self, slot: int) -> None:
        self.pool.release(self.bt.drop(slot))
        self.active[slot] = False
        self.written[slot] = self.last[slot] = self.remaining[slot] = 0

    def finish(self, slot: int) -> None:
        self._drop(slot)

    def preempt(self, slot: int) -> None:
        self._drop(slot)

    # -- shared prefixes ----------------------------------------------------

    def register_prefix(self, name: str, tokens) -> int:
        """Prefill the page-aligned head of ``tokens`` once and pin its
        pages under ``name`` (refcount held by the registry); returns the
        number of tokens the record covers (0 = too short to share).
        Needs a free slot to run the prefill in.

        With ``max_prefixes`` set, the registry is a bounded LRU: when full,
        the least-recently-used prefix whose pages nobody else holds
        (registry refcount only, i.e. every page at refcount 1) is evicted
        first; in-use prefixes are never evicted, and a full registry of
        in-use prefixes raises."""
        reg_len = (len(tokens) // self.page_size) * self.page_size
        if reg_len == 0:
            return 0
        if name in self.prefixes:       # re-register: replace, don't leak
            self.drop_prefix(name)
        if self.max_prefixes is not None:
            while len(self.prefixes) >= self.max_prefixes:
                victim = next(
                    (nm for nm, pre in self.prefixes.items()
                     if all(self.pool.refcount[p] == 1 for p in pre.pages)),
                    None)
                if victim is None:
                    raise RuntimeError(
                        f"prefix registry full ({self.max_prefixes}) and "
                        f"every prefix is referenced by a running slot")
                self.drop_prefix(victim)
                self.prefix_evictions += 1
        free = [s for s in range(self.slots) if not self.active[s]]
        if not free:
            raise RuntimeError("register_prefix needs a free slot")
        slot = free[0]
        pages = self.pool.alloc(pages_needed(reg_len, self.page_size))
        self.bt.append(slot, pages)
        self._slot_reset(slot)
        self._run_prefill(slot, list(tokens)[:reg_len], 0)
        snap = self._slot_snapshot(slot)
        self.bt.drop(slot)        # registry keeps the pages' refcount
        self.prefixes[name] = PrefixRecord(
            tokens=tuple(int(t) for t in tokens[:reg_len]), pages=pages,
            state=snap)
        return reg_len

    def drop_prefix(self, name: str) -> None:
        pre = self.prefixes.pop(name)
        self.pool.release(pre.pages)
