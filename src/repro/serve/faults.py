"""Deterministic fault injection for the serving stack.

The robustness claims of the scheduler/engine pair (no partial effects on
``PoolExhausted``, retry-safe ``DecodeFault``, NaN-guarded logits, no page
leaks, bitwise-identical completed outputs) are only worth stating if they
are *executable*.  This module makes them so: ``FaultyEngine`` wraps any
engine behind the scheduler protocol and injects failures from a seeded
``FaultPlan`` — the same seed always produces the same fault trace, so a
failing run is replayable and CI can pin exact outcomes.

Three injection sites, chosen because they are the three places the real
stack can fail:

* ``admit`` — ``PoolExhausted`` raised *before* the engine is touched
  (models allocation failure; the no-partial-effects contract means the
  wrapper needs no cleanup).
* ``decode`` — either ``PoolExhausted`` (models page growth failing
  mid-step; triggers the scheduler's eviction path) or ``DecodeFault``
  (models a transient device fault; the scheduler retries the quantum).
  Both raise before delegation, so no cursor advances.
* logits — the engine itself calls ``plan.corrupt_logits`` on the
  host-visible logits between device transfer and token emission
  (``engine.fault_hook``), poisoning whole rows with NaN.  This exercises
  the NaN guard + decode-graph rescue: the engine re-runs the SAME jitted
  step (idempotent by the rows>=written-are-rewritten invariant), so the
  rescued tokens are bitwise those of a fault-free run.

Because every injected fault is either raised before any state change or
rescued by re-running an idempotent graph, a run under *any* FaultPlan must
complete with outputs bitwise identical to the fault-free run — that
equality is asserted in tests/test_faults.py and the CI smoke step.
"""
from __future__ import annotations

import numpy as np

from repro.serve.paging import DecodeFault, PoolExhausted


class FaultPlan:
    """A seeded schedule of failures.

    Probabilities are per *opportunity* (one admit call, one decode call,
    one logits row).  ``max_faults`` bounds the total injections so a hot
    plan cannot livelock a request past the scheduler's retry budgets —
    after the bound, the plan goes quiet and the run completes.
    """

    def __init__(self, seed: int, *, p_admit: float = 0.0,
                 p_growth: float = 0.0, p_transient: float = 0.0,
                 p_nan: float = 0.0, max_faults: int | None = 50):
        for name, p in (("p_admit", p_admit), ("p_growth", p_growth),
                        ("p_transient", p_transient), ("p_nan", p_nan)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name}={p} is not a probability")
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self.p_admit = p_admit
        self.p_growth = p_growth
        self.p_transient = p_transient
        self.p_nan = p_nan
        self.max_faults = max_faults
        self.admit_faults = 0
        self.growth_faults = 0
        self.transient_faults = 0
        self.nan_rows = 0

    @property
    def total(self) -> int:
        return (self.admit_faults + self.growth_faults
                + self.transient_faults + self.nan_rows)

    def _armed(self) -> bool:
        return self.max_faults is None or self.total < self.max_faults

    def _fire(self, p: float) -> bool:
        # always draw, so the rng stream (and thus the trace) depends only
        # on the seed and the opportunity sequence, not on max_faults
        return (self.rng.random() < p) and self._armed()

    # -- sites ---------------------------------------------------------------

    def on_admit(self) -> None:
        if self._fire(self.p_admit):
            self.admit_faults += 1
            raise PoolExhausted(
                f"[injected seed={self.seed}] admit allocation failure")

    def on_decode(self) -> None:
        if self._fire(self.p_growth):
            self.growth_faults += 1
            raise PoolExhausted(
                f"[injected seed={self.seed}] page growth failure")
        if self._fire(self.p_transient):
            self.transient_faults += 1
            raise DecodeFault(
                f"[injected seed={self.seed}] transient decode fault")

    def corrupt_logits(self, lg: np.ndarray, site: str) -> np.ndarray:
        """Poison whole logit rows with NaN, in place.  ``lg`` is the
        host-side copy the engine is about to emit tokens from — the device
        cache is untouched, which is exactly the failure the NaN guard is
        built for.  Rows are the leading axes (everything but vocab)."""
        if self.p_nan <= 0.0:
            return lg
        hit = self.rng.random(lg.size // lg.shape[-1]) < self.p_nan
        if self._armed() and hit.any():
            if not lg.flags.writeable:    # np.asarray of a device array
                lg = lg.copy()
            lg.reshape(-1, lg.shape[-1])[hit] = np.nan
            self.nan_rows += int(hit.sum())
        return lg

    def stats(self) -> dict:
        return {"seed": self.seed, "admit_faults": self.admit_faults,
                "growth_faults": self.growth_faults,
                "transient_faults": self.transient_faults,
                "nan_rows": self.nan_rows}


class FaultyEngine:
    """Engine wrapper injecting a FaultPlan at the protocol boundary.

    Everything not intercepted (finish/preempt/suspend/resume/attribute
    reads) forwards to the wrapped engine, so the scheduler cannot tell the
    difference — including ``hasattr(engine, "suspend")`` for the swap
    policy.  The wrapper also arms the engine's ``fault_hook`` so the
    logits site fires inside the engine's own guard loop.
    """

    def __init__(self, engine, plan: FaultPlan):
        self._engine = engine
        self.plan = plan
        engine.fault_hook = plan

    def admit(self, slot, request):
        self.plan.on_admit()
        return self._engine.admit(slot, request)

    def decode(self, slots):
        self.plan.on_decode()
        return self._engine.decode(slots)

    def __getattr__(self, name):
        return getattr(self._engine, name)
