"""Deterministic fault injection for the serving stack.

The robustness claims of the scheduler/engine pair (no partial effects on
``PoolExhausted``, retry-safe ``DecodeFault``, NaN-guarded logits, no page
leaks, bitwise-identical completed outputs) are only worth stating if they
are *executable*.  This module makes them so: ``FaultyEngine`` wraps any
engine behind the scheduler protocol and injects failures from a seeded
``FaultPlan`` — the same seed always produces the same fault trace, so a
failing run is replayable and CI can pin exact outcomes.

Three injection sites, chosen because they are the three places the real
stack can fail:

* ``admit`` — ``PoolExhausted`` raised *before* the engine is touched
  (models allocation failure; the no-partial-effects contract means the
  wrapper needs no cleanup).
* ``decode`` — either ``PoolExhausted`` (models page growth failing
  mid-step; triggers the scheduler's eviction path) or ``DecodeFault``
  (models a transient device fault; the scheduler retries the quantum).
  Both raise before delegation, so no cursor advances.
* logits — the engine itself calls ``plan.corrupt_logits`` on the
  host-visible logits between device transfer and token emission
  (``engine.fault_hook``), poisoning whole rows with NaN.  This exercises
  the NaN guard + decode-graph rescue: the engine re-runs the SAME jitted
  step (idempotent by the rows>=written-are-rewritten invariant), so the
  rescued tokens are bitwise those of a fault-free run.

Because every injected fault is either raised before any state change or
rescued by re-running an idempotent graph, a run under *any* FaultPlan must
complete with outputs bitwise identical to the fault-free run — that
equality is asserted in tests/test_faults.py and the CI smoke step.
"""
from __future__ import annotations

import numpy as np

from repro.obs import ENGINE_TRACK, NULL_TRACER, Registry
from repro.serve.paging import DecodeFault, PoolExhausted


class FaultPlan:
    """A seeded schedule of failures.

    Probabilities are per *opportunity* (one admit call, one decode call,
    one logits row).  ``max_faults`` bounds the total injections so a hot
    plan cannot livelock a request past the scheduler's retry budgets —
    after the bound, the plan goes quiet and the run completes.

    Injection counts live in an obs Registry (``metrics=``, or a private
    one); the ``admit_faults``/``growth_faults``/``transient_faults``/
    ``nan_rows`` names are read-only views and ``stats()`` reads them.
    """

    def __init__(self, seed: int, *, p_admit: float = 0.0,
                 p_growth: float = 0.0, p_transient: float = 0.0,
                 p_nan: float = 0.0, max_faults: int | None = 50,
                 metrics: Registry | None = None, trace=None):
        for name, p in (("p_admit", p_admit), ("p_growth", p_growth),
                        ("p_transient", p_transient), ("p_nan", p_nan)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name}={p} is not a probability")
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self.p_admit = p_admit
        self.p_growth = p_growth
        self.p_transient = p_transient
        self.p_nan = p_nan
        self.max_faults = max_faults
        self.metrics = metrics if metrics is not None else Registry()
        self.trace = trace if trace is not None else NULL_TRACER
        self._c_admit = self.metrics.counter("fault_admit_total")
        self._c_growth = self.metrics.counter("fault_growth_total")
        self._c_transient = self.metrics.counter("fault_transient_total")
        self._c_nan = self.metrics.counter("fault_nan_rows_total")

    @property
    def admit_faults(self) -> int:
        return self._c_admit.value

    @property
    def growth_faults(self) -> int:
        return self._c_growth.value

    @property
    def transient_faults(self) -> int:
        return self._c_transient.value

    @property
    def nan_rows(self) -> int:
        return self._c_nan.value

    @property
    def total(self) -> int:
        return (self.admit_faults + self.growth_faults
                + self.transient_faults + self.nan_rows)

    def _armed(self) -> bool:
        return self.max_faults is None or self.total < self.max_faults

    def _fire(self, p: float) -> bool:
        # always draw, so the rng stream (and thus the trace) depends only
        # on the seed and the opportunity sequence, not on max_faults
        return (self.rng.random() < p) and self._armed()

    # -- sites ---------------------------------------------------------------

    def on_admit(self) -> None:
        if self._fire(self.p_admit):
            self._c_admit.inc()
            self.trace.event("fault.inject", "fault", ENGINE_TRACK,
                             {"site": "admit"})
            raise PoolExhausted(
                f"[injected seed={self.seed}] admit allocation failure")

    def on_decode(self) -> None:
        if self._fire(self.p_growth):
            self._c_growth.inc()
            self.trace.event("fault.inject", "fault", ENGINE_TRACK,
                             {"site": "growth"})
            raise PoolExhausted(
                f"[injected seed={self.seed}] page growth failure")
        if self._fire(self.p_transient):
            self._c_transient.inc()
            self.trace.event("fault.inject", "fault", ENGINE_TRACK,
                             {"site": "transient"})
            raise DecodeFault(
                f"[injected seed={self.seed}] transient decode fault")

    def corrupt_logits(self, lg: np.ndarray, site: str) -> np.ndarray:
        """Poison whole logit rows with NaN, in place.  ``lg`` is the
        host-side copy the engine is about to emit tokens from — the device
        cache is untouched, which is exactly the failure the NaN guard is
        built for.  Rows are the leading axes (everything but vocab)."""
        if self.p_nan <= 0.0:
            return lg
        hit = self.rng.random(lg.size // lg.shape[-1]) < self.p_nan
        if self._armed() and hit.any():
            if not lg.flags.writeable:    # np.asarray of a device array
                lg = lg.copy()
            lg.reshape(-1, lg.shape[-1])[hit] = np.nan
            self._c_nan.inc(int(hit.sum()))
            self.trace.event("fault.inject", "fault", ENGINE_TRACK,
                             {"site": site, "rows": int(hit.sum())})
        return lg

    def stats(self) -> dict:
        return {"seed": self.seed, "admit_faults": self.admit_faults,
                "growth_faults": self.growth_faults,
                "transient_faults": self.transient_faults,
                "nan_rows": self.nan_rows}


class FaultyEngine:
    """Engine wrapper injecting a FaultPlan at the protocol boundary.

    Everything not intercepted (finish/preempt/suspend/resume/attribute
    reads) forwards to the wrapped engine, so the scheduler cannot tell the
    difference — including ``hasattr(engine, "suspend")`` for the swap
    policy.  The wrapper also arms the engine's ``fault_hook`` so the
    logits site fires inside the engine's own guard loop.
    """

    def __init__(self, engine, plan: FaultPlan):
        self._engine = engine
        self.plan = plan
        engine.fault_hook = plan
        if not plan.trace and getattr(engine, "trace", None):
            plan.trace = engine.trace   # fault events land in the run trace

    def admit(self, slot, request):
        self.plan.on_admit()
        return self._engine.admit(slot, request)

    def decode(self, slots):
        self.plan.on_decode()
        return self._engine.decode(slots)

    def __getattr__(self, name):
        return getattr(self._engine, name)
