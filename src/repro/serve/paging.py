"""Global KV page pool + per-slot block tables (vLLM-style paging).

The contiguous cache charges every slot ``max_len`` rows up front, so host
capacity is ``slots * max_len`` regardless of how long requests actually
are.  Paging splits the cache row axis into fixed-size pages owned by a
single global pool; a slot holds an ordered *block table* of page ids and
only ever pays for the pages its live prefix touches.  The split-KV decode
kernel's *gapped* coarsening already fetches strided KV panes — a page
gather is the same access pattern with the stride replaced by a table
lookup, which is exactly how ``kernels/decode_attention.make_paged_kernel``
consumes the tables this module manages.

Page 0 is the NULL page: it is never allocated, every device block table is
padded with it, and the model's scatter paths route inactive slots' writes
to it — garbage lands there instead of corrupting live pages, replacing the
``jnp.where`` slot-mask over the whole cache that the contiguous path needs.

Refcounting serves shared prefixes (common system prompts): a page whose
refcount exceeds one is frozen (read-only by convention — writers always
append past the shared boundary) and is returned to the free list only when
the last holder releases it.

Invariants (executable in tests/test_paging.py):
  * a writable page (refcount == 1) appears in at most one block table
  * free pages + live pages == num_pages - 1 (the null page is neither)
  * a shared page is freed exactly when its refcount reaches zero
  * any admit/decode/finish/preempt sequence conserves pages (no leaks)
"""
from __future__ import annotations

import numpy as np

NULL_PAGE = 0


class PoolExhausted(RuntimeError):
    """Raised when an allocation cannot be served; the scheduler reacts by
    evicting a running request (host-swap or requeue-with-cache-drop)
    rather than crashing the server."""


class DecodeFault(RuntimeError):
    """A transient decode-step failure: when this raises, no generation
    cursor has advanced and the pool is consistent (pages grown for the
    aborted step stay accounted in their tables — same contract as
    PoolExhausted mid-growth), so the scheduler can simply retry the
    quantum.  Raised by the fault-injection harness (repro.serve.faults)
    and by the engine itself when the NaN-logit guard exhausts its rescue
    retries."""


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages covering ``n_tokens`` cache rows."""
    return max(0, -(-n_tokens // page_size))


class PagePool:
    """Free-list page allocator with refcounts.

    Pages are plain ints in [1, num_pages); page 0 (NULL_PAGE) is reserved.
    ``alloc`` pops LIFO from the free list (hot pages stay hot), ``incref``
    shares, ``release`` decrefs and returns pages to the free list at zero.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("PagePool needs >= 2 pages (page 0 is null)")
        if page_size < 1:
            raise ValueError(f"page_size must be positive, got {page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.refcount = np.zeros(self.num_pages, np.int32)
        self._free = list(range(self.num_pages - 1, 0, -1))  # pop() -> 1 first

    # -- capacity ------------------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        return int(np.count_nonzero(self.refcount[1:]))

    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the null page)."""
        return self.num_pages - 1

    @property
    def tokens_capacity(self) -> int:
        return self.capacity * self.page_size

    # -- alloc / share / release --------------------------------------------

    def alloc(self, n: int) -> list[int]:
        """Pop ``n`` pages with refcount 1 each; raises PoolExhausted (with
        no side effects) when fewer than ``n`` pages are free."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} pages, {len(self._free)} free "
                f"of {self.capacity}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self.refcount[p] = 1
        return pages

    def incref(self, pages) -> None:
        """Share already-live pages (the shared-prefix admit path)."""
        for p in pages:
            if p == NULL_PAGE or self.refcount[p] <= 0:
                raise ValueError(f"incref of dead page {p}")
            self.refcount[p] += 1

    def release(self, pages) -> None:
        """Decref; a page returns to the free list exactly at refcount 0."""
        for p in pages:
            if p == NULL_PAGE:
                continue
            if self.refcount[p] <= 0:
                raise ValueError(f"double free of page {p}")
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)

    # -- invariant check (the executable spec) ------------------------------

    def check(self) -> None:
        """Raise AssertionError if the pool's bookkeeping is inconsistent."""
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert NULL_PAGE not in free, "null page on the free list"
        for p in free:
            assert self.refcount[p] == 0, f"free page {p} has refs"
        live = {p for p in range(1, self.num_pages) if self.refcount[p] > 0}
        assert free | live == set(range(1, self.num_pages)), \
            "leaked pages: neither free nor live"
        assert not (free & live)


class BlockTables:
    """Per-slot ordered page lists + their padded device image.

    ``append``/``drop`` mutate host state; ``device()`` renders the
    (slots, max_pages) int32 array the kernels consume, with inactive or
    short rows padded by NULL_PAGE so stray writes land on the null page.
    """

    def __init__(self, slots: int, max_pages: int):
        self.slots = int(slots)
        self.max_pages = int(max_pages)
        self.tables: list[list[int]] = [[] for _ in range(self.slots)]

    def __getitem__(self, slot: int) -> list[int]:
        return self.tables[slot]

    def append(self, slot: int, pages) -> None:
        t = self.tables[slot]
        if len(t) + len(pages) > self.max_pages:
            raise PoolExhausted(
                f"slot {slot}: {len(t)}+{len(pages)} pages exceed the "
                f"per-slot table of {self.max_pages}")
        t.extend(int(p) for p in pages)

    def drop(self, slot: int) -> list[int]:
        """Clear a slot's table and hand back the pages it held (the caller
        releases them against the pool)."""
        pages, self.tables[slot] = self.tables[slot], []
        return pages

    def truncate(self, slot: int, n_keep: int) -> list[int]:
        """Shrink a slot's table to its first ``n_keep`` pages and hand back
        the dropped tail (the caller releases it against the pool) — the
        speculative-decode rollback: rejected drafted positions' pages leave
        the table front-to-back intact, so shared-prefix pages (always a
        prefix of the table) are never touched."""
        if n_keep < 0:
            raise ValueError(f"truncate({slot}, {n_keep})")
        t = self.tables[slot]
        tail, self.tables[slot] = t[n_keep:], t[:n_keep]
        return tail

    def num_pages(self, slot: int) -> int:
        return len(self.tables[slot])

    def device(self, active=None) -> np.ndarray:
        """(slots, max_pages) int32, NULL_PAGE-padded.  ``active`` (bool per
        slot) additionally nulls whole rows — the write-protection image the
        prefill path uses so only the admitted slot touches live pages."""
        out = np.full((self.slots, self.max_pages), NULL_PAGE, np.int32)
        for s, t in enumerate(self.tables):
            if active is not None and not active[s]:
                continue
            out[s, : len(t)] = t
        return out

    def owners(self) -> dict[int, list[int]]:
        """page -> slots holding it (test helper for the aliasing invariant)."""
        own: dict[int, list[int]] = {}
        for s, t in enumerate(self.tables):
            for p in t:
                own.setdefault(p, []).append(s)
        return own


class SwapStore:
    """Host-side bookkeeping for swapped-out (suspended) slot state.

    The scheduler's swap-vs-recompute policy is "swap when the suspended
    bytes fit the host budget, recompute otherwise"; this store IS that
    budget.  It never touches device memory — it holds whatever opaque
    suspension object the engine hands back, keyed by request id, and
    accounts bytes against ``budget_bytes`` (None = unbounded).

    Invariant (check()): ``used_bytes`` equals the sum of the stored
    entries' sizes, and never exceeds the budget.

    Counters live in an ``repro.obs`` Registry (``metrics=``, or a private
    one) — the historical ``swapped_out``/``swapped_in``/``dropped``/
    ``refused`` attributes are read-only views over it.
    """

    def __init__(self, budget_bytes: int | None = None, metrics=None):
        if budget_bytes is not None and budget_bytes < 0:
            raise ValueError(f"budget_bytes must be >= 0, got {budget_bytes}")
        if metrics is None:
            from repro.obs import Registry
            metrics = Registry()
        self.budget_bytes = budget_bytes
        self._entries: dict[int, tuple] = {}    # rid -> (susp, nbytes)
        self.metrics = metrics
        self._out = metrics.counter("swap_out_total", "lifetime puts")
        self._in = metrics.counter("swap_in_total", "lifetime pops (resumes)")
        self._drop = metrics.counter("swap_dropped_total",
                                     "cancelled while suspended")
        self._refuse = metrics.counter(
            "swap_refused_total", "policy said recompute (over budget)")
        self._used = metrics.gauge("swap_used_bytes", "host bytes held")
        self._used.set(0)

    @property
    def used_bytes(self) -> int:
        return self._used.value

    @property
    def swapped_out(self) -> int:
        return self._out.value

    @property
    def swapped_in(self) -> int:
        return self._in.value

    @property
    def dropped(self) -> int:
        return self._drop.value

    @property
    def refused(self) -> int:
        return self._refuse.value

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, rid: int) -> bool:
        return rid in self._entries

    def fits(self, nbytes: int) -> bool:
        """Would ``nbytes`` more fit the budget?  A refusal is counted so
        the policy split is observable in serving stats."""
        ok = self.budget_bytes is None \
            or self.used_bytes + nbytes <= self.budget_bytes
        if not ok:
            self._refuse.inc()
        return ok

    def put(self, rid: int, susp, nbytes: int) -> None:
        if rid in self._entries:
            raise ValueError(f"request {rid} is already swapped out")
        self._entries[rid] = (susp, int(nbytes))
        self._used.inc(int(nbytes))
        self._out.inc()

    def peek(self, rid: int):
        """The stored suspension, NOT removed — resume may still fail with
        PoolExhausted, in which case the entry must survive."""
        return self._entries[rid][0]

    def pop(self, rid: int):
        """Remove after a successful resume."""
        susp, nbytes = self._entries.pop(rid)
        self._used.dec(nbytes)
        self._in.inc()
        return susp

    def drop(self, rid: int) -> None:
        """Discard a suspension whose request was cancelled/failed."""
        _, nbytes = self._entries.pop(rid)
        self._used.dec(nbytes)
        self._drop.inc()

    def check(self) -> None:
        assert self.used_bytes == sum(n for _, n in self._entries.values())
        assert self.budget_bytes is None \
            or self.used_bytes <= self.budget_bytes, "swap budget exceeded"
