"""Paged-KV serving subsystem.

``paging``     — the global page pool (free-list allocation, refcounting for
                 shared-prefix pages) and per-slot block tables.
``scheduler``  — FCFS + preemption continuous-batching scheduler, engine-
                 agnostic (property-testable against a fake engine).
``engine``     — PagedEngine: the model-coupled serving engine (paged cache,
                 chunked prefill through page allocation, on-device decode
                 blocks, preempt/resume).
``spec``       — SpecPagedEngine: speculative decoding (draft-K proposals,
                 one batched verify pass through the short-q coarsened
                 kernel, paged rollback of rejected rows).
``faults``     — deterministic fault injection (seeded FaultPlan wrapping
                 any engine): executable robustness claims — injected
                 PoolExhausted / DecodeFault / NaN logits must leave
                 completed outputs bitwise identical to a fault-free run.
"""
from repro.serve.engine import PagedEngine, Suspension
from repro.serve.faults import FaultPlan, FaultyEngine
from repro.serve.paging import (NULL_PAGE, BlockTables, DecodeFault,
                                PagePool, PoolExhausted, SwapStore,
                                pages_needed)
from repro.serve.scheduler import Request, Scheduler, State
from repro.serve.spec import SpecPagedEngine, draft_of

__all__ = ["NULL_PAGE", "BlockTables", "DecodeFault", "FaultPlan",
           "FaultyEngine", "PagePool", "PoolExhausted", "PagedEngine",
           "SpecPagedEngine", "State", "Suspension", "SwapStore",
           "draft_of", "pages_needed", "Request", "Scheduler"]
