"""Paged-KV serving subsystem.

``paging``     — the global page pool (free-list allocation, refcounting for
                 shared-prefix pages) and per-slot block tables.
``scheduler``  — FCFS + preemption continuous-batching scheduler, engine-
                 agnostic (property-testable against a fake engine).
``engine``     — PagedEngine: the model-coupled serving engine (paged cache,
                 chunked prefill through page allocation, on-device decode
                 blocks, preempt/resume).
"""
from repro.serve.engine import PagedEngine
from repro.serve.paging import (NULL_PAGE, BlockTables, PagePool,
                                PoolExhausted, pages_needed)
from repro.serve.scheduler import Request, Scheduler

__all__ = ["NULL_PAGE", "BlockTables", "PagePool", "PoolExhausted",
           "PagedEngine", "pages_needed", "Request", "Scheduler"]
