"""Speculative decoding on the paged engine: draft K, verify in one pass.

A small DRAFT model proposes K tokens per slot per step; the TARGET model
scores all K+1 positions in ONE batched pass through the short-q coarsened
flash kernel (models.model.lm_verify_step -> the `flash_attention_verify`
tuner family), and the longest prefix of draft tokens matching the target's
greedy argmaxes is accepted.  Greedy verify is EXACT in exact arithmetic:
every emitted token is the target's own argmax given the accepted prefix.

Bitwise parity with non-spec decode needs one more guard.  XLA lowers the
T-row verify graph and the 1-row decode graph with different reduction
orders (GEMM k-panels, attention/softmax reductions pick strategies by
shape), so verify logits match decode logits only to ~1% of the logit
spread (bf16 cache rows drift by an ulp and the error scales with
activation magnitude) — enough to flip an argmax on a near-tie.  The
engine therefore trusts a verify row only when its top-1/top-2 margin
clears ``tie_tau`` TIMES the row's logit std (default 0.1, an order of
magnitude above the observed relative divergence).  A row under the
guard ends the step's emission there; a slot that would emit nothing gets
its one token from a RESCUE pass through the base engine's own jitted
decode function — bitwise-identical to non-spec decode by construction, so
progress is guaranteed and every emitted token is one the base engine would
have produced.  tests/test_spec.py pins output parity, including under
forced rejection and preemption.

Expected speedup: with per-position acceptance rate a, one step emits
E = (1 - a^(K+1)) / (1 - a) tokens for one target verify (≈ one decode-step
cost amortized over E tokens) plus K cheap draft steps.

Paged mechanics:

* The draft KV cache is itself PAGED and shares the target's page-id space:
  page p means row p of the target pools AND row p of the draft pools, so
  one allocator/block-table/rollback covers both models.  Draft pool rows
  at reallocated pages are stale garbage by construction — always
  overwritten (prefill or draft scan) before any read.
* A verify step appends up to K+1 rows per slot, so pages are grown for
  the WORST case before any compute (PoolExhausted propagates with the
  same consistent-not-leaked contract as the base engine), and
  `step_growth_bound` lets the scheduler account that growth at admission
  so a step launched right after an admit can't abort mid-verify.
* Rejection rolls back: the slot's block table is truncated to the pages
  covering the accepted rows (BlockTables.truncate — shared-prefix pages
  sit at the front and are never touched) and the tail pages are released.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig, ATTN_GLOBAL, ATTN_LOCAL
from repro.obs import ENGINE_TRACK
from repro.serve.engine import PagedEngine
from repro.serve.paging import pages_needed


def draft_of(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Derive a draft config from a target config: the standard `reduced`
    shrink (few layers, d_model 128, d_ff 256) but sharing the target's
    FULL vocab — draft proposals must be target token ids."""
    small = cfg.reduced(**{k: v for k, v in overrides.items()
                           if k != "vocab"})
    return dataclasses.replace(small, vocab=cfg.vocab)


class SpecPagedEngine(PagedEngine):
    """PagedEngine whose decode step is draft-K / batched-verify.

    Same admit/decode/finish/preempt protocol as the base engine (the
    Scheduler drives both identically); `decode` returns the accepted
    tokens per slot — between 1 (immediate rejection: the target's
    correction) and K+1 (all drafts accepted + the bonus token) per step.

    draft_params=None initializes a fresh draft from ``rng`` (useful for
    benchmarks that want forced rejections); passing the target's own
    (cfg, params) as the draft gives acceptance rate 1.0 — the upper-bound
    sanity check.
    """

    def __init__(self, cfg: ModelConfig, params, *, spec_k: int,
                 draft_cfg: Optional[ModelConfig] = None, draft_params=None,
                 rng=None, tie_tau: float = 0.1, **kw):
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        tune = kw.pop("tune", None)
        super().__init__(cfg, params, **kw)
        if tune:
            # warm with the verify family included (its spec carries K);
            # self.cfg carries the backend/quant replacements super applied
            from repro.tune import warm_from_flag
            warm_from_flag(self.cfg, tune, seq=self.max_len,
                           batch=self.slots, page_size=self.page_size,
                           spec_k=spec_k, metrics=self.obs)
        bad = [k for k in self.cfg.layer_kinds()
               if k not in (ATTN_GLOBAL, ATTN_LOCAL)]
        if bad:
            raise NotImplementedError(
                f"speculative decoding needs an attention-only stack "
                f"(recurrent/SSM state cannot be rewound past rejected "
                f"rows); target has {sorted(set(bad))}")
        self.spec_k = int(spec_k)
        self.draft_cfg = draft_cfg if draft_cfg is not None \
            else draft_of(self.cfg)
        if self.draft_cfg.vocab != self.cfg.vocab:
            raise ValueError(
                f"draft vocab {self.draft_cfg.vocab} != target vocab "
                f"{self.cfg.vocab}; draft proposals must be target ids")
        bad = [k for k in self.draft_cfg.layer_kinds()
               if k not in (ATTN_GLOBAL, ATTN_LOCAL)]
        if bad:
            raise NotImplementedError(
                f"draft model must be attention-only; has {sorted(set(bad))}")
        if draft_params is None:
            draft_params = M.lm_init(rng if rng is not None
                                     else jax.random.PRNGKey(0),
                                     self.draft_cfg)
        self.draft_params = draft_params
        # the draft cache shares the TARGET's page-id space: one page pool
        # worth of ids, two sets of pools (target + draft) indexed by them
        self.draft_cache = M.lm_init_cache_paged(
            self.draft_cfg, self.slots, self.pool.num_pages, self.page_size)
        self.cache_mib += sum(
            int(x.size) * jnp.dtype(x.dtype).itemsize
            for x in jax.tree.leaves(self.draft_cache)) / 2**20

        # the draft pools swap out with the target's (shared page-id space)
        self._swap_page_bytes, self._swap_fixed_bytes = self._swap_layout()

        self.tie_tau = float(tie_tau)
        o = self.obs
        self._c_drafted = o.counter("spec_drafted_total",
                                    "draft tokens offered to verify")
        self._c_accepted = o.counter("spec_accepted_total",
                                     "draft tokens accepted")
        self._c_spec_steps = o.counter("spec_steps_total")
        self._c_rescues = o.counter(
            "spec_rescue_steps_total",
            "steps that needed a decode-graph rescue")
        self._c_nan_rows = o.counter(
            "spec_nan_rows_total", "verify rows voided by the NaN guard")
        dcfg = self.draft_cfg
        self._draft_prefill_fn = jax.jit(
            lambda p, c, t, po, m, bt: M.lm_prefill(
                p, {"tokens": t}, dcfg, cache=c, pos0=po, mask=m,
                block_table=bt))
        tcfg = self.cfg
        self._verify_fn = jax.jit(
            lambda p, c, t, po, vl, bt: M.lm_verify_step(
                p, c, t, po, tcfg, block_table=bt, valid_len=vl))
        self._draft_fns: dict[int, Any] = {}

    @property
    def drafted(self) -> int:
        return self._c_drafted.value

    @property
    def accepted(self) -> int:
        return self._c_accepted.value

    @property
    def spec_steps(self) -> int:
        return self._c_spec_steps.value

    @property
    def rescue_steps(self) -> int:
        return self._c_rescues.value

    @property
    def nan_rows(self) -> int:
        return self._c_nan_rows.value

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(1, self.drafted)

    # -- admission accounting (scheduler hook) ------------------------------

    def _step_rows(self) -> int:
        return self.spec_k + 1          # a verify appends up to K+1 rows

    def step_growth_bound(self, req=None) -> int:
        return self._growth_bound(req)

    # -- host swap (suspend/resume) covers BOTH models' pools ----------------

    def _swap_layout(self):
        per_page, fixed = super()._swap_layout()
        draft = getattr(self, "draft_cache", None)
        if draft is not None:           # absent during super().__init__
            for c in draft["blocks"]:   # attention-only: every leaf paged
                per_page += sum(a.nbytes // a.shape[1]
                                for a in jax.tree.leaves(c))
            for c in draft["tail"]:
                per_page += sum(a.nbytes // a.shape[0]
                                for a in jax.tree.leaves(c))
        return per_page, fixed

    def _gather_pages(self, idx):
        saved = super()._gather_pages(idx)
        i = jnp.asarray(idx, jnp.int32)
        saved["draft_blocks"] = [
            jax.tree.map(lambda a: np.asarray(a[:, i]), c)
            for c in self.draft_cache["blocks"]]
        saved["draft_tail"] = [jax.tree.map(lambda a: np.asarray(a[i]), c)
                               for c in self.draft_cache["tail"]]
        return saved

    def _scatter_pages(self, idx, saved) -> None:
        super()._scatter_pages(idx, saved)
        i = jnp.asarray(idx, jnp.int32)
        self.draft_cache = {
            "blocks": [jax.tree.map(lambda a, v: a.at[:, i].set(v), c, sv)
                       for c, sv in zip(self.draft_cache["blocks"],
                                        saved["draft_blocks"])],
            "tail": [jax.tree.map(lambda a, v: a.at[i].set(v), c, sv)
                     for c, sv in zip(self.draft_cache["tail"],
                                      saved["draft_tail"])],
        }

    # -- draft-side prefill --------------------------------------------------

    def _run_draft_prefill(self, slot: int, tokens) -> None:
        """Ingest the FULL prompt into the draft cache through the slot's
        (already-allocated) block table.  Shared-prefix pages are written
        too: sharers write identical draft K/V there (same tokens, same
        draft params, deterministic), so the frozen-page convention holds
        in effect if not in letter."""
        mask = jnp.zeros((self.slots,), bool).at[slot].set(True)
        only = np.zeros((self.slots,), bool)
        only[slot] = True
        bt_dev = self._device_table(only)
        for i in range(0, len(tokens), self.chunk):
            piece = tokens[i:i + self.chunk]
            buf = np.zeros((self.slots, len(piece)), np.int32)
            buf[slot] = piece
            pos0 = jnp.asarray(self.written, jnp.int32).at[slot].set(i)
            _, self.draft_cache = self._draft_prefill_fn(
                self.draft_params, self.draft_cache, jnp.asarray(buf), pos0,
                mask, bt_dev)

    def admit(self, slot: int, req) -> int:
        first = super().admit(slot, req)
        # no draft-side allocation: the target's pages cover the draft, so
        # this cannot raise PoolExhausted after super() succeeded
        self._run_draft_prefill(slot, list(req.prompt))
        return first

    # -- the spec step -------------------------------------------------------

    def _draft_fn(self, n: int):
        """Jitted draft scan: n chained greedy steps through the paged
        draft cache, step j writing cache row pos0+j and proposing the
        token for position pos0+j+1.  ``keff`` masks writes past a slot's
        own draft budget (batch padding)."""
        fn = self._draft_fns.get(n)
        if fn is not None:
            return fn
        dcfg = self.draft_cfg

        def run(params, cache, tok, pos0, keff, bt):
            def body(carry, j):
                tok, pos, cache = carry
                logits, cache = M.lm_decode_step(
                    params, cache, tok, pos, dcfg, block_table=bt,
                    write_mask=j <= keff)
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                return (nxt[:, None], pos + 1, cache), nxt

            (_, _, cache), toks = jax.lax.scan(
                body, (tok, pos0, cache), jnp.arange(n))
            return toks.T, cache                     # (slots, n)

        fn = self._draft_fns[n] = jax.jit(run)
        return fn

    def decode(self, slots) -> dict[int, list[int]]:
        """One draft-K / verify / accept / rollback step for the running
        ``slots``.  Emits 1..K+1 tokens per slot.  Page growth for the
        WORST case (all K accepted) happens before any compute;
        PoolExhausted propagates to the scheduler with slots whose growth
        already succeeded keeping their pages — consistent, not leaked."""
        slots = [s for s in slots if self.active[s]]
        if not slots:
            return {}
        ps = self.page_size
        keff = np.zeros((self.slots,), np.int32)
        for s in slots:
            # never draft past the request's budget: a step emits at most
            # keff+1 tokens and remaining >= 1 here
            keff[s] = min(self.spec_k, int(self.remaining[s]) - 1)
        kpad = int(keff[slots].max())
        for s in slots:
            need = pages_needed(int(self.written[s]) + int(keff[s]) + 1, ps) \
                - self.bt.num_pages(s)
            if need > 0:
                self.bt.append(s, self.pool.alloc(need))

        t0 = time.perf_counter()
        bt_dev = self._device_table(self.active)
        pos0 = jnp.asarray(self.written, jnp.int32)
        keff_dev = jnp.asarray(keff, jnp.int32)
        last = np.zeros((self.slots, 1), np.int32)
        last[slots, 0] = self.last[slots]
        last_dev = jnp.asarray(last)

        # draft keff+1 chained steps (kpad+1 padded): feeds last, d_1..d_k,
        # writing draft rows written..written+keff — the draft cache ends
        # one row AHEAD of the accepted prefix in the all-accept case and
        # exactly at it after a rollback, both equal to new_written
        with self.trace.span("verify.pass", "engine", ENGINE_TRACK,
                             {"slots": len(slots), "k": kpad}):
            td = time.perf_counter()
            drafts, self.draft_cache = self._draft_fn(kpad + 1)(
                self.draft_params, self.draft_cache, last_dev, pos0,
                keff_dev, bt_dev)

            # verify all K+1 positions in ONE short-q pass: row t scores
            # position written+t+1 given [prompt..., last, d_1..d_t]
            vtok = jnp.concatenate([last_dev, drafts[:, :kpad]], axis=1)
            logits, self.cache = self._verify_fn(
                self.params, self.cache, vtok, pos0, keff_dev + 1, bt_dev)
            jax.block_until_ready(logits)
            self._c_decode_dev.inc(time.perf_counter() - td)
        lg = np.asarray(logits, np.float32)              # (slots, kpad+1, V)
        if self.fault_hook is not None:
            lg = self.fault_hook.corrupt_logits(lg, site="verify")
        greedy = lg.argmax(-1)
        top2 = np.partition(lg, -2, axis=-1)[..., -2:]
        # tie guard threshold: margin relative to the row's logit spread
        # (inter-graph divergence scales with activation magnitude)
        clear = (top2[..., 1] - top2[..., 0]) >= self.tie_tau * lg.std(-1)
        # NaN guard: a poisoned (non-finite) verify row compares False into
        # ``clear`` already, but make it explicit — the row is voided, so
        # emission stops before it and the decode-graph rescue below takes
        # over when nothing else would emit.  That is the whole fault story:
        # no token derived from a poisoned row can ever be emitted.
        finite = np.isfinite(lg).all(-1)
        voided = int((~finite[np.asarray(slots)]).sum())
        if voided:
            self._c_nan_rows.inc(voided)
            self.trace.event("nan.voided", "engine", ENGINE_TRACK,
                             {"rows": voided})
        clear &= finite
        drafts = np.asarray(drafts)
        self._c_decode_steps.inc()
        self._c_spec_steps.inc()

        out = {}
        rescue = []
        for s in slots:
            k, g, d, ok = int(keff[s]), greedy[s], drafts[s], clear[s]
            n_acc = 0
            while n_acc < k and ok[n_acc] and d[n_acc] == g[n_acc]:
                n_acc += 1
            # accepted drafts d_1..d_n_acc == g_0..g_{n_acc-1}, then the
            # target's own next token g_n_acc (correction or bonus) — but
            # only when row n_acc's margin clears the tie guard; a guarded
            # row's position is left to a decode-geometry step instead
            # (the rescue below, or simply the next step)
            emitted = [int(g[j]) for j in range(n_acc + (1 if ok[n_acc]
                                                         else 0))]
            self._c_drafted.inc(k)
            self._c_accepted.inc(n_acc)
            if not emitted:
                # keep the page holding row `written`: the rescue pass
                # scatters there and emits exactly one token
                rescue.append(s)
                self.pool.release(self.bt.truncate(
                    s, pages_needed(int(self.written[s]) + 1, ps)))
                continue
            new_written = int(self.written[s]) + len(emitted)
            # rollback: drop pages past the accepted rows (target AND
            # draft — shared id space); stale rows below the page boundary
            # are pos-masked and overwritten before any read
            self.pool.release(
                self.bt.truncate(s, pages_needed(new_written, ps)))
            self.written[s] = new_written
            self.last[s] = emitted[-1]
            self.remaining[s] -= len(emitted)
            self._c_decode_tokens.inc(len(emitted))
            out[s] = emitted

        if rescue:
            # one base-engine decode step, shared by every rescued slot:
            # the same jitted function the non-spec engine runs, so its
            # argmax (and the cache row it writes) is bitwise the base
            # engine's.  Non-rescued slots ride along harmlessly — their
            # scatter lands on their next row (correct token, overwritten
            # by the next verify) or the null page, and their logits are
            # discarded.
            self._c_rescues.inc()
            tokens = np.zeros((self.slots, 1), np.int32)
            tokens[slots, 0] = self.last[slots]
            with self.trace.span("decode.rescue", "engine", ENGINE_TRACK,
                                 {"slots": len(rescue)}):
                td = time.perf_counter()
                toks, _, self.cache = self._decode_fn(1)(
                    self.params, self.cache, jnp.asarray(tokens),
                    jnp.asarray(self.written, jnp.int32),
                    self._device_table(self.active))
                jax.block_until_ready(toks)
                self._c_decode_dev.inc(time.perf_counter() - td)
            toks = np.asarray(toks)
            for s in rescue:
                tok = int(toks[s, 0])
                out[s] = [tok]
                self.written[s] += 1
                self.last[s] = tok
                self.remaining[s] -= 1
                self._c_decode_tokens.inc()
        self.decode_s += time.perf_counter() - t0
        return out
