"""Flash attention with q-row-block coarsening (GQA / causal / local window)
plus custom-VJP backward kernels.

Forward — the q-row axis is the coarsenable "work-item" axis:

  consecutive : one program owns C adjacent q blocks -> one (C*bq, D) DMA and
                — because the fused rows are adjacent — the causal triangle
                skip still prunes ~half the kv blocks.
  gapped      : one program owns C q blocks strided S/C apart.  The fused rows
                span the whole sequence, so the causal skip degenerates to the
                worst row — the TPU analog of the paper's divergence penalty
                (work-items with different control paths fused together).

KV tiles are fetched once per fused program (paper §III.B: fewer total memory
accesses) — consecutive coarsening divides kv traffic by C up to the causal
skew.  GQA is expressed in the kv index_map (heads share kv tiles).

Backward — two passes, each coarsened on the axis it streams:

  dK/dV (``make_bwd_dkv_kernel``): the KV-BLOCK axis is the work-item axis,
      exactly as in the split-KV decode kernel.  Each program owns C kv
      blocks (consecutive = one wide (C*bkv, D) K/V/dK/dV pane per operand,
      gapped = C strided panes) and sweeps the q blocks, recomputing the
      probabilities flash-style from the saved (m, l) residuals.  The causal
      skip prunes q blocks strictly before the fused kv rows — consecutive
      keeps the pruning, gapped fuses an early kv block into every program
      and degenerates to the worst row (same divergence framing as decode).

  dQ (``make_bwd_dq_kernel``): coarsened on the q-row axis *matching the
      forward* — one program owns the same C q blocks the forward fused and
      sweeps kv blocks accumulating dQ.

Both backward passes recompute p = exp(s - m) / l from the forward residuals
instead of materializing the (S, S) probability matrix — the fused-kernel
saving the mea/XLA baseline cannot express (its per-chunk carry round-trips
HBM between scan steps).
"""
from __future__ import annotations

import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.coarsening import CoarseningConfig, KIND_GAPPED

NEG = -1e30


def _check_geometry(sq: int, sk: int, causal: bool, window) -> None:
    if (causal or window is not None) and sq != sk:
        raise ValueError(f"causal/window attention needs sq == sk "
                         f"(got {sq} vs {sk})")


def _q_axis_layout(b: int, h: int, sq: int, d: int, c: int, bq: int,
                   gapped: bool):
    """BlockSpecs + array views for the q-row-coarsened kernels (forward
    and dQ): the (C*bq, D) q/do/dq tiles and the (C*bq,) residual rows.
    The gapped view (C, Sq/C) is a pure reshape of row order, so residual
    arrays flatten back to (B, H, Sq) with rows in global order."""
    sg = sq // c
    if gapped:
        q_spec = pl.BlockSpec((1, 1, c, bq, d),
                              lambda bb, hh, qi, ki: (bb, hh, 0, qi, 0))
        q_view = lambda q: q.reshape(b, h, c, sg, d)
        r_spec = pl.BlockSpec((1, 1, c, bq),
                              lambda bb, hh, qi, ki: (bb, hh, 0, qi))
        r_view = lambda r: r.reshape(b, h, c, sg)
        o_shape, r_shape = (b, h, c, sg, d), (b, h, c, sg)
    else:
        q_spec = pl.BlockSpec((1, 1, c * bq, d),
                              lambda bb, hh, qi, ki: (bb, hh, qi, 0))
        q_view = lambda q: q
        r_spec = pl.BlockSpec((1, 1, c * bq),
                              lambda bb, hh, qi, ki: (bb, hh, qi))
        r_view = lambda r: r
        o_shape, r_shape = (b, h, sq, d), (b, h, sq)
    return q_spec, q_view, r_spec, r_view, o_shape, r_shape


def _q_axis_mask_live(qi, ki, *, c: int, bq: int, bkv: int, sg: int,
                      gapped: bool, causal: bool, window):
    """(mask, live) for one (q program, kv block) step of a q-row-coarsened
    kernel.  mask is the per-element causal/window mask over the fused
    (C*bq, bkv) tile; live is the whole-block skip: a consecutive program's
    fused rows are adjacent so the causal triangle prunes ~half the kv
    blocks, a gapped program's rows span the sequence so the skip
    degenerates to the worst row (the divergence penalty)."""
    rows_per_prog = c * bq
    j = jax.lax.broadcasted_iota(jnp.int32, (c, bq), 1)
    k = jax.lax.broadcasted_iota(jnp.int32, (c, bq), 0)
    if gapped:
        rows = (k * sg + qi * bq + j).reshape(rows_per_prog)
    else:
        rows = (qi * rows_per_prog + k * bq + j).reshape(rows_per_prog)
    cols = ki * bkv + jnp.arange(bkv, dtype=jnp.int32)
    mask = jnp.ones((rows_per_prog, bkv), dtype=bool)
    if causal:
        mask &= cols[None, :] <= rows[:, None]
    if window is not None:
        mask &= cols[None, :] > rows[:, None] - window

    min_row = rows[0] if not gapped else qi * bq   # smallest fused row id
    live = jnp.bool_(True)
    if causal:
        live = ki * bkv <= (min_row + rows_per_prog - 1 if not gapped
                            else (c - 1) * sg + qi * bq + bq - 1)
    if window is not None:
        # skip kv blocks entirely left of every fused row's window
        live &= (ki + 1) * bkv > (min_row - (window or 0) + 1)
    return mask, live


def make_kernel(b: int, h: int, hkv: int, s: int, d: int,
                cfg: CoarseningConfig, *, bq: int = 128, bkv: int = 128,
                causal: bool = True, window: int | None = None,
                scale: float | None = None,
                interpret: bool = True, sk: int | None = None,
                return_residuals: bool = False) -> Callable:
    """Forward kernel.  run(q (B,H,Sq,D), k, v (B,Hkv,Sk,D)) -> o (B,H,Sq,D)
    f32, or (o, m, l) with m, l (B,H,Sq) f32 when ``return_residuals`` —
    the online-softmax row max and normalizer the backward kernels consume.
    ``sk`` (default Sq) supports cross-attention; causal/window need Sq==Sk.
    """
    sq = s
    sk = sq if sk is None else sk
    c = cfg.degree
    if sq % (c * bq) or sk % bkv:
        raise ValueError("seq not tileable")
    _check_geometry(sq, sk, causal, window)
    gapped = cfg.kind == KIND_GAPPED
    group = h // hkv
    nq, nk = sq // (c * bq), sk // bkv
    sg = sq // c                      # gapped slice length
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    rows_per_prog = c * bq

    def body(q_ref, k_ref, v_ref, *refs):
        if return_residuals:
            o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
        else:
            o_ref, m_ref, l_ref, acc_ref = refs
        qi, ki = pl.program_id(2), pl.program_id(3)

        @pl.when(ki == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        mask, live = _q_axis_mask_live(qi, ki, c=c, bq=bq, bkv=bkv, sg=sg,
                                       gapped=gapped, causal=causal,
                                       window=window)

        @pl.when(live)
        def _compute():
            q = q_ref[...].reshape(rows_per_prog, d)
            kk = k_ref[...].reshape(bkv, d)
            vv = v_ref[...].reshape(bkv, d)
            sij = jnp.dot(q, kk.T, preferred_element_type=jnp.float32) * scale
            sij = jnp.where(mask, sij, NEG)
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, sij.max(axis=1))
            p = jnp.exp(sij - m_new[:, None]) * mask
            alpha = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
            acc_ref[...] = (acc_ref[...] * alpha[:, None]
                            + jnp.dot(p, vv, preferred_element_type=jnp.float32))
            m_ref[...] = m_new

        @pl.when(ki == nk - 1)
        def _fin():
            l = l_ref[...]
            lg = jnp.where(l == 0.0, 1.0, l)
            o_ref[...] = (acc_ref[...] / lg[:, None]).reshape(o_ref.shape)
            if return_residuals:
                mo_ref[...] = m_ref[...].reshape(mo_ref.shape)
                lo_ref[...] = l.reshape(lo_ref.shape)

    kv_index = lambda bb, hh, qi, ki: (bb, hh // group, ki, 0)
    q_spec, q_view, r_spec, _, o_shape, r_shape = _q_axis_layout(
        b, h, sq, d, c, bq, gapped)

    out_specs = (q_spec, r_spec, r_spec) if return_residuals else q_spec
    out_shape = (
        (jax.ShapeDtypeStruct(o_shape, jnp.float32),
         jax.ShapeDtypeStruct(r_shape, jnp.float32),
         jax.ShapeDtypeStruct(r_shape, jnp.float32))
        if return_residuals else jax.ShapeDtypeStruct(o_shape, jnp.float32))

    call = pl.pallas_call(
        body,
        grid=(b, h, nq, nk),
        in_specs=[
            q_spec,
            pl.BlockSpec((1, 1, bkv, d), kv_index),
            pl.BlockSpec((1, 1, bkv, d), kv_index),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((rows_per_prog,), jnp.float32),
            pltpu.VMEM((rows_per_prog,), jnp.float32),
            pltpu.VMEM((rows_per_prog, d), jnp.float32),
        ],
        interpret=interpret,
    )

    def run(q, k, v):
        out = call(q_view(q), k, v)
        if not return_residuals:
            return out.reshape(b, h, sq, d)
        o, m, l = out
        # the gapped residual view (C, Sq/C) is a pure reshape of row order
        return (o.reshape(b, h, sq, d), m.reshape(b, h, sq),
                l.reshape(b, h, sq))

    return run


def make_bwd_dq_kernel(b: int, h: int, hkv: int, s: int, d: int,
                       cfg: CoarseningConfig, *, bq: int = 128,
                       bkv: int = 128, causal: bool = True,
                       window: int | None = None,
                       scale: float | None = None,
                       interpret: bool = True,
                       sk: int | None = None) -> Callable:
    """dQ pass, coarsened on the q-row axis exactly like the forward.

    run(q, k, v, do (B,H,Sq,D), m, l, delta (B,H,Sq)) -> dq (B,H,Sq,D) f32,
    where delta = rowsum(do * o) and (m, l) are the forward residuals.
    """
    sq = s
    sk = sq if sk is None else sk
    c = cfg.degree
    if sq % (c * bq) or sk % bkv:
        raise ValueError("seq not tileable")
    _check_geometry(sq, sk, causal, window)
    gapped = cfg.kind == KIND_GAPPED
    group = h // hkv
    nq, nk = sq // (c * bq), sk // bkv
    sg = sq // c
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    rows_per_prog = c * bq

    def body(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, dl_ref,
             dq_ref, acc_ref):
        qi, ki = pl.program_id(2), pl.program_id(3)

        @pl.when(ki == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        mask, live = _q_axis_mask_live(qi, ki, c=c, bq=bq, bkv=bkv, sg=sg,
                                       gapped=gapped, causal=causal,
                                       window=window)

        @pl.when(live)
        def _compute():
            q = q_ref[...].reshape(rows_per_prog, d).astype(jnp.float32)
            kk = k_ref[...].reshape(bkv, d).astype(jnp.float32)
            vv = v_ref[...].reshape(bkv, d).astype(jnp.float32)
            do = do_ref[...].reshape(rows_per_prog, d).astype(jnp.float32)
            m = m_ref[...].reshape(rows_per_prog)
            l = l_ref[...].reshape(rows_per_prog)
            l = jnp.where(l == 0.0, 1.0, l)
            dl = dl_ref[...].reshape(rows_per_prog)
            sij = jnp.dot(q, kk.T, preferred_element_type=jnp.float32) * scale
            # flash-style recompute: p from the saved (m, l) residuals; the
            # double-where keeps masked entries at exp(NEG)~0 even when a
            # row's m is the NEG sentinel (fully-masked rows)
            p = jnp.exp(jnp.where(mask, sij - m[:, None], NEG)) / l[:, None]
            dp = jnp.dot(do, vv.T, preferred_element_type=jnp.float32)
            ds = p * (dp - dl[:, None])
            acc_ref[...] += jnp.dot(ds, kk,
                                    preferred_element_type=jnp.float32) * scale

        @pl.when(ki == nk - 1)
        def _fin():
            dq_ref[...] = acc_ref[...].reshape(dq_ref.shape)

    kv_index = lambda bb, hh, qi, ki: (bb, hh // group, ki, 0)
    q_spec, q_view, r_spec, r_view, o_shape, _ = _q_axis_layout(
        b, h, sq, d, c, bq, gapped)

    call = pl.pallas_call(
        body,
        grid=(b, h, nq, nk),
        in_specs=[
            q_spec,
            pl.BlockSpec((1, 1, bkv, d), kv_index),
            pl.BlockSpec((1, 1, bkv, d), kv_index),
            q_spec,                                    # do
            r_spec, r_spec, r_spec,                    # m, l, delta
        ],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(o_shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((rows_per_prog, d), jnp.float32)],
        interpret=interpret,
    )

    def run(q, k, v, do, m, l, delta):
        dq = call(q_view(q), k, v, q_view(do), r_view(m), r_view(l),
                  r_view(delta))
        return dq.reshape(b, h, sq, d)

    return run


def make_bwd_dkv_kernel(b: int, h: int, hkv: int, s: int, d: int,
                        cfg: CoarseningConfig, *, bq: int = 128,
                        bkv: int = 128, causal: bool = True,
                        window: int | None = None,
                        scale: float | None = None,
                        interpret: bool = True,
                        sk: int | None = None) -> Callable:
    """dK/dV pass with the KV-BLOCK axis as the coarsening axis.

    Each program owns C kv blocks (consecutive = one wide (C*bkv, D) pane
    per K/V/dK/dV operand, gapped = C strided panes) and sweeps q blocks
    recomputing one wide dQ·K tile per step.  run(q, k, v, do, m, l, delta)
    -> (dk, dv) (B,Hkv,Sk,D) f32 — per-q-head partials are reduced over the
    GQA group outside the kernel.
    """
    sq = s
    sk = sq if sk is None else sk
    c = cfg.degree
    if sk % (c * bkv) or sq % bq:
        raise ValueError("seq not tileable")
    _check_geometry(sq, sk, causal, window)
    gapped = cfg.kind == KIND_GAPPED
    group = h // hkv
    nkv, nq = sk // (c * bkv), sq // bq
    skg = sk // c                      # gapped segment length (kv rows)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    cols_per_prog = c * bkv

    def col_ids(ki):
        j = jax.lax.broadcasted_iota(jnp.int32, (c, bkv), 1)
        kb = jax.lax.broadcasted_iota(jnp.int32, (c, bkv), 0)
        if gapped:
            return (kb * skg + ki * bkv + j).reshape(cols_per_prog)
        return (ki * cols_per_prog + kb * bkv + j).reshape(cols_per_prog)

    def body(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, dl_ref,
             dk_ref, dv_ref, dk_s, dv_s):
        ki, qi = pl.program_id(2), pl.program_id(3)

        @pl.when(qi == 0)
        def _init():
            dk_s[...] = jnp.zeros_like(dk_s)
            dv_s[...] = jnp.zeros_like(dv_s)

        cols = col_ids(ki)                             # (C*bkv,)
        rows = qi * bq + jnp.arange(bq, dtype=jnp.int32)
        mask = jnp.ones((bq, cols_per_prog), dtype=bool)
        if causal:
            mask &= cols[None, :] <= rows[:, None]
        if window is not None:
            mask &= cols[None, :] > rows[:, None] - window

        # causal skip: prune q blocks strictly before every fused kv row.
        # consecutive: min fused col = ki*C*bkv keeps ~half the sweep pruned;
        # gapped fuses segment-0 rows into every program -> worst-row sweep
        # (the decode kernel's divergence framing).
        live = jnp.bool_(True)
        if causal:
            min_col = ki * bkv if gapped else ki * cols_per_prog
            live = min_col <= qi * bq + bq - 1
        if window is not None:
            max_col = ((c - 1) * skg + ki * bkv + bkv - 1) if gapped \
                else ki * cols_per_prog + cols_per_prog - 1
            live &= max_col > qi * bq - window

        @pl.when(live)
        def _compute():
            q = q_ref[...].reshape(bq, d).astype(jnp.float32)
            kk = k_ref[...].reshape(cols_per_prog, d).astype(jnp.float32)
            vv = v_ref[...].reshape(cols_per_prog, d).astype(jnp.float32)
            do = do_ref[...].reshape(bq, d).astype(jnp.float32)
            m = m_ref[...].reshape(bq)
            l = l_ref[...].reshape(bq)
            l = jnp.where(l == 0.0, 1.0, l)
            dl = dl_ref[...].reshape(bq)
            sij = jnp.dot(q, kk.T, preferred_element_type=jnp.float32) * scale
            p = jnp.exp(jnp.where(mask, sij - m[:, None], NEG)) / l[:, None]
            dv_s[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
            dp = jnp.dot(do, vv.T, preferred_element_type=jnp.float32)
            ds = p * (dp - dl[:, None])
            dk_s[...] += jnp.dot(ds.T, q,
                                 preferred_element_type=jnp.float32) * scale

        @pl.when(qi == nq - 1)
        def _fin():
            dk_ref[...] = dk_s[...].reshape(dk_ref.shape)
            dv_ref[...] = dv_s[...].reshape(dv_ref.shape)

    if gapped:
        kv_spec = pl.BlockSpec((1, 1, c, bkv, d),
                               lambda bb, hh, ki, qi: (bb, hh // group, 0, ki, 0))
        kv_view = lambda x: x.reshape(b, hkv, c, skg, d)
        dkv_spec = pl.BlockSpec((1, 1, c, bkv, d),
                                lambda bb, hh, ki, qi: (bb, hh, 0, ki, 0))
        dkv_shape = (b, h, c, skg, d)
    else:
        kv_spec = pl.BlockSpec((1, 1, c * bkv, d),
                               lambda bb, hh, ki, qi: (bb, hh // group, ki, 0))
        kv_view = lambda x: x
        dkv_spec = pl.BlockSpec((1, 1, c * bkv, d),
                                lambda bb, hh, ki, qi: (bb, hh, ki, 0))
        dkv_shape = (b, h, sk, d)

    q_spec = pl.BlockSpec((1, 1, bq, d), lambda bb, hh, ki, qi: (bb, hh, qi, 0))
    r_spec = pl.BlockSpec((1, 1, bq), lambda bb, hh, ki, qi: (bb, hh, qi))

    call = pl.pallas_call(
        body,
        grid=(b, h, nkv, nq),
        in_specs=[
            q_spec,
            kv_spec,
            kv_spec,
            q_spec,                                    # do
            r_spec, r_spec, r_spec,                    # m, l, delta
        ],
        out_specs=(dkv_spec, dkv_spec),
        out_shape=(jax.ShapeDtypeStruct(dkv_shape, jnp.float32),
                   jax.ShapeDtypeStruct(dkv_shape, jnp.float32)),
        scratch_shapes=[
            pltpu.VMEM((cols_per_prog, d), jnp.float32),
            pltpu.VMEM((cols_per_prog, d), jnp.float32),
        ],
        interpret=interpret,
    )

    def run(q, k, v, do, m, l, delta):
        dkh, dvh = call(q, kv_view(k), kv_view(v), do, m, l, delta)
        dkh = dkh.reshape(b, h, sk, d)
        dvh = dvh.reshape(b, h, sk, d)
        # GQA: reduce per-q-head partials onto the shared kv heads
        dk = dkh.reshape(b, hkv, group, sk, d).sum(axis=2)
        dv = dvh.reshape(b, hkv, group, sk, d).sum(axis=2)
        return dk, dv

    return run
