"""Flash attention with q-row-block coarsening (GQA / causal / local window).

The q-row axis is the coarsenable "work-item" axis:

  consecutive : one program owns C adjacent q blocks -> one (C*bq, D) DMA and
                — because the fused rows are adjacent — the causal triangle
                skip still prunes ~half the kv blocks.
  gapped      : one program owns C q blocks strided S/C apart.  The fused rows
                span the whole sequence, so the causal skip degenerates to the
                worst row — the TPU analog of the paper's divergence penalty
                (work-items with different control paths fused together).

KV tiles are fetched once per fused program (paper §III.B: fewer total memory
accesses) — consecutive coarsening divides kv traffic by C up to the causal
skew.  GQA is expressed in the kv index_map (heads share kv tiles).
"""
from __future__ import annotations

import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.coarsening import CoarseningConfig, KIND_GAPPED

NEG = -1e30


def make_kernel(b: int, h: int, hkv: int, s: int, d: int,
                cfg: CoarseningConfig, *, bq: int = 128, bkv: int = 128,
                causal: bool = True, window: int | None = None,
                scale: float | None = None,
                interpret: bool = True) -> Callable:
    c = cfg.degree
    if s % (c * bq) or s % bkv:
        raise ValueError("seq not tileable")
    gapped = cfg.kind == KIND_GAPPED
    group = h // hkv
    nq, nk = s // (c * bq), s // bkv
    sg = s // c                       # gapped slice length
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    rows_per_prog = c * bq

    def row_ids(qi):
        j = jax.lax.broadcasted_iota(jnp.int32, (c, bq), 1)
        k = jax.lax.broadcasted_iota(jnp.int32, (c, bq), 0)
        if gapped:
            return (k * sg + qi * bq + j).reshape(rows_per_prog)
        return (qi * rows_per_prog + k * bq + j).reshape(rows_per_prog)

    def body(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        qi, ki = pl.program_id(2), pl.program_id(3)

        @pl.when(ki == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        rows = row_ids(qi)                             # (R,)
        cols = ki * bkv + jnp.arange(bkv, dtype=jnp.int32)
        mask = jnp.ones((rows_per_prog, bkv), dtype=bool)
        if causal:
            mask &= cols[None, :] <= rows[:, None]
        if window is not None:
            mask &= cols[None, :] > rows[:, None] - window

        # causal block skip: only when *all* fused rows precede this kv block
        min_row = rows[0] if not gapped else qi * bq   # smallest fused row id
        live = jnp.bool_(True)
        if causal:
            live = ki * bkv <= (min_row + rows_per_prog - 1 if not gapped
                                else (c - 1) * sg + qi * bq + bq - 1)
        if window is not None:
            # skip kv blocks entirely left of every fused row's window
            live &= (ki + 1) * bkv > (min_row - (window or 0) + 1)

        @pl.when(live)
        def _compute():
            q = q_ref[...].reshape(rows_per_prog, d)
            kk = k_ref[...].reshape(bkv, d)
            vv = v_ref[...].reshape(bkv, d)
            sij = jnp.dot(q, kk.T, preferred_element_type=jnp.float32) * scale
            sij = jnp.where(mask, sij, NEG)
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, sij.max(axis=1))
            p = jnp.exp(sij - m_new[:, None]) * mask
            alpha = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
            acc_ref[...] = (acc_ref[...] * alpha[:, None]
                            + jnp.dot(p, vv, preferred_element_type=jnp.float32))
            m_ref[...] = m_new

        @pl.when(ki == nk - 1)
        def _fin():
            l = l_ref[...]
            l = jnp.where(l == 0.0, 1.0, l)
            o_ref[...] = (acc_ref[...] / l[:, None]).reshape(o_ref.shape)

    kv_index = lambda bb, hh, qi, ki: (bb, hh // group, ki, 0)
    if gapped:
        q_spec = pl.BlockSpec((1, 1, c, bq, d), lambda bb, hh, qi, ki: (bb, hh, 0, qi, 0))
        q_view = lambda q: q.reshape(b, h, c, sg, d)
        o_shape = (b, h, c, sg, d)
        o_unview = lambda o: o.reshape(b, h, s, d)
    else:
        q_spec = pl.BlockSpec((1, 1, c * bq, d), lambda bb, hh, qi, ki: (bb, hh, qi, 0))
        q_view = lambda q: q
        o_shape = (b, h, s, d)
        o_unview = lambda o: o

    call = pl.pallas_call(
        body,
        grid=(b, h, nq, nk),
        in_specs=[
            q_spec,
            pl.BlockSpec((1, 1, bkv, d), kv_index),
            pl.BlockSpec((1, 1, bkv, d), kv_index),
        ],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(o_shape, jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((rows_per_prog,), jnp.float32),
            pltpu.VMEM((rows_per_prog,), jnp.float32),
            pltpu.VMEM((rows_per_prog, d), jnp.float32),
        ],
        interpret=interpret,
    )

    def run(q, k, v):
        return o_unview(call(q_view(q), k, v))

    return run
