"""Pure-jnp oracles for every Pallas kernel family (no pallas, no tiling).

Each oracle computes the kernel semantics in one untiled shot; tests assert
that every (kind, degree, replication, vector_width) Pallas variant matches
its oracle, which is exactly the paper's correctness invariant: coarsening
redistributes work but must not change results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# --- ew_stream --------------------------------------------------------------

def ew_stream(inputs, *, ai: int, variant: str = "base") -> jax.Array:
    """Oracle for kernels.ew_stream: same math, whole array, no tiling."""
    from repro.kernels.ew_stream import _variant_compute

    n = inputs[0].shape[0]
    n_arith = ai * (len(inputs) + 1)
    regs = [x.reshape(1, n) for x in inputs]
    gids = jnp.arange(n, dtype=jnp.int32).reshape(1, n)
    return _variant_compute(variant, regs, gids, n_arith).reshape(n)


# --- gather_stream ----------------------------------------------------------

def gather_stream(tables, idx, *, ai: int) -> jax.Array:
    """Oracle for the indirect-indexed kernel: out[i] = chain(t[idx[i]]...)."""
    from repro.kernels.ew_stream import _arith_chain

    n = idx.shape[0]
    regs = [t[idx] for t in tables]
    n_arith = ai * (len(tables) + 1)
    return _arith_chain(regs, n_arith)


# --- matmul -----------------------------------------------------------------

def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


# --- stencil (5-point, Hotspot analog) --------------------------------------

def stencil5(x: jax.Array, coef: tuple = (0.5, 0.125, 0.125, 0.125, 0.125)) -> jax.Array:
    c0, cn, cs, cw, ce = coef
    xp = jnp.pad(x, 1, mode="edge")
    return (c0 * x + cn * xp[:-2, 1:-1] + cs * xp[2:, 1:-1]
            + cw * xp[1:-1, :-2] + ce * xp[1:-1, 2:])


# --- chunked row scan (Pathfinder DP analog) --------------------------------

def dp_scan(cost: jax.Array) -> jax.Array:
    """Pathfinder dynamic programming: row t distance =
    cost[t] + min(shift-left, center, shift-right) of row t-1."""
    def step(prev, row):
        left = jnp.concatenate([prev[:1], prev[:-1]])
        right = jnp.concatenate([prev[1:], prev[-1:]])
        cur = row + jnp.minimum(prev, jnp.minimum(left, right))
        return cur, cur
    init = cost[0]
    _, rows = jax.lax.scan(step, init, cost[1:])
    return jnp.concatenate([init[None], rows], axis=0)


# --- flash attention ---------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: int | None = None, scale: float | None = None) -> jax.Array:
    """(B,H,Sq,D) x (B,Hkv,Sk,D) GQA attention oracle (Sk may differ from Sq
    for the non-causal cross-attention case)."""
    b, h, s, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(sk)[None, :]
    mask = jnp.ones((s, sk), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


# --- decode attention ---------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int | None = None,
                     scale: float | None = None) -> jax.Array:
    """Dense full-length decode-attention oracle (same math as the XLA model
    path in models/layers.py).  q: (B,1,H,D); caches: (B,S,Hkv,D);
    pos: (B,) int32."""
    b, _, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg,
                        k_cache.astype(jnp.float32)) * scale
    kpos = jnp.arange(s, dtype=jnp.int32)
    mask = kpos[None, :] <= pos[:, None]
    if window is not None:
        mask = mask & (kpos[None, :] > pos[:, None] - window)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# --- verify attention (speculative decode: short q vs a long cache) ----------

def verify_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos0: jax.Array, *, window: int | None = None,
                     scale: float | None = None) -> jax.Array:
    """Batched-verify oracle: T drafted rows against the full cache.

    q: (B,T,H,D); caches: (B,S,Hkv,D); pos0: (B,) int32 — row t of slot b
    sits at cache position ``pos0[b] + t`` and attends to every cache row at
    or before it.  T==1 is exactly `decode_attention`; the math mirrors it
    row for row (same einsum contraction, f32 accumulation, dense softmax)
    so a verify pass over rows the decode path would have produced one at a
    time is bitwise-identical to it on the ref backend."""
    b, t, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, t, hkv, g, d).astype(jnp.float32)
    logits = jnp.einsum("bthgd,bshd->bthgs", qg,
                        k_cache.astype(jnp.float32)) * scale
    kpos = jnp.arange(s, dtype=jnp.int32)
    rows = pos0[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]   # (B,T)
    mask = kpos[None, None, :] <= rows[:, :, None]                   # (B,T,S)
    if window is not None:
        mask = mask & (kpos[None, None, :] > rows[:, :, None] - window)
    logits = jnp.where(mask[:, :, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bthgs,bshd->bthgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, t, h, d).astype(q.dtype)


# --- grouped-expert MoE FFN ---------------------------------------------------

def moe_ffn(xe: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
            wts: jax.Array) -> jax.Array:
    """Oracle for the grouped-expert fused FFN: per-expert
    ``(silu(xe@w1) * (xe@w3)) @ w2`` over the padded dispatch buffer,
    scaled by the per-token combine weights.

    xe: (E,C,d); w1,w3: (E,d,F); w2: (E,F,d); wts: (E,C) -> (E,C,d) f32.
    """
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w1,
                               preferred_element_type=jnp.float32))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w3,
                       preferred_element_type=jnp.float32)
    ye = jnp.einsum("ecf,efd->ecd", h.astype(xe.dtype), w2,
                    preferred_element_type=jnp.float32)
    return ye * wts[..., None].astype(jnp.float32)


# --- Mamba-2 SSD --------------------------------------------------------------

def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array,
        chunk: int = 64) -> jax.Array:
    """Naive (quadratic-in-S, exact) SSD oracle.

    x:(b,s,h,p) dt:(b,s,h) A:(h,) B:(b,s,g,n) C:(b,s,g,n); g divides h.
    y[t] = sum_{u<=t} C[t]·B[u] * exp(sum_{u<v<=t} dA[v]) * dt[u] * x[u]
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)          # (b,s,h,n)
    Ch = jnp.repeat(C, rep, axis=2)
    dA = dt * A[None, None, :]               # (b,s,h) log-decay per step
    cum = jnp.cumsum(dA, axis=1)             # (b,s,h)
    # L[t,u] = exp(cum[t]-cum[u]) for u<=t else 0
    diff = cum[:, :, None, :] - cum[:, None, :, :]      # (b,t,u,h)
    tids = jnp.arange(s)
    causal = (tids[None, :, None, None] >= tids[None, None, :, None])
    # double-where: clamp the non-causal exponent BEFORE exp so its (masked)
    # gradient can't produce inf * 0 = nan
    diff = jnp.where(causal, diff, 0.0)
    L = jnp.where(causal, jnp.exp(diff), 0.0)
    CB = jnp.einsum("bthn,buhn->btuh", Ch, Bh)          # (b,t,u,h)
    W = CB * L * dt[:, None, :, :]                      # weight for x[u]
    return jnp.einsum("btuh,buhp->bthp", W, x)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int = 64,
                state0: jax.Array | None = None,
                return_state: bool = False):
    """Linear-time chunked SSD (the model/XLA path; same math as `ssd`).

    Layouts as `ssd`: x:(b,s,h,p) dt:(b,s,h) A:(h,) B,C:(b,s,g,n).
    lax.scan over chunks carrying the (h,n,p) state — O(S*c) not O(S^2).
    state0: optional (b,h,n,p) initial state (chunked prefill continuation);
    return_state=True additionally returns the final state.
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)

    def resh(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs, dts = resh(x), resh(dt)
    Bh, Ch = resh(B), resh(C)
    tri = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))

    def step(state, inp):
        xc, dtc, bc, cc = inp                      # (b,c,h,p) (b,c,h) (b,c,g,n)
        dA = dtc * A[None, None, :]                # (b,c,h)
        cum = jnp.cumsum(dA, axis=1)
        diff = cum[:, :, None, :] - cum[:, None, :, :]      # (b,t,u,h)
        diff = jnp.where(tri[None, :, :, None], diff, 0.0)
        L = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        bh = jnp.repeat(bc, rep, axis=2)           # (b,c,h,n)
        ch = jnp.repeat(cc, rep, axis=2)
        cb = jnp.einsum("bthn,buhn->btuh", ch, bh)
        w = cb * L * dtc[:, None, :, :]
        y = jnp.einsum("btuh,buhp->bthp", w, xc)
        y = y + jnp.einsum("bthn,bhnp->bthp", ch * jnp.exp(cum)[..., None],
                           state)
        total = cum[:, -1]                         # (b,h)
        w_in = dtc * jnp.exp(total[:, None] - cum) # (b,c,h)
        upd = jnp.einsum("bthn,bthp->bhnp", bh * w_in[..., None], xc)
        state = jnp.exp(total)[..., None, None] * state + upd
        return state, y

    if state0 is None:
        state0 = jnp.zeros((b, h, n, p), x.dtype)
    state, ys = jax.lax.scan(step, state0.astype(x.dtype), (xs, dts, Bh, Ch))
    y = ys.swapaxes(0, 1).reshape(b, s, h, p)
    return (y, state) if return_state else y


# --- RG-LRU (RecurrentGemma) --------------------------------------------------

RGLRU_C = 8.0


def rglru(x: jax.Array, r: jax.Array, i: jax.Array,
          a_param: jax.Array) -> jax.Array:
    """RG-LRU oracle: h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t * x_t).

    x,r,i: (b,s,d) (r,i are pre-sigmoid gates), a_param: (d,) pre-softplus.
    a_t = exp(-c * softplus(a_param) * sigmoid(r_t)).
    """
    hs, _ = rglru_with_state(x, r, i, a_param, None)
    return hs


def rglru_with_state(x: jax.Array, r: jax.Array, i: jax.Array,
                     a_param: jax.Array, h0: jax.Array | None):
    """`rglru` with an explicit initial state — the chunked-prefill form.

    h0: (b,d) f32 hidden state (None -> zeros).  Returns (hs, h_final) so a
    later chunk (or the per-token decode step) can continue the recurrence.
    """
    rg = jax.nn.sigmoid(r)
    ig = jax.nn.sigmoid(i)
    log_a = -RGLRU_C * jax.nn.softplus(a_param)[None, None, :] * rg  # (b,s,d)
    a = jnp.exp(log_a)
    gated = ig * x
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))

    def step(h, inp):
        a_t, gx_t, m_t = inp
        h = a_t * h + m_t * gx_t
        return h, h
    b, s, d = x.shape
    init = jnp.zeros((b, d), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    xs = (a.swapaxes(0, 1), gated.swapaxes(0, 1), mult.swapaxes(0, 1))
    h_final, hs = jax.lax.scan(step, init, xs)
    return hs.swapaxes(0, 1), h_final
