"""jit'd public wrappers for every kernel family.

Each op takes a CoarseningConfig and dispatches to the Pallas kernel
(interpret=True on CPU; on TPU the same pallas_call lowers via Mosaic) or, for
``backend='ref'``, to the pure-jnp oracle — the path used by model training
on CPU and by the XLA dry-run lowering.

The ``cfg`` argument also accepts strings: a spec label ("con4+pipe2") is
parsed, and ``"auto"`` resolves through the repro.tune autotuner — modeled
ranking against the persisted tuning cache, so the second call with the same
geometry never re-searches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.coarsening import CoarseningConfig
from repro.kernels import (
    ew_stream as _ew,
    gather_stream as _gather,
    matmul as _matmul,
    stencil as _stencil,
    chunk_scan as _scan,
    flash_attention as _flash,
    sparse_attention as _sparse,
    decode_attention as _decode,
    moe_ffn as _moe_ffn,
    ssd as _ssd,
    rglru as _rglru,
    ref,
)

BASE = CoarseningConfig()


def _interpret() -> bool:
    """Pallas lowering mode for the jit'd ops: interpret on CPU hosts, the
    real Mosaic lowering on accelerator backends — which is what lets
    tune.wall_measurer time COMPILED kernels (measured provenance) on a TPU
    host while keeping interpret-mode timing as the CPU fallback."""
    return jax.default_backend() == "cpu"


@functools.lru_cache(maxsize=1024)
def _auto_cfg(cache_path, family, shape, dtype, backend, params):
    from repro.tune import KernelSpec, autotune, default_cache
    spec = KernelSpec(family=family, shape=shape, dtype=dtype,
                      backend=backend, params=params)
    return autotune(spec, cache=default_cache())


def resolve_cfg(cfg, family: str, shape, *, dtype="float32",
                backend: str = "pallas", **params) -> CoarseningConfig:
    """Normalise an op's cfg argument: CoarseningConfig passes through,
    "auto" goes through the tuner (cache-backed), any other string is a
    coarsening spec label.

    Callers must pass the REAL array dtype (and, for quantized ops, the
    wbits/kv_bits params): the tuner cache is keyed on it, and bf16 vs f32
    vs quantized instances of one geometry cost — and can win — differently.
    The "float32" default only serves dtype-less specs."""
    if isinstance(cfg, CoarseningConfig):
        return cfg
    if cfg == "auto":
        if backend == "ref":              # oracle path: nothing to tune
            return BASE
        # keyed on the cache path so repointing REPRO_TUNE_CACHE is honoured
        from repro.tune import default_cache_path
        return _auto_cfg(default_cache_path(), family,
                         tuple(int(s) for s in shape), str(dtype),
                         backend, tuple(sorted(params.items())))
    return CoarseningConfig.parse(cfg)


@functools.lru_cache(maxsize=256)
def _ew_fn(n, cfg, n_loads, ai, variant, block):
    return jax.jit(_ew.make_kernel(n, cfg, n_loads=n_loads, ai=ai,
                                   variant=variant, block=block,
                                   interpret=_interpret()))


def ew_stream(inputs, cfg: CoarseningConfig | str = BASE, *, ai: int = 6,
              variant: str = "base", block: int = 1024):
    n = inputs[0].shape[0]
    cfg = resolve_cfg(cfg, "ew_stream", (n,), dtype=inputs[0].dtype.name,
                      n_loads=len(inputs), ai=ai, variant=variant,
                      block=block)
    fn = _ew_fn(n, cfg, len(inputs), ai, variant, block)
    return fn(*inputs)


@functools.lru_cache(maxsize=256)
def _gather_fn(n, table, cfg, n_loads, ai, block):
    return jax.jit(_gather.make_kernel(n, table, cfg, n_loads=n_loads, ai=ai,
                                       block=block, interpret=_interpret()))


def gather_stream(idx, tables, cfg: CoarseningConfig | str = BASE, *,
                  ai: int = 6, block: int = 1024):
    cfg = resolve_cfg(cfg, "gather_stream",
                      (idx.shape[0], tables[0].shape[0]),
                      dtype=tables[0].dtype.name,
                      n_loads=len(tables), ai=ai, block=block)
    fn = _gather_fn(idx.shape[0], tables[0].shape[0], cfg, len(tables), ai, block)
    return fn(idx, *tables)


@functools.lru_cache(maxsize=256)
def _matmul_fn(m, n, k, cfg, bm, bn, bk, backend):
    if backend == "ref":
        return jax.jit(ref.matmul)
    return jax.jit(_matmul.make_kernel(m, n, k, cfg, bm=bm, bn=bn, bk=bk,
                                       interpret=_interpret()))


def matmul(a, b, cfg: CoarseningConfig | str = BASE, *, bm: int = 128,
           bn: int = 128, bk: int = 256, backend: str = "pallas"):
    m, k = a.shape
    n = b.shape[1]
    cfg = resolve_cfg(cfg, "matmul", (m, n, k), dtype=a.dtype.name,
                      backend=backend, bm=bm, bn=bn, bk=bk)
    return _matmul_fn(m, n, k, cfg, bm, bn, bk, backend)(a, b)


@functools.lru_cache(maxsize=256)
def _quant_matmul_fn(m, n, k, cfg, bits, group, bm, bn, bk, backend):
    if backend == "ref":
        return jax.jit(ref.matmul)
    return jax.jit(_matmul.make_qkernel(m, n, k, cfg, bits=bits, group=group,
                                        bm=bm, bn=bn, bk=bk,
                                        interpret=_interpret()))


def quant_matmul(a, qw, cfg: CoarseningConfig | str = BASE, *, bm: int = 128,
                 bn: int = 128, bk: int = 256, backend: str = "pallas"):
    """Dequant-fused matmul against a QTensor weight: ``a (m,k) @ qw (k,n)``
    with the packed weight pane DMA'd and dequantized in VMEM once per
    program.  The tuner spec carries ``wbits``/``group``, so quantized and
    dense instances of the same geometry occupy DIFFERENT cache keys and can
    pick different coarsening degrees.  backend='ref' is the dense-dequant
    oracle."""
    m, k = a.shape
    n = qw.shape[-1]
    if qw.shape != (k, n):
        raise ValueError(f"quant_matmul: a {a.shape} vs qw {qw.shape}")
    cfg = resolve_cfg(cfg, "matmul", (m, n, k), dtype=a.dtype.name,
                      backend=backend, bm=bm, bn=bn, bk=bk,
                      wbits=qw.bits, group=qw.group)
    if backend == "ref":
        from repro.quant.qtypes import dequantize
        return _quant_matmul_fn(m, n, k, cfg, qw.bits, qw.group, bm, bn, bk,
                                backend)(a, dequantize(qw))
    return _quant_matmul_fn(m, n, k, cfg, qw.bits, qw.group, bm, bn, bk,
                            backend)(a, qw.q, qw.scale)


@functools.lru_cache(maxsize=256)
def _stencil_fn(rows, cols, cfg, block_rows):
    return jax.jit(_stencil.make_kernel(rows, cols, cfg, block_rows=block_rows,
                                        interpret=_interpret()))


def stencil5(x, cfg: CoarseningConfig | str = BASE, *, block_rows: int = 8):
    cfg = resolve_cfg(cfg, "stencil5", x.shape, dtype=x.dtype.name,
                      block_rows=block_rows)
    return _stencil_fn(x.shape[0], x.shape[1], cfg, block_rows)(x)


@functools.lru_cache(maxsize=256)
def _scan_fn(rows, cols, cfg):
    return jax.jit(_scan.make_kernel(rows, cols, cfg, interpret=_interpret()))


def dp_scan(cost, cfg: CoarseningConfig | str = BASE):
    cfg = resolve_cfg(cfg, "dp_scan", cost.shape, dtype=cost.dtype.name)
    return _scan_fn(cost.shape[0], cost.shape[1], cfg)(cost)


@functools.lru_cache(maxsize=256)
def _flash_vjp_fn(b, h, hkv, sq, sk, d, cfg, bwd_cfg, bq, bkv, causal,
                  window, scale, dtype_name):
    """Custom-VJP flash attention for one geometry: the VJP forward saves
    the (o, m, l) online-softmax residuals; the backward runs the dK/dV
    kernel coarsened on the KV-BLOCK axis (``bwd_cfg``) and the dQ kernel
    coarsened on the q-row axis matching the forward (``cfg``) —
    independent degrees, since the two passes stream different axes.

    Forward-only calls stay pure-forward: the primal runs a residual-free
    kernel (a pallas_call's outputs can't be DCE'd, so emitting m/l there
    would write two dead (B,H,Sq) f32 arrays per call), and ``bwd_cfg``
    may arrive unresolved ("auto") — the flash_attention_bwd family is
    searched and the backward kernels built only when a backward trace
    actually runs."""
    fwd = _flash.make_kernel(b, h, hkv, sq, d, cfg, bq=bq, bkv=bkv,
                             causal=causal, window=window, scale=scale,
                             sk=sk, interpret=_interpret())
    fwd_res = _flash.make_kernel(b, h, hkv, sq, d, cfg, bq=bq, bkv=bkv,
                                 causal=causal, window=window, scale=scale,
                                 sk=sk, return_residuals=True,
                                 interpret=_interpret())

    @jax.custom_vjp
    def attn(q, k, v):
        return fwd(q, k, v)

    def attn_fwd(q, k, v):
        from jax.ad_checkpoint import checkpoint_name
        o, m, l = fwd_res(q, k, v)
        # name ALL the kernel's outputs, not just o: the backward consumes
        # (o, m, l), so a remat policy that saved o alone would still
        # replay the whole pallas_call to rebuild m/l
        o = checkpoint_name(o, "flash_attn_out")
        m = checkpoint_name(m, "flash_attn_out")
        l = checkpoint_name(l, "flash_attn_out")
        return o, (q, k, v, o, m, l)

    def attn_bwd(res, g):
        rbwd = resolve_cfg(bwd_cfg, "flash_attention_bwd",
                           (b, h, hkv, sq, sk, d), dtype=dtype_name,
                           backend="pallas", bq=bq, bkv=bkv,
                           causal=bool(causal))
        bwd_dq = _flash.make_bwd_dq_kernel(b, h, hkv, sq, d, cfg, bq=bq,
                                           bkv=bkv, causal=causal,
                                           window=window, scale=scale, sk=sk,
                                           interpret=_interpret())
        bwd_dkv = _flash.make_bwd_dkv_kernel(b, h, hkv, sq, d, rbwd, bq=bq,
                                             bkv=bkv, causal=causal,
                                             window=window, scale=scale,
                                             sk=sk, interpret=_interpret())
        q, k, v, o, m, l = res
        g = g.astype(jnp.float32)
        delta = jnp.sum(g * o, axis=-1)                # (B,H,Sq) f32
        dq = bwd_dq(q, k, v, g, m, l, delta)
        dk, dv = bwd_dkv(q, k, v, g, m, l, delta)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))

    attn.defvjp(attn_fwd, attn_bwd)
    return jax.jit(attn)


@functools.lru_cache(maxsize=256)
def _flash_ref_fn(causal, window, scale):
    return jax.jit(functools.partial(ref.attention, causal=causal,
                                     window=window, scale=scale))


def flash_attention(q, k, v, cfg: CoarseningConfig | str = BASE, *,
                    bwd_cfg: CoarseningConfig | str | None = None,
                    bq: int = 128, bkv: int = 128, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    backend: str = "pallas"):
    """Differentiable coarsened flash attention.  q: (B,H,Sq,D);
    k, v: (B,Hkv,Sk,D) -> (B,H,Sq,D) f32.

    ``cfg`` coarsens the forward (and the dQ backward pass) on the q-row
    axis; ``bwd_cfg`` (default "auto" through the ``flash_attention_bwd``
    tuner family) coarsens the dK/dV backward pass on the kv-block axis.
    ``scale`` overrides the default 1/sqrt(D) logit scaling.
    """
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if backend == "ref":
        return _flash_ref_fn(causal, window, scale)(q, k, v)
    cfg = resolve_cfg(cfg, "flash_attention", (b, h, hkv, sq, sk, d),
                      dtype=q.dtype.name, backend=backend, bq=bq, bkv=bkv,
                      causal=bool(causal))
    if bwd_cfg is None:
        bwd_cfg = "auto"
    # bwd_cfg stays unresolved here: forward-only callers must not pay a
    # flash_attention_bwd search (or a cache write) they never use — the
    # VJP rule resolves it when a backward trace happens
    if isinstance(bwd_cfg, str):
        bwd_cfg = bwd_cfg if bwd_cfg == "auto" \
            else CoarseningConfig.parse(bwd_cfg)
    return _flash_vjp_fn(b, h, hkv, sq, sk, d, cfg, bwd_cfg, bq, bkv,
                         causal, window, scale, q.dtype.name)(q, k, v)


@functools.lru_cache(maxsize=256)
def _flash_sparse_fn(b, h, hkv, sq, sk, d, cfg, bwd_cfg, bq, bkv, causal,
                     window, global_stride, scale, dtype_name):
    """Custom-VJP block-sparse flash attention for one geometry + pattern.

    The per-q-block live-KV index is a pure function of the geometry, so it
    is built host-side here and closed over as a jit constant — callers
    never thread it.  The forward runs the sparse kernel (coarsened over
    the live-slot axis by ``cfg``); the backward reuses the DENSE-mask
    backward kernels: the sparse forward's (m, l) residuals are identical
    to the dense-mask forward's (the index covers the pattern mask
    exactly; verified in tests), so `make_bwd_dq_kernel` /
    `make_bwd_dkv_kernel` consume them unchanged.  For global-stride
    patterns the dense backward kernels can't express the strided columns,
    so the backward differentiates the jnp oracle instead — strided
    TRAINING pays dense cost (documented fallback); strided prefill still
    takes the sparse kernel.
    """
    # kept as a host numpy constant: converting to a device array here
    # would bind it to whatever trace is active at build time (this
    # factory is lru-cached, so that tracer would leak into later traces);
    # as numpy it is lifted per-trace like any closure constant
    idx = _sparse.build_block_index(sq, sk, bq, bkv, causal=causal,
                                    window=window,
                                    global_stride=global_stride)
    max_live = int(idx.shape[1])
    mk = functools.partial(_sparse.make_kernel, b, h, hkv, sq, d, cfg,
                           bq=bq, bkv=bkv, max_live=max_live, causal=causal,
                           window=window, global_stride=global_stride,
                           scale=scale, sk=sk, interpret=_interpret())
    fwd = mk()
    fwd_res = mk(return_residuals=True)

    @jax.custom_vjp
    def attn(q, k, v):
        return fwd(q, k, v, idx)

    def attn_fwd(q, k, v):
        from jax.ad_checkpoint import checkpoint_name
        o, m, l = fwd_res(q, k, v, idx)
        o = checkpoint_name(o, "flash_attn_out")
        m = checkpoint_name(m, "flash_attn_out")
        l = checkpoint_name(l, "flash_attn_out")
        return o, (q, k, v, o, m, l)

    def attn_bwd(res, g):
        q, k, v, o, m, l = res
        g = g.astype(jnp.float32)
        if global_stride:
            primal = functools.partial(
                _sparse.ref_sparse_attention, causal=causal, window=window,
                global_stride=global_stride, scale=scale)
            _, vjp = jax.vjp(primal, q, k, v)
            dq, dk, dv = vjp(g)
            return (dq.astype(q.dtype), dk.astype(k.dtype),
                    dv.astype(v.dtype))
        rbwd = resolve_cfg(bwd_cfg, "flash_attention_bwd",
                           (b, h, hkv, sq, sk, d), dtype=dtype_name,
                           backend="pallas", bq=bq, bkv=bkv,
                           causal=bool(causal))
        # dQ at BASE: cfg's degree is a live-SLOT degree, not a q-row one
        bwd_dq = _flash.make_bwd_dq_kernel(b, h, hkv, sq, d, BASE, bq=bq,
                                           bkv=bkv, causal=causal,
                                           window=window, scale=scale, sk=sk,
                                           interpret=_interpret())
        bwd_dkv = _flash.make_bwd_dkv_kernel(b, h, hkv, sq, d, rbwd, bq=bq,
                                             bkv=bkv, causal=causal,
                                             window=window, scale=scale,
                                             sk=sk, interpret=_interpret())
        delta = jnp.sum(g * o, axis=-1)                # (B,H,Sq) f32
        dq = bwd_dq(q, k, v, g, m, l, delta)
        dk, dv = bwd_dkv(q, k, v, g, m, l, delta)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))

    attn.defvjp(attn_fwd, attn_bwd)
    return jax.jit(attn)


@functools.lru_cache(maxsize=256)
def _sparse_ref_fn(causal, window, global_stride, scale):
    return jax.jit(functools.partial(_sparse.ref_sparse_attention,
                                     causal=causal, window=window,
                                     global_stride=global_stride,
                                     scale=scale))


def flash_attention_sparse(q, k, v, cfg: CoarseningConfig | str = BASE, *,
                           bwd_cfg: CoarseningConfig | str | None = None,
                           bq: int = 128, bkv: int = 128, causal: bool = True,
                           window: int | None = None,
                           global_stride: int | None = None,
                           scale: float | None = None,
                           backend: str = "pallas"):
    """Block-sparse flash attention over a per-q-block live-KV index.
    q: (B,H,Sq,D); k, v: (B,Hkv,Sk,D) -> (B,H,Sq,D) f32.

    Each q-block program walks only the kv blocks with live (q, k) pairs
    under the pattern {``causal``, sliding ``window``, LongFormer-style
    ``global_stride`` columns}; ``cfg`` coarsens over the LIVE-SLOT axis
    (consecutive = adjacent index slots, gapped = slots strided
    max_live/degree apart).  The ``flash_attention_sparse`` tuner family
    keys on the pattern (window/gstride/max_live join the spec), so a 32k
    window=512 instance occupies a different cache row — and picks a
    different winning degree — than the dense family at the same shape.
    backend='ref' is the dense-mask jnp oracle (the parity target)."""
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if backend == "ref":
        return _sparse_ref_fn(causal, window, global_stride, scale)(q, k, v)
    idx = _sparse.build_block_index(sq, sk, bq, bkv, causal=causal,
                                    window=window,
                                    global_stride=global_stride)
    max_live, n_live = int(idx.shape[1]), int((idx >= 0).sum())
    cfg = resolve_cfg(cfg, "flash_attention_sparse", (b, h, hkv, sq, sk, d),
                      dtype=q.dtype.name, backend=backend, bq=bq, bkv=bkv,
                      causal=bool(causal), window=window or 0,
                      gstride=global_stride or 0, max_live=max_live,
                      n_live=n_live)
    if bwd_cfg is None:
        bwd_cfg = "auto"
    # unresolved "auto" rides into the VJP rule exactly as in
    # flash_attention: forward-only callers never pay a bwd-family search
    if isinstance(bwd_cfg, str):
        bwd_cfg = bwd_cfg if bwd_cfg == "auto" \
            else CoarseningConfig.parse(bwd_cfg)
    return _flash_sparse_fn(b, h, hkv, sq, sk, d, cfg, bwd_cfg, bq, bkv,
                            causal, window, global_stride, scale,
                            q.dtype.name)(q, k, v)


@functools.lru_cache(maxsize=256)
def _decode_fn(b, h, hkv, s, d, cfg, bkv, window, scale, backend,
               kv_bits=None):
    if backend == "ref":
        return jax.jit(functools.partial(ref.decode_attention, window=window,
                                         scale=scale))
    return jax.jit(_decode.make_kernel(b, h, hkv, s, d, cfg, bkv=bkv,
                                       window=window, scale=scale,
                                       kv_bits=kv_bits,
                                       interpret=_interpret()))


def decode_attention(q, k_cache, v_cache, pos, cfg: CoarseningConfig | str = BASE,
                     *, bkv: int = 128, window: int | None = None,
                     scale: float | None = None, backend: str = "pallas",
                     k_scale=None, v_scale=None):
    """Split-KV decode attention.  q: (B,1,H,D); caches: (B,S,Hkv,D);
    pos: (B,) int32 -> (B,1,H,D).  The coarsening axis is the kv-block
    axis (each program owns cfg.degree kv blocks of bkv rows).

    Passing ``k_scale``/``v_scale`` (B,S,Hkv) selects the int8 KV-cache
    mode: the caches are int8 payloads and the dequant is fused into the
    kernel's VMEM pass (``kv_bits=8`` on the tuner spec — a distinct cache
    key from the bf16 instance of the same geometry)."""
    b, _, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    quant = k_scale is not None
    kv_bits = 8 if quant else None
    params = dict(bkv=bkv, window=window or 0)
    if quant:
        params["kv_bits"] = 8
    cfg = resolve_cfg(cfg, "decode_attention", (b, h, hkv, s, d),
                      dtype=k_cache.dtype.name, backend=backend, **params)
    if backend == "ref" and quant:
        from repro.quant.qtypes import dequantize_kv
        k_cache = dequantize_kv(k_cache, k_scale)
        v_cache = dequantize_kv(v_cache, v_scale)
        quant = False
    fn = _decode_fn(b, h, hkv, s, d, cfg, bkv, window, scale, backend,
                    kv_bits if backend != "ref" else None)
    if quant:
        return fn(q, k_cache, v_cache, k_scale, v_scale, pos)
    return fn(q, k_cache, v_cache, pos)


@functools.lru_cache(maxsize=256)
def _paged_decode_fn(b, h, hkv, n_pages, npp, d, cfg, page_size, window,
                     scale, backend, kv_bits=None):
    if backend == "ref":
        def run(q, k_pool, v_pool, bt, pos):
            # gather-to-contiguous oracle: resolve the block table on the
            # host-visible (XLA) side, then dense full-length attention
            k = k_pool[bt].reshape(b, npp * page_size, hkv, d)
            v = v_pool[bt].reshape(b, npp * page_size, hkv, d)
            return ref.decode_attention(q, k, v, pos, window=window,
                                        scale=scale)
        return jax.jit(run)
    return jax.jit(_decode.make_paged_kernel(b, h, hkv, n_pages, npp, d, cfg,
                                             page_size=page_size,
                                             window=window, scale=scale,
                                             kv_bits=kv_bits,
                                             interpret=_interpret()))


def paged_decode_attention(q, k_pool, v_pool, block_table, pos,
                           cfg: CoarseningConfig | str = BASE, *,
                           window: int | None = None,
                           scale: float | None = None,
                           backend: str = "pallas",
                           k_scale=None, v_scale=None):
    """Split-KV decode attention through a per-slot block table.

    q: (B,1,H,D); pools: (P, page_size, Hkv, D) shared by all slots;
    block_table: (B, npp) int32 logical->physical page map (NULL-padded);
    pos: (B,) int32 -> (B,1,H,D).  The coarsening axis is the LOGICAL-PAGE
    axis (each program owns cfg.degree pages, resolved through the table —
    the gapped strided-pane DMA with the stride replaced by a lookup).

    ``k_scale``/``v_scale`` (P, page_size, Hkv) select the int8 pool mode
    (kv_bits=8 joins the tuner key, as does the page size)."""
    b, _, h, d = q.shape
    n_pages, page_size, hkv, _ = k_pool.shape
    npp = block_table.shape[1]
    quant = k_scale is not None
    params = dict(page_size=page_size, window=window or 0)
    if quant:
        params["kv_bits"] = 8
    cfg = resolve_cfg(cfg, "decode_attention_paged", (b, h, hkv, npp, d),
                      dtype=k_pool.dtype.name, backend=backend, **params)
    if backend == "ref" and quant:
        from repro.quant.qtypes import dequantize_kv
        k_pool = dequantize_kv(k_pool, k_scale)
        v_pool = dequantize_kv(v_pool, v_scale)
        quant = False
    fn = _paged_decode_fn(b, h, hkv, n_pages, npp, d, cfg, page_size,
                          window, scale, backend,
                          8 if quant and backend != "ref" else None)
    if quant:
        return fn(q, k_pool, v_pool, k_scale, v_scale, block_table, pos)
    return fn(q, k_pool, v_pool, block_table, pos)


@functools.lru_cache(maxsize=256)
def _verify_fn(b, h, hkv, t, n_pages, npp, d, cfg, page_size, window,
               scale, backend, kv_bits=None):
    if backend == "ref":
        def run(q, k_pool, v_pool, bt, pos0):
            # gather-to-contiguous oracle, same shape as the paged-decode
            # ref path: resolve the table on the XLA side, then the dense
            # per-row verify oracle
            k = k_pool[bt].reshape(b, npp * page_size, hkv, d)
            v = v_pool[bt].reshape(b, npp * page_size, hkv, d)
            return ref.verify_attention(q, k, v, pos0, window=window,
                                        scale=scale)
        return jax.jit(run)
    return jax.jit(_decode.make_verify_kernel(b, h, hkv, t, n_pages, npp, d,
                                              cfg, page_size=page_size,
                                              window=window, scale=scale,
                                              kv_bits=kv_bits,
                                              interpret=_interpret()))


def flash_attention_verify(q, k_pool, v_pool, block_table, pos0,
                           cfg: CoarseningConfig | str = BASE, *,
                           window: int | None = None,
                           scale: float | None = None,
                           backend: str = "pallas",
                           k_scale=None, v_scale=None):
    """Batched-verify attention through a per-slot block table (the
    speculative-decode short-q flash geometry).

    q: (B,T,H,D) — T drafted rows per slot, row t at cache position
    ``pos0[b] + t``; pools: (P, page_size, Hkv, D); block_table: (B, npp)
    int32; pos0: (B,) int32 -> (B,T,H,D).  The coarsening axis is the
    LOGICAL-PAGE axis as in `paged_decode_attention`, but the tuner family
    (``flash_attention_verify``) is distinct: scoring T*G rows per fetched
    page moves the memory/compute crossover, so the winning degree differs
    from both the decode and prefill families.

    ``k_scale``/``v_scale`` (P, page_size, Hkv) select the int8 pool mode
    (kv_bits=8 joins the tuner key)."""
    b, t, h, d = q.shape
    n_pages, page_size, hkv, _ = k_pool.shape
    npp = block_table.shape[1]
    quant = k_scale is not None
    params = dict(page_size=page_size, window=window or 0)
    if quant:
        params["kv_bits"] = 8
    cfg = resolve_cfg(cfg, "flash_attention_verify", (b, h, hkv, t, npp, d),
                      dtype=k_pool.dtype.name, backend=backend, **params)
    if backend == "ref" and quant:
        from repro.quant.qtypes import dequantize_kv
        k_pool = dequantize_kv(k_pool, k_scale)
        v_pool = dequantize_kv(v_pool, v_scale)
        quant = False
    fn = _verify_fn(b, h, hkv, t, n_pages, npp, d, cfg, page_size,
                    window, scale, backend,
                    8 if quant and backend != "ref" else None)
    if quant:
        return fn(q, k_pool, v_pool, k_scale, v_scale, block_table, pos0)
    return fn(q, k_pool, v_pool, block_table, pos0)


@functools.lru_cache(maxsize=256)
def _moe_ffn_fn(e, cap, d, f, cfg, backend):
    if backend == "ref":
        return jax.jit(ref.moe_ffn)
    return jax.jit(_moe_ffn.make_kernel(e, cap, d, f, cfg,
                                         interpret=_interpret()))


def moe_ffn(xe, w1, w3, w2, wts, cfg: CoarseningConfig | str = BASE, *,
            backend: str = "pallas"):
    """Grouped-expert fused gate/up/down FFN over the padded MoE dispatch
    buffer.  xe: (E,C,d); w1,w3: (E,d,F); w2: (E,F,d); wts: (E,C) combine
    weights -> (E,C,d) float32.  The coarsening axis is the EXPERT axis
    (each program owns cfg.degree experts; consecutive = one wide weight
    DMA per operand, gapped = degree strided DMAs)."""
    e, cap, d = xe.shape
    f = w1.shape[-1]
    cfg = resolve_cfg(cfg, "moe_ffn", (e, cap, d, f), dtype=xe.dtype.name,
                      backend=backend)
    return _moe_ffn_fn(e, cap, d, f, cfg, backend)(xe, w1, w3, w2, wts)


@functools.lru_cache(maxsize=256)
def _quant_moe_ffn_fn(e, cap, d, f, cfg, bits, group, backend):
    if backend == "ref":
        return jax.jit(ref.moe_ffn)
    return jax.jit(_moe_ffn.make_qkernel(e, cap, d, f, cfg, bits=bits,
                                         group=group,
                                         interpret=_interpret()))


def quant_moe_ffn(xe, qw1, qw3, qw2, wts, cfg: CoarseningConfig | str = BASE,
                  *, backend: str = "pallas"):
    """Grouped-expert fused FFN with QTensor expert weights: the packed
    w1/w3/w2 panes of each program's ``degree`` experts are DMA'd (one wide
    packed pane per operand for consecutive, strided for gapped) and
    dequantized in VMEM once, then the fused gate/up/down chain runs as in
    ``moe_ffn``.  backend='ref' is the dense-dequant einsum oracle."""
    e, cap, d = xe.shape
    f = qw1.shape[-1]
    cfg = resolve_cfg(cfg, "moe_ffn", (e, cap, d, f), dtype=xe.dtype.name,
                      backend=backend, wbits=qw1.bits, group=qw1.group)
    if backend == "ref":
        from repro.quant.qtypes import dequantize
        return _quant_moe_ffn_fn(e, cap, d, f, cfg, qw1.bits, qw1.group,
                                 backend)(xe, dequantize(qw1),
                                          dequantize(qw3), dequantize(qw2),
                                          wts)
    return _quant_moe_ffn_fn(e, cap, d, f, cfg, qw1.bits, qw1.group, backend)(
        xe, qw1.q, qw1.scale, qw3.q, qw3.scale, qw2.q, qw2.scale, wts)


@functools.lru_cache(maxsize=256)
def _ssd_fn(b, h, g, s, p, n, cfg, chunk, backend):
    if backend == "ref":
        def run(x, dt, a, bmat, cmat):
            # kernel layout (B,H,S,P) -> ref layout (B,S,H,P)
            y = ref.ssd(x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), a,
                        bmat.transpose(0, 2, 1, 3), cmat.transpose(0, 2, 1, 3))
            return y.transpose(0, 2, 1, 3)
        return jax.jit(run)
    return jax.jit(_ssd.make_kernel(b, h, g, s, p, n, cfg, chunk=chunk,
                                     interpret=_interpret()))


def ssd(x, dt, a, bmat, cmat, cfg: CoarseningConfig | str = BASE, *,
        chunk: int = 64, backend: str = "pallas"):
    """x:(B,H,S,P) dt:(B,H,S) a:(H,) bmat/cmat:(B,G,S,N)."""
    b, h, s, p = x.shape
    g, n = bmat.shape[1], bmat.shape[3]
    cfg = resolve_cfg(cfg, "ssd", (b, h, g, s, p, n), dtype=x.dtype.name,
                      backend=backend, chunk=chunk)
    return _ssd_fn(b, h, g, s, p, n, cfg, chunk, backend)(x, dt, a, bmat, cmat)


@functools.lru_cache(maxsize=256)
def _embed_fn(n, vocab, d, cfg, block):
    from repro.kernels import embed_gather as _eg
    return jax.jit(_eg.make_kernel(n, vocab, d, cfg, block=block,
                                   interpret=_interpret()))


def embed_gather(ids, table, cfg: CoarseningConfig | str = BASE, *,
                 block: int = 256):
    cfg = resolve_cfg(cfg, "embed_gather",
                      (ids.shape[0], table.shape[0], table.shape[1]),
                      dtype=table.dtype.name, block=block)
    return _embed_fn(ids.shape[0], table.shape[0], table.shape[1], cfg,
                     block)(ids, table)


@functools.lru_cache(maxsize=256)
def _rglru_fn(b, s, d, cfg, block_d, block_t, backend):
    if backend == "ref":
        return jax.jit(ref.rglru)
    return jax.jit(_rglru.make_kernel(b, s, d, cfg, block_d=block_d,
                                      block_t=block_t,
                                      interpret=_interpret()))


def rglru(x, r, i, a_param, cfg: CoarseningConfig | str = BASE, *,
          block_d: int = 128, block_t: int = 64, backend: str = "pallas"):
    b, s, d = x.shape
    cfg = resolve_cfg(cfg, "rglru", (b, s, d), dtype=x.dtype.name,
                      backend=backend, block_d=block_d, block_t=block_t)
    return _rglru_fn(b, s, d, cfg, block_d, block_t, backend)(x, r, i, a_param)
