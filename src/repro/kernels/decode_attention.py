"""Split-KV decode attention with kv-block coarsening (flash-decode style).

Decode attention — one query token per sequence against a (S, Hkv, D) cache —
is the serving hot path: every generated token must stream the live cache
prefix.  The coarsenable work-item axis here is the KV-BLOCK axis: each
program owns C kv blocks of ``bkv`` rows,

  consecutive : C adjacent blocks -> one (C*bkv, D) cache DMA per operand
                per program (the wide burst-coalesced LSU, paper Fig. 4 top)
  gapped      : C blocks strided S/C apart -> C strided DMAs per operand
                (the C narrow cached LSUs, paper Fig. 4 bottom)

and reduces them into a partial online-softmax state ``(m, l, acc)``.  A
cheap exact combine outside the kernel merges the per-split partials
(split-KV / flash-decode).  The grid is LENGTH-AWARE: a program whose fused
kv rows all lie beyond the slot's ``pos`` (or entirely left of its sliding
window) skips its compute, so per-token cost tracks the live prefix
``pos+1`` rather than the allocated ``max_len`` — coarsening then divides
the remaining per-block DMA issue overhead by C (paper §III.B: fewer total
memory accesses at bounded resource cost).
"""
from __future__ import annotations

import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.coarsening import CoarseningConfig, KIND_GAPPED

NEG = -1e30


def _combine(m, l, acc):
    """Merge per-split partial softmax states exactly.

    m, l: (B, Hkv, G, n_splits); acc: (B, Hkv, G, n_splits, D).
    """
    m_max = m.max(axis=-1)
    w = jnp.exp(m - m_max[..., None])
    w = jnp.where(m <= NEG * 0.5, 0.0, w)           # dead splits contribute 0
    l_tot = (l * w).sum(axis=-1)
    out = (acc * w[..., None]).sum(axis=-2)
    l_tot = jnp.where(l_tot == 0.0, 1.0, l_tot)
    return out / l_tot[..., None]


def make_kernel(b: int, h: int, hkv: int, s: int, d: int,
                cfg: CoarseningConfig, *, bkv: int = 128,
                window: int | None = None, scale: float | None = None,
                kv_bits: int | None = None,
                interpret: bool = True) -> Callable:
    """Build the split-KV decode kernel.

    Returned callable: run(q (B,1,H,D), k_cache, v_cache (B,S,Hkv,D),
    pos (B,) int32) -> (B,1,H,D).

    ``kv_bits=8`` enables the int8 KV-cache mode: the caches arrive int8
    with per-(token, kv-head) f32 scales (B,S,Hkv) and the callable becomes
    run(q, k_cache, v_cache, k_scale, v_scale, pos).  The dequant
    (scale-multiply) is fused into the same VMEM pass the online softmax
    already makes, so the cache DMA — the decode hot path's dominant
    traffic — halves against bf16 while the kernel math stays f32.
    """
    c = cfg.degree
    if s % (c * bkv):
        raise ValueError(f"cache len {s} not tileable by degree*bkv={c * bkv}")
    gapped = cfg.kind == KIND_GAPPED
    g = h // hkv
    if g * hkv != h:
        raise ValueError(f"n_heads {h} not divisible by n_kv_heads {hkv}")
    n_splits = s // (c * bkv)
    sg = s // c                          # gapped segment length (rows)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if kv_bits not in (None, 8):
        raise ValueError(f"kv_bits must be None or 8, got {kv_bits}")
    quant = kv_bits == 8

    def body(pos_ref, q_ref, k_ref, v_ref, *refs):
        if quant:
            ks_ref, vs_ref, m_ref, l_ref, acc_ref = refs
        else:
            m_ref, l_ref, acc_ref = refs
        si = pl.program_id(2)
        pos = pos_ref[0, 0]

        # fused kv row extent for the length-aware skip
        if gapped:
            first_row = si * bkv
            last_row = (c - 1) * sg + si * bkv + bkv - 1
        else:
            first_row = si * c * bkv
            last_row = si * c * bkv + c * bkv - 1
        live = first_row <= pos
        if window is not None:
            live &= last_row > pos - window

        @pl.when(live)
        def _compute():
            q = q_ref[...].reshape(g, d).astype(jnp.float32)
            kk = k_ref[...].reshape(c * bkv, d)
            vv = v_ref[...].reshape(c * bkv, d)
            if quant:
                # fused dequant: one scale-multiply over the pane already in
                # VMEM (per-token x kv-head scales)
                kk = kk.astype(jnp.float32) * ks_ref[...].reshape(c * bkv, 1)
                vv = vv.astype(jnp.float32) * vs_ref[...].reshape(c * bkv, 1)
            m = jnp.full((g,), NEG, jnp.float32)
            l = jnp.zeros((g,), jnp.float32)
            acc = jnp.zeros((g, d), jnp.float32)
            cols0 = jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
            for j in range(c):          # unrolled: C fused kv blocks
                start = (j * sg + si * bkv) if gapped else (si * c * bkv
                                                            + j * bkv)
                cols = cols0 + start
                mask = cols <= pos
                if window is not None:
                    mask &= cols > pos - window
                kj = kk[j * bkv:(j + 1) * bkv].astype(jnp.float32)
                vj = vv[j * bkv:(j + 1) * bkv].astype(jnp.float32)
                sij = jnp.dot(q, kj.T,
                              preferred_element_type=jnp.float32) * scale
                sij = jnp.where(mask, sij, NEG)
                m_new = jnp.maximum(m, sij.max(axis=1))
                p = jnp.exp(sij - m_new[:, None]) * mask
                alpha = jnp.exp(m - m_new)
                l = l * alpha + p.sum(axis=1)
                acc = acc * alpha[:, None] + jnp.dot(
                    p, vj, preferred_element_type=jnp.float32)
                m = m_new
            m_ref[...] = m.reshape(m_ref.shape)
            l_ref[...] = l.reshape(l_ref.shape)
            acc_ref[...] = acc.reshape(acc_ref.shape)

        @pl.when(jnp.logical_not(live))
        def _dead():
            m_ref[...] = jnp.full_like(m_ref, NEG)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

    # K/V cache views: consecutive fetches one contiguous (c*bkv, d) pane;
    # gapped views the row axis as (C, S/C) and fetches C strided panes.
    # The scale panes follow the same distribution, minus the D axis.
    if gapped:
        kv_spec = pl.BlockSpec((1, c, bkv, 1, d),
                               lambda bb, hh, si: (bb, 0, si, hh, 0))
        kv_view = lambda x: x.reshape(b, c, sg, hkv, d)
        sc_spec = pl.BlockSpec((1, c, bkv, 1),
                               lambda bb, hh, si: (bb, 0, si, hh))
        sc_view = lambda x: x.reshape(b, c, sg, hkv)
    else:
        kv_spec = pl.BlockSpec((1, c * bkv, 1, d),
                               lambda bb, hh, si: (bb, si, hh, 0))
        kv_view = lambda x: x
        sc_spec = pl.BlockSpec((1, c * bkv, 1),
                               lambda bb, hh, si: (bb, si, hh))
        sc_view = lambda x: x

    in_specs = [
        pl.BlockSpec((1, 1), lambda bb, hh, si: (bb, 0)),          # pos
        pl.BlockSpec((1, 1, g, d), lambda bb, hh, si: (bb, hh, 0, 0)),
        kv_spec,
        kv_spec,
    ]
    if quant:
        in_specs += [sc_spec, sc_spec]

    call = pl.pallas_call(
        body,
        grid=(b, hkv, n_splits),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, 1, g, 1), lambda bb, hh, si: (bb, hh, 0, si)),
            pl.BlockSpec((1, 1, g, 1), lambda bb, hh, si: (bb, hh, 0, si)),
            pl.BlockSpec((1, 1, g, 1, d),
                         lambda bb, hh, si: (bb, hh, 0, si, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, hkv, g, n_splits), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, n_splits), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, n_splits, d), jnp.float32),
        ),
        interpret=interpret,
    )

    if quant:
        def run(q, k_cache, v_cache, k_scale, v_scale, pos):
            qv = q.reshape(b, hkv, g, d)
            pos2 = pos.reshape(b, 1).astype(jnp.int32)
            m, l, acc = call(pos2, qv, kv_view(k_cache), kv_view(v_cache),
                             sc_view(k_scale), sc_view(v_scale))
            out = _combine(m, l, acc)                 # (B, Hkv, G, D)
            return out.reshape(b, 1, h, d).astype(q.dtype)
    else:
        def run(q, k_cache, v_cache, pos):
            qv = q.reshape(b, hkv, g, d)
            pos2 = pos.reshape(b, 1).astype(jnp.int32)
            m, l, acc = call(pos2, qv, kv_view(k_cache), kv_view(v_cache))
            out = _combine(m, l, acc)                 # (B, Hkv, G, D)
            return out.reshape(b, 1, h, d).astype(q.dtype)

    return run


def make_paged_kernel(b: int, h: int, hkv: int, n_pages: int, npp: int,
                      d: int, cfg: CoarseningConfig, *, page_size: int = 64,
                      window: int | None = None, scale: float | None = None,
                      kv_bits: int | None = None,
                      interpret: bool = True) -> Callable:
    """Split-KV decode attention through a per-slot BLOCK TABLE.

    The caches arrive as a global page pool shared by every slot —
    k/v: (P, page_size, Hkv, D) — and each slot's logical cache row ``r``
    lives at pool row ``(block_table[slot, r // page_size], r % page_size)``.
    The kv block IS the page (bkv == page_size), so the coarsening axis is
    the LOGICAL-PAGE axis of the slot: each program owns C logical pages,

      consecutive : C adjacent logical pages
      gapped      : C logical pages strided npp/C apart

    and in BOTH cases the physical fetch is C table-resolved page loads —
    paging is the paper's *gapped* access pattern with the fixed stride
    replaced by the block-table indirection (C narrow cached LSUs,
    Fig. 4 bottom); coarsening amortizes the per-page issue + table-lookup
    overhead exactly as it amortizes the strided DMA issue overhead.

    Logical pages past a slot's allocation sit at NULL_PAGE in the table;
    their rows are beyond ``pos`` and the causal mask (which also covers
    partially-filled tail pages) zeroes them out of the softmax.

    Returned callable:
      run(q (B,1,H,D), k_pool, v_pool (P,ps,Hkv,D), block_table (B,npp)
          int32, pos (B,) int32) -> (B,1,H,D)
    ``kv_bits=8``: pools are int8 with (P,ps,Hkv) f32 scale pools and the
    callable takes (q, k_pool, v_pool, k_scale, v_scale, block_table, pos);
    dequant is fused into the same VMEM pass as the contiguous kernel.
    """
    c = cfg.degree
    ps = page_size
    if npp % c:
        raise ValueError(f"slot pages {npp} not tileable by degree {c}")
    gapped = cfg.kind == KIND_GAPPED
    g = h // hkv
    if g * hkv != h:
        raise ValueError(f"n_heads {h} not divisible by n_kv_heads {hkv}")
    n_splits = npp // c
    seg = npp // c                       # gapped logical-page stride
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if kv_bits not in (None, 8):
        raise ValueError(f"kv_bits must be None or 8, got {kv_bits}")
    quant = kv_bits == 8

    def logical_page(si, j):
        return (j * seg + si) if gapped else (si * c + j)

    def body(pos_ref, bt_ref, q_ref, k_ref, v_ref, *refs):
        if quant:
            ks_ref, vs_ref, m_ref, l_ref, acc_ref = refs
        else:
            m_ref, l_ref, acc_ref = refs
        si = pl.program_id(2)
        pos = pos_ref[0, 0]

        # fused logical-row extent for the length-aware skip (page indices
        # are logical, so the extent math matches the contiguous kernel's)
        if gapped:
            first_row = si * ps
            last_row = ((c - 1) * seg + si) * ps + ps - 1
        else:
            first_row = si * c * ps
            last_row = (si * c + c - 1) * ps + ps - 1
        live = first_row <= pos
        if window is not None:
            live &= last_row > pos - window

        @pl.when(live)
        def _compute():
            q = q_ref[...].reshape(g, d).astype(jnp.float32)
            m = jnp.full((g,), NEG, jnp.float32)
            l = jnp.zeros((g,), jnp.float32)
            acc = jnp.zeros((g, d), jnp.float32)
            cols0 = jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
            for j in range(c):       # unrolled: C table-resolved page loads
                lp = logical_page(si, j)
                pp = bt_ref[0, lp]   # physical page (the table gather)
                kj = pl.load(k_ref, (pl.dslice(pp, 1), slice(None),
                                     slice(None), slice(None))
                             ).reshape(ps, d).astype(jnp.float32)
                vj = pl.load(v_ref, (pl.dslice(pp, 1), slice(None),
                                     slice(None), slice(None))
                             ).reshape(ps, d).astype(jnp.float32)
                if quant:
                    kj = kj * pl.load(
                        ks_ref, (pl.dslice(pp, 1), slice(None), slice(None))
                    ).reshape(ps, 1)
                    vj = vj * pl.load(
                        vs_ref, (pl.dslice(pp, 1), slice(None), slice(None))
                    ).reshape(ps, 1)
                cols = cols0 + lp * ps
                mask = cols <= pos
                if window is not None:
                    mask &= cols > pos - window
                sij = jnp.dot(q, kj.T,
                              preferred_element_type=jnp.float32) * scale
                sij = jnp.where(mask, sij, NEG)
                m_new = jnp.maximum(m, sij.max(axis=1))
                p = jnp.exp(sij - m_new[:, None]) * mask
                alpha = jnp.exp(m - m_new)
                l = l * alpha + p.sum(axis=1)
                acc = acc * alpha[:, None] + jnp.dot(
                    p, vj, preferred_element_type=jnp.float32)
                m = m_new
            m_ref[...] = m.reshape(m_ref.shape)
            l_ref[...] = l.reshape(l_ref.shape)
            acc_ref[...] = acc.reshape(acc_ref.shape)

        @pl.when(jnp.logical_not(live))
        def _dead():
            m_ref[...] = jnp.full_like(m_ref, NEG)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

    # the pool rides in whole (its page axis is gathered in-body, so no
    # BlockSpec offset can window it); the head axis is still windowed
    pool_spec = pl.BlockSpec((n_pages, ps, 1, d),
                             lambda bb, hh, si: (0, 0, hh, 0))
    sc_pool_spec = pl.BlockSpec((n_pages, ps, 1),
                                lambda bb, hh, si: (0, 0, hh))
    in_specs = [
        pl.BlockSpec((1, 1), lambda bb, hh, si: (bb, 0)),          # pos
        pl.BlockSpec((1, npp), lambda bb, hh, si: (bb, 0)),        # table
        pl.BlockSpec((1, 1, g, d), lambda bb, hh, si: (bb, hh, 0, 0)),
        pool_spec,
        pool_spec,
    ]
    if quant:
        in_specs += [sc_pool_spec, sc_pool_spec]

    call = pl.pallas_call(
        body,
        grid=(b, hkv, n_splits),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, 1, g, 1), lambda bb, hh, si: (bb, hh, 0, si)),
            pl.BlockSpec((1, 1, g, 1), lambda bb, hh, si: (bb, hh, 0, si)),
            pl.BlockSpec((1, 1, g, 1, d),
                         lambda bb, hh, si: (bb, hh, 0, si, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, hkv, g, n_splits), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, n_splits), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, n_splits, d), jnp.float32),
        ),
        interpret=interpret,
    )

    if quant:
        def run(q, k_pool, v_pool, k_scale, v_scale, block_table, pos):
            qv = q.reshape(b, hkv, g, d)
            pos2 = pos.reshape(b, 1).astype(jnp.int32)
            bt = block_table.astype(jnp.int32)
            m, l, acc = call(pos2, bt, qv, k_pool, v_pool, k_scale, v_scale)
            out = _combine(m, l, acc)                 # (B, Hkv, G, D)
            return out.reshape(b, 1, h, d).astype(q.dtype)
    else:
        def run(q, k_pool, v_pool, block_table, pos):
            qv = q.reshape(b, hkv, g, d)
            pos2 = pos.reshape(b, 1).astype(jnp.int32)
            bt = block_table.astype(jnp.int32)
            m, l, acc = call(pos2, bt, qv, k_pool, v_pool)
            out = _combine(m, l, acc)                 # (B, Hkv, G, D)
            return out.reshape(b, 1, h, d).astype(q.dtype)

    return run


def make_verify_kernel(b: int, h: int, hkv: int, t: int, n_pages: int,
                       npp: int, d: int, cfg: CoarseningConfig, *,
                       page_size: int = 64, window: int | None = None,
                       scale: float | None = None,
                       kv_bits: int | None = None,
                       interpret: bool = True) -> Callable:
    """Batched-verify attention through a per-slot block table (short-q
    flash: the speculative-decode geometry).

    Structurally this is `make_paged_kernel` generalized from one query row
    to T drafted rows per slot: the coarsening axis is still the slot's
    LOGICAL-PAGE axis (each program owns C table-resolved page loads), but
    every fused page is now scored against a (T*G, D) q pane — row t of
    slot b sits at cache position ``pos0[b] + t`` and carries its own
    causal/window mask.  That changes the economics the tuner sees: decode
    (t=1) amortizes the per-page issue + table-lookup latency over G query
    rows, verify amortizes it over T*G rows, so the memory/compute
    crossover — and the winning degree — moves (the
    ``flash_attention_verify`` tuner family).

    Returned callable:
      run(q (B,T,H,D), k_pool, v_pool (P,ps,Hkv,D), block_table (B,npp)
          int32, pos0 (B,) int32) -> (B,T,H,D)
    ``kv_bits=8``: int8 pools + (P,ps,Hkv) f32 scale pools, callable takes
    (q, k_pool, v_pool, k_scale, v_scale, block_table, pos0).
    """
    c = cfg.degree
    ps = page_size
    if npp % c:
        raise ValueError(f"slot pages {npp} not tileable by degree {c}")
    gapped = cfg.kind == KIND_GAPPED
    g = h // hkv
    if g * hkv != h:
        raise ValueError(f"n_heads {h} not divisible by n_kv_heads {hkv}")
    n_splits = npp // c
    seg = npp // c                       # gapped logical-page stride
    rows = t * g                         # fused q rows per program
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if kv_bits not in (None, 8):
        raise ValueError(f"kv_bits must be None or 8, got {kv_bits}")
    quant = kv_bits == 8

    def logical_page(si, j):
        return (j * seg + si) if gapped else (si * c + j)

    def body(pos_ref, bt_ref, q_ref, k_ref, v_ref, *refs):
        if quant:
            ks_ref, vs_ref, m_ref, l_ref, acc_ref = refs
        else:
            m_ref, l_ref, acc_ref = refs
        si = pl.program_id(2)
        pos0 = pos_ref[0, 0]

        if gapped:
            first_row = si * ps
            last_row = ((c - 1) * seg + si) * ps + ps - 1
        else:
            first_row = si * c * ps
            last_row = (si * c + c - 1) * ps + ps - 1
        # the deepest drafted row (pos0 + t - 1) reaches furthest right; the
        # shallowest (pos0) bounds the window skip on the left
        live = first_row <= pos0 + (t - 1)
        if window is not None:
            live &= last_row > pos0 - window

        @pl.when(live)
        def _compute():
            q = q_ref[...].reshape(rows, d).astype(jnp.float32)
            m = jnp.full((rows,), NEG, jnp.float32)
            l = jnp.zeros((rows,), jnp.float32)
            acc = jnp.zeros((rows, d), jnp.float32)
            cols0 = jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
            # per-draft-row cache positions: row (ti, gi) sits at pos0 + ti
            tpos = pos0 + jax.lax.broadcasted_iota(jnp.int32, (t, 1), 0)
            for j in range(c):       # unrolled: C table-resolved page loads
                lp = logical_page(si, j)
                pp = bt_ref[0, lp]   # physical page (the table gather)
                kj = pl.load(k_ref, (pl.dslice(pp, 1), slice(None),
                                     slice(None), slice(None))
                             ).reshape(ps, d).astype(jnp.float32)
                vj = pl.load(v_ref, (pl.dslice(pp, 1), slice(None),
                                     slice(None), slice(None))
                             ).reshape(ps, d).astype(jnp.float32)
                if quant:
                    kj = kj * pl.load(
                        ks_ref, (pl.dslice(pp, 1), slice(None), slice(None))
                    ).reshape(ps, 1)
                    vj = vj * pl.load(
                        vs_ref, (pl.dslice(pp, 1), slice(None), slice(None))
                    ).reshape(ps, 1)
                cols = cols0 + lp * ps                     # (1, ps)
                maskt = cols <= tpos                       # (t, ps)
                if window is not None:
                    maskt &= cols > tpos - window
                mask = jnp.broadcast_to(maskt[:, None, :],
                                        (t, g, ps)).reshape(rows, ps)
                sij = jnp.dot(q, kj.T,
                              preferred_element_type=jnp.float32) * scale
                sij = jnp.where(mask, sij, NEG)
                m_new = jnp.maximum(m, sij.max(axis=1))
                p = jnp.exp(sij - m_new[:, None]) * mask
                alpha = jnp.exp(m - m_new)
                l = l * alpha + p.sum(axis=1)
                acc = acc * alpha[:, None] + jnp.dot(
                    p, vj, preferred_element_type=jnp.float32)
                m = m_new
            m_ref[...] = m.reshape(m_ref.shape)
            l_ref[...] = l.reshape(l_ref.shape)
            acc_ref[...] = acc.reshape(acc_ref.shape)

        @pl.when(jnp.logical_not(live))
        def _dead():
            m_ref[...] = jnp.full_like(m_ref, NEG)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

    pool_spec = pl.BlockSpec((n_pages, ps, 1, d),
                             lambda bb, hh, si: (0, 0, hh, 0))
    sc_pool_spec = pl.BlockSpec((n_pages, ps, 1),
                                lambda bb, hh, si: (0, 0, hh))
    in_specs = [
        pl.BlockSpec((1, 1), lambda bb, hh, si: (bb, 0)),          # pos0
        pl.BlockSpec((1, npp), lambda bb, hh, si: (bb, 0)),        # table
        pl.BlockSpec((1, 1, rows, d), lambda bb, hh, si: (bb, hh, 0, 0)),
        pool_spec,
        pool_spec,
    ]
    if quant:
        in_specs += [sc_pool_spec, sc_pool_spec]

    call = pl.pallas_call(
        body,
        grid=(b, hkv, n_splits),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, 1, rows, 1), lambda bb, hh, si: (bb, hh, 0, si)),
            pl.BlockSpec((1, 1, rows, 1), lambda bb, hh, si: (bb, hh, 0, si)),
            pl.BlockSpec((1, 1, rows, 1, d),
                         lambda bb, hh, si: (bb, hh, 0, si, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, hkv, rows, n_splits), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, rows, n_splits), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, rows, n_splits, d), jnp.float32),
        ),
        interpret=interpret,
    )

    def _qview(q):
        # (B,T,H,D) -> (B,Hkv,T*G,D), rows (ti, gi) flattened t-major so the
        # per-page mask broadcast above lines up
        return q.reshape(b, t, hkv, g, d).transpose(0, 2, 1, 3, 4) \
                .reshape(b, hkv, rows, d)

    def _oview(out, dtype):
        # combined (B,Hkv,T*G,D) -> (B,T,H,D)
        return out.reshape(b, hkv, t, g, d).transpose(0, 2, 1, 3, 4) \
                  .reshape(b, t, h, d).astype(dtype)

    if quant:
        def run(q, k_pool, v_pool, k_scale, v_scale, block_table, pos0):
            pos2 = pos0.reshape(b, 1).astype(jnp.int32)
            bt = block_table.astype(jnp.int32)
            m, l, acc = call(pos2, bt, _qview(q), k_pool, v_pool,
                             k_scale, v_scale)
            return _oview(_combine(m, l, acc), q.dtype)
    else:
        def run(q, k_pool, v_pool, block_table, pos0):
            pos2 = pos0.reshape(b, 1).astype(jnp.int32)
            bt = block_table.astype(jnp.int32)
            m, l, acc = call(pos2, bt, _qview(q), k_pool, v_pool)
            return _oview(_combine(m, l, acc), q.dtype)

    return run
