"""Grouped-expert MoE FFN with expert coarsening (fused gate/up/down).

The MoE layer's dispatch buffer is a padded ``(E_pad, C, d)`` tensor — many
small per-expert matmuls, exactly the launch-bound shape the paper coarsens.
The coarsenable work-item axis here is the EXPERT axis: each program owns
``degree`` experts,

  consecutive : degree adjacent experts -> one wide (degree*d, ff) weight
                DMA per operand per program (the burst-coalesced LSU,
                paper Fig. 4 top)
  gapped      : degree experts strided E_pad/degree apart -> degree strided
                DMAs per operand (the narrow cached LSUs, paper Fig. 4
                bottom)

and computes the FULL ``silu(x@w1) * (x@w3) @ w2`` chain for each of them
with the ``(cap, ff)`` intermediate held in registers/VMEM — the
producer/consumer fusion of Zarch & Becchi's pipes paper: the three einsums
the XLA path runs would round-trip that intermediate through HBM twice.
The per-token combine weights (top-k router prob x live mask) are fused in
as the final scale, so the kernel's output scatters directly into the token
accumulator.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.coarsening import CoarseningConfig, KIND_GAPPED


def make_kernel(e: int, cap: int, d: int, f: int, cfg: CoarseningConfig, *,
                interpret: bool = True) -> Callable:
    """Build the grouped-expert fused-FFN kernel.

    Returned callable: run(xe (E,C,d), w1 (E,d,F), w3 (E,d,F), w2 (E,F,d),
    wts (E,C)) -> (E,C,d) float32 — ``(silu(xe@w1) * (xe@w3)) @ w2`` per
    expert, scaled by the combine weight.
    """
    c = cfg.degree
    if e % c:
        raise ValueError(f"experts {e} not tileable by degree {c}")
    grid = e // c
    gapped = cfg.kind == KIND_GAPPED

    def body(x_ref, w1_ref, w3_ref, w2_ref, wt_ref, o_ref):
        x = x_ref[...].reshape(c, cap, d)
        w1 = w1_ref[...].reshape(c, d, f)
        w3 = w3_ref[...].reshape(c, d, f)
        w2 = w2_ref[...].reshape(c, f, d)
        wt = wt_ref[...].reshape(c, cap)
        out = jnp.zeros((c, cap, d), jnp.float32)
        for j in range(c):              # unrolled: the fused experts
            xj = x[j]
            h = jax.nn.silu(jnp.dot(xj, w1[j],
                                    preferred_element_type=jnp.float32))
            h = h * jnp.dot(xj, w3[j], preferred_element_type=jnp.float32)
            # the (cap, f) intermediate never leaves the program
            yj = jnp.dot(h.astype(xj.dtype), w2[j],
                         preferred_element_type=jnp.float32)
            yj = yj * wt[j][:, None].astype(jnp.float32)
            out = out.at[j].set(yj)
        o_ref[...] = out.reshape(o_ref.shape)

    # Expert-axis views: consecutive fetches one contiguous pane of C
    # experts per operand; gapped views the expert axis as (C, E/C) and
    # fetches C strided panes (experts i, i+grid, ..., i+(C-1)*grid).
    if gapped:
        x_spec = pl.BlockSpec((c, 1, cap, d), lambda i: (0, i, 0, 0))
        w_spec = pl.BlockSpec((c, 1, d, f), lambda i: (0, i, 0, 0))
        w2_spec = pl.BlockSpec((c, 1, f, d), lambda i: (0, i, 0, 0))
        wt_spec = pl.BlockSpec((c, 1, cap), lambda i: (0, i, 0))
        o_spec = pl.BlockSpec((c, 1, cap, d), lambda i: (0, i, 0, 0))
        view = lambda t: t.reshape((c, grid) + t.shape[1:])
        o_shape = (c, grid, cap, d)
        unview = lambda o: o.reshape(e, cap, d)
    else:
        x_spec = pl.BlockSpec((c, cap, d), lambda i: (i, 0, 0))
        w_spec = pl.BlockSpec((c, d, f), lambda i: (i, 0, 0))
        w2_spec = pl.BlockSpec((c, f, d), lambda i: (i, 0, 0))
        wt_spec = pl.BlockSpec((c, cap), lambda i: (i, 0))
        o_spec = pl.BlockSpec((c, cap, d), lambda i: (i, 0, 0))
        view = lambda t: t
        o_shape = (e, cap, d)
        unview = lambda o: o

    call = pl.pallas_call(
        body,
        grid=(grid,),
        in_specs=[x_spec, w_spec, w_spec, w2_spec, wt_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(o_shape, jnp.float32),
        cost_estimate=pl.CostEstimate(
            flops=6 * e * cap * d * f,
            bytes_accessed=2 * (3 * e * d * f + 2 * e * cap * d),
            transcendentals=e * cap * f),
        interpret=interpret,
    )

    def run(xe, w1, w3, w2, wts):
        return unview(call(view(xe), view(w1), view(w3), view(w2),
                           view(wts)))

    return run


def make_qkernel(e: int, cap: int, d: int, f: int, cfg: CoarseningConfig, *,
                 bits: int = 8, group: int = 32,
                 interpret: bool = True) -> Callable:
    """Dequant-fused grouped-expert FFN: the w1/w3/w2 panes arrive PACKED
    (int8, or int4 nibbles along the contraction axis) plus scales.  Each
    program DMAs the packed panes of its ``degree`` experts (consecutive =
    one wide packed pane per operand — 2-4x fewer bytes than the dense
    kernel's — gapped = degree strided packed panes), dequantizes them in
    VMEM ONCE, and runs the same fused silu-gate/up/down chain.  The
    per-pane dequant is exactly the per-work-item overhead coarsening
    amortizes in the paper.

    Returned callable: run(xe (E,C,d), w1q, w1s, w3q, w3s, w2q, w2s,
    wts (E,C)) -> (E,C,d) f32, where per expert
      bits=8: w1q/w3q (E,d,F) int8 + scales (E,1,F); w2q (E,F,d) + (E,1,d)
      bits=4: w1q/w3q (E,d/2,F) uint8 + scales (E,d/group,F);
              w2q (E,F/2,d) uint8 + scales (E,F/group,d)
    """
    c = cfg.degree
    if e % c:
        raise ValueError(f"experts {e} not tileable by degree {c}")
    if bits not in (8, 4):
        raise ValueError(f"bits must be 8 or 4, got {bits}")
    if bits == 4 and (d % group or f % group or group % 2):
        raise ValueError(f"int4 needs even group tiling d={d} and f={f}, "
                         f"got group={group}")
    grid = e // c
    gapped = cfg.kind == KIND_GAPPED

    def _deq(qv, sv):
        """(c, Kp, N) packed + (c, S, N) scales -> (c, K, N) f32."""
        if bits == 8:
            return qv.astype(jnp.float32) * sv
        from repro.quant.qtypes import unpack_int4
        return unpack_int4(qv, axis=1) * jnp.repeat(sv, group, axis=1)

    kd = d // 2 if bits == 4 else d                  # packed contraction dims
    kf = f // 2 if bits == 4 else f
    sd = d // group if bits == 4 else 1              # scale rows
    sf = f // group if bits == 4 else 1

    def body(x_ref, w1q_ref, w1s_ref, w3q_ref, w3s_ref, w2q_ref, w2s_ref,
             wt_ref, o_ref):
        x = x_ref[...].reshape(c, cap, d).astype(jnp.float32)
        w1 = _deq(w1q_ref[...].reshape(c, kd, f),
                  w1s_ref[...].reshape(c, sd, f))
        w3 = _deq(w3q_ref[...].reshape(c, kd, f),
                  w3s_ref[...].reshape(c, sd, f))
        w2 = _deq(w2q_ref[...].reshape(c, kf, d),
                  w2s_ref[...].reshape(c, sf, d))
        wt = wt_ref[...].reshape(c, cap)
        out = jnp.zeros((c, cap, d), jnp.float32)
        for j in range(c):              # unrolled: the fused experts
            xj = x[j]
            h = jax.nn.silu(jnp.dot(xj, w1[j],
                                    preferred_element_type=jnp.float32))
            h = h * jnp.dot(xj, w3[j], preferred_element_type=jnp.float32)
            yj = jnp.dot(h, w2[j], preferred_element_type=jnp.float32)
            yj = yj * wt[j][:, None].astype(jnp.float32)
            out = out.at[j].set(yj)
        o_ref[...] = out.reshape(o_ref.shape)

    # Expert-axis views mirror the dense kernel's: consecutive fetches one
    # contiguous pane of C experts per operand, gapped a (C, E/C) view.
    def espec(*dims):
        if gapped:
            return pl.BlockSpec((c, 1) + dims,
                                lambda i: (0, i) + (0,) * len(dims))
        return pl.BlockSpec((c,) + dims, lambda i: (i,) + (0,) * len(dims))

    if gapped:
        view = lambda t: t.reshape((c, grid) + t.shape[1:])
        o_shape = (c, grid, cap, d)
        unview = lambda o: o.reshape(e, cap, d)
    else:
        view = lambda t: t
        o_shape = (e, cap, d)
        unview = lambda o: o

    wbytes = 3 * e * d * f * bits // 8
    call = pl.pallas_call(
        body,
        grid=(grid,),
        in_specs=[espec(cap, d),
                  espec(kd, f), espec(sd, f),
                  espec(kd, f), espec(sd, f),
                  espec(kf, d), espec(sf, d),
                  espec(cap)],
        out_specs=espec(cap, d),
        out_shape=jax.ShapeDtypeStruct(o_shape, jnp.float32),
        cost_estimate=pl.CostEstimate(
            flops=6 * e * cap * d * f + 2 * 3 * e * d * f,  # chain + dequant
            bytes_accessed=wbytes + 2 * 2 * e * cap * d,
            transcendentals=e * cap * f),
        interpret=interpret,
    )

    def run(xe, w1q, w1s, w3q, w3s, w2q, w2s, wts):
        args = (xe, w1q, w1s, w3q, w3s, w2q, w2s, wts)
        return unview(call(*(view(t) for t in args)))

    return run
