"""Block-sparse flash attention: per-q-block live-KV indices, coarsened over
the LIVE block list.

The dense flash kernel (kernels/flash_attention.py) walks every kv block and
masks the dead ones — at long context with a local window almost the whole
sweep is dead work: the DMA and grid-step latency are paid before the mask
throws the tile away.  This kernel moves the sparsity from the predicate
level to the kernel-structure level: a host-side builder enumerates, per q
block, the kv blocks that contain at least one live (q, k) pair under the
pattern (causal / sliding window / LongFormer-style global stride), pads
every row to the same ``max_live`` length with a NULL sentinel — the same
static-shape trick serve/paging.py plays with its NULL page, except the
sentinel here is -1 because block 0 is a legitimately live block — and the
kernel resolves logical block ids through that index in-body, exactly like
``make_paged_kernel`` resolves pages through a block table.

Coarsening applies over the live-SLOT axis instead of the dense kv range:

  consecutive : one program owns C adjacent index slots (slot si*C+j) —
                for the window band these are usually adjacent kv blocks.
  gapped      : one program owns C slots strided max_live/C apart
                (slot j*seg+si) — the strided-LSU analog; physically both
                kinds issue C index-resolved block loads per step, the
                paged-decode story.

NULL (-1) slots are skipped under ``pl.when`` — no DMA, no compute — which
is what makes poisoned dead blocks (garbage K/V outside the live set)
invisible by construction, not by masking.  Per-element masks still apply
inside listed blocks (diagonal partials, window edges, stride columns).

The jnp ``ref_sparse_attention`` below is the dense-mask parity oracle; it
is also the training fallback for patterns the dense backward kernels can't
express (global stride — see ops.flash_attention_sparse).
"""
from __future__ import annotations

import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.coarsening import CoarseningConfig, KIND_GAPPED

NEG = -1e30

# the NULL slot sentinel: index rows are padded to max_live with it, and the
# kernel skips sentinel slots entirely (serve/paging.py reserves a null PAGE
# instead — its page 0 is never allocated; kv block 0 is live under every
# causal pattern, so the index uses an out-of-range id rather than a
# reserved block)
NULL_BLOCK = -1


# ---------------------------------------------------------------------------
# pattern semantics (shared by the builder, the kernel and the oracle)
# ---------------------------------------------------------------------------

def _element_mask(rows, cols, *, causal: bool, window, global_stride):
    """Live (q, k) pairs under the pattern, elementwise over broadcastable
    row/col position arrays (works for both jnp and np inputs).

    causal         : col <= row
    window         : col > row - window ... OR the col is a global column
    global_stride  : cols ≡ 0 (mod stride) are globally attended (LongFormer
                     global tokens), still subject to causality
    """
    xp = jnp if isinstance(rows, jnp.ndarray) else np
    mask = xp.ones(xp.broadcast_shapes(rows.shape, cols.shape), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        keep = cols > rows - window
        if global_stride:
            keep |= cols % global_stride == 0
        mask &= keep
    return mask


def _block_live(sq: int, sk: int, bq: int, bkv: int, *, causal: bool,
                window, global_stride) -> np.ndarray:
    """(nq, nk) bool: block (i, j) contains >= 1 live (q, k) pair.

    Computed in closed form per tile (the band boundaries have slope 1, so a
    rectangle intersects the band iff its (row - col) range does), which
    keeps the builder O(nq * nk) instead of O(sq * sk) — exactness is pinned
    against the elementwise mask by the hypothesis property tests.
    """
    nq, nk = sq // bq, sk // bkv
    i = np.arange(nq)[:, None]
    j = np.arange(nk)[None, :]
    r0, r1 = i * bq, i * bq + bq - 1          # tile row range
    c0, c1 = j * bkv, j * bkv + bkv - 1       # tile col range
    live = np.ones((nq, nk), dtype=bool)
    if causal:
        live &= c0 <= r1
    if window is not None:
        band = c1 > r0 - window
        if global_stride:
            # smallest multiple of the stride inside the tile's col range
            cg = -(-c0 // global_stride) * global_stride
            stride_live = cg <= c1
            if causal:
                # some fused row can see it (broadcasts (1,nk) -> (nq,nk))
                stride_live = stride_live & (cg <= r1)
            band |= stride_live
        live &= band
    return live


def max_live_blocks(sq: int, sk: int, bq: int, bkv: int, *,
                    causal: bool = True, window=None, global_stride=None,
                    pad_multiple: int = 8) -> int:
    """The padded per-q-block index width build_block_index will produce —
    exposed so tuner specs can carry max_live without building the index."""
    live = _block_live(sq, sk, bq, bkv, causal=causal, window=window,
                       global_stride=global_stride)
    ml = int(live.sum(axis=1).max(initial=1))
    return -(-ml // pad_multiple) * pad_multiple


@functools.lru_cache(maxsize=256)
def build_block_index(sq: int, sk: int, bq: int, bkv: int, *,
                      causal: bool = True, window: int | None = None,
                      global_stride: int | None = None,
                      pad_multiple: int = 8) -> np.ndarray:
    """Per-q-block live kv block ids, NULL-padded to a static shape.

    Returns (nq, max_live) int32: row i lists the kv block ids with at least
    one live (q, k) pair for q rows [i*bq, (i+1)*bq), ascending, padded to
    ``max_live`` with NULL_BLOCK.  max_live is rounded up to ``pad_multiple``
    so every tuner degree in {1, 2, 4, 8} divides the slot count (the
    degree-divisibility legality the flash_attention_sparse family checks).

    Cached (the index is a pure function of the geometry); treat the result
    as read-only.
    """
    if sq % bq or sk % bkv:
        raise ValueError(f"sequence not tileable: {sq}x{sk} by {bq}x{bkv}")
    live = _block_live(sq, sk, bq, bkv, causal=causal, window=window,
                       global_stride=global_stride)
    nq = live.shape[0]
    counts = live.sum(axis=1)
    max_live = -(-int(counts.max(initial=1)) // pad_multiple) * pad_multiple
    idx = np.full((nq, max_live), NULL_BLOCK, dtype=np.int32)
    for i in range(nq):
        row = np.nonzero(live[i])[0]
        idx[i, :len(row)] = row
    return idx


def ref_sparse_attention(q, k, v, *, causal: bool = True, window=None,
                         global_stride=None, scale=None):
    """Dense-mask oracle over (B,H,Sq,D) x (B,Hkv,Sk,D) — kernels/ref.py's
    ``attention`` extended with the global-stride columns.  The parity
    target for the sparse kernel and the jnp fallback for ineligible
    geometries / strided training."""
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = _element_mask(jnp.arange(sq)[:, None], jnp.arange(sk)[None, :],
                         causal=causal, window=window,
                         global_stride=global_stride)
    logits = jnp.where(mask, logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


# ---------------------------------------------------------------------------
# the block-sparse kernel
# ---------------------------------------------------------------------------

def make_kernel(b: int, h: int, hkv: int, s: int, d: int,
                cfg: CoarseningConfig, *, bq: int = 128, bkv: int = 128,
                max_live: int, causal: bool = True,
                window: int | None = None, global_stride: int | None = None,
                scale: float | None = None, interpret: bool = True,
                sk: int | None = None,
                return_residuals: bool = False) -> Callable:
    """Block-sparse forward.  run(q (B,H,Sq,D), k, v (B,Hkv,Sk,D),
    idx (nq, max_live) int32) -> o (B,H,Sq,D) f32, or (o, m, l) with
    m, l (B,H,Sq) f32 when ``return_residuals``.

    The grid is (B, H, Sq/bq, max_live/C): each program owns one q block and
    C index SLOTS per step (consecutive slot si*C+j, gapped slot j*seg+si),
    resolves each slot to a logical kv block id through ``idx`` in-body and
    loads only those blocks — NULL slots are skipped under ``pl.when``
    (no DMA), so dead kv blocks are never read at all.
    """
    sq = s
    sk = sq if sk is None else sk
    c = cfg.degree
    if sq % bq or sk % bkv:
        raise ValueError("seq not tileable")
    if max_live % c:
        raise ValueError("live-slot list not tileable by degree")
    gapped = cfg.kind == KIND_GAPPED
    group = h // hkv
    nq, nkb = sq // bq, sk // bkv
    n_steps = max_live // c
    seg = max_live // c                # gapped slot stride
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    def body(idx_ref, q_ref, k_ref, v_ref, *refs):
        if return_residuals:
            o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
        else:
            o_ref, m_ref, l_ref, acc_ref = refs
        qi, si = pl.program_id(2), pl.program_id(3)

        @pl.when(si == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        rows = qi * bq + jnp.arange(bq, dtype=jnp.int32)
        cols0 = jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
        for j in range(c):             # unrolled: C index-resolved slots
            slot = (j * seg + si) if gapped else (si * c + j)
            lb = idx_ref[0, slot]      # logical kv block id, or NULL_BLOCK

            @pl.when(lb >= 0)          # NULL slot: no DMA, no compute
            def _slot(lb=lb):
                q = q_ref[...].reshape(bq, d)
                kk = pl.load(k_ref, (slice(None), slice(None),
                                     pl.dslice(lb, 1), slice(None),
                                     slice(None))).reshape(bkv, d)
                vv = pl.load(v_ref, (slice(None), slice(None),
                                     pl.dslice(lb, 1), slice(None),
                                     slice(None))).reshape(bkv, d)
                cols = cols0 + lb * bkv                        # (1, bkv)
                mask = _element_mask(rows[:, None], cols, causal=causal,
                                     window=window,
                                     global_stride=global_stride)
                sij = jnp.dot(q, kk.T,
                              preferred_element_type=jnp.float32) * scale
                sij = jnp.where(mask, sij, NEG)
                m_prev = m_ref[...]
                m_new = jnp.maximum(m_prev, sij.max(axis=1))
                p = jnp.exp(sij - m_new[:, None]) * mask
                alpha = jnp.exp(m_prev - m_new)
                l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
                acc_ref[...] = (acc_ref[...] * alpha[:, None]
                                + jnp.dot(p, vv,
                                          preferred_element_type=jnp.float32))
                m_ref[...] = m_new

        @pl.when(si == n_steps - 1)
        def _fin():
            l = l_ref[...]
            lg = jnp.where(l == 0.0, 1.0, l)
            o_ref[...] = (acc_ref[...] / lg[:, None]).reshape(o_ref.shape)
            if return_residuals:
                mo_ref[...] = m_ref[...].reshape(mo_ref.shape)
                lo_ref[...] = l.reshape(lo_ref.shape)

    # the index row rides whole per q block; K/V ride WHOLE viewed as
    # (B, Hkv, nkb, bkv, D) so the body can resolve any listed block —
    # the make_paged_kernel idiom (its pools ride whole the same way)
    idx_spec = pl.BlockSpec((1, max_live),
                            lambda bb, hh, qi, si: (qi, 0))
    q_spec = pl.BlockSpec((1, 1, bq, d),
                          lambda bb, hh, qi, si: (bb, hh, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, nkb, bkv, d),
                           lambda bb, hh, qi, si: (bb, hh // group, 0, 0, 0))
    r_spec = pl.BlockSpec((1, 1, bq), lambda bb, hh, qi, si: (bb, hh, qi))

    out_specs = (q_spec, r_spec, r_spec) if return_residuals else q_spec
    out_shape = (
        (jax.ShapeDtypeStruct((b, h, sq, d), jnp.float32),
         jax.ShapeDtypeStruct((b, h, sq), jnp.float32),
         jax.ShapeDtypeStruct((b, h, sq), jnp.float32))
        if return_residuals
        else jax.ShapeDtypeStruct((b, h, sq, d), jnp.float32))

    call = pl.pallas_call(
        body,
        grid=(b, h, nq, n_steps),
        in_specs=[
            idx_spec,
            q_spec,
            kv_spec,
            kv_spec,
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )

    def run(q, k, v, idx):
        kv = lambda x: x.reshape(b, hkv, nkb, bkv, d)
        out = call(idx, q, kv(k), kv(v))
        if not return_residuals:
            return out
        return out                     # (o, m, l), already in global order

    return run
