"""Mamba-2 SSD (state-space duality) chunked kernel, head-coarsenable.

The sequence is processed in chunks with a persistent VMEM state carry — the
chunk axis is *sequential* (like the paper's barrier kernels, gapped
coarsening over chunks is inapplicable).  The coarsenable "work-item" axis is
the HEAD axis (independent):

  consecutive : C adjacent heads fused per program.  Heads in the same group
                share B/C projections, so the B/C tile is fetched ONCE for all
                C heads — the exact burst-coalescing story of the paper
                (requires group_size % C == 0).
  gapped      : C heads strided H/C apart — only valid for n_groups == 1
                (else the strided heads need C distinct B/C fetches).

Inputs (kernel layout):  x:(B,H,S,P)  dt:(B,H,S)  A:(H,)  B,C:(B,G,S,N)
Chunk recurrence (matching ref.ssd):
  y[t]   = Σ_{u<=t, same chunk} Cb[t]·Bb[u] e^{cum[t]-cum[u]} dt[u] x[u]
         + Cb[t] e^{cum[t]} · state
  state' = e^{cum[-1]} state + Σ_u Bb[u] dt[u] e^{cum[-1]-cum[u]} x[u]
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.coarsening import CoarseningConfig, KIND_GAPPED


def make_kernel(b: int, h: int, g: int, s: int, p: int, n: int,
                cfg: CoarseningConfig, *, chunk: int = 64,
                interpret: bool = True) -> Callable:
    c = cfg.degree
    rep = h // g
    gapped = cfg.kind == KIND_GAPPED
    if s % chunk:
        raise ValueError("seq not divisible by chunk")
    if gapped and g != 1:
        raise ValueError("gapped head-coarsening requires n_groups == 1")
    if not gapped and c > 1 and rep % c != 0:
        raise ValueError("consecutive head-coarsening requires group_size % C == 0")
    if h % c:
        raise ValueError("heads not divisible by degree")
    nh, nc = h // c, s // chunk

    def body(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref):
        ci = pl.program_id(2)

        @pl.when(ci == 0)
        def _init():
            state_ref[...] = jnp.zeros_like(state_ref)

        xs = x_ref[...].reshape(c, chunk, p)
        dts = dt_ref[...].reshape(c, chunk)
        aa = a_ref[...].reshape(c)
        bb = b_ref[...].reshape(chunk, n)
        cc = c_ref[...].reshape(chunk, n)

        dA = dts * aa[:, None]                       # (c, ck) log decay
        cum = jnp.cumsum(dA, axis=1)                 # (c, ck)
        tri = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
        L = jnp.where(tri[None], jnp.exp(cum[:, :, None] - cum[:, None, :]), 0.0)
        cb = jnp.dot(cc, bb.T, preferred_element_type=jnp.float32)  # (ck, ck)
        w = cb[None] * L * dts[:, None, :]           # (c, ck, ck)
        y_intra = jnp.einsum("ctu,cup->ctp", w, xs)
        decay_out = jnp.exp(cum)                     # (c, ck)
        y_state = jnp.einsum("ctn,cnp->ctp",
                             cc[None] * decay_out[:, :, None], state_ref[...])
        o_ref[...] = (y_intra + y_state).reshape(o_ref.shape)

        total = cum[:, -1]                           # (c,)
        w_in = dts * jnp.exp(total[:, None] - cum)   # (c, ck)
        upd = jnp.einsum("ctn,ctp->cnp", bb[None] * w_in[:, :, None], xs)
        state_ref[...] = jnp.exp(total)[:, None, None] * state_ref[...] + upd

    if gapped:
        x_spec = pl.BlockSpec((1, c, 1, chunk, p), lambda bb_, hh, ci: (bb_, 0, hh, ci, 0))
        dt_spec = pl.BlockSpec((1, c, 1, chunk), lambda bb_, hh, ci: (bb_, 0, hh, ci))
        a_spec = pl.BlockSpec((c, 1), lambda bb_, hh, ci: (0, hh))
        xv = lambda x: x.reshape(b, c, nh, s, p)
        dtv = lambda d: d.reshape(b, c, nh, s)
        av = lambda a: a.reshape(c, nh)
        o_shape = (b, c, nh, s, p)
        ounv = lambda o: o.reshape(b, h, s, p)
        bc_index = lambda bb_, hh, ci: (bb_, 0, ci, 0)
    else:
        x_spec = pl.BlockSpec((1, c, chunk, p), lambda bb_, hh, ci: (bb_, hh, ci, 0))
        dt_spec = pl.BlockSpec((1, c, chunk), lambda bb_, hh, ci: (bb_, hh, ci))
        a_spec = pl.BlockSpec((c,), lambda bb_, hh, ci: (hh,))
        xv = lambda x: x
        dtv = lambda d: d
        av = lambda a: a
        o_shape = (b, h, s, p)
        ounv = lambda o: o
        bc_index = lambda bb_, hh, ci: (bb_, (hh * c) // rep, ci, 0)

    call = pl.pallas_call(
        body,
        grid=(b, nh, nc),
        in_specs=[
            x_spec, dt_spec, a_spec,
            pl.BlockSpec((1, 1, chunk, n), bc_index),
            pl.BlockSpec((1, 1, chunk, n), bc_index),
        ],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct(o_shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((c, n, p), jnp.float32)],
        interpret=interpret,
    )

    def run(x, dt, a, bmat, cmat):
        """x:(B,H,S,P) dt:(B,H,S) a:(H,) bmat/cmat:(B,G,S,N) -> (B,H,S,P)."""
        return ounv(call(xv(x), dtv(dt), av(a), bmat, cmat))

    return run
