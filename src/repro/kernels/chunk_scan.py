"""Sequential DP scan kernel (Pathfinder / dynamic-programming analog).

dist[t] = cost[t] + min(dist[t-1] shifted {-1,0,+1})

The time axis carries a dependence, so the grid is *sequential* and the carry
(previous row) lives in persistent VMEM scratch.  Consecutive coarsening fuses
C successive rows per program (fewer/wider DMAs, C-long serial chain inside).
**Gapped coarsening is inapplicable** — interleaving non-adjacent rows breaks
the carry — mirroring the paper's finding that kernels with cross-work-item
synchronization (barriers) favour replication over coarsening (§IV.B.1).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.coarsening import CoarseningConfig, KIND_GAPPED


def make_kernel(rows: int, cols: int, cfg: CoarseningConfig, *,
                interpret: bool = True) -> Callable:
    if cfg.kind == KIND_GAPPED:
        raise ValueError("gapped coarsening breaks the sequential carry of a "
                         "DP scan (paper: barrier kernels favour replication)")
    c = cfg.degree
    if rows % c:
        raise ValueError("rows not divisible by degree")
    grid = rows // c

    def body(cost_ref, o_ref, carry_ref):
        t = pl.program_id(0)

        @pl.when(t == 0)
        def _init():
            carry_ref[...] = jnp.full_like(carry_ref, jnp.inf)

        def step(k, prev):
            row = cost_ref[k, :]
            left = jnp.concatenate([prev[:1], prev[:-1]])
            right = jnp.concatenate([prev[1:], prev[-1:]])
            first = (t == 0) & (k == 0)
            cur = jnp.where(
                first, row,
                row + jnp.minimum(prev, jnp.minimum(left, right)))
            o_ref[k, :] = cur
            return cur

        carry_ref[...] = jax.lax.fori_loop(0, c, step, carry_ref[...])

    spec = pl.BlockSpec((c, cols), lambda t: (t, 0))
    call = pl.pallas_call(
        body, grid=(grid,), in_specs=[spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        scratch_shapes=[pltpu.VMEM((cols,), jnp.float32)],
        interpret=interpret,
    )
    return call
