"""Indirect-indexed (irregular) streaming kernel — paper Fig. 5(b).

out[i] = chain(t0[idx[i]], ..., t{L-1}[idx[i]])

The index stream is regular and coarsens exactly like ew_stream; the *data*
accesses are data-dependent gathers that cannot be coalesced — the case where
the paper finds coarsening wins collapse (F2) and the Intel compiler falls
back to cached narrow LSUs.

TPU adaptation: the LSU cache becomes a VMEM-resident table window.  For
interpret-mode correctness the kernel keeps the whole table resident (one
constant BlockSpec) and gathers in-VMEM; `core.analysis.gather_cost` prices
the realistic windowed version (window DMA per step + per-miss HBM latency)
according to the configured locality/hit-rate, which is what the Fig. 12
benchmark sweeps.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.coarsening import CoarseningConfig, plan_stream, stream_view, unstream_view


def make_indices(n: int, table: int, locality_window: int, seed: int = 0) -> np.ndarray:
    """Paper §III.C index generator: irregularity via a locality window.

    Each index block of ``locality_window`` stream positions draws from a
    random contiguous table window of the same size (randomized base --
    'a, b randomized starting indexes', Fig. 5b).  window == table  ->  fully
    random (irregularity degree 1); window small -> high locality.
    """
    rng = np.random.default_rng(seed)
    w = max(1, min(locality_window, table))
    n_blocks = (n + w - 1) // w
    bases = rng.integers(0, max(1, table - w), size=n_blocks)
    offs = rng.integers(0, w, size=n)
    blk = np.repeat(bases, w)[:n]
    return ((blk + offs) % table).astype(np.int32)


def make_kernel(n: int, table: int, cfg: CoarseningConfig, *, n_loads: int = 8,
                ai: int = 6, block: int = 1024,
                interpret: bool = True) -> Callable:
    from repro.kernels.ew_stream import _arith_chain

    plan = plan_stream(n, cfg, block=block)
    n_arith = ai * (n_loads + 1)

    def body(idx_ref, *refs):
        table_refs, o_ref = refs[:-1], refs[-1]
        c, b = plan.cfg.degree, plan.block
        idx = idx_ref[...].reshape(c * b)
        # in-VMEM gather (LSU-cache hit path)
        regs = [t_ref[...][idx].reshape(c, b) for t_ref in table_refs]
        out = _arith_chain(regs, n_arith)
        o_ref[...] = out.reshape(o_ref.shape)

    stream_spec = pl.BlockSpec(plan.block_shape, plan.index_map)
    table_spec = pl.BlockSpec((table,), lambda i: (0,))
    call = pl.pallas_call(
        body,
        grid=(plan.grid,),
        in_specs=[stream_spec] + [table_spec] * n_loads,
        out_specs=stream_spec,
        out_shape=jax.ShapeDtypeStruct(plan.view_shape, jnp.float32),
        interpret=interpret,
    )

    def run(idx, *tables):
        out = call(stream_view(idx, plan), *tables)
        return unstream_view(out, plan)

    return run
