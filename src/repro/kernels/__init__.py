"""Pallas TPU kernels (VMEM BlockSpec tiling), each coarsenable per the paper.

Families: ew_stream (Fig.6 microbenchmark), gather_stream (irregular access,
table-resident), windowed_gather (irregular access with scalar-prefetched
data-dependent window DMAs — the true LSU-cache analog), embed_gather
(model-scale irregular access), matmul, stencil (Hotspot), chunk_scan
(Pathfinder DP), flash_attention, decode_attention (split-KV serving),
moe_ffn (grouped-expert fused FFN, expert-axis coarsening), ssd (Mamba-2),
rglru (RecurrentGemma).  `ops` holds jit'd wrappers; `ref` holds the
pure-jnp oracles used by tests and by the XLA dry-run path.
"""
