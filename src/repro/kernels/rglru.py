"""RG-LRU (RecurrentGemma) gated linear recurrence, channel-coarsenable.

  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(i_t) * x_t)
  a_t = exp(-c * softplus(a_param) * sigmoid(r_t))

The time axis is sequential (persistent carry); channels are independent, so
the CHANNEL axis is the coarsenable work-item axis — both consecutive and
gapped apply (channel blocks have no cross dependencies), making RG-LRU the
in-model analog of the paper's regular streaming microbenchmark.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.coarsening import CoarseningConfig, KIND_GAPPED

RGLRU_C = 8.0


def make_kernel(b: int, s: int, d: int, cfg: CoarseningConfig, *,
                block_d: int = 128, block_t: int = 64,
                interpret: bool = True) -> Callable:
    c = cfg.degree
    w = c * block_d                          # fused channels per program
    if d % w or s % block_t:
        raise ValueError("shape not tileable")
    gapped = cfg.kind == KIND_GAPPED
    nd, nt = d // w, s // block_t

    def body(x_ref, r_ref, i_ref, a_ref, o_ref, h_ref):
        ti = pl.program_id(2)

        @pl.when(ti == 0)
        def _init():
            h_ref[...] = jnp.zeros_like(h_ref)

        x = x_ref[...].reshape(block_t, w)
        rg = jax.nn.sigmoid(r_ref[...].reshape(block_t, w))
        ig = jax.nn.sigmoid(i_ref[...].reshape(block_t, w))
        ap = jax.nn.softplus(a_ref[...].reshape(w))
        log_a = -RGLRU_C * ap[None, :] * rg
        a_t = jnp.exp(log_a)
        mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        u = mult * ig * x

        # linear recurrence via associative scan (parallel within the block):
        # h_t = A_t * h_in + U_t where (A,U) compose left-to-right.
        def comb(l, r):
            return (l[0] * r[0], l[1] * r[0] + r[1])

        A, U = jax.lax.associative_scan(comb, (a_t, u), axis=0)
        hs = A * h_ref[...][None, :] + U
        o_ref[...] = hs.reshape(o_ref.shape)
        h_ref[...] = hs[-1]

    if gapped:
        spec = pl.BlockSpec((1, block_t, c, block_d),
                            lambda bb, di, ti: (bb, ti, 0, di))
        a_spec = pl.BlockSpec((c, block_d), lambda bb, di, ti: (0, di))
        view = lambda z: z.reshape(b, s, c, d // c)
        a_view = lambda a: a.reshape(c, d // c)
        o_shape = (b, s, c, d // c)
        unview = lambda o: o.reshape(b, s, d)
    else:
        spec = pl.BlockSpec((1, block_t, w), lambda bb, di, ti: (bb, ti, di))
        a_spec = pl.BlockSpec((w,), lambda bb, di, ti: (di,))
        view = lambda z: z
        a_view = lambda a: a
        o_shape = (b, s, d)
        unview = lambda o: o

    call = pl.pallas_call(
        body,
        grid=(b, nd, nt),
        in_specs=[spec, spec, spec, a_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(o_shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((w,), jnp.float32)],
        interpret=interpret,
    )

    def run(x, r, i, a_param):
        return unview(call(view(x), view(r), view(i), a_view(a_param)))

    return run
