"""5-point stencil kernel (Hotspot / structured-grid analog).

Row-blocked with coarsening over row blocks.  Halo handling: the vertical
neighbours are passed as pre-shifted copies of the input (an XLA-level roll),
so every variant (consecutive/gapped) uses the identical stream machinery —
the halo cost appears as 3 input streams instead of 1, which
`core.analysis.stream_cost` prices with n_loads=3.  Horizontal neighbours are
in-block shifts (columns fully resident).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.coarsening import CoarseningConfig, KIND_GAPPED

COEF = (0.5, 0.125, 0.125, 0.125, 0.125)  # center, n, s, w, e


def _shifted(x: jax.Array):
    up = jnp.concatenate([x[:1], x[:-1]], axis=0)     # row i-1 (edge pad)
    dn = jnp.concatenate([x[1:], x[-1:]], axis=0)     # row i+1
    return up, dn


def make_kernel(rows: int, cols: int, cfg: CoarseningConfig, *,
                block_rows: int = 8, interpret: bool = True) -> Callable:
    c = cfg.degree
    if rows % (c * block_rows):
        raise ValueError("rows not tileable")
    grid = rows // (c * block_rows)
    gapped = cfg.kind == KIND_GAPPED
    c0, cn, cs, cw, ce = COEF

    def body(x_ref, up_ref, dn_ref, o_ref):
        x = x_ref[...].reshape(c * block_rows, cols)
        up = up_ref[...].reshape(c * block_rows, cols)
        dn = dn_ref[...].reshape(c * block_rows, cols)
        w = jnp.concatenate([x[:, :1], x[:, :-1]], axis=1)
        e = jnp.concatenate([x[:, 1:], x[:, -1:]], axis=1)
        o_ref[...] = (c0 * x + cn * up + cs * dn + cw * w + ce * e
                      ).reshape(o_ref.shape)

    if gapped:
        spec = pl.BlockSpec((c, block_rows, cols), lambda i: (0, i, 0))
        view = lambda a: a.reshape(c, rows // c, cols)
        o_shape = (c, rows // c, cols)
        unview = lambda o: o.reshape(rows, cols)
    else:
        spec = pl.BlockSpec((c * block_rows, cols), lambda i: (i, 0))
        view = lambda a: a
        o_shape = (rows, cols)
        unview = lambda o: o

    call = pl.pallas_call(
        body, grid=(grid,), in_specs=[spec] * 3, out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(o_shape, jnp.float32),
        interpret=interpret,
    )

    def run(x):
        up, dn = _shifted(x)
        return unview(call(view(x), view(up), view(dn)))

    return run
