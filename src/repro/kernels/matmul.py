"""Coarsenable blocked matmul — the dense-linear-algebra app analog (LU/NN/GE).

Coarsening fuses C row-blocks of A (and of the output) into one program:

  consecutive : one (C*bm, bk) contiguous A tile  -> 1 wide DMA
  gapped      : C strided (bm, bk) tiles          -> C narrow DMAs

Either way the B tile is fetched ONCE per program instead of once per
row-block — the paper's "reduction in the total number of memory accesses"
(§III.B) applied to the MXU: B traffic drops by C.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.coarsening import CoarseningConfig, KIND_GAPPED


def make_kernel(m: int, n: int, k: int, cfg: CoarseningConfig, *,
                bm: int = 128, bn: int = 128, bk: int = 256,
                interpret: bool = True) -> Callable:
    c = cfg.degree
    bn = bn * cfg.vector_width                      # SIMD analog: wider lanes
    if m % (c * bm) or n % bn or k % bk:
        raise ValueError(f"shape ({m},{n},{k}) not tileable by "
                         f"C*bm={c*bm}, bn={bn}, bk={bk}")
    gm, gn, gk = m // (c * bm), n // bn, k // bk
    gapped = cfg.kind == KIND_GAPPED

    def body(a_ref, b_ref, o_ref):
        kk = pl.program_id(2)

        @pl.when(kk == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        a = a_ref[...].reshape(c * bm, bk)
        acc = jnp.dot(a, b_ref[...], preferred_element_type=jnp.float32)
        o_ref[...] += acc.reshape(o_ref.shape)

    if gapped:
        # A viewed (C, M/C, K): program (i,j,kk) fuses row-blocks i, i+gm, ...
        a_spec = pl.BlockSpec((c, bm, bk), lambda i, j, kk: (0, i, kk))
        o_spec = pl.BlockSpec((c, bm, bn), lambda i, j, kk: (0, i, j))
        a_view = lambda a: a.reshape(c, m // c, k)
        o_shape = (c, m // c, n)
        o_unview = lambda o: o.reshape(m, n)
    else:
        a_spec = pl.BlockSpec((c * bm, bk), lambda i, j, kk: (i, kk))
        o_spec = pl.BlockSpec((c * bm, bn), lambda i, j, kk: (i, j))
        a_view = lambda a: a
        o_shape = (m, n)
        o_unview = lambda o: o

    call = pl.pallas_call(
        body,
        grid=(gm, gn, gk),
        in_specs=[a_spec, pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(o_shape, jnp.float32),
        cost_estimate=pl.CostEstimate(flops=2 * m * n * k,
                                      bytes_accessed=4 * (m * k + k * n + m * n),
                                      transcendentals=0),
        interpret=interpret,
    )

    def run(a, b):
        return o_unview(call(a_view(a), b))

    return run
