"""Coarsenable blocked matmul — the dense-linear-algebra app analog (LU/NN/GE).

Coarsening fuses C row-blocks of A (and of the output) into one program:

  consecutive : one (C*bm, bk) contiguous A tile  -> 1 wide DMA
  gapped      : C strided (bm, bk) tiles          -> C narrow DMAs

Either way the B tile is fetched ONCE per program instead of once per
row-block — the paper's "reduction in the total number of memory accesses"
(§III.B) applied to the MXU: B traffic drops by C.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.coarsening import CoarseningConfig, KIND_GAPPED


def _a_o_layout(m: int, n: int, k: int, c: int, bm: int, bn: int, bk: int,
                gapped: bool):
    """The A-operand / output BlockSpecs + views shared by the dense and the
    dequant-fused kernels (coarsening lives entirely on the A row axis)."""
    if gapped:
        # A viewed (C, M/C, K): program (i,j,kk) fuses row-blocks i, i+gm, ...
        a_spec = pl.BlockSpec((c, bm, bk), lambda i, j, kk: (0, i, kk))
        o_spec = pl.BlockSpec((c, bm, bn), lambda i, j, kk: (0, i, j))
        a_view = lambda a: a.reshape(c, m // c, k)
        o_shape = (c, m // c, n)
        o_unview = lambda o: o.reshape(m, n)
    else:
        a_spec = pl.BlockSpec((c * bm, bk), lambda i, j, kk: (i, kk))
        o_spec = pl.BlockSpec((c * bm, bn), lambda i, j, kk: (i, j))
        a_view = lambda a: a
        o_shape = (m, n)
        o_unview = lambda o: o
    return a_spec, o_spec, a_view, o_shape, o_unview


def make_kernel(m: int, n: int, k: int, cfg: CoarseningConfig, *,
                bm: int = 128, bn: int = 128, bk: int = 256,
                interpret: bool = True) -> Callable:
    c = cfg.degree
    bn = bn * cfg.vector_width                      # SIMD analog: wider lanes
    if m % (c * bm) or n % bn or k % bk:
        raise ValueError(f"shape ({m},{n},{k}) not tileable by "
                         f"C*bm={c*bm}, bn={bn}, bk={bk}")
    gm, gn, gk = m // (c * bm), n // bn, k // bk
    gapped = cfg.kind == KIND_GAPPED

    def body(a_ref, b_ref, o_ref):
        kk = pl.program_id(2)

        @pl.when(kk == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        a = a_ref[...].reshape(c * bm, bk)
        acc = jnp.dot(a, b_ref[...], preferred_element_type=jnp.float32)
        o_ref[...] += acc.reshape(o_ref.shape)

    a_spec, o_spec, a_view, o_shape, o_unview = _a_o_layout(
        m, n, k, c, bm, bn, bk, gapped)

    call = pl.pallas_call(
        body,
        grid=(gm, gn, gk),
        in_specs=[a_spec, pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(o_shape, jnp.float32),
        cost_estimate=pl.CostEstimate(flops=2 * m * n * k,
                                      bytes_accessed=4 * (m * k + k * n + m * n),
                                      transcendentals=0),
        interpret=interpret,
    )

    def run(a, b):
        return o_unview(call(a_view(a), b))

    return run


def make_qkernel(m: int, n: int, k: int, cfg: CoarseningConfig, *,
                 bits: int = 8, group: int = 32,
                 bm: int = 128, bn: int = 128, bk: int = 256,
                 interpret: bool = True) -> Callable:
    """Dequant-fused quantized-B matmul: B arrives PACKED (int8 payload, or
    int4 nibbles two-per-byte along K) plus scales, so the B-pane DMA moves
    2-4x fewer bytes; the pane is dequantized in VMEM once per program and
    the dot runs exactly like the dense kernel.  Coarsening is unchanged
    (A row-blocks), which is the point: the tuner can trade the cheaper B
    traffic against the extra per-pane dequant compute.

    Returned callable: run(a (m,k), bq, bscale) -> (m,n) f32 where
      bits=8: bq (k,n) int8, bscale (1,n);  bits=4: bq (k/2,n) uint8
      offset-binary nibbles, bscale (k/group, n).
    """
    c = cfg.degree
    bn = bn * cfg.vector_width
    if m % (c * bm) or n % bn or k % bk:
        raise ValueError(f"shape ({m},{n},{k}) not tileable by "
                         f"C*bm={c*bm}, bn={bn}, bk={bk}")
    if bits not in (8, 4):
        raise ValueError(f"bits must be 8 or 4, got {bits}")
    if bits == 4 and (bk % 2 or group % 2 or bk % group):
        raise ValueError(f"int4 needs even bk tiled by group, got "
                         f"bk={bk}, group={group}")
    gm, gn, gk = m // (c * bm), n // bn, k // bk
    gapped = cfg.kind == KIND_GAPPED

    def body(a_ref, bq_ref, bs_ref, o_ref):
        kk = pl.program_id(2)

        @pl.when(kk == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        a = a_ref[...].reshape(c * bm, bk).astype(jnp.float32)
        if bits == 8:
            w = bq_ref[...].astype(jnp.float32) * bs_ref[...]   # (bk,bn)*(1,bn)
        else:
            from repro.quant.qtypes import unpack_int4
            vals = unpack_int4(bq_ref[...], axis=0)             # (bk, bn)
            w = vals * jnp.repeat(bs_ref[...], group, axis=0)
        acc = jnp.dot(a, w, preferred_element_type=jnp.float32)
        o_ref[...] += acc.reshape(o_ref.shape)

    a_spec, o_spec, a_view, o_shape, o_unview = _a_o_layout(
        m, n, k, c, bm, bn, bk, gapped)
    if bits == 8:
        bq_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
        bs_spec = pl.BlockSpec((1, bn), lambda i, j, kk: (0, j))
    else:
        bq_spec = pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j))
        bs_spec = pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j))

    wbytes = k * n * bits // 8
    call = pl.pallas_call(
        body,
        grid=(gm, gn, gk),
        in_specs=[a_spec, bq_spec, bs_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(o_shape, jnp.float32),
        cost_estimate=pl.CostEstimate(
            flops=2 * m * n * k + 2 * k * n * gm,   # dot + per-pane dequant
            bytes_accessed=4 * (m * k + m * n) + wbytes,
            transcendentals=0),
        interpret=interpret,
    )

    def run(a, bq, bs):
        return o_unview(call(a_view(a), bq, bs))

    return run
