"""The paper's Fig. 6 microbenchmark kernel, as a coarsenable Pallas kernel.

Template:  load phase (n_loads streams) -> arithmetic phase (AI-controlled op
chain) -> store phase.  Divergence variants mirror §III.C / Fig. 7:

  base                no control flow
  if_id               branch on the work-item id (direct divergence)
  if_in               branch on a loaded value (indirect divergence)
  for_const_if_id     constant-bound loop wrapping an id-branch
  for_in_if_in        data-bound loop wrapping a data-branch
  div2 / div4         if-in divergence degree 2 / 4 (paper Fig. 13)

TPU adaptation notes (DESIGN.md §2): id-dependent predicates are trace-time
iota patterns (foldable, cheap — the analog of the offline compiler exploiting
known divergence); data-dependent predicates force predication of *all* paths;
data-bound loops run to a static worst-case bound with per-iteration masks
(the analog of the paper's pipeline-flush penalty).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.coarsening import (
    CoarseningConfig,
    StreamPlan,
    plan_stream,
    pallas_stream_call,
    flat_pid,
    KIND_GAPPED,
)

VARIANTS = ("base", "if_id", "if_in", "for_const_if_id", "for_in_if_in",
            "div2", "div4")
FOR_CONST_TRIPS = 5
FOR_IN_MAX_TRIPS = 8


def _arith_chain(regs: list, n_arith: int) -> jax.Array:
    """Bounded op chain: AI arithmetic ops per element (paper Fig. 6 body)."""
    acc = regs[0]
    n = len(regs)
    for t in range(n_arith):
        r = regs[(t + 1) % n]
        m = t % 3
        if m == 0:
            acc = acc + r
        elif m == 1:
            acc = acc - r
        else:
            acc = acc * 0.5 + r * 0.5
    return acc


def _global_ids(plan: StreamPlan, i) -> jax.Array:
    """Global element index of each (k, j) element of program i's tile."""
    c, b, g = plan.cfg.degree, plan.block, plan.grid
    k = jax.lax.broadcasted_iota(jnp.int32, (c, b), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (c, b), 1)
    if plan.contiguous:
        return (i * c + k) * b + j
    return k * (g * b) + i * b + j


def _variant_compute(variant: str, regs: list, gids: jax.Array,
                     n_arith: int) -> jax.Array:
    """Apply the divergence variant around the arithmetic chain."""
    if variant == "base":
        return _arith_chain(regs, n_arith)
    if variant == "if_id":
        taken = _arith_chain(regs, n_arith)
        return jnp.where(gids % 2 == 0, taken, regs[0])
    if variant == "if_in":
        taken = _arith_chain(regs, n_arith)
        pred = jnp.floor(jnp.abs(regs[-1]) * 16.0).astype(jnp.int32) % 2 == 0
        return jnp.where(pred, taken, regs[0])
    if variant == "for_const_if_id":
        def body(_, acc):
            taken = _arith_chain([acc] + regs[1:], max(1, n_arith // FOR_CONST_TRIPS))
            return jnp.where(gids % 2 == 0, taken, acc)
        return jax.lax.fori_loop(0, FOR_CONST_TRIPS, body, regs[0])
    if variant == "for_in_if_in":
        bound = jnp.floor(jnp.abs(regs[-1]) * 8.0).astype(jnp.int32) % FOR_IN_MAX_TRIPS
        pred_in = jnp.floor(jnp.abs(regs[-2]) * 16.0).astype(jnp.int32) % 2 == 0

        def body(t, acc):
            live = t < bound
            taken = _arith_chain([acc] + regs[1:], max(1, n_arith // FOR_IN_MAX_TRIPS))
            return jnp.where(live & pred_in, taken, acc)
        return jax.lax.fori_loop(0, FOR_IN_MAX_TRIPS, body, regs[0])
    if variant in ("div2", "div4"):
        deg = 2 if variant == "div2" else 4
        sel = jnp.floor(jnp.abs(regs[-1]) * 16.0).astype(jnp.int32) % deg
        per_path = max(1, n_arith)
        out = _arith_chain(regs, per_path)
        for p in range(1, deg):
            alt = _arith_chain(regs[p % len(regs):] + regs[:p % len(regs)], per_path)
            out = jnp.where(sel == p, alt, out)
        return out
    raise ValueError(f"unknown variant {variant!r}")


def make_kernel(n: int, cfg: CoarseningConfig, *, n_loads: int = 8,
                ai: int = 6, variant: str = "base",
                block: int = 1024, interpret: bool = True) -> Callable:
    """Build the coarsened streaming kernel: (in0..in{L-1}) -> out.

    ai follows the paper: arithmetic-ops / memory-ops, memory ops =
    n_loads + 1 store, so the chain has ai * (n_loads + 1) ops.
    """
    if variant not in VARIANTS:
        raise ValueError(f"variant must be in {VARIANTS}")
    plan = plan_stream(n, cfg, block=block)
    n_arith = ai * (n_loads + 1)

    def body(*refs):
        in_refs, o_ref = refs[:-1], refs[-1]
        i = flat_pid(plan)
        c, b = plan.cfg.degree, plan.block
        regs = [r[...].reshape(c, b) for r in in_refs]
        gids = _global_ids(plan, i)
        out = _variant_compute(variant, regs, gids, n_arith)
        o_ref[...] = out.reshape(o_ref.shape)

    flops = n * n_arith
    bytes_moved = n * 4 * (n_loads + 1)
    cost = pl.CostEstimate(flops=flops, bytes_accessed=bytes_moved,
                           transcendentals=0)
    return pallas_stream_call(body, plan, n_loads, interpret=interpret,
                              cost_estimate=cost)
