"""Coarsened embedding gather — the paper's irregular-access pattern INSIDE
the LM: out[i, :] = table[ids[i], :].

This is `gather_stream` grown to model scale: the index stream (token ids) is
regular and coarsenable; the row fetches are data-dependent.  The TPU-native
structure is a *scalar-prefetch* grid: the ids block for each grid step is
prefetched into SMEM, and the kernel gathers rows from the VMEM-resident
table shard (the LSU-cache analog is explicit: vocab shards live in VMEM,
hit rate = fraction of ids in this shard).

  consecutive : one program owns C adjacent id-blocks -> one wide id DMA.
  gapped      : C strided id-blocks -> C narrow id DMAs.

For the full-vocab tables of the assigned archs the table stays in HBM/ANY
on real hardware with per-row DMAs; in interpret mode we keep the table
resident (correctness path) and `core.analysis.gather_cost` prices the
realistic fetch, as with gather_stream (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.coarsening import CoarseningConfig, plan_stream, flat_pid


def make_kernel(n_ids: int, vocab: int, d: int, cfg: CoarseningConfig, *,
                block: int = 256, interpret: bool = True) -> Callable:
    """Build ids:(N,) table:(V,d) -> out:(N,d)."""
    plan = plan_stream(n_ids, cfg, block=block)
    c, b = cfg.degree, plan.block

    def body(ids_ref, table_ref, o_ref):
        ids = ids_ref[...].reshape(c * b)
        rows = table_ref[...][ids]                  # in-VMEM row gather
        o_ref[...] = rows.reshape(o_ref.shape)

    ids_spec = pl.BlockSpec(plan.block_shape, plan.index_map)
    # out blocks: same distribution with a trailing feature dim
    if plan.contiguous:
        out_view = (plan.grid, c, b, d)
        out_spec = pl.BlockSpec((1, c, b, d), lambda i: (i, 0, 0, 0))
    else:
        out_view = (c, plan.grid, b, d)
        out_spec = pl.BlockSpec((c, 1, b, d), lambda i: (0, i, 0, 0))

    call = pl.pallas_call(
        body,
        grid=(plan.grid,),
        in_specs=[ids_spec, pl.BlockSpec((vocab, d), lambda i: (0, 0))],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_view, jnp.float32),
        interpret=interpret,
    )

    def run(ids, table):
        out = call(ids.reshape(plan.view_shape), table)
        if plan.contiguous:
            return out.reshape(n_ids, d)
        # gapped view: (C, G, B, d) -> logical order (G*B per slice)
        return out.reshape(n_ids, d)

    return run


def ref_embed_gather(ids: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)
