"""Windowed gather with scalar-prefetched, data-dependent window fetches —
the faithful TPU implementation of the paper's cached LSU (Fig. 5b / Fig. 12).

Unlike `gather_stream` (whole-table-resident correctness path), each grid
step DMAs only a 2L-wide, L-aligned window of the table selected by a
PREFETCHED per-block base row — Pallas's scalar-prefetch mechanism, the
TPU-native data-dependent block fetch.  The LSU-cache analogy is exact:

  window residency  = the LSU cache line(s)
  locality L        = the paper's irregularity degree
  per-slice windows = gapped coarsening needs C distinct windows per program
                      (C narrow cached LSUs); consecutive programs share
                      locality and fetch C windows of ADJACENT id-blocks

Constraints: indices must come from `gather_stream.make_indices(n, V, L)`
(each L-long run of stream positions draws from one L-wide table window) and
the stream block B must satisfy B <= L, L % B == 0, so each fused slice's
indices fit one aligned 2L window.  The table is viewed (V/L, L) and the
window BlockSpec is (2, L) with a prefetched row index — an L-aligned 2L-wide
fetch always covers an arbitrary L-window.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.coarsening import CoarseningConfig, plan_stream


def make_kernel(n: int, table: int, cfg: CoarseningConfig, *,
                window: int = 1024, block: int = 256, ai: int = 6,
                interpret: bool = True) -> Callable:
    from repro.kernels.ew_stream import _arith_chain

    if block > window or window % block or table % window:
        raise ValueError("need block <= window, window % block == 0, "
                         "table % window == 0")
    plan = plan_stream(n, cfg, block=block)
    c, b, g = cfg.degree, plan.block, plan.grid
    n_rows = table // window
    n_arith = ai * 2                      # 1 load + 1 store

    def body(bases_ref, idx_ref, *refs):
        win_refs, o_ref = refs[:-1], refs[-1]
        i = pl.program_id(0)
        idx = idx_ref[...].reshape(c, b)
        outs = []
        for k in range(c):
            base_row = bases_ref[i, k]
            local = idx[k] - base_row * window
            # two row-granular fetches = the 2L-wide L-aligned window
            rows = jnp.concatenate(
                [win_refs[2 * k][...].reshape(window),
                 win_refs[2 * k + 1][...].reshape(window)])
            outs.append(rows[local])
        vals = jnp.stack(outs)            # (C, B)
        o_ref[...] = _arith_chain([vals], n_arith).reshape(o_ref.shape)

    idx_spec = pl.BlockSpec(plan.block_shape, lambda i, bases: plan.index_map(i))
    # (1, L) blocks index in single-row units -> row-granular placement;
    # each slice fetches rows base and base+1 of the (V/L, L) table view
    win_specs = []
    for k in range(c):
        win_specs.append(pl.BlockSpec(
            (1, window), lambda i, bases, k=k: (bases[i, k], 0)))
        win_specs.append(pl.BlockSpec(
            (1, window), lambda i, bases, k=k: (bases[i, k] + 1, 0)))
    out_spec = pl.BlockSpec(plan.block_shape, lambda i, bases: plan.index_map(i))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(plan.grid,),
        in_specs=[idx_spec] + win_specs,
        out_specs=out_spec,
    )
    call = pl.pallas_call(
        body, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(plan.view_shape, jnp.float32),
        interpret=interpret,
    )

    def plan_bases(idx: np.ndarray) -> np.ndarray:
        """Host-side planner: window base row per (program, slice)."""
        view = np.asarray(idx).reshape(plan.view_shape)     # (G,C,B)|(C,G,B)
        if plan.contiguous:
            mins = view.min(axis=2)                         # (G, C)
        else:
            mins = view.min(axis=2).T                       # (C, G) -> (G, C)
        bases = np.minimum(mins // window, n_rows - 2)
        return bases.astype(np.int32)

    def run(idx, tbl):
        bases = jnp.asarray(plan_bases(np.asarray(idx)))
        wins = [tbl.reshape(n_rows, window)] * (2 * c)
        out = call(bases, idx.reshape(plan.view_shape), *wins)
        return out.reshape(n)

    return run


def ref(idx, tbl, ai: int = 6):
    from repro.kernels.ew_stream import _arith_chain
    vals = tbl[idx].reshape(1, -1)
    return _arith_chain([vals], ai * 2).reshape(-1)
