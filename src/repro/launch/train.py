"""Training driver: data pipeline -> sharded train step -> checkpoints, with
watchdog, preemption handling and retry — the single-process version of the
fleet runtime (multi-host launch documented in README §Scale).

CPU-friendly examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m --reduced ...
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.data import DataConfig, TokenPipeline
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, accumulate_grads
from repro.optim.schedule import wsd_schedule
from repro.checkpoint import CheckpointManager
from repro.runtime import StepWatchdog, PreemptionHandler, retry_step
from repro.distributed.sharding import (param_shardings, batch_specs,
                                        make_shard_ctx)
from repro.launch.steps import StepConfig, build_train_step


def make_mesh_for_host():
    devs = jax.devices()
    return jax.make_mesh((len(devs), 1), ("data", "model"))


def train(cfg: ModelConfig, *, steps: int, batch: int, seq: int,
          ckpt_dir: str | None, n_micro: int = 1, remat: str = "none",
          lr: float = 3e-4, save_every: int = 50, seed: int = 0,
          log_every: int = 10, mesh: Mesh | None = None,
          fail_at_step: int | None = None, tune: str | None = None,
          quant: str | None = None):
    if tune:
        # pre-tune the ops-level kernel families at this run's geometry so
        # any cfg="auto" dispatch resolves from the persisted cache instead
        # of searching — including the flash_attention/-_bwd pair the train
        # step itself hits when cfg.attn_backend="pallas" (the forward and
        # the dK/dV backward coarsen different axes, so both are warmed).
        from repro.tune import warm_from_flag
        warm_from_flag(cfg, tune, seq=seq, batch=batch)
    mesh = mesh or make_mesh_for_host()
    with mesh:
        losses, params = _train_in_mesh(
            cfg, steps=steps, batch=batch, seq=seq, ckpt_dir=ckpt_dir,
            n_micro=n_micro, remat=remat, lr=lr, save_every=save_every,
            seed=seed, log_every=log_every, mesh=mesh,
            fail_at_step=fail_at_step)
    if quant and quant != "none":
        _quant_eval(cfg, params, quant, batch=batch, seq=seq, seed=seed)
    return losses, params


def _quant_eval(cfg: ModelConfig, params, quant: str, *, batch, seq, seed):
    """Post-training weight-only quantization report: quantize the trained
    params (repro.quant) and compare the eval loss on one held-out batch
    against the f32 path — the serving-readiness parity check for --quant."""
    from repro.quant import quantize_params, tree_nbytes
    data = TokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=seed + 991,
        frontend=cfg.frontend, d_model=cfg.d_model,
        src_len=min(seq, 512), is_encdec=cfg.is_encdec))
    hb = jax.tree.map(jnp.asarray, data.next_batch())
    loss_f = jax.jit(lambda p, b: M.lm_loss(p, b, cfg)[0])
    dense = float(loss_f(params, hb))
    qparams, rep = quantize_params(params, quant, group=cfg.quant_group)
    quant_loss = float(loss_f(qparams, hb))
    print(f"quant[{quant}]: eval loss {quant_loss:.4f} vs f32 {dense:.4f} "
          f"(delta {quant_loss - dense:+.4f}); params "
          f"{tree_nbytes(params) / 2**20:.2f} -> "
          f"{tree_nbytes(qparams) / 2**20:.2f} MiB "
          f"({rep['quantized']} leaves quantized)")


def _train_in_mesh(cfg: ModelConfig, *, steps, batch, seq, ckpt_dir, n_micro,
                   remat, lr, save_every, seed, log_every, mesh,
                   fail_at_step):
    sc = StepConfig(seq=seq, batch=batch, kind="train", n_micro=n_micro,
                    remat=remat, opt=AdamWConfig(lr=lr))
    step_fn, _, in_sh, out_sh = build_train_step(cfg, mesh, sc)
    jit_step = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=(0, 1))

    data = TokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=seed + 7,
        frontend=cfg.frontend, d_model=cfg.d_model,
        src_len=min(seq, 512), is_encdec=cfg.is_encdec))

    params = jax.jit(
        lambda k: M.lm_init(k, cfg), out_shardings=in_sh[0]
    )(jax.random.PRNGKey(seed))
    opt_state = jax.jit(adamw_init, out_shardings=in_sh[1])(params)

    start_step = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=3, save_interval_steps=save_every)
        latest = mgr.latest_step()
        if latest is not None:
            skel = jax.tree.map(np.asarray, {"params": params, "opt": opt_state})
            restored, manifest = mgr.restore(
                skel, shardings={"params": in_sh[0], "opt": in_sh[1]})
            params, opt_state = restored["params"], restored["opt"]
            start_step = manifest["extra"]["step"]
            data.load_state_dict(manifest["extra"]["data"])
            print(f"resumed from step {start_step}")

    wd = StepWatchdog(threshold=4.0, hang_timeout=3600)
    pre = PreemptionHandler().install()
    losses = []
    bsh = in_sh[2]

    for step in range(start_step, steps):
        if pre.preempted:
            print(f"preempted at step {step}; checkpointing")
            break
        batch_np = data.next_batch()
        hb = jax.tree.map(lambda a, s: jax.device_put(a, s), batch_np, bsh)

        def run():
            if fail_at_step == step and not getattr(run, "failed", False):
                run.failed = True
                from repro.runtime import SimulatedFailure
                raise SimulatedFailure(f"injected failure at step {step}")
            return jit_step(params, opt_state, hb)

        with wd.step(step):
            params, opt_state, loss, gn = retry_step(
                run, retries=2,
                on_retry=lambda a, e: print(f"  retry {a}: {e}"))
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {float(loss):.4f} gnorm {float(gn):.3f}")
        losses.append(float(loss))
        if mgr and mgr.should_save(step):
            mgr.save(step, {"params": params, "opt": opt_state},
                     extra={"step": step + 1, "data": data.state_dict()})
    else:
        step = steps - 1

    if mgr:
        mgr.save(step + 1 if not pre.preempted else step,
                 {"params": params, "opt": opt_state},
                 extra={"step": step + 1, "data": data.state_dict()},
                 blocking=True)
    pre.uninstall()
    return losses, params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    from repro.tune import TUNE_CHOICES
    ap.add_argument("--tune", default=None, choices=[None, *TUNE_CHOICES],
                    help="warm the coarsening tuning cache before training")
    ap.add_argument("--attn-backend", default=None, choices=["ref", "pallas"],
                    help="training attention dispatch: 'pallas' routes the "
                         "blocks through the coarsened custom-VJP flash "
                         "kernel (attn_cfg/attn_bwd_cfg from the tuning "
                         "cache --tune warms)")
    ap.add_argument("--attn-sparse", default=None, choices=["auto", "off"],
                    help="block-sparse dispatch for local-attention layers "
                         "(window set): 'auto' routes eligible prefill "
                         "geometries through the live-index kernel, 'off' "
                         "pins the dense-mask kernel")
    ap.add_argument("--attn-global-stride", type=int, default=None,
                    help="LongFormer-style global columns on local layers: "
                         "every Nth kv position stays visible past the "
                         "window (needs a windowed arch; training through "
                         "a strided pattern differentiates the jnp oracle)")
    ap.add_argument("--quant", default=None,
                    choices=[None, "none", "int8", "int4"],
                    help="after training, quantize the weights (repro.quant "
                         "weight-only) and report the eval-loss delta vs "
                         "f32 on a held-out batch")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.attn_backend:
        import dataclasses
        cfg = dataclasses.replace(cfg, attn_backend=args.attn_backend)
    if args.attn_sparse:
        import dataclasses
        cfg = dataclasses.replace(cfg, attn_sparse=args.attn_sparse)
    if args.attn_global_stride:
        import dataclasses
        cfg = dataclasses.replace(cfg,
                                  attn_global_stride=args.attn_global_stride)
    losses, _ = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                      ckpt_dir=args.ckpt_dir, n_micro=args.n_micro,
                      remat=args.remat, lr=args.lr,
                      save_every=args.save_every, tune=args.tune,
                      quant=args.quant)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    if args.tune:
        from repro.tune import tune_report
        print(tune_report())


if __name__ == "__main__":
    main()
