"""Batched serving driver: fixed-slot continuous batching over the decode
step.  Prompts are ingested token-by-token through the same decode step
(prefill = forced decode), finished sequences free their slot for the next
request — the minimal form of continuous batching that exercises cache
management, slot scheduling and batched sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --slots 4 --requests 8 --gen-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import model as M
from repro.models.config import ModelConfig


class BatchedServer:
    def __init__(self, cfg: ModelConfig, params, *, slots: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0,
                 tune: str | None = None):
        if tune:
            # pre-tune the ops-level kernel families at prompt-ingest scale
            # (slots x max_len tokens — the largest geometry this server
            # touches; per-token decode shapes are below the coarsenable
            # minimum and dispatch uncoarsened)
            from repro.tune import warm_from_flag
            warm_from_flag(cfg, tune, seq=max_len, batch=slots)
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.temperature = temperature
        self.cache = M.lm_init_cache(cfg, slots, max_len,
                                     enc_len=min(max_len, 64))
        self.pos = np.zeros((slots,), np.int32)
        self.active = np.zeros((slots,), bool)
        self.queues: list[list[int]] = [[] for _ in range(slots)]  # to ingest
        self.outputs: list[list[int]] = [[] for _ in range(slots)]
        self.completed: list[list[int]] = []   # archived finished sequences
        self.budget = np.zeros((slots,), np.int32)
        self.key = jax.random.PRNGKey(seed)
        self._step = jax.jit(
            lambda p, c, t, po: M.lm_decode_step(p, c, t, po, cfg))

    def try_admit(self, prompt: list[int], gen_tokens: int) -> bool:
        for s in range(self.slots):
            if not self.active[s]:
                self.active[s] = True
                self.pos[s] = 0
                self.queues[s] = list(prompt)
                self.outputs[s] = []
                self.budget[s] = gen_tokens
                # fresh cache rows for the slot
                self.cache = jax.tree.map(
                    lambda a: a.at[:, s].set(0.0) if a.ndim >= 2 else a,
                    self.cache)
                return True
        return False

    def step(self) -> None:
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in range(self.slots):
            if not self.active[s]:
                continue
            if self.queues[s]:
                tokens[s, 0] = self.queues[s][0]
            elif self.outputs[s]:
                tokens[s, 0] = self.outputs[s][-1]
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(tokens),
                                        jnp.asarray(self.pos))
        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            nxt = jax.random.categorical(sub, logits / self.temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        nxt = np.asarray(nxt)
        for s in range(self.slots):
            if not self.active[s]:
                continue
            if self.queues[s]:
                self.queues[s].pop(0)          # still ingesting the prompt
                if not self.queues[s]:
                    self.outputs[s].append(int(nxt[s]))  # first generated tok
            else:
                self.outputs[s].append(int(nxt[s]))
            self.pos[s] += 1
            if (not self.queues[s] and len(self.outputs[s]) >= self.budget[s]) \
                    or self.pos[s] >= self.max_len - 1:
                self.active[s] = False
                self.completed.append(list(self.outputs[s]))

    @property
    def any_active(self) -> bool:
        return bool(self.active.any())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    from repro.tune import TUNE_CHOICES
    ap.add_argument("--tune", default=None, choices=[None, *TUNE_CHOICES],
                    help="warm the coarsening tuning cache before serving")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(cfg, params, slots=args.slots,
                           max_len=args.max_len, tune=args.tune)

    rng = np.random.default_rng(0)
    pending = [list(rng.integers(1, cfg.vocab, args.prompt_len))
               for _ in range(args.requests)]
    done, t0, steps = 0, time.perf_counter(), 0
    while pending or server.any_active:
        while pending and server.try_admit(pending[0], args.gen_tokens):
            pending.pop(0)
        if not server.any_active:
            break
        server.step()
        steps += 1
        newly = sum(1 for s in range(server.slots)
                    if not server.active[s] and server.outputs[s])
    dt = time.perf_counter() - t0
    total_tokens = args.requests * (args.prompt_len + args.gen_tokens)
    print(f"served {args.requests} requests / {total_tokens} tokens in "
          f"{steps} batched steps, {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s on CPU interpret-scale)")
    print("sample output:", server.outputs[0][:8])


if __name__ == "__main__":
    main()
