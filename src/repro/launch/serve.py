"""Batched serving driver: fixed-slot continuous batching with CHUNKED
PREFILL and on-device decode blocks.

Admission runs the prompt through `lm_prefill` in seq-chunks — each chunk is
one batched model step that fills the admitted slot's K/V + recurrent caches
(other slots' caches are mask-protected) — so a request costs
``ceil(prompt_len/chunk) + gen_tokens`` model steps instead of
``prompt_len + gen_tokens``.  Decode then runs up to ``decode_block`` steps
fully on-device (a jitted lax.scan over `lm_decode_step` with in-loop
sampling) between host syncs.  Decode attention dispatches to the coarsened
split-KV kernel when the model config selects ``decode_backend='pallas'``.

``--quant int8|int4`` serves weight-only-quantized params (repro.quant;
dequant-fused kernels where the geometry allows, dense-dequant fallback
elsewhere) and ``--kv-quant int8`` switches the K/V cache to int8 payloads
with per-(token, kv-head) scales, quantized on append — together they
roughly double the slots*max_len a host can hold; the driver prints the
weight/cache memory next to tok/s.

``--cache paged`` swaps the fixed-stride per-slot cache for the paged KV
cache (repro.serve): a global page pool + per-slot block tables, FCFS
admission with preemption on pool exhaustion, and shared-prefix page
refcounting.  The driver then runs as a streaming front-end — requests are
submitted to the Scheduler, which admits/preempts/retires against the
PagedEngine (examples/serve_batched.py is a client of the same API).

``--spec-k K`` (paged only) turns on speculative decoding: a draft model
(``--draft-config``: an arch name, ``self``, or the default `draft_of`
shrink) proposes K tokens per slot per step and the target scores all K+1
positions in one batched pass through the short-q coarsened verify kernel,
accepting the longest matching prefix and rolling rejected pages back; the
driver reports the acceptance rate next to tok/s.

  PYTHONPATH=src python -m repro.launch.serve --cache paged --spec-k 4 \
      --draft-config self --slots 3 --requests 6 --gen-tokens 24

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --slots 4 --requests 8 --prompt-len 32 --chunk 16 --gen-tokens 16 \
      --quant int8 --kv-quant int8

  PYTHONPATH=src python -m repro.launch.serve --cache paged --num-pages 24 \
      --page-size 16 --slots 4 --requests 8 --gen-tokens 16

Robustness (paged only): ``--host-swap-mib`` bounds the host budget for
swap-out eviction (suspend/resume instead of recompute), ``--deadline`` /
``--max-queue-wait`` / ``--max-waiting`` set the cancellation and
backpressure contract, and ``--fault-seed --fault-admit/-decode/-transient/
-nan`` run the whole trace under deterministic fault injection
(repro.serve.faults) — completed outputs stay bitwise identical and a page
leak assertion runs at shutdown.  Ctrl-C drains gracefully on both paths.

Observability (paged only): ``--trace-out`` records the run and writes a
Chrome trace-event JSON (request lifecycles on per-request tracks, engine /
scheduler spans on their own tracks; load in Perfetto or chrome://tracing),
``--metrics-out`` dumps the metrics registry at exit (JSON snapshot, or
Prometheus text for ``.prom`` paths), and ``--metrics-every N`` prints a
compact registry line every N scheduler quanta.  Reported tok/s is over
device time (jitted calls + sync); scheduler/host time prints separately.
See repro.obs.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import model as M
from repro.models.config import ModelConfig


@functools.partial(jax.jit, donate_argnums=(0,))
def _slot_reset(cache, slot):
    """Zero one slot's rows across every cache leaf in a single jitted
    scatter (stacked block leaves carry batch on axis 1, tail on axis 0) —
    no whole-tree re-materialization per admission.  Zeros are scattered in
    each leaf's own dtype (int8 payloads of a quantized KV cache included)."""
    return {
        "blocks": [jax.tree.map(
            lambda a: a.at[:, slot].set(jnp.zeros((), a.dtype)), c)
            for c in cache["blocks"]],
        "tail": [jax.tree.map(
            lambda a: a.at[slot].set(jnp.zeros((), a.dtype)), c)
            for c in cache["tail"]],
    }


def _tree_mib(tree) -> float:
    """Total leaf bytes of a pytree (concrete or eval_shape structs), MiB."""
    return sum(int(x.size) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree) if hasattr(x, "dtype")) / 2**20


class BatchedServer:
    def __init__(self, cfg: ModelConfig, params, *, slots: int, max_len: int,
                 chunk: int = 16, decode_block: int = 1,
                 temperature: float = 0.0, seed: int = 0,
                 tune: str | None = None, decode_backend: str | None = None,
                 moe_backend: str | None = None, quant: str | None = None,
                 kv_quant: str | None = None):
        if decode_backend is not None:
            cfg = dataclasses.replace(cfg, decode_backend=decode_backend)
        if moe_backend is not None:
            cfg = dataclasses.replace(cfg, moe_backend=moe_backend)
        if quant is not None:
            cfg = dataclasses.replace(cfg, quant=quant)
        if kv_quant is not None:
            cfg = dataclasses.replace(cfg, kv_quant=kv_quant)
        self.weight_mib_dense = _tree_mib(params)
        self.quant_report = None
        if cfg.quant in ("int8", "int4"):
            from repro.quant import quantize_params
            params, self.quant_report = quantize_params(
                params, cfg.quant, group=cfg.quant_group)
        if tune:
            # pre-tune the kernel families this server's hot loops hit: the
            # ops-level streams at prompt-ingest scale plus the split-KV
            # decode-attention family at the allocated cache length
            from repro.tune import warm_from_flag
            warm_from_flag(cfg, tune, seq=max_len, batch=slots)
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.chunk, self.decode_block = chunk, decode_block
        self.temperature = temperature
        self.weight_mib = _tree_mib(params)
        self.cache = M.lm_init_cache(cfg, slots, max_len,
                                     enc_len=min(max_len, 64))
        # the serving headline: quantized weights + int8 KV cut the bytes
        # that bound slots*max_len per host — report both against dense
        self.cache_mib = _tree_mib(self.cache)
        dense_cfg = dataclasses.replace(cfg, kv_quant="none")
        self.cache_mib_dense = _tree_mib(jax.eval_shape(
            lambda: M.lm_init_cache(dense_cfg, slots, max_len,
                                    enc_len=min(max_len, 64))))
        self.pos = np.zeros((slots,), np.int32)
        self.active = np.zeros((slots,), bool)
        self.outputs: list[list[int]] = [[] for _ in range(slots)]
        self.completed: list[list[int]] = []   # archived finished sequences
        self.budget = np.zeros((slots,), np.int32)
        self.key = jax.random.PRNGKey(seed)
        # perf accounting (prefill and decode reported separately); the
        # *_device_s timers cover only the jitted model calls + the sync, so
        # tok/s reflects device-step time and host bookkeeping is reported
        # as overhead, not smeared into throughput
        self.prefill_steps = self.decode_steps = 0
        self.prefill_tokens = self.decoded_tokens = 0
        self.prefill_s = self.decode_s = 0.0
        self.prefill_device_s = self.decode_device_s = 0.0
        self._prefill = jax.jit(
            lambda p, c, t, po, m: M.lm_prefill(p, {"tokens": t}, cfg,
                                                cache=c, pos0=po, mask=m))
        self._decode_fns: dict[int, callable] = {}

    # -- decode: n steps on-device between host syncs -----------------------

    def _decode_fn(self, n: int):
        fn = self._decode_fns.get(n)
        if fn is not None:
            return fn
        cfg, temp = self.cfg, self.temperature

        def run(params, cache, tok, pos, key):
            def body(carry, i):
                tok, pos, cache = carry
                logits, cache = M.lm_decode_step(params, cache, tok, pos, cfg)
                if temp > 0:
                    nxt = jax.random.categorical(jax.random.fold_in(key, i),
                                                 logits / temp, -1)
                else:
                    nxt = jnp.argmax(logits, -1)
                nxt = nxt.astype(jnp.int32)
                return (nxt[:, None], pos + 1, cache), nxt

            (_, _, cache), toks = jax.lax.scan(
                body, (tok, pos, cache), jnp.arange(n))
            return toks.T, cache                       # (slots, n)

        fn = self._decode_fns[n] = jax.jit(run)
        return fn

    # -- admission: chunked prefill -----------------------------------------

    def try_admit(self, prompt: list[int], gen_tokens: int) -> bool:
        # the cache holds max_len-1 prompt rows + the decode row; an
        # oversized prompt must be rejected loudly — silently truncating it
        # changes what the model conditions on
        if len(prompt) > self.max_len - 1:
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds the max_len "
                f"{self.max_len} cache (holds {self.max_len - 1} prompt "
                f"rows); rejecting instead of truncating")
        free = [s for s in range(self.slots) if not self.active[s]]
        if not free:
            return False
        s = free[0]
        t0 = time.perf_counter()
        self.cache = _slot_reset(self.cache, jnp.asarray(s, jnp.int32))
        mask = jnp.zeros((self.slots,), bool).at[s].set(True)
        logits = None
        td = time.perf_counter()
        for i in range(0, len(prompt), self.chunk):
            piece = prompt[i:i + self.chunk]
            tokens = np.zeros((self.slots, len(piece)), np.int32)
            tokens[s] = piece
            pos0 = jnp.asarray(self.pos, jnp.int32).at[s].set(i)
            logits, self.cache = self._prefill(
                self.params, self.cache, jnp.asarray(tokens), pos0, mask)
            self.prefill_steps += 1
        jax.block_until_ready(logits)
        self.prefill_device_s += time.perf_counter() - td
        self.prefill_s += time.perf_counter() - t0
        self.prefill_tokens += len(prompt)

        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            first = int(jax.random.categorical(
                sub, logits[s] / self.temperature))
        else:
            first = int(jnp.argmax(logits[s]))
        self.active[s] = True
        self.pos[s] = len(prompt)
        self.outputs[s] = [first]
        self.budget[s] = gen_tokens
        self._maybe_finish(s)
        return True

    # -- decode step(s) ------------------------------------------------------

    def step(self) -> None:
        if not self.active.any():
            return
        t0 = time.perf_counter()
        act = np.flatnonzero(self.active)
        remaining = int(min(self.budget[s] - len(self.outputs[s])
                            for s in act))
        headroom = int(self.max_len - 1 - self.pos[act].max())
        n = max(1, min(self.decode_block, remaining, headroom))
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in act:
            tokens[s, 0] = self.outputs[s][-1]
        self.key, sub = jax.random.split(self.key)
        td = time.perf_counter()
        toks, self.cache = self._decode_fn(n)(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.pos), sub)
        toks = np.asarray(toks)              # device sync
        self.decode_device_s += time.perf_counter() - td
        self.decode_steps += n
        for s in act:
            take = min(n, int(self.budget[s]) - len(self.outputs[s]))
            self.outputs[s].extend(int(v) for v in toks[s, :take])
            self.decoded_tokens += take
            self.pos[s] += n
            self._maybe_finish(s)
        self.decode_s += time.perf_counter() - t0

    def _maybe_finish(self, s: int) -> None:
        if len(self.outputs[s]) >= self.budget[s] \
                or self.pos[s] >= self.max_len - 1:
            self.active[s] = False
            self.completed.append(list(self.outputs[s]))

    @property
    def any_active(self) -> bool:
        return bool(self.active.any())


def _serve_paged(args, cfg, params, rng) -> None:
    """Streaming front-end over the paged engine: submit the request trace
    to the Scheduler and let it admit / preempt / retire against the pool."""
    from repro.obs import Registry, TraceRecorder
    from repro.serve import (FaultPlan, FaultyEngine, PagedEngine, Scheduler,
                             SpecPagedEngine, State, draft_of)

    reg = Registry()
    trace = TraceRecorder(enabled=bool(args.trace_out))
    num_pages = args.num_pages if args.num_pages is not None else \
        args.slots * -(-args.max_len // args.page_size) + 1
    kw = dict(slots=args.slots, num_pages=num_pages,
              page_size=args.page_size, max_len=args.max_len,
              chunk=args.chunk, tune=args.tune,
              decode_backend=args.decode_backend,
              moe_backend=args.moe_backend, quant=args.quant,
              kv_quant=args.kv_quant, metrics=reg, trace=trace)
    if args.spec_k:
        if args.draft_config == "self":
            draft_cfg, draft_params = cfg, params
        elif args.draft_config:
            draft_cfg = get_config(args.draft_config)
            if args.reduced:
                draft_cfg = draft_cfg.reduced()
            draft_cfg = dataclasses.replace(draft_cfg, vocab=cfg.vocab)
            draft_params = None        # fresh init at the draft geometry
        else:
            draft_cfg, draft_params = draft_of(cfg), None
        engine = SpecPagedEngine(cfg, params, spec_k=args.spec_k,
                                 draft_cfg=draft_cfg,
                                 draft_params=draft_params,
                                 rng=jax.random.PRNGKey(1), **kw)
    else:
        engine = PagedEngine(cfg, params, decode_block=args.decode_block,
                             **kw)
    plan = None
    front = engine
    if any((args.fault_admit, args.fault_decode, args.fault_transient,
            args.fault_nan)):
        plan = FaultPlan(args.fault_seed, p_admit=args.fault_admit,
                         p_growth=args.fault_decode,
                         p_transient=args.fault_transient,
                         p_nan=args.fault_nan, metrics=reg, trace=trace)
        front = FaultyEngine(engine, plan)
    swap_bytes = None if args.host_swap_mib is None \
        else int(args.host_swap_mib * 2**20)
    sched = Scheduler(front, host_swap_bytes=swap_bytes,
                      max_waiting=args.max_waiting, metrics=reg, trace=trace)
    for _ in range(args.requests):
        sched.submit(list(rng.integers(1, cfg.vocab, args.prompt_len)),
                     args.gen_tokens, deadline=args.deadline,
                     max_queue_wait=args.max_queue_wait)
    t0 = time.perf_counter()
    try:
        if args.metrics_every:
            # same convergence contract as run_until_done, with a compact
            # registry line printed every N scheduler quanta
            while sched.step():
                if sched.steps > 100_000:
                    raise RuntimeError("scheduler did not converge")
                if sched.steps % args.metrics_every == 0:
                    print(f"[q={sched.time}] {reg.line(prefix='sched')}")
            done = sorted(sched.finished, key=lambda r: r.rid)
        else:
            done = sched.run_until_done()
    except KeyboardInterrupt:
        # graceful drain: cancel everything in flight, free its pages,
        # then fall through to the same stats + leak check as a full run
        done = sched.drain(reason="interrupted")
        print(f"\ninterrupted — drained {len(done)} requests")
    dt = time.perf_counter() - t0
    # shutdown leak assertion: every page is either free or live-refcounted
    engine.pool.check()
    assert engine.pool.num_free + engine.pool.num_live \
        == engine.pool.capacity, "page leak at shutdown"
    npre = sum(r.preemptions for r in done)
    total = args.requests * (args.prompt_len + args.gen_tokens)
    print(f"served {len(done)} requests / {total} tokens (paged: "
          f"{engine.pool.capacity} pages x {engine.page_size} tok) in "
          f"{engine.prefill_steps} prefill + {engine.decode_steps} decode "
          f"model steps, {npre} preemptions, {dt:.2f}s")
    by_state = {s.value: n for s in State
                if (n := sum(r.state is s for r in done))}
    print(f"robustness: states {by_state} | swap-evictions "
          f"{engine.suspends} (resumed {engine.resumes}, "
          f"{sched.swap.used_bytes / 2**20:.2f} MiB held, "
          f"{sched.swap.refused} over-budget refusals) | "
          f"decode faults {sched.decode_faults}, NaN rescues "
          f"{engine.nan_rescues}")
    if plan is not None:
        print(f"fault injection: {plan.stats()}")
    # tok/s over DEVICE time (the jitted model calls + their sync), so the
    # number measures the engine, not the scheduler; host/scheduler time is
    # its own line instead of being smeared into throughput
    pdev, ddev = engine.prefill_device_s, engine.decode_device_s
    print(f"prefill: {engine.prefill_tokens} tok in {pdev:.2f}s device "
          f"({engine.prefill_tokens / max(pdev, 1e-9):.1f} tok/s)"
          f" | decode: {engine.decoded_tokens} tok in {ddev:.2f}s device "
          f"({engine.decoded_tokens / max(ddev, 1e-9):.1f} tok/s)"
          f" (CPU interpret-scale)")
    ovh = max(dt - pdev - ddev, 0.0)
    print(f"overhead: scheduler+host {ovh:.2f}s of {dt:.2f}s wall "
          f"({ovh / max(dt, 1e-9):.0%})")
    print(f"memory: weights {engine.weight_mib:.2f} MiB | paged kv pool "
          f"{engine.cache_mib:.2f} MiB "
          f"({engine.pool.tokens_capacity} pooled tokens)")
    if args.spec_k:
        print(f"speculative: K={args.spec_k} "
              f"draft={args.draft_config or 'draft_of'} | "
              f"acceptance {engine.acceptance_rate:.3f} "
              f"({engine.accepted}/{max(engine.drafted, 1)} drafts) | "
              f"{engine.spec_steps} verify steps "
              f"({engine.rescue_steps} tie-guard rescues) for "
              f"{engine.decoded_tokens} tokens "
              f"({engine.decoded_tokens / max(engine.spec_steps, 1):.2f} "
              f"tok/step)")
    print("sample output:", done[0].output[:8])
    if args.trace_out:
        trace.dump(args.trace_out)
        extra = f" ({trace.dropped} dropped)" if trace.dropped else ""
        print(f"trace: {len(trace)} events -> {args.trace_out}{extra}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            if args.metrics_out.endswith(".prom"):
                f.write(reg.to_prometheus())
            else:
                json.dump(reg.snapshot(), f, indent=1, sort_keys=True)
        print(f"metrics: {len(reg)} series -> {args.metrics_out}")
    if args.tune:
        from repro.tune import tune_report
        print(tune_report())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk: prompt tokens per batched step")
    ap.add_argument("--decode-block", type=int, default=8,
                    help="decode steps run on-device between host syncs")
    ap.add_argument("--decode-backend", default=None,
                    choices=[None, "ref", "pallas"],
                    help="decode attention path (pallas = split-KV kernel)")
    ap.add_argument("--moe-backend", default=None,
                    choices=[None, "ref", "pallas"],
                    help="expert FFN path (pallas = fused grouped-expert "
                         "kernel, expert-axis coarsening)")
    ap.add_argument("--quant", default=None,
                    choices=[None, "none", "int8", "int4"],
                    help="weight-only quantization of FFN/MoE/attention "
                         "projections (repro.quant; dequant-fused kernels "
                         "where geometry allows, dense-dequant elsewhere)")
    ap.add_argument("--kv-quant", default=None, choices=[None, "none", "int8"],
                    help="int8 KV cache: quantize-on-append, dequant fused "
                         "into the split-KV decode kernel (~2x the "
                         "slots*max_len a host can hold)")
    from repro.tune import TUNE_CHOICES
    ap.add_argument("--tune", default=None, choices=[None, *TUNE_CHOICES],
                    help="warm the coarsening tuning cache before serving")
    ap.add_argument("--cache", default="contiguous",
                    choices=["contiguous", "paged"],
                    help="KV cache layout: contiguous per-slot strides or "
                         "the paged pool + block tables (repro.serve)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged cache: tokens per page (= the decode "
                         "kernel's kv block)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged cache: pool pages incl. the null page "
                         "(default: slots*max_len/page_size + 1)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding (paged cache only): draft K "
                         "tokens per slot per step and verify them in one "
                         "batched short-q pass (0 = off)")
    ap.add_argument("--draft-config", default=None,
                    help="draft model for --spec-k: an arch name, 'self' "
                         "(draft = target, the acceptance upper bound), or "
                         "unset for the default draft_of() shrink")
    ap.add_argument("--host-swap-mib", type=float, default=None,
                    help="paged: host budget (MiB) for swap-out of preempted "
                         "slots; within budget, eviction suspends to host "
                         "and resumes without re-prefill (unset = unbounded, "
                         "0 = always recompute)")
    ap.add_argument("--deadline", type=int, default=None,
                    help="paged: cancel any request still unfinished after "
                         "this many scheduler quanta (terminal CANCELLED, "
                         "pages freed)")
    ap.add_argument("--max-queue-wait", type=int, default=None,
                    help="paged: reject a request that waits more quanta "
                         "than this between admissions (terminal REJECTED "
                         "with a retry-after hint)")
    ap.add_argument("--max-waiting", type=int, default=None,
                    help="paged: backpressure bound on the wait queue; "
                         "submits past it are shed with REJECTED")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the fault-injection plan (see repro."
                         "serve.faults); faults fire only when a --fault-* "
                         "probability is set")
    ap.add_argument("--fault-admit", type=float, default=0.0,
                    help="P(injected PoolExhausted) per admit call")
    ap.add_argument("--fault-decode", type=float, default=0.0,
                    help="P(injected PoolExhausted page-growth failure) per "
                         "decode call")
    ap.add_argument("--fault-transient", type=float, default=0.0,
                    help="P(injected transient DecodeFault) per decode call")
    ap.add_argument("--fault-nan", type=float, default=0.0,
                    help="P(NaN-poisoned logits row) per emitted row "
                         "(exercises the NaN guard + decode-graph rescue)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="paged: record the run (request lifecycles, "
                         "engine/scheduler spans) and write a Chrome "
                         "trace-event JSON — load it in Perfetto or "
                         "chrome://tracing")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="paged: write the metrics registry at exit — a "
                         "JSON snapshot, or Prometheus text exposition when "
                         "the path ends in .prom")
    ap.add_argument("--metrics-every", type=int, default=None, metavar="N",
                    help="paged: print a compact metrics line every N "
                         "scheduler quanta")
    args = ap.parse_args()
    if args.spec_k and args.cache != "paged":
        ap.error("--spec-k needs --cache paged (the draft KV cache and "
                 "verify rollback are built on the page pool)")
    if args.cache != "paged" and (args.trace_out or args.metrics_out
                                  or args.metrics_every):
        ap.error("--trace-out/--metrics-out/--metrics-every need --cache "
                 "paged (the recorder hooks live in the scheduler/paged-"
                 "engine stack)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.lm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    if args.cache == "paged":
        _serve_paged(args, cfg, params, rng)
        return
    server = BatchedServer(cfg, params, slots=args.slots,
                           max_len=args.max_len, chunk=args.chunk,
                           decode_block=args.decode_block, tune=args.tune,
                           decode_backend=args.decode_backend,
                           moe_backend=args.moe_backend, quant=args.quant,
                           kv_quant=args.kv_quant)

    pending = [list(rng.integers(1, cfg.vocab, args.prompt_len))
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    try:
        while pending or server.any_active:
            while pending and server.try_admit(pending[0], args.gen_tokens):
                pending.pop(0)
            if not server.any_active:
                break
            server.step()
    except KeyboardInterrupt:
        # graceful drain: archive in-flight partial outputs, then fall
        # through to the normal stats so the run is still accounted for
        for s in np.flatnonzero(server.active):
            server.active[s] = False
            server.completed.append(list(server.outputs[s]))
        print(f"\ninterrupted — {len(pending)} requests unserved, "
              f"{len(server.completed)} archived (partial output kept)")
    dt = time.perf_counter() - t0
    total_tokens = args.requests * (args.prompt_len + args.gen_tokens)
    print(f"served {args.requests} requests / {total_tokens} tokens in "
          f"{server.prefill_steps} prefill + {server.decode_steps} decode "
          f"model steps, {dt:.2f}s")
    pdev, ddev = server.prefill_device_s, server.decode_device_s
    print(f"prefill: {server.prefill_tokens} tok in {pdev:.2f}s device "
          f"({server.prefill_tokens / max(pdev, 1e-9):.1f} tok/s)"
          f" | decode: {server.decoded_tokens} tok in {ddev:.2f}s device "
          f"({server.decoded_tokens / max(ddev, 1e-9):.1f} tok/s)"
          f" (CPU interpret-scale)")
    ovh = max(dt - pdev - ddev, 0.0)
    print(f"overhead: driver+host {ovh:.2f}s of {dt:.2f}s wall "
          f"({ovh / max(dt, 1e-9):.0%})")
    print(f"memory: weights {server.weight_mib:.2f} MiB "
          f"(dense {server.weight_mib_dense:.2f} MiB, "
          f"{server.weight_mib_dense / max(server.weight_mib, 1e-9):.2f}x) | "
          f"kv cache {server.cache_mib:.2f} MiB "
          f"(bf16 {server.cache_mib_dense:.2f} MiB, "
          f"{server.cache_mib_dense / max(server.cache_mib, 1e-9):.2f}x)")
    print("sample output:", server.completed[0][:8] if server.completed
          else server.outputs[0][:8])
    if args.tune:
        from repro.tune import tune_report
        print(tune_report())


if __name__ == "__main__":
    main()
