"""Production mesh definitions.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e pod),
axes (data, model).  Multi-pod: 2 pods = 512 chips, axes (pod, data, model);
'pod' is an outer data-parallel axis (params replicated per pod, hierarchical
gradient all-reduce).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices, have {len(devices)}; launch through "
            f"launch/dryrun.py (it forces 512 host devices) or a real fleet")
    return jax.make_mesh(shape, axes, devices=devices[:need])
