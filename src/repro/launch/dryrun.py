import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be executed as its own process (the two lines above run before any jax
import, because jax locks the device count on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]

For each cell it prints memory_analysis() and cost_analysis() (proving fit
and providing the §Roofline terms) and writes a JSON artifact under
experiments/dryrun/.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, TRAIN_N_MICRO, get_config
from repro.core import rooflines
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import StepConfig, build_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def cell_skip_reason(arch: str, shape: str) -> str | None:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.is_subquadratic:
        return "pure full-attention arch: long_500k skipped (DESIGN.md)"
    return None


def run_cell(arch: str, shape: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    overrides = dict(overrides or {})
    cfg = get_config(arch)
    # model-level (not StepConfig) overrides
    if overrides.get("moe_combine_bf16"):
        import dataclasses
        cfg = dataclasses.replace(cfg, moe_combine_dtype="bfloat16")
    sh = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    kw = {k: v for k, v in overrides.items() if k != "moe_combine_bf16"}
    if sh["kind"] == "train" and "n_micro" not in kw:
        kw["n_micro"] = TRAIN_N_MICRO.get(arch, 4)
    sc = StepConfig(seq=sh["seq"], batch=sh["batch"], kind=sh["kind"], **kw)
    fn, abstract, in_sh, out_sh = build_step(cfg, mesh, sc)

    t0 = time.time()
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[sc.kind]
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*abstract)
        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    print(mem)
    ca = compiled.cost_analysis()
    print({k: v for k, v in (ca or {}).items()
           if k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    coll = rooflines.collective_bytes(hlo)

    # model flops: 6 N D for train (fwd+bwd), 2 N D for inference fwd
    n_active = cfg.active_param_count()
    tokens = sh["batch"] * (sh["seq"] if sc.kind in ("train", "prefill") else 1)
    mf = (6 if sc.kind == "train" else 2) * n_active * tokens
    roof = rooflines.analyze(compiled, hlo, chips, model_flops=mf)

    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": sc.kind,
        "compile_s": round(t1 - t0, 1),
        "argument_size": getattr(mem, "argument_size_in_bytes", None),
        "output_size": getattr(mem, "output_size_in_bytes", None),
        "temp_size": getattr(mem, "temp_size_in_bytes", None),
        "flops": roof.flops,
        "bytes_accessed": roof.bytes_accessed,
        "collective_bytes": roof.coll_bytes,
        "collectives": coll,
        "compute_s": roof.compute_s,
        "memory_s": roof.memory_s,
        "collective_s": roof.collective_s,
        "bound": roof.bound,
        "model_flops": mf,
        "useful_ratio": roof.useful_ratio,
        "overrides": overrides or {},
    }
    os.makedirs(ART_DIR, exist_ok=True)
    suffix = "_".join(f"{k}-{v}" for k, v in (overrides or {}).items())
    name = f"{arch}_{shape}_{rec['mesh']}" + (f"_{suffix}" if suffix else "")
    with open(os.path.join(ART_DIR, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCHS)
    ap.add_argument("--shape", default=None, choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--sp-activations", action="store_true")
    ap.add_argument("--xkv-precompute", action="store_true")
    ap.add_argument("--replicate-serve-weights", action="store_true")
    ap.add_argument("--moe-combine-bf16", action="store_true")
    args = ap.parse_args()

    overrides = {}
    if args.n_micro is not None:
        overrides["n_micro"] = args.n_micro
    if args.remat is not None:
        overrides["remat"] = args.remat
    if args.sp_activations:
        overrides["sp_activations"] = True
    if args.xkv_precompute:
        overrides["xkv_precompute"] = True
    if args.replicate_serve_weights:
        overrides["replicate_serve_weights"] = True
    if args.moe_combine_bf16:
        overrides["moe_combine_bf16"] = True

    archs = ARCHS if args.all or not args.arch else (args.arch,)
    shapes = tuple(SHAPES) if args.all or not args.shape else (args.shape,)
    pods = {"single": (False,), "multi": (True,),
            "both": (False, True)}[args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            reason = cell_skip_reason(arch, shape)
            if reason:
                print(f"SKIP {arch} x {shape}: {reason}")
                continue
            for mp in pods:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape, mp, overrides or None)
                    print(f"OK   {tag}: bound={rec['bound']} "
                          f"compute={rec['compute_s']:.3e}s "
                          f"memory={rec['memory_s']:.3e}s "
                          f"coll={rec['collective_s']:.3e}s "
                          f"(compile {rec['compile_s']}s)")
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e!r}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        sys.exit(1)
    print("\nall cells compiled")


if __name__ == "__main__":
    main()
