"""Step builders shared by train.py, serve.py and dryrun.py.

Each builder returns (fn, abstract_args, in_shardings, out_shardings) so the
dry-run can .lower().compile() with ShapeDtypeStructs (no allocation) and the
real drivers can jit the same fn with live arrays.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, accumulate_grads
from repro.distributed.sharding import (
    param_specs, param_shardings, batch_specs, cache_specs, make_shard_ctx,
    dp_axes)


@dataclasses.dataclass(frozen=True)
class StepConfig:
    seq: int
    batch: int                   # global batch (rows)
    kind: str = "train"          # train | prefill | decode
    n_micro: int = 1
    remat: str = "full"
    opt: AdamWConfig = AdamWConfig()
    enc_len: int = 4096          # enc-dec cross-attention source length
    param_dtype: str = "float32"
    serve_dtype: str = "bfloat16"
    # §Perf hillclimb levers (flag-gated so baseline/optimized both lower)
    sp_activations: bool = False         # Megatron-SP residual sharding
    xkv_precompute: bool = False         # enc-dec: cross-K/V outside scan
    replicate_serve_weights: bool = False  # decode: no FSDP gather


def _named(mesh, tree_of_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def abstract_batch(cfg: ModelConfig, sc: StepConfig):
    b, s = sc.batch, sc.seq
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    batch = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
    if cfg.is_encdec:
        batch["src_frames"] = sds((b, min(sc.enc_len, s), cfg.d_model),
                                  jnp.bfloat16)
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
        batch["pos3"] = sds((b, 3, s), i32)          # (B,3,S): microbatchable
    return batch


def abstract_params(cfg: ModelConfig, dtype=None):
    tree = jax.eval_shape(lambda: M.lm_init(jax.random.PRNGKey(0), cfg))
    if dtype is not None:
        tree = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.dtype(dtype)), tree)
    return tree


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, mesh: Mesh, sc: StepConfig):
    shard = make_shard_ctx(mesh, sp="model" if sc.sp_activations else None)

    def train_step(params, opt_state, batch):
        def loss_fn(p, b):
            return M.lm_loss(p, b, cfg, shard=shard, remat=sc.remat,
                             xkv_precompute=sc.xkv_precompute)

        loss, grads, metrics = accumulate_grads(loss_fn, params, batch,
                                                sc.n_micro)
        params, opt_state, gn = adamw_update(params, grads, opt_state, sc.opt)
        return params, opt_state, loss, gn

    p_abs = abstract_params(cfg)
    o_abs = jax.eval_shape(adamw_init, p_abs)
    b_abs = abstract_batch(cfg, sc)

    psh = param_shardings(p_abs, mesh)
    osh = {"m": psh, "v": psh,
           "step": NamedSharding(mesh, P())}
    bsh = _named(mesh, batch_specs(cfg, mesh, batch=sc.batch))
    scalar = NamedSharding(mesh, P())
    return (train_step, (p_abs, o_abs, b_abs), (psh, osh, bsh),
            (psh, osh, scalar, scalar))


# ---------------------------------------------------------------------------
# prefill (inference forward; logits for the last position)
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig, mesh: Mesh, sc: StepConfig):
    shard = make_shard_ctx(mesh, sp="model" if sc.sp_activations else None)

    def prefill_step(params, batch):
        hidden, _ = M.lm_apply(params, batch, cfg, shard=shard, remat="none")
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (hidden[:, -1] @ head.astype(hidden.dtype))
        return logits.astype(jnp.float32)[:, : cfg.vocab]

    p_abs = abstract_params(cfg, dtype=sc.serve_dtype)
    b_abs = abstract_batch(cfg, sc)
    b_abs.pop("labels")
    psh = param_shardings(p_abs, mesh)
    bspecs = batch_specs(cfg, mesh, batch=sc.batch)
    bspecs.pop("labels")
    bsh = _named(mesh, bspecs)
    out = NamedSharding(mesh, P(dp_axes(mesh), _vocab_axis(cfg, mesh)))
    return prefill_step, (p_abs, b_abs), (psh, bsh), out


def _vocab_axis(cfg, mesh):
    tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    return "model" if cfg.vocab % tp == 0 else None


# ---------------------------------------------------------------------------
# decode (one token against a seq-long cache)
# ---------------------------------------------------------------------------

def build_serve_step(cfg: ModelConfig, mesh: Mesh, sc: StepConfig):
    shard = make_shard_ctx(mesh)

    def serve_step(params, cache, tokens, pos):
        return M.lm_decode_step(params, cache, tokens, pos, cfg, shard=shard)

    b = sc.batch
    p_abs = abstract_params(cfg, dtype=sc.serve_dtype)
    c_abs = jax.eval_shape(
        lambda: M.lm_init_cache(cfg, b, sc.seq, jnp.bfloat16,
                                enc_len=min(sc.enc_len, sc.seq)))
    t_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((b,), jnp.int32)

    psh = param_shardings(p_abs, mesh,
                          serve_replicated=sc.replicate_serve_weights)
    csh = _named(mesh, cache_specs(cfg, mesh, batch=b, seq=sc.seq))
    dp = dp_axes(mesh)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_deg = 1
    for a in dp:
        dp_deg *= axes[a]
    bspec = dp if b % dp_deg == 0 else None
    tsh = NamedSharding(mesh, P(bspec, None))
    possh = NamedSharding(mesh, P(bspec))
    logits_sh = NamedSharding(mesh, P(bspec, _vocab_axis(cfg, mesh)))
    return (serve_step, (p_abs, c_abs, t_abs, pos_abs),
            (psh, csh, tsh, possh), (logits_sh, csh))


def build_step(cfg: ModelConfig, mesh: Mesh, sc: StepConfig):
    if sc.kind == "train":
        return build_train_step(cfg, mesh, sc)
    if sc.kind == "prefill":
        return build_prefill_step(cfg, mesh, sc)
    if sc.kind == "decode":
        return build_serve_step(cfg, mesh, sc)
    raise ValueError(sc.kind)
