"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""
from repro.models.config import ModelConfig, SSM

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280,
    pattern_period=(SSM,), ssm_state=128, ssm_headdim=64, ssm_groups=1,
    expand=2, tie_embeddings=True,
)
