"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, pattern (R,R,L).
[arXiv:2402.19427]"""
from repro.models.config import ModelConfig, RECURRENT, ATTN_LOCAL

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000, head_dim=256,
    pattern_period=(RECURRENT, RECURRENT, ATTN_LOCAL), window=2048,
    lru_width=2560, tie_embeddings=True,
)
