"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, pattern (R,R,L).
[arXiv:2402.19427]"""
from repro.models.config import ModelConfig, RECURRENT, ATTN_LOCAL

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000, head_dim=256,
    pattern_period=(RECURRENT, RECURRENT, ATTN_LOCAL), window=2048,
    lru_width=2560, tie_embeddings=True,
    # every attention layer here is local: with attn_backend="pallas",
    # attn_sparse="auto" takes the block-sparse live-index kernel for
    # window=2048 prefill past ~4k tokens (below that the dense grid is
    # already mostly live)
    attn_sparse="auto",
)
