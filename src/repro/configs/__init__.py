"""Architecture registry: --arch <id> resolves here."""
from repro.models.config import ModelConfig

_MODULES = {
    "qwen2-vl-7b": "qwen2_vl_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "yi-34b": "yi_34b",
    "qwen1.5-4b": "qwen15_4b",
    "qwen3-0.6b": "qwen3_06b",
    "gemma3-1b": "gemma3_1b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-370m": "mamba2_370m",
}

ARCHS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


# input shapes assigned to every architecture (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

# baseline gradient-accumulation per arch for train_4k (fit-driven; see
# EXPERIMENTS.md §Perf M2/C5 — the optimized configs lower these with SP)
TRAIN_N_MICRO = {
    "yi-34b": 16,
    "qwen2-vl-7b": 8,
    "qwen1.5-4b": 8,
    "recurrentgemma-2b": 8,
    "qwen2-moe-a2.7b": 8,
    "olmoe-1b-7b": 8,
    "gemma3-1b": 4,
    "qwen3-0.6b": 4,
    "mamba2-370m": 4,
    "seamless-m4t-large-v2": 4,
}
