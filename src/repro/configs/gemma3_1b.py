"""gemma3-1b [dense] — 5:1 local:global attention, 128k ctx.
[hf:google/gemma-3-1b-pt]"""
from repro.models.config import ModelConfig, ATTN_LOCAL, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab=262144, head_dim=256,
    pattern_period=(ATTN_LOCAL,) * 5 + (ATTN_GLOBAL,), window=512,
    rope_theta=1e6, tie_embeddings=True,
)
