"""gemma3-1b [dense] — 5:1 local:global attention, 128k ctx.
[hf:google/gemma-3-1b-pt]"""
from repro.models.config import ModelConfig, ATTN_LOCAL, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab=262144, head_dim=256,
    pattern_period=(ATTN_LOCAL,) * 5 + (ATTN_GLOBAL,), window=512,
    rope_theta=1e6, tie_embeddings=True,
    # the 5:1 local layers are the block-sparse prefill target: with
    # attn_backend="pallas", attn_sparse="auto" routes window=512 prefill
    # through the live-index kernel (long-context gate: at 32k ctx the
    # index visits ~26x fewer kv blocks than the dense causal grid)
    attn_sparse="auto",
)
