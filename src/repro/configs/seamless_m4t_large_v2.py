"""seamless-m4t-large-v2 [audio] — enc-dec; audio frontend is a stub
providing precomputed frame embeddings. [arXiv:2308.11596]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=256206, head_dim=64,
    is_encdec=True, n_enc_layers=24, frontend="audio",
)
