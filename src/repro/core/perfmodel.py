"""Closed-form per-step FLOPs / HBM bytes / collective bytes per device.

Why analytic: XLA's ``cost_analysis()`` counts while-loop bodies ONCE
(verified in tests/test_rooflines.py), and the model stack deliberately scans
over layer periods and attention chunks, so raw HLO numbers undercount by the
trip counts.  We wrote every loop, so every trip count is known — the terms
below are exact closed forms for the structures we emit, validated against
``cost_analysis`` on a fully-unrolled reduced config (same test).

All quantities are PER DEVICE per step.  Conventions:
  * matmul flops = 2*m*n*k ; backward = 2x forward ; remat 'full' adds +1
    forward recompute (factor 4/3 on fwd+bwd total).
  * HBM bytes: every tensor XLA materialises is written once + read once at
    its consumers; we count the dominant streams (weights, activations saved
    across the scan, optimizer state, caches).
  * collective bytes follow the standard decompositions: all-gather moves
    (n-1)/n of the gathered size per device; reduce-scatter likewise;
    all-reduce = RS + AG = 2x.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.config import (
    ModelConfig, ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, SSM)


@dataclasses.dataclass
class Terms:
    flops: float = 0.0            # per device
    hbm_bytes: float = 0.0        # per device
    coll_bytes: float = 0.0       # per device wire bytes (ICI)
    notes: dict = dataclasses.field(default_factory=dict)

    def __add__(self, o):
        n = dict(self.notes)
        n.update(o.notes)
        return Terms(self.flops + o.flops, self.hbm_bytes + o.hbm_bytes,
                     self.coll_bytes + o.coll_bytes, n)

    def scale(self, k: float) -> "Terms":
        return Terms(self.flops * k, self.hbm_bytes * k, self.coll_bytes * k,
                     dict(self.notes))


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    dp: int                      # data axis size (x pod for multi-pod)
    tp: int
    pods: int = 1

    @property
    def chips(self) -> int:
        return self.dp * self.tp


def _layer_param_counts(cfg: ModelConfig):
    """(matmul params per layer kind, dict) — embedding excluded."""
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    attn = d * nq * hd * 2 + d * nkv * hd * 2
    ffn = 3 * d * cfg.d_ff
    moe_active = cfg.top_k * 3 * d * cfg.moe_d_ff + d * cfg.n_experts \
        + cfg.n_shared_experts * 3 * d * cfg.moe_d_ff
    moe_total = (cfg.n_experts + cfg.n_shared_experts) * 3 * d * cfg.moe_d_ff \
        + d * cfg.n_experts
    dr = cfg.d_rnn
    rnn = 2 * d * dr + 2 * dr * dr + dr * d
    din = cfg.d_inner
    ssm = d * (2 * din + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads) \
        + din * d
    return dict(attn=attn, ffn=ffn, moe_active=moe_active,
                moe_total=moe_total, rnn=rnn, ssm=ssm)


COLL_LATENCY_S = 1e-6     # per-collective launch latency on ICI


def train_step_terms(cfg: ModelConfig, *, seq: int, batch: int,
                     mesh: MeshInfo, remat: str = "full",
                     n_micro: int = 1, moe_capacity_factor: float = 1.5,
                     sp_activations: bool = False,
                     grad_compression: str = "none",
                     bucket_bytes: int = 0) -> Terms:
    """Per-device terms for one optimizer step (all microbatches).

    Optimization flags (§Perf hillclimb levers):
      sp_activations   — Megatron-SP: residuals sequence-sharded on the TP
                         axis; each block boundary costs one RS+AG instead of
                         two ARs -> TP wire bytes x0.5
      grad_compression — 'int8': error-feedback int8 on the DP grad
                         reduce-scatter -> RS bytes x0.25
      bucket_bytes     — >0: grads bucketed into this size before the DP
                         collectives -> op count = n_buckets (latency term)
    """
    dp, tp = mesh.dp, mesh.tp
    b_local = batch / dp                      # rows per dp shard
    toks = b_local * seq                      # tokens per device per step
    pc = _layer_param_counts(cfg)
    kinds = cfg.layer_kinds()

    # ---- matmul flops (per token: 2 * params_active; bwd 2x; remat +fwd)
    bwd_mult = 3.0
    if remat == "full":
        bwd_mult = 4.0
    elif remat == "dots":
        bwd_mult = 3.4
    mm_params = 0.0
    moe_overcompute = 0.0
    for k in kinds:
        if k in (ATTN_GLOBAL, ATTN_LOCAL):
            mm_params += pc["attn"]
            if cfg.n_experts:
                mm_params += pc["moe_active"]
                moe_overcompute += pc["moe_active"] * (moe_capacity_factor - 1)
            else:
                mm_params += pc["ffn"]
        elif k == RECURRENT:
            mm_params += pc["rnn"] + pc["ffn"]
        elif k == SSM:
            mm_params += pc["ssm"]
    if cfg.is_encdec:
        mm_params += cfg.n_enc_layers * (pc["attn"] + pc["ffn"])
        mm_params += cfg.n_layers * (pc["attn"] // 2)   # cross-attn kv+q/o
    head = cfg.d_model * cfg.vocab                       # logits matmul
    flops = (mm_params + moe_overcompute + head) * 2 * toks * bwd_mult / tp

    # ---- attention flops: 4*S_kv_eff per token per (qk+pv), fwd; x bwd_mult
    attn_flops = 0.0
    for k in kinds:
        if k == ATTN_GLOBAL:
            kv_eff = seq / 2
        elif k == ATTN_LOCAL:
            kv_eff = min(cfg.window or seq, seq)
        else:
            continue
        attn_flops += 4 * toks * kv_eff * cfg.n_heads * cfg.hd
    if cfg.is_encdec:
        attn_flops += cfg.n_enc_layers * 4 * toks * seq * cfg.n_heads * cfg.hd
        attn_flops += cfg.n_layers * 4 * toks * min(4096, seq) * cfg.n_heads * cfg.hd
    # ssm: intra-chunk (c per token) + state (N per token), per head-dim
    ssm_flops = 0.0
    n_ssm = sum(1 for k in kinds if k == SSM)
    if n_ssm:
        chunk = 64
        ssm_flops = n_ssm * toks * cfg.d_inner * (3 * chunk + 4 * cfg.ssm_state)
    rnn_flops = sum(8 * toks * cfg.d_rnn for k in kinds if k == RECURRENT)
    flops += (attn_flops + ssm_flops + rnn_flops) * bwd_mult / tp

    # ---- HBM bytes -------------------------------------------------------
    p_total = cfg.param_count()
    p_local = p_total / (dp * tp)             # FSDP x TP sharded
    # weights: fwd gather-read + bwd gather-read (bf16), grads f32 write+read,
    # optimizer: read p,m,v + write p,m,v (f32)
    w_bytes = p_local * (2 * 2 + 4 * 2) * max(1, n_micro) + p_local * 6 * 4
    # activations saved across scan (remat full: one residual per layer) +
    # recompute streams ~ 3x layer IO per microbatch
    d = cfg.d_model
    act_saved = len(kinds) * toks * d * 2     # bf16 residuals
    act_stream = len(kinds) * toks * d * 2 * 6
    # logits loss chunks: read hidden + head slice, write f32 chunk
    loss_bytes = toks * (cfg.vocab / tp) * 4 * 2
    hbm = w_bytes + (act_saved * 2 + act_stream) + loss_bytes

    # ---- collective bytes --------------------------------------------------
    coll = 0.0
    ops = 0.0
    notes = {}
    p_bytes_bf16 = p_total * 2
    p_bytes_f32 = p_total * 4
    n_layers_all = len(kinds) + (cfg.n_enc_layers if cfg.is_encdec else 0)
    n_leaves = n_layers_all * 10 + 4          # ~param tensors (op count)
    if dp > 1:
        ag = (dp - 1) / dp
        # FSDP: all-gather params (fwd + bwd) per microbatch, reduce-scatter
        # grads once per microbatch (f32, or int8+EF when compressed)
        fsdp_ag = 2 * (p_bytes_bf16 / tp) * ag * max(1, n_micro)
        rs_bytes = p_bytes_f32 * (0.25 if grad_compression == "int8" else 1.0)
        fsdp_rs = (rs_bytes / tp) * ag * max(1, n_micro)
        coll += fsdp_ag + fsdp_rs
        notes["fsdp_ag"] = fsdp_ag
        notes["fsdp_rs"] = fsdp_rs
        if bucket_bytes:
            n_buckets = max(1, int(p_bytes_f32 / tp / bucket_bytes))
            ops += (2 + 1) * max(1, n_micro) * n_buckets
            notes["grad_buckets"] = n_buckets
        else:
            ops += 3 * max(1, n_micro) * n_leaves
    if mesh.pods > 1:
        # hierarchical DP all-reduce of grads across pods (2x RS+AG)
        pod_bytes = p_bytes_f32 * (0.25 if grad_compression == "int8" else 1.0)
        pod_ar = 2 * (pod_bytes / (tp * dp / mesh.pods)) \
            * (mesh.pods - 1) / mesh.pods
        coll += pod_ar
        notes["pod_allreduce"] = pod_ar
        ops += (max(1, int(p_bytes_f32 / tp / bucket_bytes))
                if bucket_bytes else n_leaves)
    if tp > 1:
        # TP: 2 activation ARs per layer fwd + 2 bwd (attn out + ffn out),
        # AR wire = 2x payload.  Megatron-SP replaces each AR *pair* with one
        # RS+AG on sequence-sharded residuals -> x0.5 wire.
        tp_mult = 0.5 if sp_activations else 1.0
        tp_ar = n_layers_all * 2 * 2 * (2 * toks * d) * (tp - 1) / tp * tp_mult
        coll += tp_ar
        notes["tp_allreduce"] = tp_ar
        ops += n_layers_all * 4
        if cfg.n_experts:
            # EP (shard_map): per MoE layer one psum of the (T_l, d) combine
            # (dtype per cfg.moe_combine_dtype), fwd + bwd, AR wire = 2x
            cb = 2 if cfg.moe_combine_dtype == "bfloat16" else 4
            ep = len(kinds) * 2 * 2 * (toks * d * cb) * (tp - 1) / tp
            coll += ep
            notes["ep_combine_psum"] = ep
            ops += len(kinds) * 2
    notes["coll_ops"] = int(ops)
    notes["coll_latency_s"] = ops * COLL_LATENCY_S
    return Terms(flops, hbm, coll + ops * COLL_LATENCY_S * LINK_BW_REF, notes)


LINK_BW_REF = 50e9  # converts op latency into equivalent wire bytes


def decode_step_terms(cfg: ModelConfig, *, seq: int, batch: int,
                      mesh: MeshInfo,
                      replicate_serve_weights: bool = False) -> Terms:
    """One decode token against a seq-long cache, per device.

    replicate_serve_weights — §Perf lever: keep bf16 weights replicated
    across the data axis at serving time (they fit: params/tp per chip), so
    decode pays NO per-step FSDP all-gather; only TP collectives remain.
    """
    dp, tp = mesh.dp, mesh.tp
    b_local = max(1.0, batch / dp)
    pc = _layer_param_counts(cfg)
    kinds = cfg.layer_kinds()
    mm_params = 0.0
    for k in kinds:
        if k in (ATTN_GLOBAL, ATTN_LOCAL):
            mm_params += pc["attn"] + (pc["moe_active"] if cfg.n_experts
                                       else pc["ffn"])
        elif k == RECURRENT:
            mm_params += pc["rnn"] + pc["ffn"]
        elif k == SSM:
            mm_params += pc["ssm"]
    if cfg.is_encdec:
        mm_params += cfg.n_layers * (pc["attn"] // 2)
    head = cfg.d_model * cfg.vocab
    flops = (mm_params + head) * 2 * b_local / tp

    # attention reads the whole KV cache (the decode bottleneck)
    kv_bytes = 0.0
    attn_flops = 0.0
    for k in kinds:
        if k in (ATTN_GLOBAL, ATTN_LOCAL):
            kv_eff = seq if k == ATTN_GLOBAL else min(cfg.window or seq, seq)
            kv_bytes += 2 * b_local * kv_eff * cfg.n_kv_heads * cfg.hd * 2
            attn_flops += 4 * b_local * kv_eff * cfg.n_heads * cfg.hd
        elif k == RECURRENT:
            kv_bytes += b_local * cfg.d_rnn * (4 + 2 * cfg.conv_width)
            attn_flops += 8 * b_local * cfg.d_rnn
        elif k == SSM:
            kv_bytes += b_local * cfg.ssm_heads * cfg.ssm_headdim \
                * cfg.ssm_state * 4 * 2
            attn_flops += 4 * b_local * cfg.d_inner * cfg.ssm_state
    flops += attn_flops / tp

    p_bytes = cfg.param_count() * 2 / (dp * tp)   # bf16 weights read
    # weights are FSDP-sharded; decode all-gathers them per step unless
    # replicated for serving
    coll = 0.0
    ops = 0.0
    notes = {}
    if dp > 1 and not replicate_serve_weights:
        ag = (dp - 1) / dp
        coll += (cfg.param_count() * 2 / tp) * ag
        notes["fsdp_ag"] = coll
        ops += len(kinds) * 10
    if tp > 1:
        n_layers_all = len(kinds)
        tp_ar = n_layers_all * 2 * (2 * b_local * cfg.d_model) * (tp - 1) / tp
        coll += tp_ar
        notes["tp_allreduce"] = tp_ar
        ops += n_layers_all * 2
    hbm = p_bytes * dp + kv_bytes / tp + b_local * cfg.vocab / tp * 4
    # note: p_bytes*dp = full (tp-sharded) weights stream after the gather
    notes["coll_ops"] = int(ops)
    return Terms(flops, hbm, coll + ops * COLL_LATENCY_S * LINK_BW_REF, notes)


def prefill_step_terms(cfg: ModelConfig, *, seq: int, batch: int,
                       mesh: MeshInfo,
                       sp_activations: bool = False) -> Terms:
    t = train_step_terms(cfg, seq=seq, batch=batch, mesh=mesh, remat="none",
                         n_micro=1)
    # forward only: 1/3 of fwd+bwd flops; no optimizer/grad traffic
    fwd = Terms(t.flops / 3.0, t.hbm_bytes * 0.35, 0.0, {})
    dp, tp = mesh.dp, mesh.tp
    coll = 0.0
    if dp > 1:
        coll += (cfg.param_count() * 2 / tp) * (dp - 1) / dp
    if tp > 1:
        toks = batch / dp * seq
        coll += len(cfg.layer_kinds()) * 2 * (2 * toks * cfg.d_model) \
            * (tp - 1) / tp * (0.5 if sp_activations else 1.0)
    fwd.coll_bytes = coll
    return fwd
