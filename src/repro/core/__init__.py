"""Core: thread-coarsening transforms + cost/roofline analysis.

The paper's primary contribution (thread coarsening as a compiler transform,
compared against pipeline replication and SIMD vectorization) lives here as a
composable configuration applied to Pallas kernels across the framework.
"""
from .coarsening import (
    CoarseningConfig,
    StreamPlan,
    RowPlan,
    plan_stream,
    plan_rows,
    pallas_stream_call,
    stream_view,
    unstream_view,
    tile,
    untile,
    KIND_NONE,
    KIND_CONSECUTIVE,
    KIND_GAPPED,
)
from . import analysis, rooflines
