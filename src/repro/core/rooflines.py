"""Three-term roofline extraction from compiled XLA artifacts (§Roofline).

  compute term    = HLO_FLOPs        / (chips * PEAK_FLOPS)
  memory term     = HLO_bytes        / (chips * HBM_BW)
  collective term = collective_bytes / (chips * LINK_BW)

``cost_analysis()`` provides FLOPs and bytes accessed.  Collective bytes are
not in cost_analysis, so we parse the (optimized when available) HLO text and
sum operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

# TPU v5e constants (assignment-specified)
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. f32[256,4096]{1,0} or bf16[8,128] — the *result* shape of an op
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in an HLO dump.

    Uses the result shape (for all-gather that's the gathered size, for
    reduce-scatter the scattered size) as the per-device wire-cost proxy;
    all-reduce is counted 2x (reduce-scatter + all-gather decomposition).
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # result shape appears after '=' : "%x = f32[..]{..} all-gather(...)"
        m = re.search(r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\b(" +
                      "|".join(_COLLECTIVES) + r")\b", s)
        if not m:
            # tuple-shaped results: "= (f32[..], f32[..]) all-reduce(...)"
            if not any(f" {c}(" in s or f"{c}-start" in s for c in _COLLECTIVES):
                continue
            kind = next(c for c in _COLLECTIVES
                        if f" {c}(" in s or f"{c}-start" in s)
            total = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(
                s.split("=", 1)[1].split(kind)[0]))
            mult = 2 if kind == "all-reduce" else 1
            out[kind] += mult * total
            out["count"] += 1
            continue
        dtype, dims, kind = m.groups()
        mult = 2 if kind == "all-reduce" else 1
        out[kind] += mult * _shape_bytes(dtype, dims)
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    chips: int
    # derived
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    model_flops: float = 0.0      # 6*N*D useful-FLOPs estimate
    useful_ratio: float = 0.0     # model_flops / hlo_flops
    bytes_per_device: float = 0.0  # from memory_analysis

    def as_row(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the ideal (compute-only) roofline this step achieves."""
        return self.compute_s / self.step_s if self.step_s else 0.0


def analyze(compiled, hlo_text: str, chips: int,
            model_flops: float = 0.0) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)["total"]
    mem = getattr(compiled, "memory_analysis", lambda: None)()
    bpd = 0.0
    if mem is not None:
        bpd = float(getattr(mem, "temp_size_in_bytes", 0) +
                    getattr(mem, "argument_size_in_bytes", 0) +
                    getattr(mem, "output_size_in_bytes", 0) -
                    getattr(mem, "alias_size_in_bytes", 0))
    # cost_analysis flops/bytes are program-wide per device under SPMD
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bound = max(terms, key=terms.get)
    return Roofline(
        flops=flops, bytes_accessed=bytes_accessed, coll_bytes=coll,
        chips=chips, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, bound=bound, model_flops=model_flops,
        useful_ratio=(model_flops / flops) if flops else 0.0,
        bytes_per_device=bpd,
    )
