"""Analytic TPU-v5e pipeline model — the paper's LSU/resource analysis, ported.

The paper evaluates coarsening variants by (a) wall time on the Arria 10 and
(b) the Intel offline compiler's report: LSU count/width/type, ALUTs, RAM
blocks.  This container has no TPU, so the equivalent artifacts here are:

  wall time        -> modeled steady-state pipeline time on TPU v5e
                      (double-buffered Pallas pipeline: per-step cost =
                      max(DMA-in, compute, DMA-out); plus per-DMA issue
                      overhead that penalises many-narrow-descriptors —
                      the burst-coalescing effect)
  LSU count/width  -> DMA descriptors per operand per grid step / bytes each
  ALUTs/RAM blocks -> VMEM working set (double-buffered) + DMA semaphores

The model is deliberately simple and *directional*: it exists to rank
coarsening variants the way the FPGA compiler report ranks LSU configurations,
and its rankings are what EXPERIMENTS.md validates against the paper's
findings F1-F5.  Constants match the roofline constants used in §Roofline.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .coarsening import CoarseningConfig, StreamPlan, KIND_GAPPED

# --- TPU v5e constants (per chip) ------------------------------------------
HBM_BW = 819e9              # B/s
MXU_FLOPS_BF16 = 197e12     # FLOP/s
MXU_FLOPS_F32 = 49e12       # FLOP/s (f32 through MXU ~ 1/4 rate)
VPU_FLOPS_F32 = 4e12        # FLOP/s elementwise (8x128 lanes x ~4 ALUs x 940MHz)
DMA_ISSUE_S = 1.0e-6        # fixed per-descriptor issue latency (s)
DMA_MIN_EFF_BYTES = 512.0   # transfers below this see proportionally lower bw
VMEM_BYTES = 128 * 2 ** 20  # 128 MiB VMEM on v5e
HBM_LATENCY_S = 0.7e-6      # single random-access latency (gather miss cost)
NUM_CORES = 1               # v5e has one TensorCore per chip
DMA_MLP = 16                # outstanding random accesses the DMA engines
                            # keep in flight (memory-level parallelism)

# weight-only quantization (repro.quant): VPU ops per dequantized element.
# int8 = convert + scale-multiply; int4 = nibble mask/shift + offset + scale.
# This is the per-pane overhead coarsening amortizes — packed panes shrink
# the DMA term by 8/wbits while dequant grows the compute term, so the
# memory/compute crossover (and hence the winning degree) MOVES.
DEQUANT_OPS = {8: 2.0, 4: 4.0}


def _wbytes(dtype_bytes: float, wbits: int | None) -> float:
    """Per-element weight bytes: packed width when quantized, else dtype."""
    return dtype_bytes if not wbits else wbits / 8.0


@dataclasses.dataclass
class KernelCost:
    """Per-variant report — the analog of the Intel compiler report table."""

    label: str
    grid: int
    # LSU analog
    dmas_per_step: int          # total DMA descriptors per grid step
    dma_bytes: float            # bytes of the *typical* descriptor (LSU width)
    # resource analog
    vmem_bytes: int             # double-buffered VMEM working set ("RAM blocks")
    dma_sems: int               # in-flight semaphores ("ALUT/control" analog)
    # time model
    dma_s_per_step: float
    compute_s_per_step: float
    modeled_s: float            # total modeled kernel time (steady state)
    bound: str                  # 'memory' | 'compute'

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


def _dma_time(bytes_per_desc: float, n_desc: int, bw: float = HBM_BW) -> float:
    """Time to move n_desc descriptors of bytes_per_desc each.

    Narrow descriptors pay (a) a fixed issue cost each and (b) reduced
    effective bandwidth when under DMA_MIN_EFF_BYTES — this is the
    burst-coalescing term that makes one 512-bit LSU beat eight 32-bit ones
    in the paper.
    """
    if n_desc == 0 or bytes_per_desc == 0:
        return 0.0
    eff = min(1.0, bytes_per_desc / DMA_MIN_EFF_BYTES)
    return n_desc * (DMA_ISSUE_S + bytes_per_desc / (bw * eff))


def stream_cost(plan: StreamPlan, *, n_loads: int, n_stores: int = 1,
                arith_per_elem: float, dtype_bytes: int = 4,
                divergence_paths: int = 1,
                divergence_uniform: bool = False,
                bounded_trip_factor: float = 1.0,
                flops_rate: float = VPU_FLOPS_F32,
                replication: int | None = None) -> KernelCost:
    """Model a coarsened streaming kernel (the paper's Fig. 6 template).

    divergence_paths:   number of control-flow paths (paper's divergence degree;
                        1 = no divergence).  Data-dependent divergence on TPU is
                        predicated: *all* paths execute -> compute multiplies.
    divergence_uniform: id-based (direct) divergence whose predicate is uniform
                        within a block -> specializable, only the taken path's
                        cost is paid on average (x (paths+1)/(2*paths) fudge for
                        the residual select).
    bounded_trip_factor: for-in analog: data-dependent trip counts run to the
                        worst-case bound (>=1).
    """
    cfg = plan.cfg
    repl = replication if replication is not None else cfg.replication
    elems_per_step = cfg.degree * plan.block
    bytes_per_dma = plan.dma_elems * dtype_bytes

    dmas_in = plan.dmas_per_operand * n_loads
    dmas_out = plan.dmas_per_operand * n_stores
    dma_in_s = _dma_time(bytes_per_dma, dmas_in)
    dma_out_s = _dma_time(bytes_per_dma, dmas_out)

    # compute: predication multiplies work for data-dependent divergence
    paths = max(1, divergence_paths)
    if paths > 1 and divergence_uniform:
        div_factor = (paths + 1) / (2 * paths) + 0.5  # specialized: ~avg path
    elif paths > 1:
        div_factor = float(paths)                     # predicated: all paths
    else:
        div_factor = 1.0
    flops_per_step = elems_per_step * arith_per_elem * div_factor * bounded_trip_factor
    compute_s = flops_per_step / flops_rate

    # replication splits the grid across R pipelines sharing HBM bandwidth.
    grid = max(1, plan.grid // repl)
    dma_shared_in = dma_in_s * repl / repl  # per-step issue unchanged ...
    # ... but the *bandwidth* portion contends: model by scaling bandwidth.
    if repl > 1:
        dma_in_s = _dma_time(bytes_per_dma, dmas_in, bw=HBM_BW / repl)
        dma_out_s = _dma_time(bytes_per_dma, dmas_out, bw=HBM_BW / repl)

    step = max(dma_in_s + dma_out_s, compute_s)
    warmup = dma_in_s + compute_s + dma_out_s
    total = warmup + step * max(0, grid - 1)

    # chip-total resources: replication multiplies the resident working sets
    # AND the in-flight queues/semaphores (each replica owns a pipeline);
    # coarsening widens one pipeline's buffers but keeps ONE queue set —
    # the TPU analog of the paper's ALUT/control saving (Fig. 9 middle).
    vmem = 2 * (n_loads + n_stores) * elems_per_step * dtype_bytes * repl
    sems = (dmas_in + dmas_out) * repl
    return KernelCost(
        label=cfg.label, grid=grid,
        dmas_per_step=dmas_in + dmas_out, dma_bytes=bytes_per_dma,
        vmem_bytes=vmem, dma_sems=sems,
        dma_s_per_step=dma_in_s + dma_out_s, compute_s_per_step=compute_s,
        modeled_s=total,
        bound="memory" if dma_in_s + dma_out_s >= compute_s else "compute",
    )


def gather_cost(plan: StreamPlan, *, n_loads: int, arith_per_elem: float,
                hit_rate: float, window_elems: int, dtype_bytes: int = 4,
                flops_rate: float = VPU_FLOPS_F32,
                replication: int | None = None) -> KernelCost:
    """Model the indirect-indexed kernel (paper Fig. 5b / cache-hit study).

    The LSU cache analog is a VMEM-resident window of ``window_elems`` fetched
    once per grid step per operand; indices hitting the window cost an in-VMEM
    gather, misses cost one random HBM access each (descriptor latency-bound).
    Coarsening widens the *index* stream exactly like the regular kernel, but
    the data fetches themselves cannot be coalesced — reproducing the paper's
    F2 (coarsening wins collapse under irregular access).
    """
    cfg = plan.cfg
    repl = replication if replication is not None else cfg.replication
    elems_per_step = cfg.degree * plan.block

    # index stream DMA (regular, coarsenable)
    idx_bytes = plan.dma_elems * 4
    dma_idx_s = _dma_time(idx_bytes, plan.dmas_per_operand)
    # window fetch per operand (one wide DMA; not affected by coarsening kind)
    dma_win_s = _dma_time(window_elems * dtype_bytes, n_loads)
    # misses: per-element random access, latency bound.  TPU divergence from
    # the paper (DESIGN.md §2): the FPGA's per-LSU caches give gapped
    # coarsening extra miss concurrency, but TPU DMA engines already sustain
    # DMA_MLP outstanding accesses for EVERY variant — so the miss term is
    # kind-independent here, and "coarsening wins collapse under irregular
    # access" (paper F2) holds for both kinds.  Gapped keeps a small edge
    # (degree extra queue slots), bounded by the engine limit.
    misses = elems_per_step * n_loads * (1.0 - hit_rate)
    overlap = min(2 * DMA_MLP,
                  DMA_MLP + (cfg.degree if cfg.kind == KIND_GAPPED else 0))
    miss_s = misses * HBM_LATENCY_S / overlap
    # in-VMEM gather for hits: ~1 elem / lane-cycle -> price as extra arith
    gather_ops = elems_per_step * n_loads * hit_rate
    store_s = _dma_time(plan.dma_elems * dtype_bytes, plan.dmas_per_operand)

    bw = HBM_BW / repl if repl > 1 else HBM_BW
    dma_s = (dma_idx_s + dma_win_s) * (HBM_BW / bw) + miss_s + store_s
    compute_s = (elems_per_step * arith_per_elem + gather_ops) / flops_rate

    grid = max(1, plan.grid // repl)
    step = max(dma_s, compute_s)
    total = dma_s + compute_s + step * max(0, grid - 1)
    vmem = 2 * (n_loads * window_elems + 2 * elems_per_step) * dtype_bytes
    return KernelCost(
        label=cfg.label, grid=grid,
        dmas_per_step=plan.dmas_per_operand * (n_loads + 2) + int(misses),
        dma_bytes=window_elems * dtype_bytes,
        vmem_bytes=vmem, dma_sems=plan.dmas_per_operand * (n_loads + 2),
        dma_s_per_step=dma_s, compute_s_per_step=compute_s, modeled_s=total,
        bound="memory" if dma_s >= compute_s else "compute",
    )


def matmul_cost(m: int, n: int, k: int, cfg: CoarseningConfig, *,
                bm: int = 128, bn: int = 128, bk: int = 512,
                dtype_bytes: int = 2, wbits: int | None = None,
                group: int = 32,
                flops_rate: float = MXU_FLOPS_BF16) -> KernelCost:
    """Blocked matmul with row-block coarsening (dense linear algebra apps).

    ``wbits`` models the dequant-fused quantized-B kernel: the B pane moves
    packed (wbits/8 bytes per element, plus the small scale pane) and each
    program pays a VPU dequant over the pane it holds in VMEM.
    """
    c = cfg.degree
    bn = bn * cfg.vector_width          # SIMD analog: wider lane tiles
    fused_m = bm * c
    grid = (m // fused_m) * (n // bn) * (k // bk)
    # A tile: fused_m x bk ; consecutive = 1 DMA, gapped = C strided DMAs
    a_dmas = 1 if cfg.kind != KIND_GAPPED else c
    a_bytes = fused_m * bk * dtype_bytes / a_dmas
    b_bytes = bk * bn * _wbytes(dtype_bytes, wbits)
    if wbits:                            # scale rows ride with the pane
        b_bytes += (bk // group if wbits == 4 else 1) * bn * 4.0
    dma_s = _dma_time(a_bytes, a_dmas) + _dma_time(b_bytes, 1)
    out_bytes = fused_m * bn * 4
    store_s = _dma_time(out_bytes / a_dmas, a_dmas) * (bk / k)  # amortised over k
    flops = 2.0 * fused_m * bn * bk
    # MXU efficiency: matmul M-dim under 128 wastes systolic rows
    eff = min(1.0, fused_m / 128) * min(1.0, bn / 128)
    compute_s = flops / (flops_rate * eff)
    if wbits:                            # per-pane VPU dequant
        compute_s += bk * bn * DEQUANT_OPS[wbits] / VPU_FLOPS_F32
    repl = cfg.replication
    if repl > 1:
        dma_s = dma_s * repl  # shared HBM
        grid = max(1, grid // repl)
    step = max(dma_s + store_s, compute_s)
    total = (dma_s + compute_s + store_s) + step * max(0, grid - 1)
    vmem = 2 * int(fused_m * bk + bk * bn) * dtype_bytes + 2 * int(fused_m * bn) * 4
    return KernelCost(
        label=cfg.label, grid=grid, dmas_per_step=a_dmas + 1,
        dma_bytes=a_bytes, vmem_bytes=vmem, dma_sems=a_dmas + 2,
        dma_s_per_step=dma_s + store_s, compute_s_per_step=compute_s,
        modeled_s=total, bound="memory" if dma_s + store_s >= compute_s else "compute",
    )


def flash_attention_cost(b: int, h: int, hkv: int, sq: int, sk: int, d: int,
                         cfg: CoarseningConfig, *, bq: int = 128,
                         bkv: int = 128, causal: bool = True,
                         dtype_bytes: int = 2,
                         dense: bool = False) -> KernelCost:
    """Coarsened flash-attention FORWARD (q-row-block coarsening).

    Each program owns C q blocks and sweeps the kv blocks once, so the kv
    traffic (and the per-block DMA issue overhead) divides by C — up to the
    causal skew: a consecutive program walks to its *max* fused row (keeping
    ~half the triangle pruned), a gapped program's fused rows span the whole
    sequence so it walks everything (the divergence penalty).

    dense=True models the pure-jnp chunked (mea) baseline: the same
    online-softmax math lowered through XLA, whose per-kv-chunk
    (p, m, l, acc) carry round-trips HBM in f32 between scan iterations —
    traffic the fused kernel keeps in VMEM.
    """
    c = 1 if dense else cfg.degree
    gapped = (not dense) and cfg.kind == KIND_GAPPED
    nq = max(1, sq // (c * bq))
    nk = max(1, sk // bkv)
    if causal and not gapped:
        # program i's fused rows end at (i+1)*c*bq: walk only kv blocks
        # at or before them
        steps = sum(min(nk, -(-((i + 1) * c * bq) // bkv)) for i in range(nq))
    else:
        steps = nq * nk
    descs = c if gapped else 1
    kv_dma_s = 2 * _dma_time(bkv * d * dtype_bytes, 1)          # K + V panes
    if dense:
        # per-step f32 carry round trip (write + read descriptors)
        carry_bytes = (bq * bkv + bq * (d + 2)) * 4.0
        kv_dma_s += _dma_time(carry_bytes, 2)
    flops = 4.0 * c * bq * bkv * d                               # qk + pv
    rate = MXU_FLOPS_BF16 if dtype_bytes == 2 else MXU_FLOPS_F32
    eff = min(1.0, c * bq / 128) * min(1.0, min(bkv, d) / 128)
    compute_s = flops / (rate * eff)
    # per-program q pane in + o pane out (f32); consecutive = 1 wide DMA,
    # gapped = C strided DMAs (the narrow-LSU analog)
    prog_s = (_dma_time(c * bq * d * dtype_bytes / descs, descs)
              + _dma_time(c * bq * d * 4.0 / descs, descs))
    step = max(kv_dma_s, compute_s)
    grid = b * h * steps
    total = b * h * nq * prog_s + (kv_dma_s + compute_s) \
        + step * max(0, grid - 1)
    vmem = 2 * int((c * bq + 2 * bkv) * d) * dtype_bytes \
        + 2 * int(c * bq * (d + 2)) * 4
    return KernelCost(
        label="dense" if dense else cfg.label, grid=grid,
        dmas_per_step=2 + 2 * descs, dma_bytes=bkv * d * dtype_bytes,
        vmem_bytes=vmem, dma_sems=2 + 2 * descs,
        dma_s_per_step=kv_dma_s, compute_s_per_step=compute_s,
        modeled_s=total,
        bound="memory" if kv_dma_s >= compute_s else "compute",
    )


def flash_attention_bwd_cost(b: int, h: int, hkv: int, sq: int, sk: int,
                             d: int, cfg: CoarseningConfig, *,
                             q_cfg: CoarseningConfig | None = None,
                             bq: int = 128, bkv: int = 128,
                             causal: bool = True, dtype_bytes: int = 2,
                             dense: bool = False) -> KernelCost:
    """Flash-attention BACKWARD: the dK/dV pass with the KV-BLOCK axis as
    the coarsening axis (``cfg``) plus the dQ pass coarsened on the q-row
    axis (``q_cfg``, defaults base) — the axes differ, which is why the
    ``flash_attention_bwd`` tuner family is independent of the forward's.

    A dK/dV program owns C kv blocks: consecutive = one wide recompute tile
    (and one wide K/V/dK/dV pane each), gapped = C strided panes and — since
    segment-0 kv rows are fused into every program — a causal sweep that
    degenerates to the worst row (the decode kernel's divergence framing).

    dense=True models the mea/XLA baseline backward: jax.checkpoint
    recomputes the forward inside one combined sweep (higher flops) and the
    recomputed probability / dS chunk blocks round-trip HBM in f32.
    """
    rate = MXU_FLOPS_BF16 if dtype_bytes == 2 else MXU_FLOPS_F32

    # ---- dK/dV pass (or the single combined dense sweep) ----
    c = 1 if dense else cfg.degree
    gapped = (not dense) and cfg.kind == KIND_GAPPED
    nkv = max(1, sk // (c * bkv))
    nq = max(1, sq // bq)
    if causal and not gapped:
        # program ki's fused kv rows start at ki*c*bkv: only q blocks at or
        # after them contribute
        steps = sum(nq - (ki * c * bkv) // bq for ki in range(nkv))
    else:
        steps = nkv * nq
    descs = c if gapped else 1
    # per q step: q + do panes in, (m, l, delta) residual rows
    qstep_s = 2 * _dma_time(bq * d * dtype_bytes, 1) + _dma_time(bq * 4.0, 3)
    if dense:
        # recomputed p and dS chunk blocks, written then re-read in f32
        qstep_s += _dma_time(2 * bq * bkv * 4.0, 4)
        flops = 12.0 * bq * bkv * d          # fwd recompute + dq + dk + dv
    else:
        flops = 8.0 * bq * (c * bkv) * d     # s, dv, dp, dk on the wide tile
    eff = min(1.0, bq / 128) * min(1.0, min(c * bkv, d) / 128)
    compute_s = flops / (rate * eff)
    # per program: K + V panes in, dK + dV panes out (f32)
    prog_s = 2 * _dma_time(c * bkv * d * dtype_bytes / descs, descs) \
        + 2 * _dma_time(c * bkv * d * 4.0 / descs, descs)
    step = max(qstep_s, compute_s)
    grid = b * h * steps
    total = b * h * nkv * prog_s + (qstep_s + compute_s) \
        + step * max(0, grid - 1)

    # ---- dQ pass (kernel path only: dense folds it into the sweep) ----
    if not dense:
        qc_cfg = q_cfg or CoarseningConfig()
        qc = qc_cfg.degree
        qgapped = qc_cfg.kind == KIND_GAPPED
        nq2 = max(1, sq // (qc * bq))
        nk2 = max(1, sk // bkv)
        if causal and not qgapped:
            steps2 = sum(min(nk2, -(-((i + 1) * qc * bq) // bkv))
                         for i in range(nq2))
        else:
            steps2 = nq2 * nk2
        descs2 = qc if qgapped else 1
        kv2_s = 2 * _dma_time(bkv * d * dtype_bytes, 1)
        flops2 = 6.0 * qc * bq * bkv * d     # s, dp, dq
        eff2 = min(1.0, qc * bq / 128) * min(1.0, min(bkv, d) / 128)
        compute2_s = flops2 / (rate * eff2)
        prog2_s = (2 * _dma_time(qc * bq * d * dtype_bytes / descs2, descs2)
                   + _dma_time(qc * bq * d * 4.0 / descs2, descs2))
        total += b * h * nq2 * prog2_s \
            + max(kv2_s, compute2_s) * b * h * steps2

    vmem = 2 * int((2 * c * bkv + 2 * bq) * d) * dtype_bytes \
        + 2 * int(2 * c * bkv * d) * 4
    return KernelCost(
        label="dense" if dense else cfg.label, grid=grid,
        dmas_per_step=2 + 4 * descs, dma_bytes=c * bkv * d * dtype_bytes / descs,
        vmem_bytes=vmem, dma_sems=2 + 4 * descs,
        dma_s_per_step=qstep_s, compute_s_per_step=compute_s,
        modeled_s=total,
        bound="memory" if qstep_s >= compute_s else "compute",
    )


def flash_attention_sparse_cost(b: int, h: int, hkv: int, sq: int, sk: int,
                                d: int, cfg: CoarseningConfig, *,
                                bq: int = 128, bkv: int = 128,
                                max_live: int = 8, n_live: int | None = None,
                                dtype_bytes: int = 2,
                                dense: bool = False) -> KernelCost:
    """Block-sparse flash forward: each q-block program walks only the
    ``max_live`` (NULL-padded) kv blocks its per-q-block index lists,
    charging live-block traffic ONLY — the dense grid's dead steps are
    gone from the model entirely, which is where the >= 8x long-context
    win lives.

    The coarsening axis is the live-SLOT axis.  As in the paged decode
    model, the index lookup kills physical contiguity: BOTH kinds issue C
    table-resolved block descriptors per operand per step (consecutive
    slots usually name adjacent blocks for window bands, but the kernel
    still resolves and loads each separately).  What the degree amortizes
    is the per-step dependent index resolution — the C unrolled lookups
    within one step read the same resident index row and pipeline, so the
    HBM-latency hop is paid once per STEP, i.e. max_live/C times per
    program instead of max_live times.

    ``n_live`` is the TOTAL number of non-NULL index entries across all nq
    rows (the builder knows it exactly); NULL slots issue no DMA and run
    no compute in the kernel, so the model bills the average live
    occupancy rather than the padded width.  Gapped coarsening spreads
    each row's NULL tail across every step (a partially-filled row keeps
    all its steps live), where consecutive concentrates the tail into
    whole dead steps — so gapped pays the per-step resolution hop on more
    steps: the paper's divergence penalty, relocated to an irregular work
    list.

    dense=True is the dense-mask flash kernel at base config walking the
    full causal grid — the baseline the sparse benchmark gates against.
    """
    if dense:
        return flash_attention_cost(b, h, hkv, sq, sk, d, CoarseningConfig(),
                                    bq=bq, bkv=bkv, causal=True,
                                    dtype_bytes=dtype_bytes, dense=False)
    c = cfg.degree
    gapped = cfg.kind == KIND_GAPPED
    nq = max(1, sq // bq)
    n_steps = max(1, max_live // c)
    grid = b * h * nq * n_steps
    if n_live is None:
        n_live = nq * max_live
    frac = min(1.0, n_live / float(nq * max_live))   # live slot occupancy
    # an average row holds L = frac*max_live live slots; consecutive packs
    # them into the first ceil(L/c) steps, gapped strides them across
    # ~min(L, n_steps) steps — each step with any live slot pays the
    # index-resolution hop
    avg_l = frac * max_live
    live_steps = min(float(n_steps),
                     avg_l if gapped else -(-avg_l // c))
    # C block descriptors per operand per step, resolved through the
    # index; NULL slots issue nothing, so a step carries C*frac live
    # panes on average
    kv_dma_s = 2 * _dma_time(bkv * d * dtype_bytes, c * frac)  # K + V
    kv_dma_s += HBM_LATENCY_S * live_steps / n_steps  # per-step index hop
    flops = 4.0 * c * frac * bq * bkv * d                     # qk + pv
    rate = MXU_FLOPS_BF16 if dtype_bytes == 2 else MXU_FLOPS_F32
    eff = min(1.0, bq / 128) * min(1.0, min(bkv, d) / 128)
    compute_s = flops / (rate * eff)
    # per-program q pane in + o pane out (f32) + the index row
    prog_s = (_dma_time(bq * d * dtype_bytes, 1)
              + _dma_time(bq * d * 4.0, 1)
              + _dma_time(max_live * 4.0, 1))
    step = max(kv_dma_s, compute_s)
    total = b * h * nq * prog_s + (kv_dma_s + compute_s) \
        + step * max(0, grid - 1)
    vmem = 2 * int((bq + 2 * c * bkv) * d) * dtype_bytes \
        + 2 * int(bq * (d + 2)) * 4 + max_live * 4
    return KernelCost(
        label=cfg.label, grid=grid, dmas_per_step=2 * c,
        dma_bytes=bkv * d * dtype_bytes, vmem_bytes=vmem, dma_sems=2 * c,
        dma_s_per_step=kv_dma_s, compute_s_per_step=compute_s,
        modeled_s=total,
        bound="memory" if kv_dma_s >= compute_s else "compute",
    )


def decode_attention_cost(b: int, h: int, hkv: int, s: int, d: int,
                          cfg: CoarseningConfig, *, bkv: int = 128,
                          kv_len: int | None = None, dtype_bytes: int = 2,
                          kv_bits: int | None = None,
                          page_size: int | None = None,
                          dense: bool = False) -> KernelCost:
    """Split-KV decode attention (one query token vs a (S, Hkv, D) cache).

    The work-item axis is the kv-block axis: the grid walks
    b x hkv x kv/(C*bkv) programs; each owns C kv blocks (consecutive = one
    wide DMA per operand, gapped = C strided DMAs — the LSU analogs) and
    reduces them to a partial (m, l, acc) that a cheap combine pass merges.
    The grid is length-aware: only blocks covering the live prefix
    ``kv_len`` are walked, not the allocated ``s``.

    ``page_size`` models the BLOCK-TABLE paged variant (bkv == page_size):
    physical contiguity across pages is gone, so consecutive coarsening
    degenerates to the gapped access pattern — C table-resolved page
    descriptors per operand regardless of kind — plus a per-page table
    lookup charged as extra issue latency.  Coarsening still amortizes the
    per-descriptor overhead, which is exactly the paper's gapped story.

    dense=True models the unfused XLA einsum baseline at the SAME tiling
    granularity (XLA streams the cache in MXU-sized panes too): it scans
    the full allocated length regardless of kv_len, and pays f32 HBM
    round-trips for the (H, S) logits and probabilities between the QK
    einsum, the softmax, and the PV einsum — traffic the fused online-
    softmax kernel never emits.
    """
    g = h // hkv
    c = 1 if dense else cfg.degree
    kv = s if (dense or kv_len is None) \
        else min(s, max(c * bkv, -(-kv_len // (c * bkv)) * c * bkv))
    n_splits = max(1, kv // (c * bkv))
    grid = b * hkv * n_splits

    # paged: physical pages are scattered, so BOTH kinds issue C page
    # descriptors per operand (the table lookup killed wide contiguity)
    descs = c if (not dense and (page_size is not None
                                 or cfg.kind == KIND_GAPPED)) else 1
    # kv_bits=8 (int8 KV cache): the cache panes — decode's dominant
    # traffic — move at 1 byte/element plus a 4-byte scale per (row, head);
    # the fused dequant is extra VPU work per pane.
    kvb = _wbytes(dtype_bytes, None if dense else kv_bits)
    bytes_per_desc = c * bkv * (d * kvb + (4.0 if kv_bits and not dense
                                           else 0.0)) / descs
    dma_s = 2 * _dma_time(bytes_per_desc, descs)          # K + V panes
    if page_size is not None and not dense:
        # per-page logical->physical resolution before each descriptor can
        # issue: one dependent SMEM/HBM-latency hop per page
        dma_s += descs * HBM_LATENCY_S
    flops = 4.0 * g * c * bkv * d + 6.0 * g * c * bkv     # qk + pv + softmax
    if kv_bits and not dense:
        flops += 2 * c * bkv * d * DEQUANT_OPS[kv_bits]   # K and V panes
    compute_s = flops / VPU_FLOPS_F32

    step = max(dma_s, compute_s)
    total = (dma_s + compute_s) + step * max(0, grid - 1)

    if dense:
        # logits (write+read) and probabilities (write+read) in f32
        logit_bytes = 2.0 * b * h * kv * 4
        total += 2 * _dma_time(logit_bytes, 2)
    else:
        # combine pass: per-split (m, l, acc) partials written then re-read
        part_bytes = b * hkv * g * n_splits * (2 + d) * 4
        total += 2 * _dma_time(part_bytes, 2)

    vmem = 2 * (2 * c * bkv * d * dtype_bytes + g * d * 4 + g * (2 + d) * 4)
    return KernelCost(
        label="dense" if dense else cfg.label, grid=grid,
        dmas_per_step=2 * descs, dma_bytes=bytes_per_desc,
        vmem_bytes=vmem, dma_sems=2 * descs,
        dma_s_per_step=dma_s, compute_s_per_step=compute_s, modeled_s=total,
        bound="memory" if dma_s >= compute_s else "compute",
    )


def flash_attention_verify_cost(b: int, h: int, hkv: int, t: int, s: int,
                                d: int, cfg: CoarseningConfig, *,
                                bkv: int = 128, kv_len: int | None = None,
                                dtype_bytes: int = 2,
                                kv_bits: int | None = None,
                                page_size: int | None = None,
                                dense: bool = False) -> KernelCost:
    """Batched-verify attention (T drafted query rows vs a long cache) —
    the speculative-decode geometry, coarsened on the kv-block/page axis
    like `decode_attention_cost`.

    The verify geometry sits BETWEEN decode and prefill, and its economics
    differ from both ends:

      * vs decode (t=1): every fetched cache pane is scored against T*G
        query rows instead of G, so compute per pane grows ~T x while the
        pane traffic is unchanged — the per-pane descriptor/table-lookup
        overhead that dominates decode is amortized over T x more work, and
        the per-program Q pane + the (T*G)-row combine partials become
        first-class traffic terms that decode's model ignores as noise.
      * vs prefill (t=s): the q side is far too short to feed the MXU
        (T*G << 128 rows), so the q-row-block coarsening axis that
        `flash_attention_cost` sweeps does not exist — the kv axis is the
        only work-item axis, walked once per program rather than once per
        q block.

    Both shifts move the memory/compute crossover, so the winning degree
    differs from both neighboring families (pinned in tests/test_tune.py).

    dense=True models the unfused XLA einsum baseline: full allocated-length
    scan + f32 HBM round-trips for the (H, T, S) logits and probabilities.
    """
    g = h // hkv
    c = 1 if dense else cfg.degree
    kv = s if (dense or kv_len is None) \
        else min(s, max(c * bkv, -(-kv_len // (c * bkv)) * c * bkv))
    n_splits = max(1, kv // (c * bkv))
    grid = b * hkv * n_splits

    descs = c if (not dense and (page_size is not None
                                 or cfg.kind == KIND_GAPPED)) else 1
    kvb = _wbytes(dtype_bytes, None if dense else kv_bits)
    bytes_per_desc = c * bkv * (d * kvb + (4.0 if kv_bits and not dense
                                           else 0.0)) / descs
    dma_s = 2 * _dma_time(bytes_per_desc, descs)          # K + V panes
    if page_size is not None and not dense:
        dma_s += descs * HBM_LATENCY_S                    # table lookups
    # T*G query rows against each fused pane: qk + pv + per-row softmax
    flops = 4.0 * t * g * c * bkv * d + 6.0 * t * g * c * bkv
    if kv_bits and not dense:
        flops += 2 * c * bkv * d * DEQUANT_OPS[kv_bits]
    compute_s = flops / VPU_FLOPS_F32

    step = max(dma_s, compute_s)
    total = (dma_s + compute_s) + step * max(0, grid - 1)

    # the (T*G, D) q pane rides into EVERY program (decode treats its G-row
    # pane as noise; at T rows it is real per-program traffic)
    q_bytes = t * g * d * 4.0
    total += grid * _dma_time(q_bytes, 1) if not dense else 0.0

    if dense:
        logit_bytes = 2.0 * b * h * t * kv * 4
        total += 2 * _dma_time(logit_bytes, 2)
    else:
        # combine pass: per-split (m, l, acc) partials over T*G rows
        part_bytes = b * hkv * t * g * n_splits * (2 + d) * 4
        total += 2 * _dma_time(part_bytes, 2)

    vmem = 2 * (2 * c * bkv * d * dtype_bytes + t * g * d * 4
                + t * g * (2 + d) * 4)
    return KernelCost(
        label="dense" if dense else cfg.label, grid=grid,
        dmas_per_step=2 * descs + 1, dma_bytes=bytes_per_desc,
        vmem_bytes=vmem, dma_sems=2 * descs + 1,
        dma_s_per_step=dma_s, compute_s_per_step=compute_s, modeled_s=total,
        bound="memory" if dma_s >= compute_s else "compute",
    )


def moe_ffn_cost(e: int, cap: int, d: int, f: int, cfg: CoarseningConfig, *,
                 dtype_bytes: int = 2, wbits: int | None = None,
                 group: int = 32, dense: bool = False) -> KernelCost:
    """Grouped-expert MoE FFN over the padded (E, C, d) dispatch buffer.

    The work-item axis is the EXPERT axis: the grid walks E/C programs, each
    owning C experts' full gate/up/down chain.  Per program, five operands
    move: x pane, w1/w3/w2 panes, output pane (consecutive = one wide DMA
    each, gapped = C strided DMAs each — the LSU analogs); the (cap, f)
    silu-gate intermediate stays in VMEM.

    dense=True models the unfused XLA einsum baseline: three separate
    per-expert einsums (grid of E degree-1 steps, each re-issuing its weight
    descriptors) plus f32 HBM round-trips for the (E, cap, f) gate and up
    intermediates between the einsums — traffic the fused kernel never
    emits (the pipes-paper producer/consumer saving).

    ``wbits`` models the dequant-fused quantized-weight kernel
    (kernels/moe_ffn.make_qkernel): the three weight panes move packed —
    8/wbits fewer bytes for the SAME wide/strided pane distribution — and
    each program pays a VPU dequant over its experts' weights.  Because the
    dense kernel here is weight-bytes-bound, quantization moves the
    memory/compute crossover and with it the winning coarsening degree.
    """
    c = 1 if dense else cfg.degree
    grid = max(1, e // c)
    descs = c if (not dense and cfg.kind == KIND_GAPPED) else 1

    wb = _wbytes(dtype_bytes, None if dense else wbits)
    w_bytes = c * d * f * wb / descs
    if wbits and not dense:                  # scale rows ride with the pane
        w_bytes += c * (d // group if wbits == 4 else 1) * f * 4.0 / descs
    x_bytes = c * cap * d * dtype_bytes / descs
    o_bytes = c * cap * d * 4 / descs
    dma_s = (3 * _dma_time(w_bytes, descs) + _dma_time(x_bytes, descs)
             + _dma_time(o_bytes, descs))

    flops = 6.0 * c * cap * d * f            # x@w1 + x@w3 + h@w2
    rate = MXU_FLOPS_BF16 if dtype_bytes == 2 else MXU_FLOPS_F32
    eff = min(1.0, cap / 128)                # cap rows under-fill the MXU
    compute_s = flops / (rate * eff)
    if wbits and not dense:                  # per-pane VPU dequant (3 panes)
        compute_s += 3 * c * d * f * DEQUANT_OPS[wbits] / VPU_FLOPS_F32

    step = max(dma_s, compute_s)
    total = (dma_s + compute_s) + step * max(0, grid - 1)

    if dense:
        # gate and up intermediates: two (E, cap, f) activation-dtype
        # buffers, each written then re-read between the einsums
        total += 2 * _dma_time(e * cap * f * float(dtype_bytes), 2)

    vmem = 2 * (3 * c * d * f * dtype_bytes + 2 * c * cap * d * dtype_bytes) \
        + c * cap * f * 4
    return KernelCost(
        label="dense" if dense else cfg.label, grid=grid,
        dmas_per_step=5 * descs, dma_bytes=w_bytes,
        vmem_bytes=vmem, dma_sems=5 * descs,
        dma_s_per_step=dma_s, compute_s_per_step=compute_s, modeled_s=total,
        bound="memory" if dma_s >= compute_s else "compute",
    )


def scan_cost(rows: int, cols: int, cfg: CoarseningConfig, *,
              arith_per_elem: float = 4.0, dtype_bytes: int = 4,
              block_cols: int = 1024,
              flops_rate: float = VPU_FLOPS_F32) -> KernelCost | None:
    """Sequential-carry kernel (Pathfinder/DP, SSD inter-chunk state).

    The time dimension carries a dependence -> the grid over rows is
    *sequential*.  Gapped coarsening would interleave non-adjacent rows and
    break the carry: inapplicable (returns None), mirroring the paper's
    finding that kernels with barriers prefer replication (§IV.B.1).
    Consecutive coarsening fuses C successive rows into one program: fewer,
    wider DMAs but a C x longer serial chain per step.
    """
    if cfg.kind == KIND_GAPPED:
        return None
    c = cfg.degree
    grid_cols = cols // (block_cols * cfg.vector_width)
    grid = (rows // c) * grid_cols
    bytes_per_dma = c * block_cols * cfg.vector_width * dtype_bytes
    dma_s = _dma_time(bytes_per_dma, 1) * 2  # in + out
    # serial chain: C rows must execute in order inside the program
    compute_s = c * block_cols * cfg.vector_width * arith_per_elem / flops_rate
    repl = cfg.replication
    if repl > 1:
        # replication splits the *columns* (independent), not the carry
        grid = max(1, grid // repl)
        dma_s = _dma_time(bytes_per_dma, 1, bw=HBM_BW / repl) * 2
    step = max(dma_s, compute_s)
    total = dma_s + compute_s + step * max(0, grid - 1)
    vmem = 4 * c * block_cols * cfg.vector_width * dtype_bytes
    return KernelCost(
        label=cfg.label, grid=grid, dmas_per_step=2, dma_bytes=bytes_per_dma,
        vmem_bytes=vmem, dma_sems=2, dma_s_per_step=dma_s,
        compute_s_per_step=compute_s, modeled_s=total,
        bound="memory" if dma_s >= compute_s else "compute",
    )


def speedup_table(costs: Sequence[KernelCost], baseline: KernelCost) -> list[dict]:
    rows = []
    for c in costs:
        r = c.as_row()
        r["speedup"] = baseline.modeled_s / c.modeled_s
        r["vmem_ratio"] = c.vmem_bytes / max(1, baseline.vmem_bytes)
        rows.append(r)
    return rows
