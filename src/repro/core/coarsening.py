"""Thread-coarsening for Pallas TPU kernels — the paper's core technique.

The paper ("Exploring Thread Coarsening on FPGA", Eghbali Zarch et al. 2022)
consolidates the work of C OpenCL work-items into one work-item.  On TPU the
work-item analog is one Pallas *grid program*; coarsening therefore shrinks the
grid by C and grows the per-program work:

* ``consecutive``  — the C fused blocks are *contiguous*.  Expressed by viewing
  the streamed axis as ``(G, C, B)`` and fetching block ``(1, C, B)``: one wide
  HBM->VMEM DMA per operand per grid step.  This is the analog of the single
  wide burst-coalesced LSU the Intel offline compiler emits (paper Fig. 4,
  top-right).

* ``gapped``       — the C fused blocks are strided by ``G``.  Expressed by
  viewing the axis as ``(C, G, B)`` and fetching block ``(C, 1, B)``: the DMA
  engine must issue C strided row transfers per operand per grid step — the
  analog of the C narrow cached LSUs (paper Fig. 4, bottom).

Both views hand the kernel body an identical ``(C, B)`` tile, so a single body
serves every coarsening variant; only the *distribution* of work differs,
exactly as in the paper's Fig. 2.

The two competing mechanisms studied by the paper are also first-class:

* ``replication``  — pipeline replication (``num_compute_units``): the grid is
  split across R independent execution resources.  Within a chip this maps to
  parallel grid dimensions over TensorCores; across chips to `shard_map`.  The
  cost model charges replicas the *shared* HBM bandwidth, reproducing the
  paper's observation that replication only scales for compute-bound kernels.

* ``vector_width`` — SIMD vectorization (``num_simd_work_items``): the minor
  (lane) block dimension is widened V×.  Like the OpenCL compiler, we refuse to
  vectorize kernels with data-dependent control flow (`simd_ok=False`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KIND_NONE = "none"
KIND_CONSECUTIVE = "consecutive"
KIND_GAPPED = "gapped"
KINDS = (KIND_NONE, KIND_CONSECUTIVE, KIND_GAPPED)

# Default 1-D streaming block: 8 sublanes x 128 lanes of f32.
DEFAULT_BLOCK = 1024


@dataclasses.dataclass(frozen=True)
class CoarseningConfig:
    """The paper's (type, degree) pair plus the two competing mechanisms.

    kind:         none | consecutive | gapped       (paper §III.A)
    degree:       work-items fused per program      (paper degrees 2/4/8)
    replication:  pipeline-replication analog       (paper `num_compute_units`)
    vector_width: SIMD-vectorization analog         (paper `num_simd_work_items`)
    """

    kind: str = KIND_NONE
    degree: int = 1
    replication: int = 1
    vector_width: int = 1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")
        if self.kind == KIND_NONE and self.degree != 1:
            object.__setattr__(self, "degree", 1)
        if self.degree < 1 or self.replication < 1 or self.vector_width < 1:
            raise ValueError("degree/replication/vector_width must be >= 1")
        if self.kind != KIND_NONE and self.degree == 1:
            object.__setattr__(self, "kind", KIND_NONE)

    @property
    def label(self) -> str:
        bits = []
        if self.kind != KIND_NONE:
            bits.append(f"{'con' if self.kind == KIND_CONSECUTIVE else 'gap'}{self.degree}")
        if self.replication > 1:
            bits.append(f"pipe{self.replication}")
        if self.vector_width > 1:
            bits.append(f"simd{self.vector_width}")
        return "+".join(bits) if bits else "base"

    @staticmethod
    def parse(spec: str) -> "CoarseningConfig":
        """Parse e.g. 'consecutive:4', 'gapped:8', 'none', 'con4+pipe2'."""
        kind, degree, repl, vw = KIND_NONE, 1, 1, 1
        for part in spec.replace(",", "+").split("+"):
            part = part.strip().lower()
            if not part or part in ("none", "base"):
                continue
            if ":" in part:
                k, d = part.split(":")
                kind = {"con": KIND_CONSECUTIVE, "consecutive": KIND_CONSECUTIVE,
                        "gap": KIND_GAPPED, "gapped": KIND_GAPPED}[k]
                degree = int(d)
            elif part.startswith("con"):
                kind, degree = KIND_CONSECUTIVE, int(part[3:])
            elif part.startswith("gap"):
                kind, degree = KIND_GAPPED, int(part[3:])
            elif part.startswith("pipe"):
                repl = int(part[4:])
            elif part.startswith("simd"):
                vw = int(part[4:])
            else:
                raise ValueError(f"bad coarsening spec part: {part!r}")
        return CoarseningConfig(kind, degree, repl, vw)


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Grid/Block plan for a coarsened 1-D stream of N elements.

    The stream is reshaped to a 3-D view whose middle/leading axes encode the
    coarsening distribution; the kernel body always sees a (degree, block)
    tile.
    """

    n: int                      # total elements
    block: int                  # base block (pre-coarsening work-item size)
    cfg: CoarseningConfig
    grid: int                   # programs launched
    view_shape: tuple           # reshaped array view
    block_shape: tuple          # BlockSpec block shape on the view
    index_map: Callable[..., tuple]
    # --- analysis metadata (the paper's LSU table analog) ---
    dmas_per_operand: int       # LSU count analog
    dma_elems: int              # elements per DMA transfer (LSU width analog)
    contiguous: bool

    @property
    def tile_shape(self) -> tuple:
        return (self.cfg.degree, self.block * self.cfg.vector_width)


def plan_stream(n: int, cfg: CoarseningConfig, block: int = DEFAULT_BLOCK) -> StreamPlan:
    """Build the grid/BlockSpec plan for a coarsened 1-D stream kernel."""
    block = block * cfg.vector_width              # SIMD analog: widen lanes
    c = cfg.degree
    if n % (block * c) != 0:
        raise ValueError(f"N={n} not divisible by degree*block={c * block}")
    grid = n // (block * c)
    if cfg.kind in (KIND_NONE, KIND_CONSECUTIVE):
        # view (G, C, B); program i fetches rows [i, :, :]  -> 1 contiguous DMA
        return StreamPlan(
            n=n, block=block, cfg=cfg, grid=grid,
            view_shape=(grid, c, block),
            block_shape=(1, c, block),
            index_map=lambda i: (i, 0, 0),
            dmas_per_operand=1, dma_elems=c * block, contiguous=True,
        )
    else:
        # view (C, G, B); program i fetches rows [:, i, :]  -> C strided DMAs
        return StreamPlan(
            n=n, block=block, cfg=cfg, grid=grid,
            view_shape=(c, grid, block),
            block_shape=(c, 1, block),
            index_map=lambda i: (0, i, 0),
            dmas_per_operand=c, dma_elems=block, contiguous=False,
        )


def stream_view(x: jax.Array, plan: StreamPlan) -> jax.Array:
    """Reshape a flat stream into the coarsening view (free: no data movement
    for the consecutive view; the gapped view is a (C, G*B) transpose of the
    logical order, realised lazily by XLA as a strided DMA pattern)."""
    c, g, b = plan.cfg.degree, plan.grid, plan.block
    if plan.contiguous:
        return x.reshape(plan.view_shape)
    # gapped: element (k, i, j) of the view is x[k*g*b + i*b + j] — i.e. the
    # stream is split into C equal segments and segment k contributes the k-th
    # row of every tile.  A pure reshape, no transpose: matches paper Fig. 2
    # ("divide work-items into C evenly distributed groups").
    return x.reshape(plan.view_shape)


def unstream_view(y: jax.Array, plan: StreamPlan) -> jax.Array:
    return y.reshape(plan.n)


def stream_specs(plan: StreamPlan, n_operands: int):
    """BlockSpecs for n_operands inputs + 1 output, all following the plan."""
    spec = pl.BlockSpec(plan.block_shape, plan.index_map)
    return [spec] * n_operands, spec


def pallas_stream_call(body: Callable, plan: StreamPlan, n_in: int,
                       out_dtype=jnp.float32, interpret: bool = True,
                       cost_estimate: pl.CostEstimate | None = None):
    """Build a pallas_call for a coarsened streaming kernel.

    ``body(*in_refs, out_ref)`` sees (1,C,B) [consecutive] or (C,1,B) [gapped]
    tiles; use ``tile(ref)`` to obtain the canonical (C,B) array.

    Pipeline replication (cfg.replication = R > 1) splits the grid into an
    outer R-way *parallel* dimension — the `num_compute_units` analog: on TPU
    the parallel dimension is distributed across TensorCores (declared via
    dimension_semantics; a no-op under interpret mode but preserved for the
    Mosaic lowering).
    """
    in_specs, out_spec = stream_specs(plan, n_in)
    kwargs: dict[str, Any] = {}
    if cost_estimate is not None:
        kwargs["cost_estimate"] = cost_estimate

    r = plan.cfg.replication
    if r > 1 and plan.grid % r == 0:
        inner = plan.grid // r
        grid = (r, inner)
        base_map = plan.index_map

        def remap(spec):
            return pl.BlockSpec(spec.block_shape,
                                lambda p, i: base_map(p * inner + i))

        in_specs = [remap(s) for s in in_specs]
        out_spec = remap(out_spec)
        try:
            from jax.experimental.pallas import tpu as pltpu
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "arbitrary"))
        except Exception:            # interpret-only environments
            pass
    else:
        grid = (plan.grid,)

    call = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(plan.view_shape, out_dtype),
        interpret=interpret,
        **kwargs,
    )

    def run(*flat_inputs):
        views = [stream_view(x, plan) for x in flat_inputs]
        return unstream_view(call(*views), plan)

    return run


def flat_pid(plan: StreamPlan):
    """Flat grid position, replication-aware (kernel-body helper)."""
    r = plan.cfg.replication
    if r > 1 and plan.grid % r == 0:
        inner = plan.grid // r
        return pl.program_id(0) * inner + pl.program_id(1)
    return pl.program_id(0)


def tile(ref) -> jax.Array:
    """Canonical (C, B) tile from either coarsening view block."""
    x = ref[...]
    return x.reshape(x.shape[0] * x.shape[1], x.shape[2])


def untile(val: jax.Array, ref) -> None:
    ref[...] = val.reshape(ref.shape)


# ---------------------------------------------------------------------------
# 2-D (row-block) coarsening plans — used by matmul / attention / stencil,
# where coarsening fuses C row-blocks of the leading dimension.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RowPlan:
    rows: int
    block_rows: int
    cfg: CoarseningConfig
    grid: int
    fused_rows: int             # rows handled per program
    stride_blocks: int          # distance (in blocks) between fused blocks
    dmas_per_operand: int
    contiguous: bool


def plan_rows(rows: int, cfg: CoarseningConfig, block_rows: int) -> RowPlan:
    c = cfg.degree
    if rows % (block_rows * c) != 0:
        raise ValueError(f"rows={rows} not divisible by degree*block={c * block_rows}")
    grid = rows // (block_rows * c)
    if cfg.kind in (KIND_NONE, KIND_CONSECUTIVE):
        return RowPlan(rows, block_rows, cfg, grid, fused_rows=c * block_rows,
                       stride_blocks=1, dmas_per_operand=1, contiguous=True)
    return RowPlan(rows, block_rows, cfg, grid, fused_rows=c * block_rows,
                   stride_blocks=grid, dmas_per_operand=c, contiguous=False)


def row_starts(plan: RowPlan, i) -> list:
    """Starting row (in units of block_rows) of each fused block for program i."""
    c = plan.cfg.degree
    if plan.contiguous:
        return [i * c + k for k in range(c)]
    return [i + k * plan.grid for k in range(c)]
