"""Distribution: 2D FSDP x TP (+EP/SP) sharding rules, pipeline parallelism,
coarsened collectives."""
from .sharding import (
    param_specs, param_shardings, batch_specs, cache_specs, make_shard_ctx)
from .pipeline import pipeline_apply
from .collectives import bucketed_psum
