"""Partition rules: 2D FSDP('data') x TP('model'), EP on 'model', SP for the
long-context decode cells.  The 'pod' axis is an outer pure-DP dimension
(params replicated across pods; gradients all-reduce hierarchically), which
is the standard multi-pod layout when per-pod HBM already fits the sharded
state.

Rules are name+rank based so the same table covers stacked (period-scanned)
and unstacked (tail) parameters: a leaf with more dims than its rule gets
leading None axes (the stack dims are never sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import ShardCtx

D, M = "data", "model"

# leaf-name -> trailing-dims spec (None entries = replicated dims)
_RULES: dict[str, tuple] = {
    "embed": (M, D),
    "lm_head": (D, M),
    # attention
    "wq": (D, M), "wk": (D, M), "wv": (D, M), "wo": (M, D),
    "bq": (M,), "bk": (M,), "bv": (M,),
    # ffn
    "w1": (D, M), "w3": (D, M), "w2": (M, D),
    # moe (matched first via the 'moe' path component)
    "moe/router": (D, None),
    "moe/w1": (M, D, None), "moe/w3": (M, D, None), "moe/w2": (M, None, D),
    "moe/shared_gate": (D, None),
    # rg-lru
    "wx": (D, M), "wgate": (D, M), "wr": (D, M), "wi": (D, M),
    "br": (M,), "bi": (M,), "a_param": (M,),
    # conv (width, channels)
    "conv/w": (None, M), "conv/b": (M,),
    # mamba
    "in_proj": (D, M), "out_proj": (M, D),
    "dt_bias": (None,), "a_log": (None,), "d_skip": (None,),
    # norms
    "scale": (None,),
}


def _leaf_rule(path: tuple, leaf, axis_sizes: Optional[dict] = None) -> tuple:
    names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
    name = names[-1]
    if "moe" in names and "shared" not in names and f"moe/{name}" in _RULES:
        rule = _RULES[f"moe/{name}"]
    elif "conv" in names and f"conv/{name}" in _RULES:
        rule = _RULES[f"conv/{name}"]
    elif name in _RULES:
        rule = _RULES[name]
    else:
        rule = (None,) * leaf.ndim
    pad = leaf.ndim - len(rule)
    if pad < 0:
        raise ValueError(f"rule {rule} longer than leaf {names} {leaf.shape}")
    rule = (None,) * pad + tuple(rule)
    if axis_sizes:
        # argument shardings must divide exactly (pjit rejects padding on
        # arguments): drop the axis on any non-divisible dim (e.g. vocab
        # 256206 % 16 != 0 -> replicate that dim)
        rule = tuple(
            a if a is None or leaf.shape[i] % axis_sizes.get(a, 1) == 0
            else None
            for i, a in enumerate(rule))
    return rule


def param_specs(abstract: Any, mesh: Optional[Mesh] = None,
                serve_replicated: bool = False) -> Any:
    """Tree of PartitionSpec matching an abstract (or real) param tree.

    serve_replicated: §Perf lever for decode — drop the FSDP ('data') axis so
    bf16 weights are replicated across data shards (they fit: params/tp per
    chip) and decode pays no per-step parameter all-gather.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else None

    def spec(p, l):
        rule = _leaf_rule(p, l, sizes)
        if serve_replicated:
            rule = tuple(None if a == D else a for a in rule)
        return P(*rule)

    return jax.tree_util.tree_map_with_path(spec, abstract)


def param_shardings(abstract: Any, mesh: Mesh,
                    serve_replicated: bool = False) -> Any:
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        param_specs(abstract, mesh, serve_replicated),
                        is_leaf=lambda x: isinstance(x, P))


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_shard_ctx(mesh: Mesh, sp=None) -> ShardCtx:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= axes[a]
    return ShardCtx(dp=dp, tp=M, sp=sp, tp_size=axes[M], dp_size=dp_size,
                    enabled=True, mesh=mesh,
                    param_spec_fn=lambda p, l: P(*_leaf_rule(p, l)))


def batch_specs(cfg: ModelConfig, mesh: Mesh, *, batch: int) -> dict:
    """PartitionSpecs for a training/prefill batch dict."""
    dp = dp_axes(mesh)
    dp_deg = 1
    for a in dp:
        dp_deg *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    bspec = dp if batch % dp_deg == 0 else None   # tiny batches replicate
    out = {"tokens": P(bspec, None), "labels": P(bspec, None)}
    if cfg.is_encdec:
        out["src_frames"] = P(bspec, None, None)
    if cfg.frontend == "vision":
        out["frontend_embeds"] = P(bspec, None, None)
        out["pos3"] = P(bspec, None, None)          # (B, 3, S)
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, *, batch: int,
                seq: int) -> Any:
    """Specs for the decode cache: shard kv-heads on 'model' when they
    divide it, otherwise shard the SEQUENCE on 'model' (flash-decoding
    style); batch on dp when divisible (long_500k: batch=1 -> SP over
    'data' too)."""
    dp = dp_axes(mesh)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_deg = 1
    for a in dp:
        dp_deg *= axes[a]
    tp_deg = axes[M]
    bspec = dp if batch % dp_deg == 0 else None
    heads_div = cfg.n_kv_heads >= tp_deg and cfg.n_kv_heads % tp_deg == 0
    if heads_div:
        kv_spec = P(None, bspec, None, M, None)       # (L,B,S,kv,hd)
    elif bspec is None:
        # batch=1 long-context: shard the sequence over data AND model (SP)
        kv_spec = P(None, None, (*dp, M), None, None)
    else:
        kv_spec = P(None, bspec, M, None, None)       # seq on model

    def spec_for(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        name = names[-1]
        nd = leaf.ndim
        if name in ("k", "v", "enc_k", "enc_v"):
            return P(*kv_spec[5 - nd:]) if nd < 5 else kv_spec
        if name == "conv":                             # (L,B,w-1,C)
            return P(*((None,) * (nd - 1) + (M,)))
        if name == "h":                                # (L,B,d)
            return P(*((None,) * (nd - 1) + (M,)))
        if name == "ssm":                              # (L,B,H,P,N)
            return P(*((None,) * (nd - 3) + (M, None, None)))
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(
        spec_for, _abstract_cache(cfg, batch, seq))


def _abstract_cache(cfg, batch, seq):
    from repro.models import model as MM
    import jax.numpy as jnp
    return jax.eval_shape(
        lambda: MM.lm_init_cache(cfg, batch, seq, jnp.bfloat16,
                                 enc_len=min(seq, 4096)))
