"""Pipeline parallelism (GPipe schedule) via shard_map + ppermute.

The layer stack is split into S stages along a 'stage' mesh axis; M
microbatches flow through; each tick every stage processes its resident
microbatch and the activations rotate stage->stage+1 with a single
collective-permute.  Bubble fraction = (S-1)/(M+S-1), the classic GPipe
trade-off.  This module is self-contained (not part of the 40-cell matrix —
those meshes name only pod/data/model axes) and is exercised by a dedicated
multi-device subprocess test.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_fn: Callable, stage_params, x, *, mesh: Mesh,
                   n_micro: int, axis: str = "stage"):
    """Run x (B, ...) through S pipeline stages.

    stage_fn(params_for_one_stage, microbatch) -> microbatch (same shape).
    stage_params: pytree whose leaves have leading dim S (one slice/stage).
    x: global batch, split into n_micro microbatches along axis 0.
    """
    s = mesh.devices.size
    b = x.shape[0]
    assert b % n_micro == 0
    mb = b // n_micro
    micros = x.reshape(n_micro, mb, *x.shape[1:])

    def body(params_local, micros_local):
        # params_local: (1, ...) slice for this stage; micros: full (replicated)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        idx = lax.axis_index(axis)
        n_ticks = n_micro + s - 1
        buf = jnp.zeros_like(micros_local[0])
        outs = jnp.zeros_like(micros_local)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any); others use rotated buf
            feed = micros_local[jnp.clip(t, 0, n_micro - 1)]
            cur = jnp.where(idx == 0, feed, buf)
            live = (t - idx >= 0) & (t - idx < n_micro)
            y = stage_fn(params_local, cur)
            y = jnp.where(live, y, cur)
            # last stage records its finished microbatch t-(S-1)
            done = jnp.where((idx == s - 1) & live,
                             y, jnp.zeros_like(y))
            outs = lax.dynamic_update_index_in_dim(
                outs, outs[jnp.clip(t - (s - 1), 0, n_micro - 1)] + done,
                jnp.clip(t - (s - 1), 0, n_micro - 1), 0)
            # rotate stage s -> s+1
            buf = lax.ppermute(y, axis,
                               [(i, (i + 1) % s) for i in range(s)])
            return (buf, outs), None

        (buf, outs), _ = lax.scan(tick, (buf, outs),
                                  jnp.arange(n_ticks, dtype=jnp.int32))
        # only the last stage holds real outputs; broadcast to all
        outs = lax.psum(jnp.where(idx == s - 1, outs, jnp.zeros_like(outs)),
                        axis)
        return outs

    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),      # params sharded by stage; data replicated
        out_specs=P(),
        check_rep=False,
    )(stage_params, micros)
    return out.reshape(b, *x.shape[1:])
