"""Coarsened collectives — the paper's burst-coalescing insight on ICI.

One wide all-reduce moves the same bytes with one descriptor + one latency
instead of N; `bucketed_psum` flattens a gradient pytree into ~64MB buckets
(optim.compression.plan_buckets) and reduces each bucket once.  The
fig9_collectives benchmark measures per-tensor vs bucketed on the HLO level
(collective op count) and wall-time on CPU.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.optim.compression import plan_buckets, bucket_coarsen, bucket_restore


def bucketed_psum(grads: Any, *, mesh: Mesh, axis: str = "data",
                  bucket_bytes: int = 64 * 2 ** 20):
    """All-reduce a pytree over `axis` as few wide buckets (coarsened)."""
    plan = plan_buckets(grads, bucket_bytes)

    def body(*buckets):
        return tuple(lax.psum(b, axis) for b in buckets)

    buckets = bucket_coarsen(grads, plan)
    specs = tuple(P() for _ in buckets)
    reduced = shard_map(body, mesh=mesh, in_specs=specs, out_specs=specs,
                        check_rep=False)(*buckets)
    return bucket_restore(list(reduced), plan)


def int8_ef_psum(grads: Any, residual: Any, *, mesh: Mesh,
                 axis: str = "data"):
    """DP all-reduce with int8 error-feedback compression: 4x fewer wire
    bytes; the quantization error is carried in `residual` (EF-SGD).

    Wire protocol: quantize locally -> psum int32 (int8 payload widened for
    overflow-safe accumulation; real ICI would use int8 RS with f32
    accumulators) -> rescale by the max of per-shard scales.
    Returns (reduced grads, new residual).
    """
    from repro.optim.compression import int8_compress_grads
    q, scales, new_resid = int8_compress_grads(grads, residual)

    leaves_q, treedef = jax.tree.flatten(q)
    leaves_s = jax.tree.leaves(scales)

    def body(*ls):
        n = len(ls) // 2
        qs, ss = ls[:n], ls[n:]
        out = []
        for qq, s in zip(qs, ss):
            smax = lax.pmax(s, axis)
            acc = lax.psum(qq.astype(jnp.int32), axis)
            out.append(acc.astype(jnp.float32) * smax)
        return tuple(out)

    specs = tuple(P() for _ in range(2 * len(leaves_q)))
    out_specs = tuple(P() for _ in leaves_q)
    reduced = shard_map(body, mesh=mesh, in_specs=specs, out_specs=out_specs,
                        check_rep=False)(*leaves_q, *leaves_s)
    return jax.tree.unflatten(treedef, reduced), new_resid


def pertensor_psum(grads: Any, *, mesh: Mesh, axis: str = "data"):
    """Baseline: one all-reduce per parameter tensor (the 'narrow LSU')."""
    leaves, treedef = jax.tree.flatten(grads)

    def body(*ls):
        return tuple(lax.psum(l, axis) for l in ls)

    specs = tuple(P() for _ in leaves)
    reduced = shard_map(body, mesh=mesh, in_specs=specs, out_specs=specs,
                        check_rep=False)(*leaves)
    return jax.tree.unflatten(treedef, reduced)
