"""repro.quant — weight-only int8/int4 quantization with dequant-fused
coarsened kernels.

``qtypes`` defines the formats (per-channel int8, group-wise packed int4),
the ``QTensor`` pytree, the one-pass absmax calibrator and the
``quantize_params`` entry point.  The fused kernels live next to their
dense siblings (kernels/matmul.py ``make_qkernel``, kernels/moe_ffn.py
``make_qkernel``, kernels/decode_attention.py ``kv_bits=8``) and dispatch
through ``kernels.ops.quant_matmul`` / ``ops.quant_moe_ffn`` /
``ops.decode_attention``; the tuner prices the packed byte and dequant
terms (core/analysis) so quantized specs can pick DIFFERENT coarsening
degrees than dense ones.
"""
from repro.quant.qtypes import (DEFAULT_GROUP, INT4_QMAX, INT8_QMAX,
                                QUANT_KEYS, QTensor, asdense,
                                calibrate_absmax, dequantize, dequantize_kv,
                                pack_int4, quantize, quantize_int4,
                                quantize_int8, quantize_kv, quantize_params,
                                tree_nbytes, unpack_int4)

__all__ = [
    "DEFAULT_GROUP", "INT4_QMAX", "INT8_QMAX", "QUANT_KEYS", "QTensor",
    "asdense", "calibrate_absmax", "dequantize", "dequantize_kv",
    "pack_int4", "quantize", "quantize_int4", "quantize_int8",
    "quantize_kv", "quantize_params", "tree_nbytes", "unpack_int4",
]
