"""Weight-only quantization formats: per-channel int8 and group-wise int4.

The paper's central trade is work-per-program against resource cost;
quantization sharpens both sides of it.  A packed int8/int4 weight pane is
2-4x fewer bytes for the SAME coarsened DMA (one *wide* packed pane per
operand for consecutive degrees, strided panes for gapped), and the per-pane
dequant (unpack + scale-multiply) is per-program overhead that coarsening
amortizes exactly like the paper's per-work-item loop overhead (§III.B).

Formats
-------
int8  per-(output-)channel symmetric: for a weight laid out (..., K, N) with
      K the contraction axis, ``scale = absmax over K / 127`` has shape
      (..., 1, N); the payload is int8 of the same logical shape.

int4  group-wise symmetric: the contraction axis is cut into groups of
      ``group`` rows; ``scale`` has shape (..., K/group, N) and the payload
      packs two 4-bit values per byte along K -> (..., K/2, N) uint8.
      Values are stored offset-binary (q + 8 in [1, 15]) so both nibbles
      stay unsigned; the symmetric range is [-7, 7] (absmax maps to 7).

Both formats are exact at the absmax (no clip error), so the round-trip
error is bounded by scale/2 elementwise — the property
tests/test_quant.py checks with hypothesis.

``QTensor`` is a registered pytree (payload + scales are leaves; bits /
group / logical shape are static), so quantized params trees jit, donate
and tree-map like dense ones.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

INT8_QMAX = 127.0
INT4_QMAX = 7.0
DEFAULT_GROUP = 32

# param-dict keys quantize_params converts (FFN + MoE expert weights +
# attention projections); everything else — embeddings, lm_head, norms,
# router, conv/recurrent/SSM mixers — stays dense.
QUANT_KEYS = frozenset({"w1", "w3", "w2", "wq", "wk", "wv", "wo"})


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QTensor:
    """A quantized weight: packed payload + scales + static metadata.

    q      int8 payload (int8 mode) or uint8 nibble-packed payload (int4)
    scale  f32 scales: (..., 1, N) per-channel / (..., K/group, N) grouped
    bits   8 | 4
    group  contraction-group size (0 for per-channel int8)
    shape  the LOGICAL (unpacked, dense) weight shape
    """

    q: jax.Array
    scale: jax.Array
    bits: int
    group: int
    shape: tuple

    def tree_flatten(self):
        return (self.q, self.scale), (self.bits, self.group, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        bits, group, shape = aux
        return cls(q=q, scale=scale, bits=bits, group=group, shape=shape)

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def nbytes(self) -> int:
        return int(self.q.size * self.q.dtype.itemsize
                   + self.scale.size * self.scale.dtype.itemsize)


# ---------------------------------------------------------------------------
# int4 nibble packing
# ---------------------------------------------------------------------------

def pack_int4(q: jax.Array, axis: int = -2) -> jax.Array:
    """Pack int values in [-7, 7] two-per-byte along ``axis`` (offset-binary:
    stored nibble = q + 8).  The packed axis must have even length."""
    k = q.shape[axis]
    if k % 2:
        raise ValueError(f"int4 pack axis length {k} must be even")
    u = (q + 8).astype(jnp.uint8)
    lo = jax.lax.slice_in_dim(u, 0, k, stride=2, axis=axis)
    hi = jax.lax.slice_in_dim(u, 1, k, stride=2, axis=axis)
    return lo | (hi << 4)


def unpack_int4(packed: jax.Array, axis: int = -2) -> jax.Array:
    """Inverse of pack_int4: (..., K/2, ...) uint8 -> (..., K, ...) f32 in
    [-7, 7] (even logical rows from the low nibble, odd from the high)."""
    lo = (packed & 0xF).astype(jnp.float32) - 8.0
    hi = (packed >> 4).astype(jnp.float32) - 8.0
    ax = axis % packed.ndim
    stacked = jnp.stack([lo, hi], axis=ax + 1)       # (..., K/2, 2, ...)
    shape = list(packed.shape)
    shape[ax] = 2 * shape[ax]
    return stacked.reshape(shape)


# ---------------------------------------------------------------------------
# quantize / dequantize
# ---------------------------------------------------------------------------

def _absmax(w: jax.Array, axis: int, keepdims: bool = True) -> jax.Array:
    return jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis,
                               keepdims=keepdims), 1e-8)


def quantize_int8(w: jax.Array) -> QTensor:
    """Per-channel symmetric int8 over the contraction axis (-2)."""
    if w.ndim < 2:
        raise ValueError(f"need a >=2-D weight, got shape {w.shape}")
    scale = _absmax(w, axis=-2) / INT8_QMAX               # (..., 1, N)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale),
                 -INT8_QMAX, INT8_QMAX).astype(jnp.int8)
    return QTensor(q=q, scale=scale.astype(jnp.float32), bits=8, group=0,
                   shape=tuple(w.shape))


def quantize_int4(w: jax.Array, group: int = DEFAULT_GROUP) -> QTensor:
    """Group-wise symmetric int4 along the contraction axis (-2), packed
    two-per-byte."""
    if w.ndim < 2:
        raise ValueError(f"need a >=2-D weight, got shape {w.shape}")
    k, n = w.shape[-2], w.shape[-1]
    if group < 2 or group % 2:
        raise ValueError(f"int4 group must be even and >= 2, got {group}")
    if k % group:
        raise ValueError(f"contraction dim {k} not divisible by group {group}")
    lead = w.shape[:-2]
    wg = w.astype(jnp.float32).reshape(lead + (k // group, group, n))
    scale = _absmax(wg, axis=-2) / INT4_QMAX              # (..., K/g, 1, N)
    q = jnp.clip(jnp.round(wg / scale), -INT4_QMAX, INT4_QMAX)
    q = q.reshape(lead + (k, n)).astype(jnp.int8)
    return QTensor(q=pack_int4(q, axis=-2),
                   scale=scale.reshape(lead + (k // group, n)).astype(
                       jnp.float32),
                   bits=4, group=group, shape=tuple(w.shape))


def quantize(w: jax.Array, mode: str, group: int = DEFAULT_GROUP) -> QTensor:
    if mode == "int8":
        return quantize_int8(w)
    if mode == "int4":
        return quantize_int4(w, group=group)
    raise ValueError(f"unknown quant mode {mode!r} (want 'int8' or 'int4')")


def dequantize(qt: QTensor) -> jax.Array:
    """QTensor -> dense f32 of the logical shape (the parity oracle every
    fused dequant kernel is tested against)."""
    if qt.bits == 8:
        return qt.q.astype(jnp.float32) * qt.scale
    vals = unpack_int4(qt.q, axis=-2)                     # (..., K, N)
    scale = jnp.repeat(qt.scale, qt.group, axis=-2)       # (..., K, N)
    return vals * scale


def asdense(w, dtype=None):
    """QTensor -> dequantized dense array; dense array -> (cast) passthrough.
    The one-line dense-dequant fallback every weight consumer can use."""
    out = dequantize(w) if isinstance(w, QTensor) else w
    return out if dtype is None else out.astype(dtype)


# ---------------------------------------------------------------------------
# KV-cache quantization (per-token, per-kv-head)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array):
    """Quantize cache rows on append: x (..., D) -> (int8 (..., D),
    scale (...,) f32) with a symmetric absmax scale per leading index
    (per token x kv-head)."""
    amax = _absmax(x, axis=-1, keepdims=False)
    scale = amax / INT8_QMAX
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -INT8_QMAX, INT8_QMAX).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


# ---------------------------------------------------------------------------
# params-tree calibration + conversion
# ---------------------------------------------------------------------------

def _eligible(path, leaf) -> bool:
    if isinstance(leaf, QTensor) or not hasattr(leaf, "ndim"):
        return False
    if leaf.ndim < 2 or not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    key = None
    for p in reversed(path):
        name = getattr(p, "key", getattr(p, "name", None))
        if name is not None:
            key = name
            break
    return key in QUANT_KEYS


def calibrate_absmax(params, *, eligible: Callable = _eligible):
    """One-pass absmax calibration over a params tree: returns a tree of the
    same structure whose eligible leaves hold the per-channel absmax
    (reduced over the contraction axis) and whose other leaves are None.
    ``quantize_params`` consumes these stats; they are also the artifact a
    later activation-aware calibrator would refine."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _absmax(leaf, axis=-2)
        if eligible(path, leaf) else None, params)


def quantize_params(params, mode: str, *, group: int = DEFAULT_GROUP,
                    eligible: Callable = _eligible):
    """Quantize every eligible weight leaf of a params tree.

    Returns (new_params, report) where report counts converted leaves and
    the byte saving.  mode: 'int8' | 'int4'.  int4 leaves whose contraction
    dim the group can't tile stay dense (counted in report['skipped']).
    """
    stats = {"quantized": 0, "skipped": 0, "bytes_before": 0, "bytes_after": 0}

    def conv(path, leaf):
        if not eligible(path, leaf):
            return leaf
        stats["bytes_before"] += int(leaf.size * leaf.dtype.itemsize)
        try:
            qt = quantize(leaf, mode, group=group)
        except ValueError:
            stats["skipped"] += 1
            stats["bytes_after"] += int(leaf.size * leaf.dtype.itemsize)
            return leaf
        stats["quantized"] += 1
        stats["bytes_after"] += qt.nbytes
        return qt

    out = jax.tree_util.tree_map_with_path(conv, params)
    return out, stats


def tree_nbytes(tree) -> int:
    """Total payload bytes of a (possibly quantized) pytree."""
    return sum(int(x.size * x.dtype.itemsize) for x in jax.tree.leaves(tree)
               if hasattr(x, "size"))
