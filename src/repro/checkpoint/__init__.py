"""Checkpoint substrate: atomic, async, elastic-restorable checkpoints."""
from .manager import CheckpointManager, save_checkpoint, load_checkpoint
