"""Atomic manifest-based checkpoints with async save, retention, integrity
hashes and elastic (mesh-shape-agnostic) restore.

Layout:  <dir>/step_<N>/
            manifest.json     — leaf paths, shapes, dtypes, sha256, user state
            arrays.npz        — all leaves, saved from host memory
         <dir>/step_<N>.tmp/  — staging; renamed atomically on completion
         <dir>/LATEST         — text file with the newest complete step

Elasticity: leaves are stored as *logical* (unsharded) arrays keyed by path,
so a restart may use any mesh — `jax.device_put(leaf, new_sharding)` reshards
on load.  On multi-host deployments the same manifest format is written per
process with disjoint shard slices (documented in DESIGN.md); this repo's
single-process runtime gathers to host 0.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{_SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(skeleton, flat, prefix=""):
    if isinstance(skeleton, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}{_SEP}")
                for k, v in skeleton.items()}
    if isinstance(skeleton, list):
        return [_unflatten_into(v, flat, f"{prefix}{i}{_SEP}")
                for i, v in enumerate(skeleton)]
    if isinstance(skeleton, tuple):
        return tuple(_unflatten_into(v, flat, f"{prefix}{i}{_SEP}")
                     for i, v in enumerate(skeleton))
    return flat[prefix[:-1]]


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Atomic synchronous save; returns final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **{k.replace(_SEP, "|"): v for k, v in arrays.items()})
    digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
    manifest = {
        "step": step,
        "time": time.time(),
        "sha256": digest,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(f"step_{step:08d}")
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


def load_checkpoint(directory: str, skeleton: Any, step: Optional[int] = None,
                    shardings: Any = None, verify: bool = True):
    """Restore into `skeleton` structure; optionally reshard (elastic)."""
    if step is None:
        with open(os.path.join(directory, "LATEST")) as f:
            name = f.read().strip()
    else:
        name = f"step_{step:08d}"
    path = os.path.join(directory, name)
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    npz_path = os.path.join(path, "arrays.npz")
    if verify:
        digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(f"checkpoint {path} integrity check failed")
    data = np.load(npz_path)
    flat = {k.replace("|", _SEP): data[k] for k in data.files}
    tree = _unflatten_into(skeleton, flat)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(jnp.asarray(a), s),
                            tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, manifest


class CheckpointManager:
    """Async save + retention + auto-resume."""

    def __init__(self, directory: str, keep: int = 3,
                 save_interval_steps: int = 100):
        self.directory = directory
        self.keep = keep
        self.save_interval_steps = save_interval_steps
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ---- save ----------------------------------------------------------
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_interval_steps == 0

    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot off-device

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self.check()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.check()

    def check(self) -> None:
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # ---- restore ---------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.directory, "LATEST")
        if not os.path.exists(latest):
            return None
        return int(open(latest).read().strip().split("_")[1])

    def restore(self, skeleton: Any, step: Optional[int] = None,
                shardings: Any = None):
        return load_checkpoint(self.directory, skeleton, step, shardings)

    # ---- retention -------------------------------------------------------
    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
