"""repro: Thread Coarsening on TPU — JAX/Pallas training & serving framework.

The paper's contribution (thread coarsening vs pipeline replication vs SIMD
vectorization) lives in `repro.core` + `repro.kernels`; the production
substrate (models, data, optim, checkpoint, runtime, distributed, launch)
makes it deployable at multi-pod scale.  See DESIGN.md.
"""
import jax as _jax

# The legacy (non-partitionable) threefry lowering is not sharding-stable:
# the same lm_init under jit with sharded out_shardings yields DIFFERENT
# weights per mesh shape (GSPMD partitions the key-expansion differently),
# which breaks every cross-mesh equivalence (elastic restart, sharded-vs-
# single-device train step).  Partitionable threefry is sharding-invariant
# by construction, so init/dropout match bit-for-bit across mesh shapes.
_jax.config.update("jax_threefry_partitionable", True)
