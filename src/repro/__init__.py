"""repro: Thread Coarsening on TPU — JAX/Pallas training & serving framework.

The paper's contribution (thread coarsening vs pipeline replication vs SIMD
vectorization) lives in `repro.core` + `repro.kernels`; the production
substrate (models, data, optim, checkpoint, runtime, distributed, launch)
makes it deployable at multi-pod scale.  See DESIGN.md.
"""
