"""Versioned JSON persistence for autotuned coarsening configs.

One cache file holds the winner per (kernel family, shape, dtype, backend,
tuning-relevant params) — the FPGA-world analog of keeping the best
(num_coarsened_items, num_compute_units, num_simd_work_items) triple per
kernel after a sweep, so production launches never pay the search again.

The file is versioned: bumping CACHE_VERSION (or changing the analytic cost
model in a way that invalidates stored winners) makes old files load as
empty, which is the invalidation story — delete the file or bump the version.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any, Optional

from repro.core.coarsening import CoarseningConfig

# v2: the flash_attention family moved to a (b, h, hkv, sq, sk, d) spec
# shape and a dedicated attention cost model (core/analysis), and gained the
# flash_attention_bwd sibling — v1 flash winners are stale.
# v3: repro.quant — matmul/moe_ffn specs grew wbits/group params and
# decode_attention kv_bits, with packed-byte + dequant terms in the cost
# models; the ops audit also started keying every family on the REAL array
# dtype (ew/gather/stencil/scan/embed previously all filed under "float32"),
# so v2 winners for those families sit under wrong keys.
# v4: speculative decoding — the flash_attention_verify family (short-q
# batched verify through the paged short-q kernel, spec shape
# (b, h, hkv, t, npp, d)) plus its cost model in core/analysis; the verify
# terms also sharpened the decode-vs-verify crossover decode winners were
# modeled against, so v3 files reload as empty.
# v5: block-sparse long-context attention — the flash_attention_sparse
# family (per-q-block live-KV index, live-slot coarsening; the sparsity
# pattern — window/gstride/max_live — joins the spec key) plus
# flash_attention_sparse_cost in core/analysis; v4 files reload as empty so
# long-context prefill shapes re-rank against the sparse candidates.
CACHE_VERSION = 5
ENV_VAR = "REPRO_TUNE_CACHE"


def default_cache_path() -> str:
    env = os.environ.get(ENV_VAR)
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro", f"tune_v{CACHE_VERSION}.json")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Identity of one tunable kernel instance (the cache key).

    params holds only tuning-relevant compile-time knobs (block sizes,
    arithmetic intensity, divergence variant, ...) as a sorted tuple of
    (name, value) pairs so the spec stays hashable and JSON-stable.
    """

    family: str
    shape: tuple
    dtype: str = "float32"
    backend: str = "pallas"
    params: tuple = ()

    @classmethod
    def make(cls, family: str, shape, dtype: str = "float32",
             backend: str = "pallas", **params) -> "KernelSpec":
        return cls(family=family, shape=tuple(int(s) for s in shape),
                   dtype=str(dtype), backend=backend,
                   params=tuple(sorted(params.items())))

    @property
    def p(self) -> dict:
        return dict(self.params)

    @property
    def key(self) -> str:
        shp = "x".join(str(s) for s in self.shape)
        prm = ",".join(f"{k}={v}" for k, v in self.params)
        return f"{self.family}|{shp}|{self.dtype}|{self.backend}|{prm}"


class TuningCache:
    """Winner-per-spec store with atomic JSON persistence.

    Entries record the chosen config label plus how it was chosen
    (source 'model' vs 'measured' and the score), so a later session can
    tell a modeled prior from a measured result.
    """

    def __init__(self, path: Optional[str] = None, autoload: bool = True,
                 metrics=None):
        self.path = path or default_cache_path()
        self.entries: dict[str, dict] = {}
        if metrics is None:
            from repro.obs import Registry
            metrics = Registry()
        self.metrics = metrics
        self._c_hits = metrics.counter("tune_cache_hits_total")
        self._c_misses = metrics.counter("tune_cache_misses_total")
        self._warned_unwritable = False
        if autoload:
            self.load()

    @property
    def stats(self) -> dict:
        """Hit/miss counts, historically a plain dict — now a view over the
        obs registry counters."""
        return {"hits": self._c_hits.value, "misses": self._c_misses.value}

    def load(self) -> None:
        try:
            with open(self.path) as f:
                blob = json.load(f)
        except (OSError, ValueError):
            return
        if not isinstance(blob, dict) or blob.get("version") != CACHE_VERSION:
            return                      # stale/corrupt cache: treat as empty
        entries = blob.get("entries", {})
        if isinstance(entries, dict):
            self.entries = dict(entries)

    def save(self) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        blob = {"version": CACHE_VERSION, "entries": self.entries}
        # atomic replace so a crashed process never truncates the cache
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(
            os.path.abspath(self.path)), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(blob, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def get(self, spec: KernelSpec) -> Optional[CoarseningConfig]:
        e = self.entries.get(spec.key)
        if e is None:
            self._c_misses.inc()
            return None
        self._c_hits.inc()
        return CoarseningConfig.parse(e["cfg"])

    def put(self, spec: KernelSpec, cfg: CoarseningConfig, *,
            modeled_s: float, measured_s: Optional[float] = None,
            source: str = "model", persist: bool = True) -> None:
        self.entries[spec.key] = {
            "cfg": cfg.label,
            "modeled_s": modeled_s,
            "measured_s": measured_s,
            "source": source,
        }
        if persist:
            try:
                self.save()
            except OSError as e:
                # an unwritable cache must not break the kernel dispatch:
                # keep the winner in memory and warn once per cache
                if not self._warned_unwritable:
                    self._warned_unwritable = True
                    print(f"repro.tune: cannot persist tuning cache to "
                          f"{self.path}: {e} (continuing in-memory)")

    def __len__(self) -> int:
        return len(self.entries)


_DEFAULT: dict[str, TuningCache] = {}


def default_cache() -> TuningCache:
    """Process-wide cache singleton, re-resolved per path so tests can
    repoint via the REPRO_TUNE_CACHE env var."""
    path = default_cache_path()
    cache = _DEFAULT.get(path)
    if cache is None:
        cache = _DEFAULT[path] = TuningCache(path)
    return cache
