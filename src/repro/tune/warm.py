"""Cache warming for the launch drivers + a wall-clock measurer.

`warm_for_model` derives the kernel shapes a (train or serve) hot loop will
hit from the ModelConfig and autotunes each family once, so the first real
step already dispatches the winning coarsening config.  `wall_measurer`
builds the measured-timing closure for the exhaustive/greedy strategies
(CPU interpret wall time here; on a real TPU the same closure measures the
Mosaic-lowered kernel — see ROADMAP Open items).
"""
from __future__ import annotations

import math
import time
from typing import Optional

from repro.tune.cache import KernelSpec, TuningCache, default_cache
from repro.tune.search import autotune


def _round_down(n: int, q: int) -> int:
    return max(q, (n // q) * q)


# strategies the launch drivers accept for their --tune flag; "auto" is an
# alias for the modeled prior
TUNE_CHOICES = ("auto", "model", "greedy", "exhaustive")

# log(measured / modeled) buckets for the calibration histogram: 0 = the
# model is perfectly calibrated, +-0.7 ~ a 2x miss
RESIDUAL_BUCKETS = (-2.0, -1.0, -0.5, -0.25, -0.1, 0.0, 0.1, 0.25, 0.5,
                    1.0, 2.0)

# per-family calibration from the most recent measured warms in this
# process (accumulates across warm_for_model calls — the serve driver warms
# the target engine and then the spec engine); tune_report() formats it
LAST_CALIBRATION: dict[str, dict] = {}


def warm_from_flag(cfg, tune: str, *, seq: int, batch: int,
                   cache: Optional[TuningCache] = None,
                   page_size: Optional[int] = None,
                   spec_k: Optional[int] = None, metrics=None) -> dict:
    """The launch drivers' --tune entry point: map the flag value to a
    (strategy, measurer) pair and warm the cache."""
    if tune not in TUNE_CHOICES:
        raise ValueError(f"tune must be one of {TUNE_CHOICES}, got {tune!r}")
    measure = wall_measurer() if tune in ("greedy", "exhaustive") else None
    strategy = "model" if tune == "auto" else tune
    return warm_for_model(cfg, seq=seq, batch=batch, cache=cache,
                          measure=measure, strategy=strategy,
                          page_size=page_size, spec_k=spec_k,
                          metrics=metrics)


def _calibration(res) -> Optional[dict]:
    """Model-vs-measured agreement for one TuneResult: pairwise rank
    concordance (Kendall-style, ties skipped), top-1 pick match, and
    log(measured/modeled) residuals.  None when fewer than two candidates
    carry a measurement (nothing to rank)."""
    meas = [c for c in res.candidates if c.measured_s is not None
            and c.measured_s > 0 and c.modeled_s > 0]
    if len(meas) < 2:
        return None
    pairs = agree = 0
    for i in range(len(meas)):
        for j in range(i + 1, len(meas)):
            a, b = meas[i], meas[j]
            if a.modeled_s == b.modeled_s or a.measured_s == b.measured_s:
                continue
            pairs += 1
            agree += int((a.modeled_s < b.modeled_s)
                         == (a.measured_s < b.measured_s))
    model_pick = min(meas, key=lambda c: c.modeled_s)
    meas_pick = min(meas, key=lambda c: c.measured_s)
    resid = sorted(math.log(c.measured_s / c.modeled_s) for c in meas)
    return {
        "n_measured": len(meas),
        "rank_agreement": round(agree / pairs, 3) if pairs else 1.0,
        "top1_match": model_pick.cfg.label == meas_pick.cfg.label,
        "model_pick": model_pick.cfg.label,
        "measured_pick": meas_pick.cfg.label,
        "residuals": [round(r, 3) for r in resid],
        "residual_median": round(resid[len(resid) // 2], 3),
    }


def tune_report(cache: Optional[TuningCache] = None) -> str:
    """The --tune exit summary: cache hit/miss counts plus the per-family
    model-vs-measured calibration collected by this process's warms."""
    cache = cache or default_cache()
    st = cache.stats
    lines = [f"tune: cache {st['hits']} hits / {st['misses']} misses "
             f"({len(cache)} entries at {cache.path})"]
    if not LAST_CALIBRATION:
        lines.append("tune: calibration n/a — no family measured this run "
                     "(cache hits, or --tune auto/model which never "
                     "measures)")
        return "\n".join(lines)
    lines.append("tune: model-vs-measured calibration "
                 "(rank agreement over measured candidates; residual = "
                 "median log(measured/modeled), 0 is perfect):")
    for fam in sorted(LAST_CALIBRATION):
        c = LAST_CALIBRATION[fam]
        pick = "top-1 MATCH" if c["top1_match"] else (
            f"top-1 MISS (model {c['model_pick']} vs measured "
            f"{c['measured_pick']})")
        lines.append(f"tune:   {fam}: rank {c['rank_agreement']:.0%} over "
                     f"{c['n_measured']} measured, {pick}, residual "
                     f"{c['residual_median']:+.3f}")
    return "\n".join(lines)


def warm_for_model(cfg, *, seq: int, batch: int,
                   cache: Optional[TuningCache] = None,
                   measure=None, strategy: str = "model",
                   verbose: bool = True,
                   page_size: Optional[int] = None,
                   spec_k: Optional[int] = None, metrics=None) -> dict:
    """Autotune the kernel families a model step exercises; returns
    {family: winning-label}.  cfg is a repro.models.config.ModelConfig.

    With ``metrics`` (an obs Registry), each measured family's calibration
    lands in ``tune_rank_agreement{family=...}`` / ``tune_top1_match`` /
    the ``tune_residual_logratio`` histogram, and in LAST_CALIBRATION for
    tune_report()."""
    cache = cache or default_cache()
    toks = batch * seq
    d = cfg.d_model
    specs = {
        # elementwise residual/activation streams: toks*d elements
        "ew_stream": KernelSpec.make(
            "ew_stream", (_round_down(toks * d, 1024 * 16),),
            n_loads=2, ai=6, variant="base", block=1024),
        # embedding lookup: toks ids against the padded vocab table
        "embed_gather": KernelSpec.make(
            "embed_gather", (_round_down(toks, 256 * 8), cfg.vocab_padded, d),
            block=256),
        # the block matmul (toks, d) @ (d, d_ff)
        "matmul": KernelSpec.make(
            "matmul", (_round_down(toks, 128 * 8),
                       _round_down(cfg.d_ff, 128),
                       _round_down(d, 256)),
            dtype="bfloat16", bm=128, bn=128, bk=256),
        # split-KV decode attention at the full allocated cache length (the
        # serve hot loop); skipped via the ValueError path when seq doesn't
        # tile by the kv block
        "decode_attention": KernelSpec.make(
            "decode_attention",
            (batch, cfg.n_heads, cfg.n_kv_heads, seq, cfg.hd),
            dtype="bfloat16", bkv=min(128, seq), window=0),
        # training flash attention: the forward (q-row coarsening axis) and
        # the backward dK/dV pass (kv-block coarsening axis) tune
        # independent degrees — warm both so a cfg.attn_backend="pallas"
        # train step's first forward AND first grad dispatch from the cache
        "flash_attention": KernelSpec.make(
            "flash_attention",
            (batch, cfg.n_heads, cfg.n_kv_heads, seq, seq, cfg.hd),
            dtype="bfloat16", bq=min(128, seq), bkv=min(128, seq),
            causal=True),
        "flash_attention_bwd": KernelSpec.make(
            "flash_attention_bwd",
            (batch, cfg.n_heads, cfg.n_kv_heads, seq, seq, cfg.hd),
            dtype="bfloat16", bq=min(128, seq), bkv=min(128, seq),
            causal=True),
    }
    wbits = {"int8": 8, "int4": 4}.get(getattr(cfg, "quant", "none"))
    if wbits:
        # the dequant-fused quantized matmul: same geometry as the dense
        # spec but its own cache key (wbits/group) — the packed-pane byte
        # and dequant terms can move the winning degree
        specs["matmul_q"] = KernelSpec.make(
            "matmul", (_round_down(toks, 128 * 8),
                       _round_down(cfg.d_ff, 128),
                       _round_down(d, 256)),
            dtype="bfloat16", bm=128, bn=128, bk=256, wbits=wbits,
            group=cfg.quant_group if wbits == 4 else 0)
    if getattr(cfg, "kv_quant", "none") == "int8":
        specs["decode_attention_q"] = KernelSpec.make(
            "decode_attention",
            (batch, cfg.n_heads, cfg.n_kv_heads, seq, cfg.hd),
            dtype="int8", bkv=min(128, seq), window=0, kv_bits=8)
    if cfg.n_experts:
        # grouped-expert fused FFN over the padded dispatch buffer, at the
        # exact capacity the layer dispatches
        from repro.models.layers import moe_default_capacity
        cap = moe_default_capacity(toks, cfg.n_experts, cfg.top_k)
        specs["moe_ffn"] = KernelSpec.make(
            "moe_ffn", (cfg.n_experts_padded, cap, d, cfg.moe_d_ff),
            dtype="bfloat16")
        if wbits:
            specs["moe_ffn_q"] = KernelSpec.make(
                "moe_ffn", (cfg.n_experts_padded, cap, d, cfg.moe_d_ff),
                dtype="bfloat16", wbits=wbits,
                group=cfg.quant_group if wbits == 4 else 0)
        # the decode step dispatches at its own (much smaller) capacity:
        # blocks.attn_block_decode passes max(4, min(B, 4*top_k)) and
        # layers.moe clamps it to the step's B tokens — a distinct spec
        # key, warmed too so the serve hot loop's first token never
        # searches inline
        cap_dec = min(batch, max(4, min(batch, 4 * cfg.top_k)))
        if cap_dec != cap:
            specs["moe_ffn_decode"] = KernelSpec.make(
                "moe_ffn", (cfg.n_experts_padded, cap_dec, d, cfg.moe_d_ff),
                dtype="bfloat16")
    if cfg.window:
        # mixed global/local stacks dispatch two param sets — warm both
        specs["decode_attention_local"] = KernelSpec.make(
            "decode_attention",
            (batch, cfg.n_heads, cfg.n_kv_heads, seq, cfg.hd),
            dtype="bfloat16", bkv=min(128, seq), window=cfg.window)
        # local-layer prefill dispatches the block-sparse live-index
        # kernel (layers.flash_attention sparse path) — warm its family at
        # the exact pattern key the dispatch will resolve
        bq_s, bkv_s = min(cfg.attn_bq, seq), min(cfg.attn_bkv, seq)
        if seq % bq_s == 0 and seq % bkv_s == 0:
            from repro.kernels.sparse_attention import build_block_index
            gs = getattr(cfg, "attn_global_stride", None)
            sidx = build_block_index(seq, seq, bq_s, bkv_s, causal=True,
                                     window=cfg.window, global_stride=gs)
            specs["flash_attention_sparse"] = KernelSpec.make(
                "flash_attention_sparse",
                (batch, cfg.n_heads, cfg.n_kv_heads, seq, seq, cfg.hd),
                dtype="bfloat16", bq=bq_s, bkv=bkv_s, causal=True,
                window=cfg.window, gstride=gs or 0,
                max_live=int(sidx.shape[1]), n_live=int((sidx >= 0).sum()))
    if page_size:
        # paged serving: the block-table decode family at the per-slot page
        # budget (page size joins the spec key — different page sizes are
        # different kernels with different winning degrees)
        npp = max(1, seq // page_size)
        kv_q = getattr(cfg, "kv_quant", "none") == "int8"
        specs["decode_attention_paged"] = KernelSpec.make(
            "decode_attention_paged",
            (batch, cfg.n_heads, cfg.n_kv_heads, npp, cfg.hd),
            dtype="int8" if kv_q else "bfloat16", page_size=page_size,
            window=0, **({"kv_bits": 8} if kv_q else {}))
        if cfg.window:
            specs["decode_attention_paged_local"] = KernelSpec.make(
                "decode_attention_paged",
                (batch, cfg.n_heads, cfg.n_kv_heads, npp, cfg.hd),
                dtype="int8" if kv_q else "bfloat16", page_size=page_size,
                window=cfg.window, **({"kv_bits": 8} if kv_q else {}))
        if spec_k:
            # speculative decoding: the batched-verify short-q family at
            # T = K+1 rows (K drafted tokens plus the last accepted one).
            # Its own family key — scoring T*G rows per fetched page moves
            # the memory/compute crossover, so the winner differs from the
            # single-row decode family at the same page geometry
            specs["flash_attention_verify"] = KernelSpec.make(
                "flash_attention_verify",
                (batch, cfg.n_heads, cfg.n_kv_heads, spec_k + 1, npp,
                 cfg.hd),
                dtype="int8" if kv_q else "bfloat16", page_size=page_size,
                window=0, **({"kv_bits": 8} if kv_q else {}))
    out = {}
    for fam, spec in specs.items():
        results = []
        try:
            best = autotune(spec, cache=cache, measure=measure,
                            strategy=strategy, on_result=results.append)
        except ValueError as e:          # geometry too small to coarsen
            if verbose:
                print(f"tune: {fam}: skipped ({e})")
            continue
        out[fam] = best.label
        if verbose:
            print(f"tune: {fam} {spec.shape} -> {best.label}")
        cal = _calibration(results[0]) if results else None
        if cal is not None:
            LAST_CALIBRATION[fam] = cal
            if metrics is not None:
                metrics.gauge("tune_rank_agreement",
                              family=fam).set(cal["rank_agreement"])
                metrics.gauge("tune_top1_match",
                              family=fam).set(int(cal["top1_match"]))
                h = metrics.histogram("tune_residual_logratio",
                                      RESIDUAL_BUCKETS,
                                      "log(measured_s / modeled_s)")
                for r in cal["residuals"]:
                    h.observe(r)
    return out


def wall_measurer(reps: int = 3):
    """measure(spec, cfg) -> seconds by timing the jit'd op on this host.

    Supports the families the benchmark suite measures (including the
    quantized matmul/moe_ffn and int8-KV decode specs).  The ops layer
    builds kernels with ``interpret=(default backend == cpu)``, so on a TPU
    host this times the COMPILED (Mosaic-lowered) kernel and the cache
    entry's ``source='measured'`` provenance refers to real silicon;
    interpret-mode timing is the CPU fallback (ROADMAP "measured-timing
    tuning" item).
    """
    import jax
    import jax.numpy as jnp

    def measure(spec: KernelSpec, cfg) -> float:
        from repro.kernels import ops
        from repro.kernels import gather_stream as gs
        p = spec.p
        key = jax.random.PRNGKey(0)

        if spec.family == "ew_stream":
            n = spec.shape[0]
            xs = tuple(jax.random.normal(jax.random.fold_in(key, i), (n,))
                       for i in range(p.get("n_loads", 8)))
            fn = lambda: ops.ew_stream(xs, cfg, ai=p.get("ai", 6),
                                       variant=p.get("variant", "base"),
                                       block=p.get("block", 1024))
        elif spec.family == "gather_stream":
            n, table = spec.shape
            idx = jnp.asarray(gs.make_indices(
                n, table, int(p.get("window_elems", 8192)), seed=0))
            tabs = tuple(jax.random.normal(jax.random.fold_in(key, i),
                                           (table,))
                         for i in range(p.get("n_loads", 8)))
            fn = lambda: ops.gather_stream(idx, tabs, cfg,
                                           ai=p.get("ai", 6),
                                           block=p.get("block", 1024))
        elif spec.family == "matmul":
            m, n, k = spec.shape
            dt = jnp.bfloat16 if spec.dtype == "bfloat16" else jnp.float32
            a = jax.random.normal(key, (m, k), dt)
            b = jax.random.normal(jax.random.fold_in(key, 1), (k, n), dt)
            if p.get("wbits"):
                from repro.quant import quantize
                qw = quantize(b.astype(jnp.float32),
                              "int8" if p["wbits"] == 8 else "int4",
                              group=p.get("group") or 32)
                fn = lambda: ops.quant_matmul(a, qw, cfg, bm=p.get("bm", 128),
                                              bn=p.get("bn", 128),
                                              bk=p.get("bk", 256))
            else:
                fn = lambda: ops.matmul(a, b, cfg, bm=p.get("bm", 128),
                                        bn=p.get("bn", 128),
                                        bk=p.get("bk", 256))
        elif spec.family == "dp_scan":
            rows, cols = spec.shape
            c = jax.random.uniform(key, (rows, cols))
            fn = lambda: ops.dp_scan(c, cfg)
        elif spec.family == "stencil5":
            rows, cols = spec.shape
            x = jax.random.normal(key, (rows, cols))
            fn = lambda: ops.stencil5(x, cfg,
                                      block_rows=p.get("block_rows", 8))
        elif spec.family == "decode_attention":
            b, h, hkv, s, d = spec.shape
            dt = jnp.bfloat16 if spec.dtype == "bfloat16" else jnp.float32
            q = jax.random.normal(key, (b, 1, h, d), dt)
            kc = jax.random.normal(jax.random.fold_in(key, 1),
                                   (b, s, hkv, d), dt)
            vc = jax.random.normal(jax.random.fold_in(key, 2),
                                   (b, s, hkv, d), dt)
            pos = jnp.full((b,), s - 1, jnp.int32)
            w = p.get("window", 0) or None
            if p.get("kv_bits"):
                from repro.quant import quantize_kv
                kq, ks = quantize_kv(kc.astype(jnp.float32))
                vq, vs = quantize_kv(vc.astype(jnp.float32))
                fn = lambda: ops.decode_attention(q, kq, vq, pos, cfg,
                                                  bkv=p.get("bkv", 128),
                                                  window=w, k_scale=ks,
                                                  v_scale=vs)
            else:
                fn = lambda: ops.decode_attention(q, kc, vc, pos, cfg,
                                                  bkv=p.get("bkv", 128),
                                                  window=w)
        elif spec.family == "decode_attention_paged":
            b, h, hkv, npp, d = spec.shape
            ps = p.get("page_size", 64)
            dt = jnp.bfloat16 if spec.dtype == "bfloat16" else jnp.float32
            # a worst-case fragmented pool: every slot's pages permuted
            n_pages = b * npp + 1
            q = jax.random.normal(key, (b, 1, h, d), dt)
            kp = jax.random.normal(jax.random.fold_in(key, 1),
                                   (n_pages, ps, hkv, d), dt)
            vp = jax.random.normal(jax.random.fold_in(key, 2),
                                   (n_pages, ps, hkv, d), dt)
            bt = jnp.asarray(jax.random.permutation(
                jax.random.fold_in(key, 3),
                jnp.arange(1, n_pages)).reshape(b, npp), jnp.int32)
            pos = jnp.full((b,), npp * ps - 1, jnp.int32)
            w = p.get("window", 0) or None
            if p.get("kv_bits"):
                from repro.quant import quantize_kv
                kq, ks = quantize_kv(kp.astype(jnp.float32))
                vq, vs = quantize_kv(vp.astype(jnp.float32))
                fn = lambda: ops.paged_decode_attention(
                    q, kq, vq, bt, pos, cfg, window=w, k_scale=ks,
                    v_scale=vs)
            else:
                fn = lambda: ops.paged_decode_attention(
                    q, kp, vp, bt, pos, cfg, window=w)
        elif spec.family == "flash_attention_verify":
            b, h, hkv, t, npp, d = spec.shape
            ps = p.get("page_size", 64)
            dt = jnp.bfloat16 if spec.dtype == "bfloat16" else jnp.float32
            # same worst-case fragmented pool as the decode family, but T
            # drafted rows per slot ending at the last cache position
            n_pages = b * npp + 1
            q = jax.random.normal(key, (b, t, h, d), dt)
            kp = jax.random.normal(jax.random.fold_in(key, 1),
                                   (n_pages, ps, hkv, d), dt)
            vp = jax.random.normal(jax.random.fold_in(key, 2),
                                   (n_pages, ps, hkv, d), dt)
            bt = jnp.asarray(jax.random.permutation(
                jax.random.fold_in(key, 3),
                jnp.arange(1, n_pages)).reshape(b, npp), jnp.int32)
            pos0 = jnp.full((b,), npp * ps - t, jnp.int32)
            w = p.get("window", 0) or None
            if p.get("kv_bits"):
                from repro.quant import quantize_kv
                kq, ks = quantize_kv(kp.astype(jnp.float32))
                vq, vs = quantize_kv(vp.astype(jnp.float32))
                fn = lambda: ops.flash_attention_verify(
                    q, kq, vq, bt, pos0, cfg, window=w, k_scale=ks,
                    v_scale=vs)
            else:
                fn = lambda: ops.flash_attention_verify(
                    q, kp, vp, bt, pos0, cfg, window=w)
        elif spec.family == "ssd":
            b, h, g, s, pdim, n = spec.shape
            x = jax.random.normal(key, (b, h, s, pdim)) * 0.5
            dtv = jax.nn.softplus(
                jax.random.normal(jax.random.fold_in(key, 1), (b, h, s)))
            a = -jax.nn.softplus(
                jax.random.normal(jax.random.fold_in(key, 2), (h,)))
            bm = jax.random.normal(jax.random.fold_in(key, 3),
                                   (b, g, s, n)) * 0.5
            cm = jax.random.normal(jax.random.fold_in(key, 4),
                                   (b, g, s, n)) * 0.5
            fn = lambda: ops.ssd(x, dtv, a, bm, cm, cfg,
                                 chunk=p.get("chunk", 64))
        elif spec.family == "rglru":
            b, s, d = spec.shape
            x = jax.random.normal(key, (b, s, d)) * 0.5
            r = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d))
            i = jax.random.normal(jax.random.fold_in(key, 2), (b, s, d))
            a_param = jax.random.normal(jax.random.fold_in(key, 3), (d,))
            fn = lambda: ops.rglru(x, r, i, a_param, cfg,
                                   block_d=p.get("block_d", 128),
                                   block_t=p.get("block_t", 64))
        elif spec.family in ("flash_attention", "flash_attention_bwd"):
            b, h, hkv, sq, sk, d = spec.shape
            dt = jnp.bfloat16 if spec.dtype == "bfloat16" else jnp.float32
            q = jax.random.normal(key, (b, h, sq, d), dt) * 0.5
            kk = jax.random.normal(jax.random.fold_in(key, 1),
                                   (b, hkv, sk, d), dt) * 0.5
            vv = jax.random.normal(jax.random.fold_in(key, 2),
                                   (b, hkv, sk, d), dt)
            causal = bool(p.get("causal", True))
            bq, bkv = p.get("bq", 128), p.get("bkv", 128)
            if spec.family == "flash_attention":
                fn = lambda: ops.flash_attention(
                    q, kk, vv, cfg, bwd_cfg="auto", bq=bq, bkv=bkv,
                    causal=causal)
            else:
                # time the backward the cfg controls: grad through the
                # custom-VJP op at a base forward with bwd_cfg pinned
                from repro.core.coarsening import CoarseningConfig
                grad = jax.jit(jax.grad(
                    lambda q_, k_, v_: jnp.sum(ops.flash_attention(
                        q_, k_, v_, CoarseningConfig(), bwd_cfg=cfg,
                        bq=bq, bkv=bkv, causal=causal)),
                    argnums=(1, 2)))
                fn = lambda: grad(q, kk, vv)
        elif spec.family == "flash_attention_sparse":
            b, h, hkv, sq, sk, d = spec.shape
            dt = jnp.bfloat16 if spec.dtype == "bfloat16" else jnp.float32
            q = jax.random.normal(key, (b, h, sq, d), dt) * 0.5
            kk = jax.random.normal(jax.random.fold_in(key, 1),
                                   (b, hkv, sk, d), dt) * 0.5
            vv = jax.random.normal(jax.random.fold_in(key, 2),
                                   (b, hkv, sk, d), dt)
            fn = lambda: ops.flash_attention_sparse(
                q, kk, vv, cfg, bq=p.get("bq", 128), bkv=p.get("bkv", 128),
                causal=bool(p.get("causal", True)),
                window=p.get("window") or None,
                global_stride=p.get("gstride") or None)
        elif spec.family == "moe_ffn":
            e, cap, d, f = spec.shape
            dt = jnp.bfloat16 if spec.dtype == "bfloat16" else jnp.float32
            xe = jax.random.normal(key, (e, cap, d), dt)
            w1 = jax.random.normal(jax.random.fold_in(key, 1), (e, d, f), dt)
            w3 = jax.random.normal(jax.random.fold_in(key, 2), (e, d, f), dt)
            w2 = jax.random.normal(jax.random.fold_in(key, 3), (e, f, d), dt)
            wts = jax.random.uniform(jax.random.fold_in(key, 4), (e, cap))
            if p.get("wbits"):
                from repro.quant import quantize
                mode = "int8" if p["wbits"] == 8 else "int4"
                g = p.get("group") or 32
                q1, q3, q2 = (quantize(w.astype(jnp.float32), mode, group=g)
                              for w in (w1, w3, w2))
                fn = lambda: ops.quant_moe_ffn(xe, q1, q3, q2, wts, cfg)
            else:
                fn = lambda: ops.moe_ffn(xe, w1, w3, w2, wts, cfg)
        elif spec.family == "embed_gather":
            n_ids, vocab, d = spec.shape
            ids = jax.random.randint(key, (n_ids,), 0, vocab)
            table = jax.random.normal(jax.random.fold_in(key, 1), (vocab, d))
            fn = lambda: ops.embed_gather(ids, table, cfg,
                                          block=p.get("block", 256))
        else:
            raise ValueError(f"wall_measurer: unsupported family "
                             f"{spec.family!r}")

        jax.block_until_ready(fn())          # compile
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2]

    return measure
