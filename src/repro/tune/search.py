"""Coarsening autotuner: enumerate, rank, (optionally) measure, pick.

The paper's methodology — sweep (coarsening kind, degree) against pipeline
replication and SIMD width per kernel and pick the per-access-pattern winner
— as reusable harness code.  Three strategies:

  model       rank every valid candidate by the core/analysis analytic cost
              (the perfmodel prior; free, no execution)
  exhaustive  measure every valid candidate with the supplied timer and rank
              by wall time (the paper's full sweep)
  greedy      measure only the top-k of the model ranking and pick the best
              measured one (the few-steps-go-a-long-way recipe: the prior
              prunes the space, measurement breaks the near-ties)

Candidate validity comes from the SAME divisibility rules the kernels
enforce (plan_stream / plan_rows geometry), so an autotuned config can
always be instantiated.  Mechanisms a kernel does not implement (e.g.
replication outside pallas_stream_call, SIMD under data-dependent control
flow) are excluded from its space rather than modeled-and-unrunnable.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

from repro.core import analysis
from repro.core.coarsening import (
    CoarseningConfig, KIND_NONE, KIND_CONSECUTIVE, KIND_GAPPED, plan_stream)
from repro.tune.cache import KernelSpec, TuningCache, default_cache

DEGREES = (1, 2, 4, 8)
REPLICATIONS = (1, 2, 4)
VECTOR_WIDTHS = (1, 2)

# ew_stream variants whose predicate depends on loaded data: like the OpenCL
# offline compiler, we refuse to vectorize these (coarsening.py simd_ok).
DATA_DEPENDENT_VARIANTS = frozenset(
    {"if_in", "for_in_if_in", "div2", "div4"})

# divergence parameters fed to stream_cost per ew_stream variant:
# (paths, uniform, bounded_trip_factor)
_VARIANT_DIVERGENCE = {
    "base": (1, False, 1.0),
    "if_id": (2, True, 1.0),
    "if_in": (2, False, 1.0),
    "for_const_if_id": (2, True, 1.0),
    "for_in_if_in": (2, False, 2.0),
    "div2": (2, False, 1.0),
    "div4": (4, False, 1.0),
}

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int32": 4,
                "int8": 1}

# counts search() invocations; tests assert cfg="auto" cache hits skip this
SEARCH_COUNT = 0


@dataclasses.dataclass(frozen=True)
class Candidate:
    cfg: CoarseningConfig
    modeled_s: float
    measured_s: Optional[float] = None

    @property
    def score(self) -> float:
        return self.measured_s if self.measured_s is not None else self.modeled_s


@dataclasses.dataclass
class TuneResult:
    spec: KernelSpec
    best: CoarseningConfig
    candidates: list         # Candidates, ranked best-first
    source: str              # 'model' | 'measured' | 'cache'


# ---------------------------------------------------------------------------
# per-family candidate legality
# ---------------------------------------------------------------------------

def _kind_degree_pairs(degrees: Sequence[int]):
    yield KIND_NONE, 1
    for d in degrees:
        if d > 1:
            yield KIND_CONSECUTIVE, d
            yield KIND_GAPPED, d


def enumerate_candidates(spec: KernelSpec,
                         degrees: Sequence[int] = DEGREES,
                         replications: Sequence[int] = REPLICATIONS,
                         vector_widths: Sequence[int] = VECTOR_WIDTHS,
                         ) -> list:
    """All (kind, degree, replication, vector_width) configs the kernel
    family can actually instantiate at this spec's geometry."""
    fam, p = spec.family, spec.p
    out = []

    # Only ew_stream lowers through pallas_stream_call, which is the one
    # place replication actually splits the grid; the other kernels carry
    # cfg.replication as an inert field, so offering it here would select
    # configs whose modeled benefit the implementation cannot deliver.

    def stream_ok(n, cfg, block):
        if n % (block * cfg.vector_width * cfg.degree):
            return False
        grid = n // (block * cfg.vector_width * cfg.degree)
        return cfg.replication == 1 or grid % cfg.replication == 0

    if fam == "ew_stream":
        n, block = spec.shape[0], p.get("block", 1024)
        simd_ok = p.get("variant", "base") not in DATA_DEPENDENT_VARIANTS
        for kind, deg in _kind_degree_pairs(degrees):
            for r in replications:
                for vw in vector_widths:
                    if vw > 1 and not simd_ok:
                        continue
                    cfg = CoarseningConfig(kind, deg, r, vw)
                    if stream_ok(n, cfg, block):
                        out.append(cfg)
    elif fam in ("gather_stream", "embed_gather"):
        n, block = spec.shape[0], p.get("block",
                                        1024 if fam == "gather_stream" else 256)
        vws = vector_widths if fam == "gather_stream" else (1,)
        for kind, deg in _kind_degree_pairs(degrees):
            for vw in vws:
                cfg = CoarseningConfig(kind, deg, 1, vw)
                if stream_ok(n, cfg, block):
                    out.append(cfg)
    elif fam == "matmul":
        m, n, k = spec.shape
        bm, bn, bk = p.get("bm", 128), p.get("bn", 128), p.get("bk", 256)
        if k % bk == 0:
            for kind, deg in _kind_degree_pairs(degrees):
                for vw in vector_widths:
                    if m % (bm * deg) == 0 and n % (bn * vw) == 0:
                        out.append(CoarseningConfig(kind, deg, 1, vw))
    elif fam == "dp_scan":
        rows = spec.shape[0]
        for kind, deg in _kind_degree_pairs(degrees):
            if kind == KIND_GAPPED:
                continue               # breaks the sequential carry
            if rows % deg == 0:
                out.append(CoarseningConfig(kind, deg))
    elif fam == "stencil5":
        rows = spec.shape[0]
        br = p.get("block_rows", 8)
        for kind, deg in _kind_degree_pairs(degrees):
            if rows % (br * deg) == 0:
                out.append(CoarseningConfig(kind, deg))
    elif fam == "flash_attention":
        b, h, hkv, sq, sk, d = spec.shape
        bq, bkv = p.get("bq", 128), p.get("bkv", 128)
        # q-row-block coarsening: each program owns `degree` q blocks of bq
        # rows and sweeps the kv blocks.  Replication and SIMD are not
        # implemented by the kernel -> excluded from its space.
        if sk % bkv == 0:
            for kind, deg in _kind_degree_pairs(degrees):
                if sq % (bq * deg) == 0:
                    out.append(CoarseningConfig(kind, deg))
    elif fam == "flash_attention_sparse":
        b, h, hkv, sq, sk, d = spec.shape
        bq, bkv = p.get("bq", 128), p.get("bkv", 128)
        # live-SLOT coarsening: each program owns `degree` slots of the
        # NULL-padded per-q-block index (consecutive = adjacent slots,
        # gapped = slots strided max_live/degree apart — physically both
        # are `degree` index-resolved block loads per step), so the degree
        # must divide the padded index width.  The builder pads max_live
        # to a multiple of 8, which keeps every DEGREES entry legal.
        ml = p.get("max_live", 8)
        if sq % bq == 0 and sk % bkv == 0:
            for kind, deg in _kind_degree_pairs(degrees):
                if ml % deg == 0:
                    out.append(CoarseningConfig(kind, deg))
    elif fam == "flash_attention_bwd":
        b, h, hkv, sq, sk, d = spec.shape
        bq, bkv = p.get("bq", 128), p.get("bkv", 128)
        # the dK/dV pass coarsens the KV-BLOCK axis (each program owns
        # `degree` kv blocks of bkv rows and sweeps q blocks) — a different
        # axis from the forward, hence the independent family
        if sq % bq == 0:
            for kind, deg in _kind_degree_pairs(degrees):
                if sk % (bkv * deg) == 0:
                    out.append(CoarseningConfig(kind, deg))
    elif fam == "decode_attention":
        b, h, hkv, s, d = spec.shape
        bkv = p.get("bkv", 128)
        # kv-split divisibility: each program owns C blocks of bkv cache
        # rows, so the allocated length must tile by C*bkv.  Replication and
        # SIMD are not implemented by the kernel -> excluded from its space.
        if s % bkv == 0:
            for kind, deg in _kind_degree_pairs(degrees):
                if s % (bkv * deg) == 0:
                    out.append(CoarseningConfig(kind, deg))
    elif fam == "decode_attention_paged":
        b, h, hkv, npp, d = spec.shape
        # block-table paging: the kv block IS the page, so each program owns
        # C logical pages (consecutive = C adjacent table entries, gapped =
        # C entries strided npp/C apart — physically both are C table-
        # resolved page loads) and the degree must divide the per-slot page
        # count.  Replication and SIMD are not implemented -> excluded.
        for kind, deg in _kind_degree_pairs(degrees):
            if npp % deg == 0:
                out.append(CoarseningConfig(kind, deg))
    elif fam == "flash_attention_verify":
        b, h, hkv, t, npp, d = spec.shape
        # speculative-decode verify: T drafted q rows vs the paged cache.
        # The coarsening axis is the slot's logical-page axis exactly as in
        # decode_attention_paged (the q side is far too short for q-row
        # blocking), so the degree must divide the per-slot page count.
        # Replication and SIMD are not implemented -> excluded.
        for kind, deg in _kind_degree_pairs(degrees):
            if npp % deg == 0:
                out.append(CoarseningConfig(kind, deg))
    elif fam == "moe_ffn":
        e, cap, d, f = spec.shape
        # expert-axis coarsening: each program owns `degree` whole experts,
        # so the degree must divide the padded expert count.  Replication
        # and SIMD are not implemented by the kernel -> excluded.
        for kind, deg in _kind_degree_pairs(degrees):
            if e % deg == 0:
                out.append(CoarseningConfig(kind, deg))
    elif fam == "ssd":
        b, h, g, s, pp, nn = spec.shape
        chunk = p.get("chunk", 64)
        if s % chunk == 0:
            for kind, deg in _kind_degree_pairs(degrees):
                if h % deg:
                    continue
                if kind == KIND_GAPPED and g != 1:
                    continue
                if kind == KIND_CONSECUTIVE and (h // g) % deg:
                    continue
                out.append(CoarseningConfig(kind, deg))
    elif fam == "rglru":
        b, s, d = spec.shape
        bd, bt = p.get("block_d", 128), p.get("block_t", 64)
        if s % bt == 0:
            for kind, deg in _kind_degree_pairs(degrees):
                if d % (bd * deg) == 0:
                    out.append(CoarseningConfig(kind, deg))
    else:
        raise ValueError(f"unknown tunable family {spec.family!r}")
    return out


# ---------------------------------------------------------------------------
# analytic cost (the perfmodel prior)
# ---------------------------------------------------------------------------

def _round_to(n: int, q: int) -> int:
    return max(q, (n // q) * q)


def model_cost(spec: KernelSpec, cfg: CoarseningConfig) -> float:
    """Modeled seconds for one candidate — the core/analysis pipeline model
    evaluated at this spec's geometry."""
    fam, p = spec.family, spec.p
    dtb = _DTYPE_BYTES.get(spec.dtype, 4)

    if fam == "ew_stream":
        n, block = spec.shape[0], p.get("block", 1024)
        paths, uniform, trips = _VARIANT_DIVERGENCE[p.get("variant", "base")]
        plan = plan_stream(n, cfg, block=block)
        return analysis.stream_cost(
            plan, n_loads=p.get("n_loads", 8),
            arith_per_elem=float(p.get("ai", 6)), dtype_bytes=dtb,
            divergence_paths=paths, divergence_uniform=uniform,
            bounded_trip_factor=trips).modeled_s

    if fam == "gather_stream":
        n, block = spec.shape[0], p.get("block", 1024)
        plan = plan_stream(n, cfg, block=block)
        return analysis.gather_cost(
            plan, n_loads=p.get("n_loads", 8),
            arith_per_elem=float(p.get("ai", 6)),
            hit_rate=float(p.get("hit_rate", 0.854)),
            window_elems=int(p.get("window_elems", 8192)),
            dtype_bytes=dtb).modeled_s

    if fam == "embed_gather":
        n_ids, vocab, d = spec.shape
        block = p.get("block", 256)
        plan = plan_stream(n_ids, cfg, block=block)
        # each id pulls a d-wide row from the VMEM-resident table window
        return analysis.gather_cost(
            plan, n_loads=1, arith_per_elem=float(d),
            hit_rate=float(p.get("hit_rate", 1.0)),
            window_elems=min(vocab * d, 1 << 21), dtype_bytes=dtb).modeled_s

    if fam == "matmul":
        m, n, k = spec.shape
        return analysis.matmul_cost(
            m, n, k, cfg, bm=p.get("bm", 128), bn=p.get("bn", 128),
            bk=p.get("bk", 256), dtype_bytes=dtb,
            wbits=p.get("wbits"), group=p.get("group") or 32).modeled_s

    if fam == "dp_scan":
        rows, cols = spec.shape
        c = analysis.scan_cost(rows, cols, cfg, block_cols=cols)
        return math.inf if c is None else c.modeled_s

    if fam == "stencil5":
        rows, cols = spec.shape
        br = p.get("block_rows", 8)
        # row-block stream: a (block_rows, cols) tile is the work item
        n_model = _round_to(rows * cols, br * cols * cfg.degree
                            * cfg.vector_width)
        plan = plan_stream(n_model, cfg, block=br * cols)
        return analysis.stream_cost(plan, n_loads=3, arith_per_elem=9.0,
                                    dtype_bytes=dtb).modeled_s

    if fam == "flash_attention":
        b, h, hkv, sq, sk, d = spec.shape
        return analysis.flash_attention_cost(
            b, h, hkv, sq, sk, d, cfg, bq=p.get("bq", 128),
            bkv=p.get("bkv", 128), causal=bool(p.get("causal", True)),
            dtype_bytes=dtb).modeled_s

    if fam == "flash_attention_sparse":
        b, h, hkv, sq, sk, d = spec.shape
        return analysis.flash_attention_sparse_cost(
            b, h, hkv, sq, sk, d, cfg, bq=p.get("bq", 128),
            bkv=p.get("bkv", 128), max_live=p.get("max_live", 8),
            n_live=p.get("n_live"), dtype_bytes=dtb).modeled_s

    if fam == "flash_attention_bwd":
        b, h, hkv, sq, sk, d = spec.shape
        return analysis.flash_attention_bwd_cost(
            b, h, hkv, sq, sk, d, cfg, bq=p.get("bq", 128),
            bkv=p.get("bkv", 128), causal=bool(p.get("causal", True)),
            dtype_bytes=dtb).modeled_s

    if fam == "decode_attention":
        b, h, hkv, s, d = spec.shape
        return analysis.decode_attention_cost(
            b, h, hkv, s, d, cfg, bkv=p.get("bkv", 128),
            kv_len=p.get("kv_len", None), dtype_bytes=dtb,
            kv_bits=p.get("kv_bits")).modeled_s

    if fam == "decode_attention_paged":
        b, h, hkv, npp, d = spec.shape
        ps = p.get("page_size", 64)
        return analysis.decode_attention_cost(
            b, h, hkv, npp * ps, d, cfg, bkv=ps,
            kv_len=p.get("kv_len", None), dtype_bytes=dtb,
            kv_bits=p.get("kv_bits"), page_size=ps).modeled_s

    if fam == "flash_attention_verify":
        b, h, hkv, t, npp, d = spec.shape
        ps = p.get("page_size", 64)
        return analysis.flash_attention_verify_cost(
            b, h, hkv, t, npp * ps, d, cfg, bkv=ps,
            kv_len=p.get("kv_len", None), dtype_bytes=dtb,
            kv_bits=p.get("kv_bits"), page_size=ps).modeled_s

    if fam == "moe_ffn":
        e, cap, d, f = spec.shape
        return analysis.moe_ffn_cost(e, cap, d, f, cfg, dtype_bytes=dtb,
                                     wbits=p.get("wbits"),
                                     group=p.get("group") or 32).modeled_s

    if fam == "ssd":
        b, h, g, s, pp, nn = spec.shape
        chunk = p.get("chunk", 64)
        # head-coarsening fuses head streams; chunks carry sequentially
        c = analysis.scan_cost(h, s * (pp + 2 * nn), cfg,
                               arith_per_elem=3 * chunk + 4 * nn,
                               block_cols=s * (pp + 2 * nn))
        return math.inf if c is None else c.modeled_s * b

    if fam == "rglru":
        b, s, d = spec.shape
        bd = p.get("block_d", 128)
        n_model = _round_to(b * s * d, bd * cfg.degree * cfg.vector_width)
        plan = plan_stream(n_model, cfg, block=bd)
        return analysis.stream_cost(plan, n_loads=3, arith_per_elem=12.0,
                                    dtype_bytes=dtb).modeled_s

    raise ValueError(f"unknown tunable family {spec.family!r}")


# ---------------------------------------------------------------------------
# search strategies
# ---------------------------------------------------------------------------

def search(spec: KernelSpec, *,
           degrees: Sequence[int] = DEGREES,
           replications: Sequence[int] = REPLICATIONS,
           vector_widths: Sequence[int] = VECTOR_WIDTHS,
           measure: Optional[Callable] = None,
           strategy: str = "model",
           top_k: int = 3) -> TuneResult:
    """Rank all valid candidates for `spec` and return the winner.

    measure(spec, cfg) -> seconds enables the measured strategies:
      exhaustive — measure every candidate
      greedy     — measure the model's top_k, rank those by wall time
    """
    global SEARCH_COUNT
    SEARCH_COUNT += 1
    if strategy not in ("model", "exhaustive", "greedy"):
        raise ValueError(f"unknown strategy {strategy!r}")
    if strategy != "model" and measure is None:
        raise ValueError(f"strategy {strategy!r} needs a measure callable")

    cfgs = enumerate_candidates(spec, degrees, replications, vector_widths)
    if not cfgs:
        raise ValueError(f"no valid coarsening candidate for {spec.key}")
    cands = [Candidate(cfg, model_cost(spec, cfg)) for cfg in cfgs]
    cands = [c for c in cands if math.isfinite(c.modeled_s)]
    cands.sort(key=lambda c: c.modeled_s)

    if strategy == "model":
        return TuneResult(spec, cands[0].cfg, cands, source="model")

    to_measure = cands if strategy == "exhaustive" else cands[:top_k]
    measured = [dataclasses.replace(c, measured_s=float(measure(spec, c.cfg)))
                for c in to_measure]
    rest = cands[len(to_measure):] if strategy == "greedy" else []
    measured.sort(key=lambda c: c.measured_s)
    return TuneResult(spec, measured[0].cfg, measured + rest,
                      source="measured")


def autotune(spec: KernelSpec, *,
             cache: Optional[TuningCache] = None,
             measure: Optional[Callable] = None,
             strategy: str = "model",
             on_result: Optional[Callable] = None,
             **search_kw) -> CoarseningConfig:
    """Cache-through search: return the winning config for `spec`, searching
    only on a cache miss and persisting the winner.

    ``on_result(res)`` fires with the full TuneResult on every cache miss —
    the tuner-telemetry hook (warm.py aggregates modeled-vs-measured
    calibration per family from it).  Cache hits carry no candidate list,
    so they do not fire."""
    if cache is None:
        cache = default_cache()
    hit = cache.get(spec)
    if hit is not None:
        return hit
    res = search(spec, measure=measure, strategy=strategy, **search_kw)
    best = res.candidates[0]
    cache.put(spec, res.best, modeled_s=best.modeled_s,
              measured_s=best.measured_s, source=res.source)
    if on_result is not None:
        on_result(res)
    return res.best
