"""repro.tune — the coarsening autotuner subsystem.

Turns the paper's manual (kind, degree) x replication x SIMD exploration
into a search-and-cache loop: `search` ranks valid candidates with the
analytic cost model (optionally refined by measured timings), `autotune`
persists winners to a versioned JSON cache, and `kernels.ops` resolves
``cfg="auto"`` through it.
"""
from repro.tune.cache import (CACHE_VERSION, ENV_VAR, KernelSpec,
                              TuningCache, default_cache, default_cache_path)
from repro.tune.search import (Candidate, TuneResult, autotune,
                               enumerate_candidates, model_cost, search)
from repro.tune.warm import (TUNE_CHOICES, tune_report, wall_measurer,
                             warm_for_model, warm_from_flag)

__all__ = [
    "CACHE_VERSION", "ENV_VAR", "KernelSpec", "TuningCache",
    "default_cache", "default_cache_path",
    "Candidate", "TuneResult", "autotune", "enumerate_candidates",
    "model_cost", "search", "TUNE_CHOICES", "tune_report", "wall_measurer",
    "warm_for_model", "warm_from_flag",
]
