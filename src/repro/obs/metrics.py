"""Counter / gauge / histogram registry for the serving + tuning stack.

One ``Registry`` instance per server (the launch driver makes one and hands
it to the engine, the scheduler, the fault plan, and the tuner) absorbs the
counters that used to live as ad-hoc attributes on ``SwapStore``,
``FaultPlan`` and ``TuningCache`` — those classes keep their old attribute
names as thin read-only views over registry instruments, so every number the
stack has ever reported now also flows through one exportable place.

Instruments:

* ``Counter``   — monotonically increasing value (ints stay ints, so a
                  registry read is bit-for-bit the legacy attribute).
* ``Gauge``     — last-set value plus the lifetime ``lo``/``hi`` water
                  marks (free-page high-water = the gauge's ``lo``).
* ``Histogram`` — fixed upper-bound buckets (+inf implicit), count + sum;
                  the serving drivers use them for TTFT, inter-token
                  latency, queue wait, and swap round-trip times.

Labels are static per instrument (``registry.counter(name, state="ok")``)
— the registry key is the Prometheus-style ``name{k="v"}`` string, which
keeps the snapshot JSON flat and the text exposition trivial.

Export: ``snapshot()`` is a plain JSON-able dict; ``to_prometheus()`` is
the text exposition format; ``line()`` is the compact one-line form the
serve driver prints every ``--metrics-every N`` quanta.
"""
from __future__ import annotations

import math

# upper bounds in seconds for latency-ish histograms (CPU-interpret scale
# through real-TPU scale); +inf is implicit
TIME_BUCKETS = (1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2,
                5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)
# upper bounds in scheduler quanta for queue-wait style histograms
QUANTA_BUCKETS = (0, 1, 2, 4, 8, 16, 32, 64, 128, 256)


def _key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


class Counter:
    __slots__ = ("name", "labels", "help", "value")

    def __init__(self, name: str, help: str = "", **labels):
        self.name, self.labels, self.help = name, labels, help
        self.value = 0

    def inc(self, n=1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease by {n}")
        self.value += n

    @property
    def key(self) -> str:
        return _key(self.name, self.labels)


class Gauge:
    __slots__ = ("name", "labels", "help", "value", "lo", "hi")

    def __init__(self, name: str, help: str = "", **labels):
        self.name, self.labels, self.help = name, labels, help
        self.value = 0.0
        self.lo = math.inf      # lifetime low-water mark
        self.hi = -math.inf     # lifetime high-water mark

    def set(self, v) -> None:
        self.value = v
        if v < self.lo:
            self.lo = v
        if v > self.hi:
            self.hi = v

    def inc(self, n=1) -> None:
        self.set(self.value + n)

    def dec(self, n=1) -> None:
        self.set(self.value - n)

    @property
    def key(self) -> str:
        return _key(self.name, self.labels)


class Histogram:
    """Fixed-bucket histogram: ``buckets`` are inclusive upper bounds in
    increasing order; the +inf bucket is implicit."""
    __slots__ = ("name", "labels", "help", "buckets", "counts", "sum",
                 "count")

    def __init__(self, name: str, buckets=TIME_BUCKETS, help: str = "",
                 **labels):
        b = tuple(float(x) for x in buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"histogram {name}: buckets must be strictly "
                             f"increasing, got {b}")
        self.name, self.labels, self.help = name, labels, help
        self.buckets = b
        self.counts = [0] * (len(b) + 1)      # last = +inf overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, v) -> None:
        v = float(v)
        i = 0
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.counts[i] += 1
                break
        else:
            self.counts[len(self.buckets)] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper bound of the bucket
        holding the q-th observation); +inf observations clamp to the last
        finite bound."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q}")
        if self.count == 0:
            return 0.0
        target, seen = q * self.count, 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target and c:
                return self.buckets[min(i, len(self.buckets) - 1)]
        return self.buckets[-1]

    @property
    def key(self) -> str:
        return _key(self.name, self.labels)


class Registry:
    """Create-or-return instrument store with JSON + Prometheus export."""

    def __init__(self):
        self._instruments: dict[str, object] = {}

    def _get(self, cls, name, labels, **kw):
        key = _key(name, labels)
        inst = self._instruments.get(key)
        if inst is None:
            inst = self._instruments[key] = cls(name, **kw, **labels)
        elif not isinstance(inst, cls):
            raise TypeError(f"{key} already registered as "
                            f"{type(inst).__name__}, not {cls.__name__}")
        return inst

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, labels, help=help)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, labels, help=help)

    def histogram(self, name: str, buckets=TIME_BUCKETS, help: str = "",
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets, help=help)

    def __contains__(self, key: str) -> bool:
        return key in self._instruments

    def __len__(self) -> int:
        return len(self._instruments)

    def get(self, name: str, **labels):
        """The instrument at ``name`` (+ labels), or None."""
        return self._instruments.get(_key(name, labels))

    def value(self, name: str, default=None, **labels):
        """Counter/gauge value (histograms: their count) by name; KeyError
        unless ``default`` is given."""
        inst = self._instruments.get(_key(name, labels))
        if inst is None:
            if default is not None:
                return default
            raise KeyError(_key(name, labels))
        return inst.count if isinstance(inst, Histogram) else inst.value

    # -- export --------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able dict of every instrument (the --metrics-out payload)."""
        counters, gauges, hists = {}, {}, {}
        for key, inst in sorted(self._instruments.items()):
            if isinstance(inst, Counter):
                counters[key] = inst.value
            elif isinstance(inst, Gauge):
                gauges[key] = {
                    "value": inst.value,
                    "lo": None if inst.lo is math.inf else inst.lo,
                    "hi": None if inst.hi is -math.inf else inst.hi}
            else:
                hists[key] = {"buckets": list(inst.buckets),
                              "counts": list(inst.counts),
                              "sum": inst.sum, "count": inst.count,
                              "p50": inst.quantile(0.5),
                              "p99": inst.quantile(0.99)}
        return {"counters": counters, "gauges": gauges, "histograms": hists}

    def to_prometheus(self) -> str:
        """Prometheus text exposition (untyped labels-inline form)."""
        lines, typed = [], set()
        for key, inst in sorted(self._instruments.items()):
            kind = ("counter" if isinstance(inst, Counter) else
                    "gauge" if isinstance(inst, Gauge) else "histogram")
            if inst.name not in typed:
                typed.add(inst.name)
                if inst.help:
                    lines.append(f"# HELP {inst.name} {inst.help}")
                lines.append(f"# TYPE {inst.name} {kind}")
            if isinstance(inst, (Counter, Gauge)):
                lines.append(f"{key} {inst.value}")
                if isinstance(inst, Gauge) and inst.hi is not -math.inf:
                    base = dict(inst.labels)
                    lines.append(f"{_key(inst.name + '_lo', base)} {inst.lo}")
                    lines.append(f"{_key(inst.name + '_hi', base)} {inst.hi}")
            else:
                cum = 0
                for ub, c in zip(inst.buckets + (math.inf,), inst.counts):
                    cum += c
                    le = "+Inf" if ub is math.inf else repr(ub)
                    lb = dict(inst.labels, le=le)
                    lines.append(f"{_key(inst.name + '_bucket', lb)} {cum}")
                lines.append(f"{_key(inst.name + '_sum', inst.labels)} "
                             f"{inst.sum}")
                lines.append(f"{_key(inst.name + '_count', inst.labels)} "
                             f"{inst.count}")
        return "\n".join(lines) + "\n"

    def line(self, prefix: str | None = None) -> str:
        """Compact one-line summary (counters + gauges; histograms as
        count/p50) for the driver's periodic --metrics-every output."""
        parts = []
        for key, inst in sorted(self._instruments.items()):
            if prefix and not inst.name.startswith(prefix):
                continue
            if isinstance(inst, Counter):
                parts.append(f"{key}={inst.value}")
            elif isinstance(inst, Gauge):
                v = inst.value
                parts.append(f"{key}={v:g}" if isinstance(v, float)
                             else f"{key}={v}")
            else:
                parts.append(f"{key}:n={inst.count},"
                             f"p50={inst.quantile(0.5):g}")
        return " ".join(parts)
